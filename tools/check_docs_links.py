"""Docs link checker: every relative markdown link and every
``path:line`` code reference in README.md + docs/*.md must resolve.

Checked, per markdown file:

* relative links ``[text](target)`` — ``target`` must exist on disk,
  resolved against the file's own directory (external ``http(s)://`` /
  ``mailto:`` targets and pure ``#anchor`` self-links are skipped; a
  ``path#anchor`` link is checked for the path part);
* inline-code file references — a backtick span that looks like a repo
  path (``benchmarks/serve_lp.py``, ``docs/serving.md``, optionally
  ``::qualifier`` or ``:line``) must exist relative to the repo root
  or to ``src/repro/`` (the docs' module-path shorthand:
  ``core/stream.py`` means ``src/repro/core/stream.py``); a ``:line``
  suffix must not exceed the file's length, and a ``::symbol``
  qualifier must occur in the file.

Exit 0 when everything resolves, 1 with one line per broken reference
otherwise.  CI runs this in the tier-1 workflow (docs-link-check step);
``tests/test_docs_links.py`` runs the same check under pytest so the
contract also holds locally.

Usage: ``python tools/check_docs_links.py [repo_root]``
"""

from __future__ import annotations

import pathlib
import re
import sys

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
# a code span counts as a file reference when it looks like a relative
# repo path: directory components, a filename with a known source-ish
# extension, optionally ::qualified.name or :line
PATHLIKE = re.compile(
    r"^(?P<path>[\w./-]+\.(?:py|md|json|yml|yaml|toml|txt))"
    r"(?:::?(?P<rest>[\w.:\[\]-]+))?$")
EXTERNAL = ("http://", "https://", "mailto:")


def md_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(md: pathlib.Path, root: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text()
    rel = md.relative_to(root)
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            errors.append(f"{rel}: broken link ({target})")
    for m in CODE_SPAN.finditer(text):
        span = m.group(1)
        pm = PATHLIKE.match(span)
        if not pm or "/" not in pm.group("path"):
            continue  # not a repo path — an expression or a bare name
        path = root / pm.group("path")
        if not path.exists():  # docs shorthand: paths relative to the pkg
            path = root / "src" / "repro" / pm.group("path")
        if not path.exists():
            errors.append(f"{rel}: code reference to missing file "
                          f"(`{span}`)")
            continue
        rest = pm.group("rest")
        if not rest:
            continue
        if rest.isdigit():  # path:line — line must exist
            n_lines = len(path.read_text().splitlines())
            if int(rest) > n_lines:
                errors.append(f"{rel}: `{span}` points past end of file "
                              f"({n_lines} lines)")
        elif "::" in span:  # path::symbol — symbol must occur in file
            symbol = rest.split(".")[0].split("::")[0]
            if symbol not in path.read_text():
                errors.append(f"{rel}: `{span}` — symbol '{symbol}' "
                              f"not found in {pm.group('path')}")
    return errors


def main(root: str = ".") -> int:
    rootp = pathlib.Path(root).resolve()
    errors = []
    checked = 0
    for md in md_files(rootp):
        errors += check_file(md, rootp)
        checked += 1
    for e in errors:
        print(e)
    print(f"checked {checked} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken references'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:2]))

"""Docs link checker: every relative markdown link and every
``path:line`` code reference in README.md + docs/*.md must resolve.

Checked, per markdown file:

* relative links ``[text](target)`` — ``target`` must exist on disk,
  resolved against the file's own directory (external ``http(s)://`` /
  ``mailto:`` targets are skipped);
* anchor fragments — a pure ``#anchor`` self-link must match a heading
  in the same file, and the fragment of a ``path.md#anchor`` link must
  match a heading in the target file.  Headings are slugified the way
  GitHub renders them (lowercase, code spans unwrapped, punctuation
  stripped, spaces to hyphens, ``-N`` suffixes for duplicates), and
  headings inside fenced code blocks don't count;
* inline-code file references — a backtick span that looks like a repo
  path (``benchmarks/serve_lp.py``, ``docs/serving.md``, optionally
  ``::qualifier`` or ``:line``) must exist relative to the repo root
  or to ``src/repro/`` (the docs' module-path shorthand:
  ``core/stream.py`` means ``src/repro/core/stream.py``); a ``:line``
  suffix must not exceed the file's length, and a ``::symbol``
  qualifier must occur in the file.

Exit 0 when everything resolves, 1 with one line per broken reference
otherwise.  CI runs this in the tier-1 workflow (docs-link-check step);
``tests/test_docs_links.py`` runs the same check under pytest so the
contract also holds locally.

Usage: ``python tools/check_docs_links.py [repo_root]``
"""

from __future__ import annotations

import pathlib
import re
import sys

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
# a code span counts as a file reference when it looks like a relative
# repo path: directory components, a filename with a known source-ish
# extension, optionally ::qualified.name or :line
PATHLIKE = re.compile(
    r"^(?P<path>[\w./-]+\.(?:py|md|json|yml|yaml|toml|txt))"
    r"(?:::?(?P<rest>[\w.:\[\]-]+))?$")
EXTERNAL = ("http://", "https://", "mailto:")
FENCE = re.compile(r"^(?:```|~~~)")
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")


def slugify(heading: str) -> str:
    """GitHub's anchor slug for a heading: code spans and link syntax
    unwrapped, lowercased, punctuation dropped (word chars, hyphens and
    spaces survive), spaces to hyphens."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    text = re.sub(r"[^\w\- ]", "", text.strip().lower())
    return text.replace(" ", "-")


def anchors_of(md: pathlib.Path) -> set[str]:
    """All anchor slugs a markdown file exposes.  Duplicate headings get
    GitHub's ``-1``/``-2`` suffixes; fenced code blocks are skipped (a
    ``# comment`` in a shell listing is not a heading)."""
    slugs: set[str] = set()
    seen: dict[str, int] = {}
    in_fence = False
    for line in md.read_text().splitlines():
        if FENCE.match(line.lstrip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING.match(line)
        if not m:
            continue
        slug = slugify(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def md_files(root: pathlib.Path) -> list[pathlib.Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(md: pathlib.Path, root: pathlib.Path) -> list[str]:
    errors = []
    text = md.read_text()
    rel = md.relative_to(root)
    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL):
            continue
        path, _, frag = target.partition("#")
        if not path:  # self-link: the anchor must exist in THIS file
            if frag and frag not in anchors_of(md):
                errors.append(f"{rel}: broken anchor ({target})")
            continue
        dest = md.parent / path
        if not dest.exists():
            errors.append(f"{rel}: broken link ({target})")
        elif frag and dest.suffix == ".md" and frag not in anchors_of(dest):
            errors.append(f"{rel}: broken anchor ({target})")
    for m in CODE_SPAN.finditer(text):
        span = m.group(1)
        pm = PATHLIKE.match(span)
        if not pm or "/" not in pm.group("path"):
            continue  # not a repo path — an expression or a bare name
        path = root / pm.group("path")
        if not path.exists():  # docs shorthand: paths relative to the pkg
            path = root / "src" / "repro" / pm.group("path")
        if not path.exists():
            errors.append(f"{rel}: code reference to missing file "
                          f"(`{span}`)")
            continue
        rest = pm.group("rest")
        if not rest:
            continue
        if rest.isdigit():  # path:line — line must exist
            n_lines = len(path.read_text().splitlines())
            if int(rest) > n_lines:
                errors.append(f"{rel}: `{span}` points past end of file "
                              f"({n_lines} lines)")
        elif "::" in span:  # path::symbol — symbol must occur in file
            symbol = rest.split(".")[0].split("::")[0]
            if symbol not in path.read_text():
                errors.append(f"{rel}: `{span}` — symbol '{symbol}' "
                              f"not found in {pm.group('path')}")
    return errors


def main(root: str = ".") -> int:
    rootp = pathlib.Path(root).resolve()
    errors = []
    checked = 0
    for md in md_files(rootp):
        errors += check_file(md, rootp)
        checked += 1
    for e in errors:
        print(e)
    print(f"checked {checked} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken references'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(*sys.argv[1:2]))

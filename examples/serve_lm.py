"""Batched LM serving with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-0.6b]

Serves a (reduced-config) model with the slot-pool engine: requests with
different prompt lengths and budgets stream through a fixed decode pool;
each slot tracks its own cache position (the decode_32k dry-run shape is
one pooled step of exactly this loop).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--pool", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=args.pool, s_max=64)

    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i,
                prompt=rng.integers(0, cfg.vocab, size=rng.integers(3, 9)),
                max_new=int(rng.integers(4, 10)))
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)}/{len(reqs)} requests, {total_tokens} tokens "
          f"in {dt:.1f}s over {engine.steps} pooled decode steps")
    for r in done:
        print(f"  req {r.uid}: prompt[{len(r.prompt)}] -> {r.out}")
    assert len(done) == len(reqs)


if __name__ == "__main__":
    main()

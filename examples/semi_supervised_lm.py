"""End-to-end driver: DynLP pseudo-labeling feeding LM training.

    PYTHONPATH=src python examples/semi_supervised_lm.py \
        [--arch qwen3-0.6b] [--steps 200] [--ckpt-dir /tmp/ssl_run]

The paper's algorithm runs as the DATA layer of the training stack:
documents stream in with 1% domain labels; DynLP labels the rest on a
dynamic kNN graph; only confidently domain-A documents feed the LM train
loop (semi-supervised data curation).  Fault-tolerance features are live:
checkpoints every N steps (rerun the same command after a kill to resume),
straggler monitor, preemption guard.

With --arch <id> --full-config this drives the real published config; the
default reduced config trains a few hundred steps on CPU.
"""

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import PseudoLabelPipeline
from repro.graph.dynamic import UNLABELED
from repro.models.api import build_model
from repro.training import optim
from repro.training.resilience import PreemptionGuard, StragglerMonitor
from repro.training.trainer import make_train_step

import jax.numpy as jnp


def make_documents(rng, n, seq, vocab, frac_labeled=0.02):
    """Two latent domains: A = ascending mod-vocab walks (learnable),
    B = i.i.d. noise (pollution the curation step should filter out)."""
    cls = rng.integers(0, 2, size=n).astype(np.int8)
    toks = np.zeros((n, seq), np.int32)
    a = cls == 1
    base = rng.integers(0, vocab, size=(n, 1))
    toks[a] = (base[a] + np.arange(seq)[None, :]) % vocab
    toks[~a] = rng.integers(0, vocab, size=((~a).sum(), seq))
    labels = np.full(n, UNLABELED, np.int8)
    lab = rng.random(n) < frac_labeled
    labels[lab] = cls[lab]
    return toks, labels, cls


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--docs-per-wave", type=int, default=400)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_smoke_config(args.arch)
    model = build_model(cfg)
    rng = np.random.default_rng(0)

    # ---- stage 1: stream documents through the DynLP pipeline ----
    pipe = PseudoLabelPipeline(k=5)
    truth = {}
    for wave in range(3):
        toks, labels, cls = make_documents(
            rng, args.docs_per_wave, args.seq, cfg.vocab)
        base = pipe.graph.num_nodes
        st = pipe.ingest(toks, labels)
        for i, c in enumerate(cls):
            truth[base + i] = c
        print(f"wave {wave}: {st.num_docs} docs labeled in "
              f"{st.lp_iterations} LP iterations ({st.lp_ms:.0f} ms)")
    quality = pipe.label_quality(truth)
    print(f"pseudo-label accuracy vs latent domain: {quality:.3f}")

    ids, curated = pipe.select(target_class=1, confidence=0.7)
    purity = np.mean([truth[i] == 1 for i in ids])
    print(f"curated {len(ids)} domain-A documents (purity {purity:.3f})")

    # ---- stage 2: train the LM on the curated stream ----
    opt_cfg = optim.OptConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optim.init_state(params)
    start = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = mgr.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[resume] from step {start}")

    guard, monitor = PreemptionGuard(), StragglerMonitor()
    first = last = None
    for step in range(start, args.steps):
        monitor.start_step()
        idx = rng.integers(0, len(curated), size=args.train_batch)
        batch = {
            "tokens": jnp.asarray(curated[idx], jnp.int32),
            "labels": jnp.asarray(np.roll(curated[idx], -1, axis=1), jnp.int32),
        }
        params, opt_state, loss, _ = step_fn(params, opt_state, batch)
        jax.block_until_ready(loss)
        if monitor.end_step():
            print(f"[straggler] at step {step}")
        if first is None:
            first = float(loss)
        last = float(loss)
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f}", flush=True)
        if mgr and ((step + 1) % args.ckpt_every == 0 or guard.requested):
            mgr.save_async(step + 1, {"params": params, "opt": opt_state})
        if guard.requested:
            print("[preempt] checkpointed; exiting")
            break
    if mgr:
        mgr.wait()
    guard.restore()
    print(f"loss {first:.3f} -> {last:.3f} on DynLP-curated data")
    assert quality > 0.9 and purity > 0.9 and last < first


if __name__ == "__main__":
    main()

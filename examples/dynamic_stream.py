"""Insert/delete dynamics + distributed LP.

    PYTHONPATH=src python examples/dynamic_stream.py

1. Demonstrates deletion semantics: a hostile cluster flips labels in its
   neighborhood; deleting it restores them — DynLP touches only the
   affected subgraph each time (watch the frontier sizes).
2. Runs the SAME propagation vertex-partitioned over a multi-device mesh
   (shard_map) in a subprocess with 8 virtual CPU devices and checks it
   reproduces the single-device labels bit-for-bit in iteration count.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.dynlp import DynLP
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph


def deletion_demo():
    rng = np.random.default_rng(0)
    g = DynamicGraph(emb_dim=4, k=3)
    dyn = DynLP(g, delta=1e-5)

    anchors = np.array([[1, 0, 0, 0], [-1, 0, 0, 0]], np.float32)
    cloud = rng.normal([1, 0, 0, 0], 0.12, (60, 4)).astype(np.float32)
    st = dyn.step(BatchUpdate(
        ins_emb=np.concatenate([anchors, cloud]),
        ins_labels=np.array([1, 0] + [UNLABELED] * 60, np.int8),
        del_ids=np.zeros(0, np.int64)))
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    print(f"seed: {len(ids)} unlabeled, mean F={g.f[ids].mean():.3f} "
          f"(class 1), frontier={st.frontier_size}, iters={st.iterations}")

    hostile = rng.normal([-0.4, 0, 0, 0], 0.1, (80, 4)).astype(np.float32)
    st = dyn.step(BatchUpdate(ins_emb=hostile,
                              ins_labels=np.full(80, UNLABELED, np.int8),
                              del_ids=np.zeros(0, np.int64)))
    hostile_ids = np.arange(62, 142)
    print(f"hostile wave: mean F(hostile)={g.f[hostile_ids].mean():.3f} "
          f"frontier={st.frontier_size} iters={st.iterations}")

    st = dyn.step(BatchUpdate(ins_emb=np.zeros((0, 4), np.float32),
                              ins_labels=np.zeros(0, np.int8),
                              del_ids=hostile_ids))
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    print(f"after deletion: mean F={g.f[ids].mean():.3f} "
          f"frontier={st.frontier_size} iters={st.iterations}")
    assert (g.f[ids] > 0.5).all()
    print("labels recovered — deletions propagate only to the affected set\n")


DIST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, sys
    sys.path.insert(0, {src!r})
    from repro.core.distributed import distributed_propagate
    from repro.core.propagate import propagate, PropagationProblem
    from repro.core.snapshot import build_problem
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph

    spec = StreamSpec(total_vertices=2000, batch_size=2000, seed=3,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    for batch, _ in gaussian_mixture_stream(spec):
        g.apply_batch(batch)
    snap = build_problem(g)
    u = snap.problem.num_unlabeled
    f0 = jnp.full((u,), 0.5); fr = jnp.ones(u, bool)
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    res_d = distributed_propagate(snap.problem, f0, fr, mesh, delta=1e-4)
    res_s = propagate(snap.problem, f0, fr, delta=1e-4)
    assert int(res_d.iterations) == int(res_s.iterations)
    np.testing.assert_allclose(np.asarray(res_d.f), np.asarray(res_s.f),
                               atol=1e-5)
    print(f"   8-device shard_map LP: {{int(res_d.iterations)}} iterations, "
          f"matches single-device bitwise-structurally")
""")


def distributed_demo():
    print("distributed LP over a 2x4 virtual mesh (subprocess):")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", DIST.format(src=src)],
                         capture_output=True, text=True, env=env, timeout=900)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise SystemExit(1)


if __name__ == "__main__":
    deletion_demo()
    distributed_demo()

"""Insert/delete dynamics + compile-once streaming + distributed LP.

    PYTHONPATH=src python examples/dynamic_stream.py

1. Demonstrates deletion semantics through the compile-once
   ``StreamEngine``: a hostile cluster flips labels in its neighborhood;
   deleting it restores them — only the affected subgraph is touched each
   time (watch the frontier sizes).
2. Streams 30 batches through ``submit``/``drain`` (host staging of batch
   t+1 overlaps device propagation of batch t) and prints the recompile
   count vs. the batch count — the bucket ladder keeps it logarithmic.
3. Shows the backend REGISTRY: the same stream through the default
   (per-rung auto) backend and through an explicit / ``REPRO_BACKEND``
   override onto the ELL→BSR MXU path, printing each engine's per-rung
   backend decisions, slot budgets, and per-Δ_t ``StreamStats``
   backend/transport fields.
4. Runs the SAME stream mesh-sharded (``StreamEngine(mesh=...)``: every
   bucket's rows vertex-partitioned via shard_map) in a subprocess with
   8 virtual CPU devices and checks the labels are bit-identical to the
   single-device engine, with partition plans reused per ladder rung.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph


def deletion_demo():
    rng = np.random.default_rng(0)
    g = DynamicGraph(emb_dim=4, k=3)
    dyn = StreamEngine(g, delta=1e-5)

    anchors = np.array([[1, 0, 0, 0], [-1, 0, 0, 0]], np.float32)
    cloud = rng.normal([1, 0, 0, 0], 0.12, (60, 4)).astype(np.float32)
    st = dyn.step(BatchUpdate(
        ins_emb=np.concatenate([anchors, cloud]),
        ins_labels=np.array([1, 0] + [UNLABELED] * 60, np.int8),
        del_ids=np.zeros(0, np.int64)))
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    print(f"seed: {len(ids)} unlabeled, mean F={g.f[ids].mean():.3f} "
          f"(class 1), frontier={st.frontier_size}, iters={st.iterations}")

    hostile = rng.normal([-0.4, 0, 0, 0], 0.1, (80, 4)).astype(np.float32)
    st = dyn.step(BatchUpdate(ins_emb=hostile,
                              ins_labels=np.full(80, UNLABELED, np.int8),
                              del_ids=np.zeros(0, np.int64)))
    hostile_ids = np.arange(62, 142)
    print(f"hostile wave: mean F(hostile)={g.f[hostile_ids].mean():.3f} "
          f"frontier={st.frontier_size} iters={st.iterations}")

    st = dyn.step(BatchUpdate(ins_emb=np.zeros((0, 4), np.float32),
                              ins_labels=np.zeros(0, np.int8),
                              del_ids=hostile_ids))
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    print(f"after deletion: mean F={g.f[ids].mean():.3f} "
          f"frontier={st.frontier_size} iters={st.iterations}")
    assert (g.f[ids] > 0.5).all()
    print("labels recovered — deletions propagate only to the affected set\n")


def streaming_demo():
    import time

    spec = StreamSpec(total_vertices=1800, batch_size=60, seed=0,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4)
    # per-batch cost = wall time between submit boundaries; pipelined
    # StreamStats.wall_ms windows overlap and would overstate it
    marks = [time.perf_counter()]
    for batch, _ in gaussian_mixture_stream(spec):
        eng.submit(batch)  # stages Δ_t while Δ_{t-1} propagates
        marks.append(time.perf_counter())
    eng.drain()
    marks.append(time.perf_counter())
    ms = sorted((b - a) * 1e3 for a, b in zip(marks, marks[1:]))
    print(f"compile-once stream: {eng.batches} batches, "
          f"{eng.recompile_count} recompiles "
          f"({len(eng.bucket_keys)} shape buckets), "
          f"median {ms[len(ms) // 2]:.1f} ms/batch\n")


def backend_demo():
    """Per-rung backend selection through the kernels.ops registry, and
    the REPRO_BACKEND fleet-wide override."""
    import numpy as np

    from repro.kernels import ops

    # small on purpose: the bsr arm runs interpret-mode Pallas off-TPU
    spec = StreamSpec(total_vertices=240, batch_size=80, seed=8,
                      class_sep=6.0, noise=0.9)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]

    def drive(tag, backend=None, env=None):
        prior = os.environ.get("REPRO_BACKEND")
        if env:
            os.environ["REPRO_BACKEND"] = env
        try:
            g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
            eng = StreamEngine(g, delta=1e-3, backend=backend)
            stats = [eng.step(b) for b in batches]
        finally:
            if env:  # restore whatever hint the caller had set
                if prior is None:
                    del os.environ["REPRO_BACKEND"]
                else:
                    os.environ["REPRO_BACKEND"] = prior
        s = eng.transport_summary()
        print(f"  {tag}: per-Δ_t backends "
              f"{[st.backend for st in stats]}")
        print(f"    rung_backends={s['rung_backends']} "
              f"slot_budgets={s['slot_budgets']} "
              f"bsr_batches={s['bsr_batches']} "
              f"overflow_fallbacks={s['backend_overflows']}")
        return g.f.copy()

    print("backend registry: same stream, three routes "
          f"(registered: {ops.backend_names()}, "
          f"auto resolves to {ops.select_backend('auto')} here)")
    f_auto = drive("auto (per-rung registry pick)")
    f_bsr = drive("explicit backend='bsr' (ELL→BSR MXU path)",
                  backend="bsr")
    f_env = drive("env REPRO_BACKEND=bsr (fleet-wide hint)", env="bsr")
    print(f"  max |Δf| bsr vs auto: {np.abs(f_bsr - f_auto).max():.2e} "
          "(allclose contract; bsr sums edges in tile order)")
    # 20·δ — the same calibration as the benchmark/test floors
    assert np.abs(f_bsr - f_auto).max() < 20 * 1e-3
    assert np.array_equal(f_bsr, f_env)  # env hint == explicit pick
    print()


DIST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, sys
    sys.path.insert(0, {src!r})
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(total_vertices=1200, batch_size=60, seed=3,
                      class_sep=6.0, noise=0.9, frac_deleted=0.15,
                      frac_unlabeled=0.84)
    mesh = make_stream_mesh()  # flat mesh over the 8 virtual devices
    g_m = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_s = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_m = StreamEngine(g_m, delta=1e-4, mesh=mesh)
    eng_s = StreamEngine(g_s, delta=1e-4)
    for batch, _ in gaussian_mixture_stream(spec):
        eng_m.step(batch)
        eng_s.step(batch)
    assert np.array_equal(g_m.f, g_s.f)
    print(f"   {{mesh.devices.size}}-device sharded stream: "
          f"{{eng_m.batches}} batches, labels bit-identical to "
          f"single-device, {{eng_m.plan_builds}} partition plans for "
          f"{{len(eng_m.bucket_keys)}} ladder rungs")
""")


def distributed_demo():
    print("mesh-sharded StreamEngine over 8 virtual devices (subprocess):")
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", DIST.format(src=src)],
                         capture_output=True, text=True, env=env, timeout=900)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise SystemExit(1)


if __name__ == "__main__":
    deletion_demo()
    streaming_demo()
    backend_demo()
    distributed_demo()

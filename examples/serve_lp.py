"""Label-propagation serving front-end on the streaming engine.

    PYTHONPATH=src python examples/serve_lp.py

1. Stands up an ``LPService`` over a ``StreamEngine`` and feeds it mixed
   traffic: mutations (vertex inserts/deletes) coalesced per admission
   window, query bursts answered from the last committed snapshot.
2. Shows the consistency contract: while a batch's solve is in flight
   the service keeps answering from the previous commit (its new
   vertices "don't exist yet"); after ``sync()`` the same query sees
   them labeled — read-your-writes.
3. Shows backpressure: a service with a tiny queue bound configured to
   reject sheds mutations with ``Backpressure`` instead of queueing
   without bound.
"""

import numpy as np

from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.serving.lp_service import Backpressure, LPService


def serving_demo():
    spec = StreamSpec(total_vertices=900, batch_size=60, seed=0,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    svc = LPService(StreamEngine(g, delta=1e-4),
                    window_ops=2 * spec.batch_size, window_ms=1e9,
                    max_pending_ops=16 * spec.batch_size)
    rng = np.random.default_rng(1)
    for batch, _ in gaussian_mixture_stream(spec):
        base = g.num_nodes
        # each stream batch arrives as three mutations in one window
        n = len(batch.ins_emb)
        svc.mutate(ins_emb=batch.ins_emb[:n // 2],
                   ins_labels=batch.ins_labels[:n // 2],
                   del_ids=batch.del_ids)
        svc.mutate(ins_emb=batch.ins_emb[n // 2:],
                   ins_labels=batch.ins_labels[n // 2:])
        svc.flush()  # admit: the solve is now in flight

        # reads never block on the in-flight solve — this batch's
        # vertices are invisible until it commits
        probe = np.arange(base, min(base + 3, g.num_nodes))
        r = svc.query(probe)
        assert (r.pred == UNLABELED).all() and (r.confidence == 0).all()
        burst = rng.integers(0, max(1, svc.committed_view().num_nodes), 64)
        svc.query(burst)

        svc.sync()  # read-your-writes from here on
        r = svc.query(probe)
        assert (r.confidence > 0).all()
    st = svc.stats()
    print(f"served {st.queries} query calls ({st.query_nodes} node lookups, "
          f"{st.queries_while_inflight} mid-flight) against "
          f"{st.mutations} mutations in {st.batches_committed} windows | "
          f"commit p50={st.commit_latency_ms['p50']:.1f} ms "
          f"p95={st.commit_latency_ms['p95']:.1f} ms | "
          f"{st.recompiles} recompiles over {st.bucket_rungs} bucket rungs\n")


def backpressure_demo():
    rng = np.random.default_rng(2)
    g = DynamicGraph(emb_dim=8, k=3)
    svc = LPService(StreamEngine(g, delta=1e-4), window_ops=32,
                    window_ms=1e9, max_pending_ops=64,
                    reject_on_overload=True)
    accepted = 0
    for _ in range(8):  # normal traffic fits the queue bound
        svc.mutate(ins_emb=rng.normal(0, 1, (8, 8)).astype(np.float32))
        accepted += 1
    try:  # a request that can never fit is shed, not queued forever
        svc.mutate(ins_emb=rng.normal(0, 1, (100, 8)).astype(np.float32))
        raise AssertionError("oversized mutation was not shed")
    except Backpressure as e:
        shed = str(e)
    svc.sync()
    print(f"backpressure: {accepted} mutations accepted, oversized one "
          f"shed ('{shed}'); {svc.stats().batches_committed} windows "
          f"committed")


if __name__ == "__main__":
    serving_demo()
    backpressure_demo()

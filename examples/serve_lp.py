"""Label-propagation serving front-end on the streaming engine.

    PYTHONPATH=src python examples/serve_lp.py

0. Quickstart: the sklearn-style ``DynLabelPropagation`` estimator —
   ``fit`` / ``partial_fit`` / ``predict`` over raw embeddings; the
   whole graph/engine/service stack is derived for you (the recommended
   front door; everything below peels a layer off it).
1. Stands up an ``LPService`` over a ``StreamEngine`` and feeds it mixed
   traffic: mutations via the typed embedding-first entry points
   (``add_points`` / ``remove_points`` — callers never build edge
   lists) coalesced per admission window, query bursts answered from
   the last committed snapshot.
2. Shows the consistency contract: while a batch's solve is in flight
   the service keeps answering from the previous commit (its new
   vertices "don't exist yet"); after ``sync()`` the same query sees
   them labeled — read-your-writes.
3. Shows backpressure: a service with a tiny queue bound configured to
   reject sheds mutations with ``Backpressure`` instead of queueing
   without bound.
4. Shows the async driver (``with svc:``): admission deadlines fire
   with zero caller traffic, concurrent readers' tickets fuse into one
   jitted device gather, and ``close()`` drains everything on exit.
"""

import numpy as np

from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.serving.estimator import DynLabelPropagation
from repro.serving.lp_service import Backpressure, LPService


def estimator_quickstart():
    """Two moons of gaussians, three labeled points per class, the rest
    inferred — then stream more points in with ``partial_fit``."""
    rng = np.random.default_rng(0)
    n = 200
    X = np.concatenate([rng.normal(-2, 0.7, (n // 2, 8)),
                        rng.normal(+2, 0.7, (n // 2, 8))]).astype(np.float32)
    truth = np.repeat([0, 1], n // 2).astype(np.int8)
    y = np.full(n, UNLABELED, np.int8)
    y[[0, 1, 2, n - 3, n - 2, n - 1]] = truth[[0, 1, 2, n - 3, n - 2, n - 1]]

    clf = DynLabelPropagation(k=5).fit(X, y)
    acc = (clf.transduction_ == truth).mean()
    Xq = np.concatenate([rng.normal(-2, 0.7, (20, 8)),
                         rng.normal(+2, 0.7, (20, 8))]).astype(np.float32)
    pred = clf.predict(Xq)  # inductive: unseen embeddings
    clf.partial_fit(Xq, np.full(len(Xq), UNLABELED, np.int8))  # stream in
    print(f"estimator quickstart: transductive acc {acc:.3f} with "
          f"{int((y != UNLABELED).sum())}/{n} seeds; predict() labeled "
          f"{len(pred)} unseen points; graph now {clf.graph_.num_alive} "
          f"vertices after partial_fit\n")


def serving_demo():
    spec = StreamSpec(total_vertices=900, batch_size=60, seed=0,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    svc = LPService(StreamEngine(g, delta=1e-4),
                    window_ops=2 * spec.batch_size, window_ms=1e9,
                    max_pending_ops=16 * spec.batch_size)
    rng = np.random.default_rng(1)
    for batch, _ in gaussian_mixture_stream(spec):
        base = g.num_nodes
        # each stream batch arrives as a few typed mutations in one
        # window — embedding-first: the service derives the graph delta
        n = len(batch.ins_emb)
        svc.add_points(batch.ins_emb[:n // 2], batch.ins_labels[:n // 2])
        if len(batch.del_ids):
            svc.remove_points(batch.del_ids)
        svc.add_points(batch.ins_emb[n // 2:], batch.ins_labels[n // 2:])
        svc.flush()  # admit: the solve is now in flight

        # reads never block on the in-flight solve — this batch's
        # vertices are invisible until it commits
        probe = np.arange(base, min(base + 3, g.num_nodes))
        r = svc.query(probe)
        assert (r.pred == UNLABELED).all() and (r.confidence == 0).all()
        burst = rng.integers(0, max(1, svc.committed_view().num_nodes), 64)
        svc.query(burst)

        svc.sync()  # read-your-writes from here on
        r = svc.query(probe)
        assert (r.confidence > 0).all()
    st = svc.stats()
    print(f"served {st.queries} query calls ({st.query_nodes} node lookups, "
          f"{st.queries_while_inflight} mid-flight) against "
          f"{st.mutations} mutations in {st.batches_committed} windows | "
          f"commit p50={st.commit_latency_ms['p50']:.1f} ms "
          f"p95={st.commit_latency_ms['p95']:.1f} ms | "
          f"{st.recompiles} recompiles over {st.bucket_rungs} bucket rungs\n")


def backpressure_demo():
    rng = np.random.default_rng(2)
    g = DynamicGraph(emb_dim=8, k=3)
    svc = LPService(StreamEngine(g, delta=1e-4), window_ops=32,
                    window_ms=1e9, max_pending_ops=64,
                    reject_on_overload=True)
    accepted = 0
    for _ in range(8):  # normal traffic fits the queue bound
        svc.add_points(rng.normal(0, 1, (8, 8)).astype(np.float32))
        accepted += 1
    try:  # a request that can never fit is shed, not queued forever
        svc.add_points(rng.normal(0, 1, (100, 8)).astype(np.float32))
        raise AssertionError("oversized mutation was not shed")
    except Backpressure as e:
        shed = str(e)
    svc.sync()
    print(f"backpressure: {accepted} mutations accepted, oversized one "
          f"shed ('{shed}'); {svc.stats().batches_committed} windows "
          f"committed")


def async_driver_demo():
    """The background driver clocks the service: deadlines fire without
    caller traffic and concurrent reads batch into fused gathers."""
    rng = np.random.default_rng(3)
    g = DynamicGraph(emb_dim=8, k=3)
    svc = LPService(StreamEngine(g, delta=1e-4),
                    window_ops=1000, window_ms=20.0)
    with svc:  # start() the driver; close() on exit drains everything
        t = svc.add_points(rng.normal(0, 1, (12, 8)).astype(np.float32),
                           (np.arange(12) % 2).astype(np.int8))
        # far below window_ops and we never call pump(): only the
        # driver's deadline clock can admit this window
        while not t.committed:
            pass
        tickets = [svc.query_async(rng.integers(0, 12, 16))
                   for _ in range(32)]
        results = [tk.wait(30.0) for tk in tickets]
        assert all((r.confidence > 0).all() for r in results)
        st = svc.stats()
    print(f"async driver: window deadline-admitted with zero caller "
          f"traffic ({st.deadline_admissions} deadline admissions); "
          f"{st.read_tickets} read tickets served by {st.read_batches} "
          f"fused device gathers")


if __name__ == "__main__":
    estimator_quickstart()
    serving_demo()
    backpressure_demo()
    async_driver_demo()

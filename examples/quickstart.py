"""Quickstart: DynLP on an evolving similarity graph.

    PYTHONPATH=src python examples/quickstart.py

Streams batches of embedded data points (90% unlabeled / 1% labeled /
9% deletions — the paper's protocol), maintains labels incrementally with
DynLP, and compares against full recomputation (ITLP) and the exact
harmonic solution (STLP).
"""

import numpy as np

from repro.core.dynlp import DynLP
from repro.core.itlp import ITLP
from repro.core.snapshot import build_problem
from repro.core.stlp import harmonic_solve
from repro.data.synth import StreamSpec, accuracy, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph


def main():
    spec = StreamSpec(total_vertices=3_000, batch_size=600, seed=42,
                      class_sep=6.0, noise=0.9)

    print("== DynLP (incremental) ==")
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    dyn = DynLP(g, delta=1e-4)
    truth = {}
    dyn_iters = 0
    for t, (batch, cls) in enumerate(gaussian_mixture_stream(spec)):
        base = g.num_nodes
        st = dyn.step(batch)
        dyn_iters += st.iterations
        for i, c in enumerate(cls):
            truth[base + i] = c
        print(f"  batch {t}: +{len(batch.ins_labels)} vertices, "
              f"-{len(batch.del_ids)} deletions | affected={st.frontier_size} "
              f"components={st.num_components} iterations={st.iterations} "
              f"({st.wall_ms:.0f} ms)")

    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    pred = (g.f[ids] >= 0.5).astype(np.int8)
    tr = np.array([truth[i] for i in ids])
    print(f"  accuracy vs ground truth: {accuracy(pred, tr):.4f}")

    print("== ITLP (full recompute per batch) ==")
    g2 = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    itl = ITLP(g2, delta=1e-4)
    itl_iters = 0
    for batch, _ in gaussian_mixture_stream(spec):
        itl_iters += itl.step(batch).iterations
    print(f"  total iterations: ITLP={itl_iters} vs DynLP={dyn_iters} "
          f"({itl_iters / max(dyn_iters, 1):.1f}x more)")

    print("== exact harmonic solution (STLP/Wagner reference) ==")
    snap = build_problem(g)
    f_h = np.asarray(harmonic_solve(snap.problem))[: len(snap.unl_ids)]
    agree = accuracy(pred, (f_h >= 0.5).astype(np.int8))
    print(f"  DynLP agreement with harmonic optimum: {agree:.4f}")
    assert agree > 0.97


if __name__ == "__main__":
    main()

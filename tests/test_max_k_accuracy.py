"""max_k accuracy on hub-heavy graphs (ROADMAP follow-up): heaviest-edge
truncation must cap the K-bucket ladder without degrading label quality.

The agreement floor asserted here matches the ``--check`` gate of the
``max_k_accuracy`` arm in benchmarks/stream_throughput.py.
"""

import numpy as np
import pytest

from repro.core.stream import StreamEngine
from repro.data.synth import accuracy, hub_stream
from repro.graph.dynamic import DynamicGraph

AGREEMENT_FLOOR = 0.98  # truncated vs untruncated prediction agreement


def _run(max_k, seed):
    g = DynamicGraph(emb_dim=8, k=4)
    eng = StreamEngine(g, delta=1e-4, max_k=max_k)
    truth = {}
    nid = 0
    for batch, cls in hub_stream(n_batches=5, per_hub=20, hubs=4, seed=seed):
        eng.step(batch)
        for c in cls:
            truth[nid] = int(c)
            nid += 1
    return g, eng, truth


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1])
def test_max_k_truncation_keeps_label_agreement(seed):
    _, eng_free, truth = _run(None, seed)
    _, eng_cap, _ = _run(8, seed)

    # the cap did real work: the free ladder climbed past it
    k_free = max(k for _, k in eng_free.bucket_keys)
    k_cap = max(k for _, k in eng_cap.bucket_keys)
    assert k_free > 8 and k_cap <= 8, (k_free, k_cap)
    assert len(eng_cap.bucket_keys) <= len(eng_free.bucket_keys)

    # both arms saw the identical insert-only stream, so the id sets match
    ids, pred_free = eng_free.predictions()
    ids_cap, pred_cap = eng_cap.predictions()
    np.testing.assert_array_equal(ids, ids_cap)
    agreement = float((pred_free == pred_cap).mean())
    assert agreement >= AGREEMENT_FLOOR, agreement

    # and neither arm lost the ground truth
    tr = np.array([truth[i] for i in ids])
    assert accuracy(pred_free, tr) >= AGREEMENT_FLOOR
    assert accuracy(pred_cap, tr) >= AGREEMENT_FLOOR

import importlib.util
import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own 512-device
# flag inside launch/dryrun.py, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:  # optional test extra absent: use the fallback
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
    from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

import os

# Tests must see exactly ONE device (the dry-run sets its own 512-device
# flag inside launch/dryrun.py, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

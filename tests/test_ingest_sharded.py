"""Mesh-sharded EmbeddingStore: property tests against the single-device
store (ISSUE 10 acceptance).

Core claims:

  * the row-sharded store + move-the-batch sweep yields graphs AND
    displaced-row (``flagged``) sets bit-identical to the single-device
    store, batch for batch, over mixed insert/delete streams — checked
    in-process on a 1-device mesh (hypothesis-driven) and over 50 mixed
    batches on a forced 8-virtual-device mesh (subprocess);
  * per-device store bytes on the 8-device mesh are exactly 1/8 of the
    single-device store's, and the jit cache stays within
    ``ingest_ladder_bound(..., sharded=True)``;
  * checkpoints are mesh-independent both ways: a sharded(8-dev) engine
    restores mesh-less and a mesh-less engine restores sharded(8-dev),
    each continues streaming, and final labels stay bit-identical to an
    uninterrupted oracle (extends the PR-8 elastic-restore contract).

Strategies use only the surface shared by real hypothesis and the
``tests/_hypothesis_fallback.py`` shim.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.ingest import DeviceIngestor
from repro.launch.mesh import make_stream_mesh

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
TESTS = os.path.dirname(os.path.abspath(__file__))


class RecordingIngestor(DeviceIngestor):
    """DeviceIngestor that records each batch's displaced-row set."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.flagged_log = []

    def select(self, g, new_ids, embn_new):
        sel = super().select(g, new_ids, embn_new)
        self.flagged_log.append(np.sort(sel.flagged))
        return sel


def _mixed_batches(rng, emb_dim, n_batches, max_batch):
    sizes = [int(rng.integers(1, max_batch + 1)) for _ in range(n_batches)]
    return [rng.normal(size=(s, emb_dim)).astype(np.float32) for s in sizes]


def _apply(g, emb, dels, selector):
    g.apply_batch(BatchUpdate(
        ins_emb=emb, ins_labels=np.full(len(emb), UNLABELED, np.int8),
        del_ids=dels), selector=selector)


def run_sharded_vs_single(mesh, n_batches, seed, emb_dim=12, k=4,
                          frac_del=0.15, max_batch=20):
    """Drive a sharded and a single-device ingest stream over the same
    mixed batches; assert graphs and flagged sets bit-identical after
    every batch.  Returns (sharded ingestor, single ingestor, total rows,
    max batch size) for callers that gate memory/cache on top."""
    rng = np.random.default_rng(seed)
    batches = _mixed_batches(rng, emb_dim, n_batches, max_batch)
    gs = DynamicGraph(emb_dim, k=k)
    g1 = DynamicGraph(emb_dim, k=k)
    ing_s = RecordingIngestor(emb_dim, mesh=mesh)
    ing_1 = RecordingIngestor(emb_dim)
    assert ing_s.store.n_shards == int(mesh.devices.size)
    assert ing_1.store.n_shards == 1
    total = 0
    for t, b in enumerate(batches):
        n_del = int(round(frac_del * len(b))) if total else 0
        dels = (rng.choice(total, size=min(n_del, total), replace=False)
                .astype(np.int64) if n_del else np.zeros(0, np.int64))
        _apply(gs, b, dels, ing_s)
        _apply(g1, b, dels, ing_1)
        total += len(b)
        np.testing.assert_array_equal(gs.knn_idx, g1.knn_idx,
                                      err_msg=f"batch {t}")
        np.testing.assert_array_equal(gs.knn_wgt, g1.knn_wgt,
                                      err_msg=f"batch {t}")
        np.testing.assert_array_equal(gs.src, g1.src)
        np.testing.assert_array_equal(gs.dst, g1.dst)
        np.testing.assert_array_equal(gs.wgt, g1.wgt)
        np.testing.assert_array_equal(
            ing_s.flagged_log[-1], ing_1.flagged_log[-1],
            err_msg=f"flagged sets diverge at batch {t}")
    return ing_s, ing_1, total, max_batch


@given(st.integers(0, 10_000), st.integers(3, 8), st.floats(0.0, 0.3))
@settings(max_examples=6, deadline=None)
def test_sharded_store_bit_identical_1dev_mesh(seed, n_batches, frac_del):
    """Property: on a 1-device mesh the sharded path (shard_map sweep,
    sharded update jits, merge reduction) is still bit-identical to the
    plain single-device store — graphs and flagged sets alike."""
    run_sharded_vs_single(make_stream_mesh(1), n_batches, seed,
                          frac_del=frac_del)


def test_sharded_store_duplicate_ties_cross_shard():
    """All-identical points spanning every shard: the merge reduction
    must resolve deep weight ties to the same lowest-global-id neighbors
    the single-device top-k picks."""
    mesh = make_stream_mesh(1)
    dup = np.ones((24, 6), np.float32)
    gs, g1 = DynamicGraph(6, k=3), DynamicGraph(6, k=3)
    ing_s, ing_1 = DeviceIngestor(6, mesh=mesh), DeviceIngestor(6)
    for lo, hi in [(0, 11), (11, 24)]:
        _apply(gs, dup[lo:hi], np.zeros(0, np.int64), ing_s)
        _apply(g1, dup[lo:hi], np.zeros(0, np.int64), ing_1)
    np.testing.assert_array_equal(gs.knn_idx, g1.knn_idx)
    np.testing.assert_array_equal(gs.knn_wgt, g1.knn_wgt)


def test_indivisible_mesh_falls_back_with_warning():
    """A mesh whose device count cannot divide the capacity ladder falls
    back to the single-device store loudly, not wrongly."""
    import warnings

    class FakeMesh:
        class devices:
            size = 7
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ing = DeviceIngestor(8, mesh=FakeMesh())
    assert ing.mesh is None and ing.store.n_shards == 1
    assert any("does not" in str(x.message) for x in w)


# --------------------------------------------------------------------- #
# forced 8-virtual-device arms (subprocess, same pattern as
# tests/test_ingest.py)
# --------------------------------------------------------------------- #
SCRIPT_8DEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import importlib.util, sys
    sys.path.insert(0, {src!r})
    import numpy as np
    spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join({tests!r}, "_hypothesis_fallback.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.path.insert(0, {tests!r})
    from test_ingest_sharded import run_sharded_vs_single
    from repro.ingest import ingest_cache_size, ingest_ladder_bound
    from repro.launch.mesh import make_stream_mesh

    mesh = make_stream_mesh()
    assert mesh.devices.size == 8, mesh
    c0 = ingest_cache_size()
    ing_s, ing_1, total, max_batch = run_sharded_vs_single(
        mesh, n_batches=50, seed=123)
    # per-device residency: each device holds exactly 1/8 of the ladder
    assert ing_s.store.device_bytes() * 8 == ing_1.store.device_bytes(), (
        ing_s.store.device_bytes(), ing_1.store.device_bytes())
    # compile discipline: both arms together stay under the a-priori
    # sharded + single ladder bound
    bound = (ingest_ladder_bound(total, max_batch, sharded=True)
             + ingest_ladder_bound(total, max_batch))
    assert ingest_cache_size() - c0 <= bound, (ingest_cache_size() - c0,
                                               bound)
    print("OK sharded-8dev", total, "rows")
""")


def test_sharded_store_bit_identical_8dev_50_batches():
    """Acceptance: 50 mixed insert/delete batches on a forced 8-virtual-
    device mesh — graphs and displaced-row sets bit-identical to the
    single-device store, per-device bytes exactly 1/8, jit cache within
    the sharded ladder bound."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT_8DEV.format(src=SRC, tests=TESTS)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK sharded-8dev" in out.stdout


# The elastic arm streams a labeled mixture through device-ingest
# engines: sharded(8dev) -> checkpoint -> mesh-LESS restore -> continue,
# and mesh-less -> checkpoint -> 8-dev sharded restore -> continue; both
# survivors must finish bit-identical to an uninterrupted oracle.
ELASTIC = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(total_vertices=320, batch_size=40, seed=9, emb_dim=4,
                      class_sep=6.0, noise=0.9, frac_deleted=0.12,
                      frac_unlabeled=0.85, frac_labeled=0.03)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    mesh = make_stream_mesh()
    assert mesh.devices.size == 8

    g_ref = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4, ingest="device")
    for b in batches:
        ref.step(b)

    # sharded(8dev) -> checkpoint -> mesh-less restore -> continue
    ga = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    ea = StreamEngine(ga, delta=1e-4, ingest="device", mesh=mesh)
    assert ea.ingestor.store.n_shards == 8
    for b in batches[:4]:
        ea.step(b)
    ea.checkpoint({dir_a!r})
    ra = StreamEngine.restore({dir_a!r})
    assert ra.ingestor.store.n_shards == 1
    for b in batches[4:]:
        ra.step(b)
    for name in ("f", "labels", "alive", "knn_idx", "knn_wgt"):
        assert np.array_equal(getattr(ra.graph, name),
                              getattr(g_ref, name)), "a:" + name

    # mesh-less -> checkpoint -> sharded(8dev) restore -> continue
    gb = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eb = StreamEngine(gb, delta=1e-4, ingest="device")
    for b in batches[:4]:
        eb.step(b)
    eb.checkpoint({dir_b!r})
    rb = StreamEngine.restore({dir_b!r}, mesh=make_stream_mesh())
    assert rb.ingestor.store.n_shards == 8
    store, orig = rb.ingestor.store, eb.ingestor.store
    assert store.count == orig.count and store.capacity == orig.capacity
    np.testing.assert_array_equal(np.asarray(store.valid),
                                  np.asarray(orig.valid))
    np.testing.assert_array_equal(np.asarray(store.kth),
                                  np.asarray(orig.kth))
    for b in batches[4:]:
        rb.step(b)
    for name in ("f", "labels", "alive", "knn_idx", "knn_wgt"):
        assert np.array_equal(getattr(rb.graph, name),
                              getattr(g_ref, name)), "b:" + name
    print("OK elastic-sharded", ra.commits, rb.commits)
""")


def test_elastic_checkpoint_sharded_both_directions_8dev(tmp_path):
    """Acceptance: checkpoints save the store mesh-independent — a
    sharded(8-dev) engine restores onto 1 device and a 1-device engine
    restores onto the 8-device mesh, both continue streaming to labels
    bit-identical with the uninterrupted oracle."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_STREAM_TRANSPORT", None)
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC.format(
            src=SRC, dir_a=str(tmp_path / "a"), dir_b=str(tmp_path / "b"))],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK elastic-sharded" in out.stdout

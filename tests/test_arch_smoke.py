"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finite values; one decode step against a cache.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.launch.specs import make_batch
from repro.models.api import build_model
from repro.models.common import ShapeSpec


SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=32, global_batch=2, kind="train")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=32, global_batch=2, kind="decode")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch(request):
    cfg = get_smoke_config(request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_forward_loss(arch):
    cfg, model, params = arch
    batch = make_batch(cfg, SMOKE_TRAIN)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{cfg.name}: loss={loss}"
    assert float(loss) > 0


def test_train_step_reduces_loss(arch):
    """A few SGD steps on fp32 master weights must strictly reduce the loss
    (bf16 in-place updates would round away small gradients — the same reason
    the real optimizer keeps fp32 masters)."""
    cfg, model, params = arch
    batch = make_batch(cfg, SMOKE_TRAIN)
    dtypes = jax.tree.map(lambda a: a.dtype, params)
    p32 = jax.tree.map(lambda a: a.astype(jnp.float32), params)

    def loss_fn(p32):
        p = jax.tree.map(lambda a, d: a.astype(d), p32, dtypes)
        return model.loss(p, batch)

    @jax.jit
    def step(p32):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p32)
        return loss, jax.tree.map(lambda a, b: a - 0.3 * b, p32, g)

    l0, p32 = step(p32)
    for _ in range(2):
        l2, p32 = step(p32)
    assert np.isfinite(float(l0)) and np.isfinite(float(l2))
    assert float(l2) < float(l0), f"{cfg.name}: {l0} -> {l2}"


def test_decode_step(arch):
    cfg, model, params = arch
    b = SMOKE_DECODE.global_batch
    if cfg.enc_dec or cfg.family in ("ssm", "hybrid"):
        cache = model.init_cache(b, SMOKE_DECODE.seq_len)
    else:
        cache = model.init_cache(b, SMOKE_DECODE.seq_len)
    batch = make_batch(cfg, SMOKE_DECODE)
    logits, new_cache = jax.jit(model.decode_step)(params, cache, batch)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), cfg.name
    # cache structure is preserved
    jax.tree.map(lambda a, c: None if a.shape == c.shape else pytest.fail(
        f"{cfg.name} cache shape changed: {a.shape} vs {c.shape}"), new_cache, cache)


def test_prefill_then_decode_consistency(arch):
    """Greedy continuation from prefill must match token-by-token decode."""
    cfg, model, params = arch
    if cfg.enc_dec:
        pytest.skip("enc-dec prefill covers the encoder; decoder starts fresh")
    b, s = 2, 16
    spec = ShapeSpec("t", seq_len=s, global_batch=b, kind="prefill")
    batch = make_batch(cfg, spec)
    logits_p, cache = jax.jit(model.prefill)(params, batch)

    if cfg.family in ("ssm", "hybrid"):
        # recurrent caches: replay the same tokens one-by-one and compare
        cache2 = model.init_cache(b, s)
        toks = batch["tokens"]
        logits_d = None
        for t in range(toks.shape[1]):
            logits_d, cache2 = jax.jit(model.decode_step)(
                params, cache2,
                {"tokens": toks[:, t : t + 1], "pos": jnp.asarray(t, jnp.int32)})
        np.testing.assert_allclose(
            np.asarray(logits_p, np.float32), np.asarray(logits_d, np.float32),
            rtol=0.15, atol=0.15)

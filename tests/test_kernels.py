"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.propagate import propagate
from repro.kernels import ref
from repro.kernels.bsr_spmv import (bsr_spmv, dense_to_bsr, ell_bsr_layout,
                                    fill_bsr_blocks)
from repro.kernels.cc_hook import cc_hook_step, connected_components_pallas
from repro.kernels.ell_propagate import ell_propagate_step
from repro.kernels.ops import propagate_pallas

from helpers import random_problem, random_undirected_coo, union_find_components
from repro.graph.structures import coo_to_csr, csr_to_ell_fast


def _random_ell_inputs(rng, n, k):
    nbr = rng.integers(-1, n, size=(n, k)).astype(np.int32)
    wgt = (rng.uniform(0.1, 1.0, (n, k)) * (nbr >= 0)).astype(np.float32)
    wl0 = (rng.uniform(0, 1, n) * (rng.random(n) < 0.3)).astype(np.float32)
    wl1 = (rng.uniform(0, 1, n) * (rng.random(n) < 0.3)).astype(np.float32)
    frontier = rng.random(n) < 0.6
    f = rng.uniform(0, 1, n).astype(np.float32)
    return nbr, wgt, wl0, wl1, frontier, f


@pytest.mark.parametrize("n,k,block_rows", [
    (64, 4, 16), (128, 8, 32), (256, 3, 256), (512, 16, 128), (96, 1, 32),
])
def test_ell_propagate_matches_ref(n, k, block_rows):
    rng = np.random.default_rng(n * k)
    nbr, wgt, wl0, wl1, frontier, f = _random_ell_inputs(rng, n, k)
    got_f, got_ch = ell_propagate_step(
        jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(wl0), jnp.asarray(wl1),
        jnp.asarray(frontier), jnp.asarray(f), delta=1e-3,
        block_rows=block_rows)
    want_f, want_ch = ref.ell_propagate_ref(
        jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(wl0), jnp.asarray(wl1),
        jnp.asarray(frontier), jnp.asarray(f), delta=1e-3)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_ch), np.asarray(want_ch))


@pytest.mark.slow
@given(st.integers(0, 1_000))
@settings(max_examples=10, deadline=None)
def test_ell_propagate_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(16, 200))
    n = (n + 15) // 16 * 16
    k = int(rng.integers(1, 9))
    nbr, wgt, wl0, wl1, frontier, f = _random_ell_inputs(rng, n, k)
    got_f, _ = ell_propagate_step(
        jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(wl0), jnp.asarray(wl1),
        jnp.asarray(frontier), jnp.asarray(f), block_rows=16)
    want_f, _ = ref.ell_propagate_ref(
        jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(wl0), jnp.asarray(wl1),
        jnp.asarray(frontier), jnp.asarray(f))
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want_f),
                               rtol=1e-6, atol=1e-6)


def test_propagate_pallas_matches_core_engine():
    """The kernel-driven loop and the jnp engine must reach the same
    harmonic fixpoint with the same iteration count."""
    rng = np.random.default_rng(7)
    p = random_problem(rng, 100, 2)
    f0 = jnp.full((100,), 0.5)
    frontier = jnp.ones(100, bool)
    res_core = propagate(p, f0, frontier, delta=1e-5, max_iters=20_000)
    res_pal = propagate_pallas(p, f0, frontier, delta=1e-5, max_iters=20_000,
                               block_rows=32)
    assert int(res_core.iterations) == int(res_pal.iterations)
    np.testing.assert_allclose(np.asarray(res_pal.f), np.asarray(res_core.f),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,k", [(64, 3), (256, 5), (128, 1)])
def test_cc_hook_matches_ref(n, k):
    rng = np.random.default_rng(n + k)
    src, dst, wgt = random_undirected_coo(rng, n, float(k))
    ell = csr_to_ell_fast(coo_to_csr(n, src, dst, wgt))
    nbr = jnp.asarray(np.asarray(ell.nbr))
    par = jnp.asarray(rng.permutation(n).astype(np.int32))
    got = cc_hook_step(nbr, par, block_rows=min(64, n))
    want = ref.cc_hook_ref(nbr, par)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cc_pallas_full_loop_matches_union_find():
    rng = np.random.default_rng(3)
    n = 256
    src, dst, wgt = random_undirected_coo(rng, n, 2.0)
    ell = csr_to_ell_fast(coo_to_csr(n, src, dst, wgt))
    par, iters = connected_components_pallas(ell.nbr, block_rows=64)
    want = union_find_components(n, src, dst)
    np.testing.assert_array_equal(np.asarray(par), want)
    assert int(iters) < 50


@pytest.mark.parametrize("n,bs,density,dtype", [
    (64, 8, 0.3, jnp.float32), (128, 16, 0.1, jnp.float32),
    (64, 8, 0.5, jnp.bfloat16), (256, 32, 0.05, jnp.float32),
])
def test_bsr_spmv_matches_dense(n, bs, density, dtype):
    rng = np.random.default_rng(int(n * bs * density))
    mask = rng.random((n // bs, n // bs)) < density
    a = rng.normal(0, 1, (n, n)).astype(np.float32)
    a *= np.kron(mask, np.ones((bs, bs)))
    x = rng.normal(0, 1, (n,)).astype(np.float32)
    blocks, cols = dense_to_bsr(jnp.asarray(a, dtype), bs)
    got = bsr_spmv(blocks, cols, jnp.asarray(x, dtype))
    want = ref.bsr_spmv_ref(blocks, cols, jnp.asarray(x, dtype))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    # and against the dense matmul ground truth
    np.testing.assert_allclose(
        np.asarray(got),
        a.astype(np.float32) @ x if dtype == jnp.float32
        else (a.astype(np.float32) @ x),
        rtol=tol * 10, atol=tol * 10)


def _random_ell(rng, n, k):
    """Random ELL adjacency with per-row-distinct neighbors (the shape
    snapshot builds guarantee)."""
    nbr = np.full((n, k), -1, np.int32)
    wgt = np.zeros((n, k), np.float32)
    for i in range(n):
        deg = int(rng.integers(0, k + 1))
        cols = rng.choice(n, size=deg, replace=False)
        nbr[i, :deg] = cols
        wgt[i, :deg] = rng.uniform(0.1, 1.0, deg)
    return nbr, wgt


@pytest.mark.parametrize("n,k,bs", [(64, 4, 8), (128, 7, 16), (96, 3, 8)])
def test_ell_to_bsr_matches_dense_oracle(n, k, bs):
    """The direct ELL→BSR build (host slot layout + device scatter fill)
    describes the same matrix as the deprecated dense_to_bsr oracle:
    identical SpMV output, identical per-row block-column sets."""
    rng = np.random.default_rng(n + k + bs)
    nbr, wgt = _random_ell(rng, n, k)
    layout = ell_bsr_layout(nbr, bs)
    assert layout.nnz == int((nbr >= 0).sum())
    assert 0.0 < layout.fill <= 1.0
    blocks, cols = fill_bsr_blocks(
        jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(layout.slot),
        block_size=bs, num_slots=layout.num_slots + 2)  # padded budget ok
    dense = np.zeros((n, n), np.float32)
    rows = np.repeat(np.arange(n), k)
    c = nbr.reshape(-1)
    keep = c >= 0
    dense[rows[keep], c[keep]] = wgt.reshape(-1)[keep]
    blocks_o, cols_o = dense_to_bsr(jnp.asarray(dense), bs)
    for i in range(n // bs):
        got = {int(c) for c in np.asarray(cols[i]) if c >= 0}
        want = {int(c) for c in np.asarray(cols_o[i]) if c >= 0}
        assert got == want, i
    x = rng.normal(0, 1, n).astype(np.float32)
    got = bsr_spmv(blocks, cols, jnp.asarray(x))
    want = bsr_spmv(blocks_o, cols_o, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got), dense @ x,
                               rtol=1e-5, atol=1e-5)


def test_ell_bsr_layout_validates_and_handles_empty():
    with pytest.raises(ValueError, match="multiple of block_size"):
        ell_bsr_layout(np.full((10, 2), -1, np.int32), 8)
    lay = ell_bsr_layout(np.full((16, 2), -1, np.int32), 8)
    assert lay.nnz == 0 and lay.num_slots == 1 and lay.fill == 0.0
    assert (lay.slot == -1).all()

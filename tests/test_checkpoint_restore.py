"""Durable engine state: crash-safe checkpoint/restore for StreamEngine
and LPService (docs/persistence.md).

In-process tests cover the roundtrip contract (restored state bit-
identical, counters and rung metadata resume, commit-boundary refusal),
the service checkpoint policy (async cadence writes, final synchronous
shutdown snapshot, failure surfacing, preemption drain) and the probe
cache.  The fault-injection arms run a victim SUBPROCESS that kills
itself with ``os._exit`` mid-drain — the in-flight solve is lost, any
in-flight async checkpoint write is torn — then restore from the latest
complete checkpoint and replay the remaining stream: final labels must
match an uninterrupted oracle bit for bit, on a single device AND on a
forced 8-virtual-device mesh (same pattern as tests/test_halo_lp.py).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import DynamicGraph
from repro.launch.mesh import make_stream_mesh
from repro.serving.lp_service import LPService
from repro.training.resilience import PreemptionGuard

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SPEC = StreamSpec(total_vertices=300, batch_size=60, seed=7,
                  class_sep=6.0, noise=0.9)

# the fault-injection stream (shared between victim scripts and the
# in-test oracles — keyword dict so both sides build the same spec)
KILL_SPEC = dict(total_vertices=320, batch_size=40, seed=9, emb_dim=4,
                 class_sep=6.0, noise=0.9, frac_deleted=0.12,
                 frac_unlabeled=0.85, frac_labeled=0.03)
KILL_AT = 5  # batch whose drain the victim dies in (of 8)

_GRAPH_KEYS = ("f", "labels", "alive", "knn_idx", "knn_wgt", "src", "dst",
               "wgt")


def _batches(spec_kw=None):
    spec = SPEC if spec_kw is None else StreamSpec(**spec_kw)
    return [b for b, _ in gaussian_mixture_stream(spec)]


def _assert_graphs_equal(g, g_ref):
    for name in _GRAPH_KEYS:
        np.testing.assert_array_equal(getattr(g, name), getattr(g_ref, name),
                                      err_msg=name)


def _service(eng, **kw):
    kw.setdefault("window_ops", 10_000)
    kw.setdefault("window_ms", 1e9)  # admission only via flush()
    kw.setdefault("max_pending_ops", 100_000)
    return LPService(eng, **kw)


def _feed(svc, batch):
    svc.mutate(ins_emb=batch.ins_emb, ins_labels=batch.ins_labels,
               del_ids=batch.del_ids)
    svc.flush()
    svc.sync()


# ---------------------------------------------------------------------- #
# engine roundtrip
# ---------------------------------------------------------------------- #
def test_engine_checkpoint_restore_roundtrip(tmp_path):
    """Checkpoint mid-stream, restore in the same process, replay the
    rest: every graph array, the counters and the committed view match
    the uninterrupted engine bit for bit."""
    batches = _batches()
    g_ref = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4)
    for b in batches:
        ref.step(b)

    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4)
    for b in batches[:3]:
        eng.step(b)
    eng.checkpoint(str(tmp_path))
    assert ckpt.latest_step(str(tmp_path)) == eng.commits

    r = StreamEngine.restore(str(tmp_path))
    assert r.commits == eng.commits and r.batches == eng.batches
    assert r.bucket_keys == eng.bucket_keys
    assert r.committed_view().commit_id == eng.commits
    for b in batches[3:]:
        r.step(b)
    _assert_graphs_equal(r.graph, g_ref)
    # the committed device view answers exactly as the oracle's does
    ids = np.flatnonzero(g_ref.alive)
    pred_r, conf_r = r.device_view().query(ids, 0.5)
    pred_o, conf_o = ref.device_view().query(ids, 0.5)
    np.testing.assert_array_equal(pred_r, pred_o)
    np.testing.assert_array_equal(conf_r, conf_o)


def test_checkpoint_refuses_in_flight(tmp_path):
    """Checkpoints are commit-boundary snapshots: with a batch in flight
    the engine refuses, and succeeds after the drain."""
    batches = _batches()
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4)
    eng.submit(batches[0])
    assert eng.in_flight
    with pytest.raises(RuntimeError, match="in flight"):
        eng.checkpoint(str(tmp_path))
    eng.drain()
    eng.checkpoint(str(tmp_path))
    assert ckpt.latest_step(str(tmp_path)) == eng.commits


def test_restore_device_ingest_preserves_store(tmp_path):
    """A device-ingest engine restores its EmbeddingStore contents —
    count, capacity rung and k-th pruning thresholds — and the replayed
    stream stays bit-identical to the uninterrupted device-ingest run."""
    batches = _batches()
    g_ref = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4, ingest="device")
    for b in batches:
        ref.step(b)

    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, ingest="device")
    for b in batches[:3]:
        eng.step(b)
    eng.checkpoint(str(tmp_path))

    r = StreamEngine.restore(str(tmp_path))
    store, orig = r.ingestor.store, eng.ingestor.store
    assert store.count == orig.count
    assert store.capacity == orig.capacity
    np.testing.assert_array_equal(np.asarray(store.valid),
                                  np.asarray(orig.valid))
    np.testing.assert_array_equal(np.asarray(store.kth),
                                  np.asarray(orig.kth))
    for b in batches[3:]:
        r.step(b)
    _assert_graphs_equal(r.graph, g_ref)


def test_restore_latest_default_and_step_selection(tmp_path):
    """restore() picks the newest complete step by default, honors an
    explicit older step, and fails loudly with no committed checkpoint."""
    with pytest.raises(FileNotFoundError, match="no committed checkpoint"):
        StreamEngine.restore(str(tmp_path))
    batches = _batches()
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4)
    eng.step(batches[0])
    eng.checkpoint(str(tmp_path))
    first = eng.commits
    eng.step(batches[1])
    eng.checkpoint(str(tmp_path))
    assert StreamEngine.restore(str(tmp_path)).commits == eng.commits
    assert StreamEngine.restore(str(tmp_path), step=first).commits == first


def test_restore_probe_cache_and_rung_metadata(tmp_path):
    """auto:measured restore on the same mesh size reinstates the probe
    cache: rungs measured before the checkpoint are NOT re-timed (their
    sweep numbers survive verbatim, ``probe_cache_hits`` ticks on multi-
    device meshes) and replayed labels match the uninterrupted engine."""
    mesh = make_stream_mesh()
    batches = _batches()
    g_ref = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4, mesh=mesh,
                       transport="auto:measured")
    for b in batches:
        ref.step(b)

    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, mesh=mesh, transport="auto:measured")
    for b in batches[:3]:
        eng.step(b)
    eng.checkpoint(str(tmp_path))
    cached = dict(eng._measured)

    r = StreamEngine.restore(str(tmp_path), mesh=make_stream_mesh(),
                             transport="auto:measured")
    assert r._measured == cached
    for b in batches[3:]:
        r.step(b)
    _assert_graphs_equal(r.graph, g_ref)
    summary = r.transport_summary()
    # cached rungs were never re-timed: their sweeps survive verbatim
    for key, sweep in cached.items():
        assert r._measured[key] == sweep
    if mesh.devices.size > 1 and cached:
        assert summary["probe_cache_hits"] >= 1, summary


def test_restore_drops_stale_rung_metadata_on_knob_change(tmp_path):
    """Rung decisions whose validity scope breaks (different transport
    knob here) are dropped and re-derived — the restored engine still
    replays bit-identically, just from a clean slate."""
    batches = _batches()
    g_ref = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4)
    for b in batches:
        ref.step(b)

    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, mesh=make_stream_mesh(),
                       transport="allgather")
    for b in batches[:3]:
        eng.step(b)
    eng.checkpoint(str(tmp_path))
    r = StreamEngine.restore(str(tmp_path), transport=None)  # mesh-less
    assert r._transport_modes == {}  # stale decisions dropped, not kept
    for b in batches[3:]:
        r.step(b)
    _assert_graphs_equal(r.graph, g_ref)


# ---------------------------------------------------------------------- #
# service checkpoint policy
# ---------------------------------------------------------------------- #
def test_service_checkpoint_cadence_async(tmp_path):
    """checkpoint_every writes async snapshots at quiescent commit
    boundaries; the newest restores to exactly the served state."""
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(StreamEngine(g, delta=1e-4), checkpoint_every=2,
                   checkpoint_dir=str(tmp_path))
    batches = _batches()
    for b in batches:
        _feed(svc, b)
    svc._ckpt_mgr.wait()  # settle the last async write before asserting
    st = svc.stats()
    assert st.checkpoints_written >= 2
    # the newest snapshot is never more than one cadence behind
    assert svc.engine.commits - st.last_checkpoint_commit < 2
    assert ckpt.latest_step(str(tmp_path)) == st.last_checkpoint_commit
    r = StreamEngine.restore(str(tmp_path))
    for b in batches[r.batches:]:
        r.step(b)
    _assert_graphs_equal(r.graph, g)


def test_service_shutdown_writes_final_sync_checkpoint(tmp_path):
    """shutdown() drains everything and writes one final synchronous
    snapshot — even without a cadence — returning its commit id."""
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(StreamEngine(g, delta=1e-4),
                   checkpoint_dir=str(tmp_path))
    batches = _batches()
    for b in batches[:2]:
        _feed(svc, b)
    # one more mutation left un-synced: shutdown must flush + commit it
    svc.mutate(ins_emb=batches[2].ins_emb, ins_labels=batches[2].ins_labels,
               del_ids=batches[2].del_ids)
    step = svc.shutdown()
    assert step == svc.engine.commits == 3
    assert ckpt.latest_step(str(tmp_path)) == step
    r = StreamEngine.restore(str(tmp_path))
    _assert_graphs_equal(r.graph, g)
    # no checkpoint_dir -> shutdown still drains, returns None
    svc2 = _service(StreamEngine(DynamicGraph(emb_dim=SPEC.emb_dim, k=5),
                                 delta=1e-4))
    _feed(svc2, batches[0])
    assert svc2.shutdown() is None


def test_service_async_checkpoint_failure_surfaces(tmp_path):
    """An async snapshot that fails to write must re-raise at the next
    mutate()/sync() — the service never pretends broken durability."""
    ckdir = tmp_path / "ck"
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(StreamEngine(g, delta=1e-4), checkpoint_every=1,
                   checkpoint_dir=str(ckdir))
    batches = _batches()
    _feed(svc, batches[0])
    svc._ckpt_mgr.wait()
    # sabotage: the checkpoint directory becomes a plain file, so every
    # subsequent write fails (works under root, unlike chmod tricks)
    import shutil

    shutil.rmtree(ckdir)
    ckdir.write_text("not a directory")
    # first failing write parks the error on the manager's worker; the
    # next cadence surfaces it into the service, then mutate() raises
    _feed(svc, batches[1])
    with pytest.raises(RuntimeError, match="durable state is stale"):
        for b in batches[2:]:
            _feed(svc, b)
    # the error is delivered once; the service keeps serving afterwards
    _feed(svc, batches[-1])


def test_service_preemption_drains_checkpoints_halts(tmp_path):
    """The preemption flow: signal -> next pump() drains the in-flight
    batch, writes a final sync checkpoint, halts the driver; afterwards
    mutations are refused and the checkpoint restores the drained state."""
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(StreamEngine(g, delta=1e-4),
                   checkpoint_dir=str(tmp_path))
    guard = svc.arm_preemption(PreemptionGuard(signals=()))
    batches = _batches()
    svc.start()
    for b in batches[:2]:
        _feed(svc, b)
    # leave a batch in flight, then "deliver" the signal
    svc.mutate(ins_emb=batches[2].ins_emb, ins_labels=batches[2].ins_labels,
               del_ids=batches[2].del_ids)
    svc.flush()
    assert svc.engine.in_flight
    guard.requested = True
    svc.pump()  # any clock tick observes the guard
    st = svc.stats()
    assert st.preempted and not svc.engine.in_flight
    assert st.last_checkpoint_commit == svc.engine.commits == 3
    assert ckpt.latest_step(str(tmp_path)) == 3
    with pytest.raises(RuntimeError, match="preempted"):
        svc.mutate(ins_emb=batches[3].ins_emb)
    svc.stop()  # completes the driver join from outside
    assert not svc.driver_running
    r = StreamEngine.restore(str(tmp_path))
    _assert_graphs_equal(r.graph, g)


def test_service_checkpoint_policy_validation(tmp_path):
    eng = StreamEngine(DynamicGraph(emb_dim=4, k=3), delta=1e-4)
    with pytest.raises(ValueError, match="requires checkpoint_dir"):
        LPService(eng, checkpoint_every=4)
    with pytest.raises(ValueError, match="checkpoint_every"):
        LPService(eng, checkpoint_every=0, checkpoint_dir=str(tmp_path))


# ---------------------------------------------------------------------- #
# fault injection: kill mid-drain, restore, replay, compare
# ---------------------------------------------------------------------- #
# The victim runs the service with a per-commit checkpoint cadence, then
# dies with os._exit INSIDE the drain of batch KILL_AT: the in-flight
# solve never commits and the newest async checkpoint write may be torn
# mid-write.  Exit code 137 proves the kill happened where intended.
VICTIM = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count={ndev}"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh
    from repro.serving.lp_service import LPService

    spec = StreamSpec(**{spec!r})
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    mesh = make_stream_mesh() if {use_mesh} else None
    if mesh is not None:
        assert mesh.devices.size == {ndev}
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, mesh=mesh, ingest={ingest!r})
    svc = LPService(eng, window_ops=10_000, window_ms=1e9,
                    max_pending_ops=100_000, checkpoint_every=1,
                    checkpoint_dir={dir!r})
    for b in batches[:{kill}]:
        svc.mutate(ins_emb=b.ins_emb, ins_labels=b.ins_labels,
                   del_ids=b.del_ids)
        svc.flush()
        svc.sync()
    b = batches[{kill}]
    svc.mutate(ins_emb=b.ins_emb, ins_labels=b.ins_labels,
               del_ids=b.del_ids)
    svc.flush()
    assert eng.in_flight
    eng.drain = lambda: os._exit(137)  # die mid-drain of batch {kill}
    svc.sync()
    raise SystemExit("unreachable: the drain should have killed us")
""")

# Replays the remaining stream from the latest complete checkpoint and
# compares against an uninterrupted in-process oracle (used standalone
# for the forced-8-device arm; the single-device arm does this inline).
CHECKER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count={ndev}"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(**{spec!r})
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    mesh = make_stream_mesh() if {use_mesh} else None
    g_ref = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4, mesh=mesh, ingest={ingest!r})
    for b in batches:
        ref.step(b)

    r = StreamEngine.restore({dir!r}, mesh=make_stream_mesh()
                             if {use_mesh} else None)
    assert 0 < r.batches <= {kill}, r.batches
    for b in batches[r.batches:]:
        r.step(b)
    for name in ("f", "labels", "alive", "knn_idx", "knn_wgt"):
        assert np.array_equal(getattr(r.graph, name),
                              getattr(g_ref, name)), name
    ids = np.flatnonzero(g_ref.alive)
    pr, cr = r.device_view().query(ids, 0.5)
    po, co = ref.device_view().query(ids, 0.5)
    assert np.array_equal(pr, po) and np.array_equal(cr, co)
    print("OK kill-restore", r.batches, "->", r.commits, "commits")
""")


def _run_script(script, **fields):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("REPRO_STREAM_TRANSPORT", None)
    return subprocess.run(
        [sys.executable, "-c", script.format(src=SRC, **fields)],
        capture_output=True, text=True, env=env, timeout=900)


def test_kill_mid_drain_restore_replay_single_device(tmp_path):
    """Victim killed mid-drain; restore from the latest complete
    checkpoint and replay the rest of the stream in THIS process: final
    labels bit-identical to the uninterrupted oracle (device ingest, so
    the EmbeddingStore crash path is exercised too)."""
    ckdir = str(tmp_path / "ck")
    out = _run_script(VICTIM, ndev=1, use_mesh=False, ingest="device",
                      spec=KILL_SPEC, dir=ckdir, kill=KILL_AT)
    assert out.returncode == 137, (out.returncode, out.stderr[-3000:])

    batches = _batches(KILL_SPEC)
    spec = StreamSpec(**KILL_SPEC)
    g_ref = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4, ingest="device")
    for b in batches:
        ref.step(b)

    r = StreamEngine.restore(ckdir)
    # the kill landed mid-drain of batch KILL_AT: the survivor covers at
    # most the KILL_AT batches that committed, never the lost one
    assert 0 < r.batches <= KILL_AT
    for b in batches[r.batches:]:
        r.step(b)
    _assert_graphs_equal(r.graph, g_ref)
    ids = np.flatnonzero(g_ref.alive)
    pred_r, conf_r = r.device_view().query(ids, 0.5)
    pred_o, conf_o = ref.device_view().query(ids, 0.5)
    np.testing.assert_array_equal(pred_r, pred_o)
    np.testing.assert_array_equal(conf_r, conf_o)


def test_kill_mid_drain_restore_replay_8dev(tmp_path):
    """Same fault injection on a forced 8-virtual-device mesh: the
    victim's checkpoint restores onto a fresh 8-device mesh in a second
    process and replays bit-identically to the sharded oracle."""
    ckdir = str(tmp_path / "ck")
    out = _run_script(VICTIM, ndev=8, use_mesh=True, ingest="host",
                      spec=KILL_SPEC, dir=ckdir, kill=KILL_AT)
    assert out.returncode == 137, (out.returncode, out.stderr[-3000:])
    out = _run_script(CHECKER, ndev=8, use_mesh=True, ingest="host",
                      spec=KILL_SPEC, dir=ckdir, kill=KILL_AT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK kill-restore" in out.stdout


# ---------------------------------------------------------------------- #
# elastic restore across mesh shapes
# ---------------------------------------------------------------------- #
ELASTIC = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(**{spec!r})
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    mesh = make_stream_mesh()
    assert mesh.devices.size == 8

    # 8-device halo engine -> checkpoint -> mesh-LESS restore
    g8 = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    e8 = StreamEngine(g8, delta=1e-4, mesh=mesh, transport="halo")
    for b in batches:
        e8.step(b)
    e8.checkpoint({dir_a!r})
    ids = np.flatnonzero(g8.alive)
    p8, c8 = e8.device_view().query(ids, 0.5)
    r1 = StreamEngine.restore({dir_a!r})
    assert r1.mesh is None and r1.transport != "halo"
    p1, c1 = r1.device_view().query(ids, 0.5)
    assert np.array_equal(p8, p1) and np.array_equal(c8, c1)

    # single-device engine -> checkpoint -> 8-device mesh restore
    g1 = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    e1 = StreamEngine(g1, delta=1e-4)
    for b in batches:
        e1.step(b)
    e1.checkpoint({dir_b!r})
    r8 = StreamEngine.restore({dir_b!r}, mesh=make_stream_mesh(),
                              transport="halo")
    assert r8.mesh is not None and r8.transport == "halo"
    pm, cm = r8.device_view().query(ids, 0.5)
    pe, ce = e1.device_view().query(ids, 0.5)
    assert np.array_equal(pm, pe) and np.array_equal(cm, ce)

    # both restored engines keep streaming bit-identically
    extra = StreamSpec(**{spec!r})
    extra.seed += 1
    more = [b for b, _ in gaussian_mixture_stream(extra)][:2]
    for b in more:
        r1.step(b)
        r8.step(b)
    assert np.array_equal(r1.graph.f, r8.graph.f)
    assert np.array_equal(r1.graph.labels, r8.graph.labels)
    print("OK elastic-restore", r1.commits, r8.commits)
""")


def test_elastic_restore_across_mesh_shapes_8dev(tmp_path):
    """A checkpoint from an 8-device halo engine restores mesh-less (and
    a single-device checkpoint restores onto 8 devices) with bit-identical
    DeviceLabelView answers — the save format is mesh-independent."""
    out = _run_script(ELASTIC, spec=KILL_SPEC,
                      dir_a=str(tmp_path / "a"), dir_b=str(tmp_path / "b"))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK elastic-restore" in out.stdout

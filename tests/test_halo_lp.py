"""Halo-exchange distributed LP vs full all-gather vs single device."""

import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
TESTS = os.path.abspath(os.path.dirname(__file__))


def test_halo_plan_invariants():
    from repro.graph.partition import apply_plan, build_halo_plan, unapply_plan
    from helpers import random_undirected_coo
    from repro.graph.structures import coo_to_csr, csr_to_ell_fast

    rng = np.random.default_rng(0)
    n = 100
    src, dst, wgt = random_undirected_coo(rng, n, 4.0)
    ell = csr_to_ell_fast(coo_to_csr(n, src, dst, wgt))
    nbr = np.asarray(ell.nbr)
    plan = build_halo_plan(nbr, 4)
    assert len(plan.perm) % 4 == 0
    m = plan.rows_per_shard
    # every cross-shard reference points into an export prefix
    owner = np.arange(len(plan.perm)) // m
    for u in range(len(plan.nbr)):
        for v in plan.nbr[u]:
            if v >= 0 and owner[v] != owner[u]:
                assert v % m < plan.export_max, (u, v)
    # roundtrip of a per-row array
    arr = rng.normal(0, 1, n).astype(np.float32)
    back = unapply_plan(plan, apply_plan(plan, arr), n)
    np.testing.assert_array_equal(back, arr)


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, sys
    sys.path.insert(0, {src!r}); sys.path.insert(0, {tests!r})
    from repro.core.distributed import distributed_propagate_halo
    from repro.core.propagate import propagate, PropagationProblem
    from repro.graph.partition import apply_plan, build_halo_plan, unapply_plan
    from repro.launch.mesh import make_mesh
    from helpers import random_problem

    rng = np.random.default_rng(5)
    n = 160
    p = random_problem(rng, n, 2)
    plan = build_halo_plan(np.asarray(p.nbr), 8)
    pp = PropagationProblem(
        nbr=jnp.asarray(plan.nbr),
        wgt=jnp.asarray(apply_plan(plan, np.asarray(p.wgt))),
        wl0=jnp.asarray(apply_plan(plan, np.asarray(p.wl0))),
        wl1=jnp.asarray(apply_plan(plan, np.asarray(p.wl1))),
        valid=jnp.asarray(apply_plan(plan, np.asarray(p.valid))),
    )
    n_pad = len(plan.perm)
    f0 = jnp.full((n_pad,), 0.5)
    fr = jnp.asarray(apply_plan(plan, np.ones(n, bool)))
    mesh = make_mesh((2, 4), ("data", "model"))
    res_h = distributed_propagate_halo(pp, f0, fr, mesh,
                                       export_max=plan.export_max, delta=1e-5)
    res_s = propagate(p, jnp.full((n,), 0.5), jnp.ones(n, bool), delta=1e-5)
    f_back = unapply_plan(plan, np.asarray(res_h.f), n)
    assert int(res_h.iterations) == int(res_s.iterations), (
        int(res_h.iterations), int(res_s.iterations))
    np.testing.assert_allclose(f_back, np.asarray(res_s.f), atol=1e-5)
    print("OK halo", int(res_h.iterations), "exports", plan.export_max,
          "of", plan.rows_per_shard)
""")


def test_halo_matches_single_device_8dev():
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC, tests=TESTS)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-2500:]
    assert "OK halo" in out.stdout

"""Async serving driver: deadlines fire with zero caller traffic, fused
reads are never torn across a mid-burst commit, shutdown drains every
in-flight ticket, and the forced-8-virtual-device benchmark keeps the
sharded read path at parity with single-device (subprocess)."""

import os
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.serving.lp_service import LPService

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
BENCH = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     "benchmarks"))

RNG = np.random.default_rng(0)


def _service(**kw):
    g = DynamicGraph(emb_dim=8, k=4)
    kw.setdefault("window_ops", 64)
    kw.setdefault("window_ms", 15.0)
    return LPService(StreamEngine(g, delta=1e-3), **kw)


def _labeled(n, base=0):
    """n vertices with the deterministic label pattern (i + base) % 2."""
    emb = RNG.normal(size=(n, 8)).astype(np.float32)
    lab = ((np.arange(n) + base) % 2).astype(np.int8)
    return emb, lab


def _wait_until(cond, timeout=20.0, msg="condition"):
    t0 = time.perf_counter()
    while not cond():
        if time.perf_counter() - t0 > timeout:
            raise AssertionError(f"timed out waiting for {msg}")
        time.sleep(0.005)


def test_deadline_fires_with_zero_caller_traffic():
    """One small mutation, then NO further calls: the driver's clock must
    close the window at its deadline and commit the batch on its own."""
    svc = _service(window_ops=1000, window_ms=25.0)
    with svc:
        t = svc.mutate(*_labeled(4))
        assert not t.committed  # window open, far below the size bound
        _wait_until(lambda: t.committed, msg="deadline admission + commit")
        st = svc.stats()
        assert st.deadline_admissions >= 1
        assert st.batches_admitted == st.batches_committed == 1
    # the committed labels are visible to a plain read afterwards
    r = svc.query(np.arange(4))
    assert (r.pred >= 0).all() and (r.confidence == 1.0).all()


def test_concurrent_readers_never_torn_across_commits():
    """Reader threads hammer the service while commits land mid-burst.

    Seeds are inserted in id order with a deterministic label pattern,
    and one admission window inserts a contiguous id block atomically —
    so every coherent view knows a PREFIX of the inserted ids.  A torn
    read (mixing two views in one result) would answer a high id while
    a lower id still reads UNLABELED, or return a wrong label."""
    svc = _service(window_ops=8, window_ms=2.0)
    total = 160
    stop = threading.Event()
    failures: list[str] = []

    def reader():
        ids = np.arange(total)
        while not stop.is_set():
            r = svc.query(ids)
            known = r.pred != UNLABELED
            if known.any():
                k = int(np.flatnonzero(known).max()) + 1
                if not known[:k].all():
                    failures.append(f"non-prefix visibility at commit "
                                    f"{r.commit_id}")
                    return
                expect = (np.arange(k) % 2).astype(np.int8)
                if not np.array_equal(r.pred[:k], expect):
                    failures.append(f"wrong labels at commit {r.commit_id}")
                    return
                if not (r.confidence[:k] == 1.0).all():
                    failures.append("seed confidence != 1.0")
                    return
            if not (r.confidence[~known] == 0.0).all():
                failures.append("unknown ids with nonzero confidence")
                return

    with svc:
        threads = [threading.Thread(target=reader) for _ in range(4)]
        for th in threads:
            th.start()
        done = 0
        while done < total:
            n = min(8, total - done)
            svc.mutate(*_labeled(n, base=done))
            done += n
            time.sleep(0.002)  # let commits interleave with read bursts
        svc.sync()
        stop.set()
        for th in threads:
            th.join(20.0)
    assert not failures, failures
    r = svc.query(np.arange(total))
    assert (r.pred != UNLABELED).all()  # everything committed in the end


def test_stop_drains_inflight_tickets():
    """Every ticket queued before stop() is fulfilled, not abandoned."""
    svc = _service()
    with svc:
        svc.mutate(*_labeled(16))
        svc.sync()
        tickets = [svc.query_async(RNG.integers(0, 16, 32))
                   for _ in range(64)]
    # context exit ran close() -> stop(): all tickets must be done
    assert all(t.done for t in tickets)
    results = [t.wait(0.1) for t in tickets]
    assert all(r.pred.shape == (32,) for r in results)
    assert not svc.driver_running


def test_reads_batch_across_concurrent_callers():
    """Concurrent async reads fuse: fewer device gathers than tickets."""
    svc = _service()
    with svc:
        svc.mutate(*_labeled(32))
        svc.sync()
        tickets = [svc.query_async(RNG.integers(0, 32, 16))
                   for _ in range(100)]
        for t in tickets:
            t.wait(30.0)
        st = svc.stats()
        assert st.read_tickets == 100
        assert st.read_batches < st.read_tickets  # fusion happened
        assert st.queries == 100  # each ticket still counts as one query


def test_async_results_match_host_view_semantics():
    """Fused device gathers answer exactly like ``LabelView.query`` —
    including dead, unknown and out-of-range ids."""
    svc = _service()
    with svc:
        svc.mutate(*_labeled(24))
        svc.mutate(ins_emb=RNG.normal(size=(8, 8)).astype(np.float32))
        svc.sync()
        svc.mutate(del_ids=np.arange(3))
        svc.sync()
        ids = np.array([-5, 0, 1, 2, 5, 23, 24, 30, 31, 32, 10**6])
        got = svc.query(ids, cutoff=0.4)
    want_pred, want_conf = svc.committed_view().query(ids, cutoff=0.4)
    np.testing.assert_array_equal(got.pred, want_pred)
    np.testing.assert_allclose(got.confidence, want_conf)


def test_driver_lifecycle_idempotent_and_restartable():
    svc = _service()
    svc.start()
    svc.start()  # idempotent
    assert svc.driver_running
    svc.stop()
    assert not svc.driver_running
    svc.start()  # restart after stop
    svc.mutate(*_labeled(4))
    svc.sync()
    assert svc.query(np.arange(4)).pred.shape == (4,)
    svc.close()
    assert not svc.driver_running


@pytest.mark.slow
def test_sharded_reads_keep_pace_with_single_device_8dev():
    """The --tiny benchmark under a forced 8-virtual-device mesh: the
    sharded arm's saturated read rate must clear the recorded ratio
    floor against single-device (the PR-5 regression was 0.47x), and
    both arms must clear the 100x lookup floor — the full --check gate
    set, which includes both bounds."""
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               REPRO_FORCE_HOST_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, os.path.join(BENCH, "serve_lp.py"),
         "--tiny", "--check", "--out", "/tmp/BENCH_serve_test.json"],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "serve_sharded" in out.stdout

"""Partitioning rules: param specs, ZeRO extension, divisibility fallbacks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distribution import partition
from repro.launch.mesh import make_mesh


@pytest.fixture(autouse=True)
def rules():
    partition.set_axis_rules({"dp": ("data",), "tp": "model",
                              "sp": "model", "ep": "model"})
    partition.set_mesh_sizes({"data": 4, "model": 4})
    yield
    partition.set_axis_rules(None)
    partition.set_mesh_sizes(None)


class FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (4, 4)


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_param_rules():
    tree = {
        "embed": _sds(128, 64),
        "lm_head": _sds(64, 128),
        "layers": {
            "attn": {"wq": _sds(8, 64, 64), "wo": _sds(8, 64, 64)},
            "mlp": {"w1": _sds(8, 64, 256), "w2": _sds(8, 256, 64)},
            "moe": {"w1": _sds(8, 16, 64, 32), "router": _sds(8, 64, 16)},
            "ln1": _sds(8, 64),
        },
    }
    specs = partition.param_specs(tree, FakeMesh)
    assert specs["embed"] == P(None, "model")
    assert specs["lm_head"] == P(None, "model")
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", None)
    assert specs["layers"]["mlp"]["w2"] == P(None, "model", None)
    assert specs["layers"]["moe"]["w1"] == P(None, "model", None, None)
    assert specs["layers"]["moe"]["router"] == P(None, None, None)
    assert specs["layers"]["ln1"] == P(None, None)


def test_param_rules_drop_nondivisible():
    tree = {"attn": {"wq": _sds(4, 64, 30)}}  # 30 % 4 != 0
    specs = partition.param_specs(tree, FakeMesh)
    assert specs["attn"]["wq"] == P(None, None, None)


def test_zero_specs_extend_and_idempotent():
    tree = {"mlp": {"w1": _sds(8, 64, 256)}, "ln": _sds(7,)}
    pspecs = partition.param_specs(tree, FakeMesh)
    z1 = partition.zero_specs(pspecs, tree, FakeMesh)
    assert z1["mlp"]["w1"] in (P("data", None, "model"),
                               P(("data",), None, "model"))
    assert z1["ln"] == P(None)  # 7 not divisible: stays replicated
    z2 = partition.zero_specs(z1, tree, FakeMesh)
    assert z2 == z1  # idempotent (the FSDP double-application bug)


def test_resolve_spec_shift_right():
    # kv-heads (2) below tp degree (4) -> tp shifts to head_dim (8)
    spec = partition.resolve_spec((6, 8, 100, 2, 8),
                                  (None, "dp", None, "tp", None), FakeMesh)
    assert spec in (P(None, "data", None, None, "model"),
                    P(None, ("data",), None, None, "model"))
    # nothing divisible -> dropped
    spec = partition.resolve_spec((5, 3), ("dp", "tp"), FakeMesh)
    assert spec == P(None, None)


def test_shard_divisibility_aware():
    mesh = make_mesh((1,), ("model",))
    partition.set_axis_rules({"tp": "model", "dp": None})
    partition.set_mesh_sizes({"model": 1})
    x = jnp.zeros((4, 6))
    with mesh:
        y = jax.jit(lambda a: partition.shard(a, "dp", "tp"))(x)
    assert y.shape == x.shape


def test_no_rules_noop():
    partition.set_axis_rules(None)
    x = jnp.ones((3, 3))
    assert partition.shard(x, "dp", "tp") is x

"""Streaming transport knob: env/ctor validation, auto heuristic, halo
edge cases (empty frontier, rung change, export-overflow fallback).

The in-process tests run at any device count (1 in tier-1, 8 in the
multi-device CI job); the overflow/fallback test needs real cross-shard
references, so it forces an 8-virtual-device mesh in a subprocess (same
pattern as tests/test_halo_lp.py).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, locality_stream
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.launch.mesh import make_stream_mesh

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _empty_batch(dim=4):
    return BatchUpdate(ins_emb=np.zeros((0, dim), np.float32),
                       ins_labels=np.zeros(0, np.int8),
                       del_ids=np.zeros(0, np.int64))


def _seed_batch(rng, dim=4, n=24):
    emb = rng.normal(0, 1, (n, dim)).astype(np.float32)
    emb[0, 0], emb[1, 0] = 3.0, -3.0
    labels = np.full(n, UNLABELED, np.int8)
    labels[0], labels[1] = 1, 0
    return BatchUpdate(ins_emb=emb, ins_labels=labels,
                       del_ids=np.zeros(0, np.int64))


def test_transport_knob_validation(monkeypatch):
    g = DynamicGraph(emb_dim=4, k=3)
    with pytest.raises(ValueError, match="unknown transport"):
        StreamEngine(g, transport="ring")
    # explicit halo without a mesh is a misconfiguration...
    with pytest.raises(ValueError, match="requires mesh"):
        StreamEngine(g, transport="halo")
    # ...but the env var is a fleet-wide hint, ignored on mesh-less
    # engines (mirrors REPRO_BACKEND degrade semantics)
    monkeypatch.setenv("REPRO_STREAM_TRANSPORT", "halo")
    eng = StreamEngine(g)
    assert eng.transport == "halo"
    rng = np.random.default_rng(0)
    st = eng.step(_seed_batch(rng))
    assert st.converged and st.transport == "single"
    # an invalid env value fails loudly at construction
    monkeypatch.setenv("REPRO_STREAM_TRANSPORT", "bogus")
    with pytest.raises(ValueError, match="REPRO_STREAM_TRANSPORT"):
        StreamEngine(DynamicGraph(emb_dim=4, k=3))


def test_run_propagation_transport_validation():
    import jax.numpy as jnp

    from helpers import random_problem
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    p = random_problem(rng, 64, 2)
    f0, fr = jnp.full((64,), 0.5), jnp.ones(64, bool)
    with pytest.raises(ValueError, match="unknown transport"):
        ops.run_propagation(p, f0, fr, transport="ring")
    with pytest.raises(ValueError, match="needs mesh"):
        ops.run_propagation(p, f0, fr, transport="halo")
    with pytest.raises(ValueError, match="needs export_max"):
        ops.run_propagation(p, f0, fr, transport="halo",
                            mesh=make_stream_mesh(1))
    # a prebuilt plan pins the transport: disagreeing kwargs are refused
    from repro.core.distributed import build_stream_plan
    plan = build_stream_plan(make_stream_mesh(1), (64, 2))
    with pytest.raises(ValueError, match="shard_plan mismatch"):
        ops.run_propagation(p, f0, fr, shard_plan=plan, transport="halo")


def test_stream_stats_report_transport():
    rng = np.random.default_rng(1)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4, mesh=make_stream_mesh(),
                       transport="allgather")
    st = eng.step(_seed_batch(rng))
    assert st.transport == "allgather"
    st = eng.step(_empty_batch())  # no-op commits without a collective
    assert st.transport == "none" and st.iterations == 0
    assert eng.transport_summary()["requested"] == "allgather"


def test_halo_empty_frontier_noop_commits():
    """A no-op Δ_t on a halo engine stages nothing — no layout build, no
    collective — but still commits and the next real batch resumes."""
    rng = np.random.default_rng(2)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4, mesh=make_stream_mesh(),
                       transport="halo")
    eng.step(_seed_batch(rng))
    st = eng.step(_empty_batch())
    assert st.converged and st.transport == "none"
    st = eng.step(BatchUpdate(
        ins_emb=rng.normal([3, 0, 0, 0], 0.1, (8, 4)).astype(np.float32),
        ins_labels=np.full(8, UNLABELED, np.int8),
        del_ids=np.zeros(0, np.int64)))
    assert st.converged and eng.commits == 3
    # labels match a mesh-less engine over the same Δ_t sequence
    rng2 = np.random.default_rng(2)
    g2 = DynamicGraph(emb_dim=4, k=3)
    ref = StreamEngine(g2, delta=1e-4)
    ref.step(_seed_batch(rng2))
    ref.step(_empty_batch())
    ref.step(BatchUpdate(
        ins_emb=rng2.normal([3, 0, 0, 0], 0.1, (8, 4)).astype(np.float32),
        ins_labels=np.full(8, UNLABELED, np.int8),
        del_ids=np.zeros(0, np.int64)))
    np.testing.assert_array_equal(g.f, g2.f)


def test_halo_rung_change_rebuilds_plan_once_per_rung():
    """A stream crossing several ladder rungs builds one halo plan per
    rung — the export budget/runner rebuild on rung change only, and
    per-batch layout recomputation never counts as a plan build."""
    spec = StreamSpec(total_vertices=700, batch_size=70, seed=5, emb_dim=2,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=2, k=5)
    eng = StreamEngine(g, delta=1e-3, mesh=make_stream_mesh(),
                       transport="halo")
    for batch, _ in locality_stream(spec):
        eng.step(batch)
    rungs = len(eng.bucket_keys)
    assert rungs >= 2, eng.bucket_keys  # the ladder actually regrew
    assert eng.plan_builds <= rungs + eng.transport_overflows
    assert eng.halo_batches + eng.transport_overflows == eng.batches


def test_auto_single_device_mesh_takes_allgather():
    """auto on a 1-device mesh has no collective bytes to save — every
    rung must resolve to all-gather without building a halo layout."""
    rng = np.random.default_rng(3)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4, mesh=make_stream_mesh(1),
                       transport="auto")
    eng.step(_seed_batch(rng))
    summary = eng.transport_summary()
    assert set(summary["rung_modes"].values()) == {"allgather"}
    assert summary["halo_batches"] == 0


def test_auto_measured_transport_probes_and_caches(monkeypatch):
    """transport='auto:measured' (ctor or env): at rung entry one real
    sweep per transport is timed and the winner cached — every rung ends
    up with a concrete mode and, on multi-device meshes, a recorded
    probe; labels match the heuristic-auto engine bit for bit."""
    spec = StreamSpec(total_vertices=300, batch_size=60, seed=6, emb_dim=2,
                      class_sep=6.0, noise=0.9)
    batches = [b for b, _ in locality_stream(spec)]
    g_m = DynamicGraph(emb_dim=2, k=5)
    g_a = DynamicGraph(emb_dim=2, k=5)
    mesh = make_stream_mesh()
    eng_m = StreamEngine(g_m, delta=1e-4, mesh=mesh,
                         transport="auto:measured")
    eng_a = StreamEngine(g_a, delta=1e-4, mesh=mesh, transport="auto")
    for b in batches:
        eng_m.step(b)
        eng_a.step(b)
    summary = eng_m.transport_summary()
    assert summary["requested"] == "auto:measured"
    assert set(summary["rung_modes"].values()) <= {"allgather", "halo"}
    assert len(summary["rung_modes"]) == len(eng_m.bucket_keys)
    if mesh.devices.size > 1:
        # at least one rung was actually probed (both transports timed)
        assert any(set(p) == {"allgather", "halo"}
                   for p in summary["measured_sweep_ms"].values()), summary
    # measuring changes only which collective runs, never the labels
    np.testing.assert_array_equal(g_m.f, g_a.f)
    # the env var spells it the same way
    monkeypatch.setenv("REPRO_STREAM_TRANSPORT", "auto:measured")
    assert StreamEngine(DynamicGraph(emb_dim=2, k=5),
                        mesh=mesh).transport == "auto:measured"


def test_export_budget_headroom_and_cap():
    from repro.graph.partition import build_halo_plan, export_budget

    nbr = np.full((64, 4), -1, np.int32)
    nbr[:, 0] = (np.arange(64) + 8) % 64  # ring: every row crosses at +8
    plan = build_halo_plan(nbr, 8)
    assert plan.rows_per_shard == 8
    # budget never exceeds the shard size however generous the headroom
    assert export_budget(plan, 64, headroom=100.0) == 8
    # and scales with the rung fill factor (half-full rung doubles it)
    b_full = export_budget(plan, 64)
    b_half = export_budget(plan, 32)
    assert b_half >= b_full


SCRIPT = textwrap.dedent("""
    import logging, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, locality_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(total_vertices=600, batch_size=60, seed=7, emb_dim=2,
                      class_sep=6.0, noise=0.9, frac_deleted=0.1,
                      frac_unlabeled=0.89)
    batches = [b for b, _ in locality_stream(spec)]
    mesh = make_stream_mesh()
    assert mesh.devices.size == 8

    g_ref = DynamicGraph(emb_dim=2, k=5)
    ref = StreamEngine(g_ref, delta=1e-4)
    g = DynamicGraph(emb_dim=2, k=5)
    eng = StreamEngine(g, delta=1e-4, mesh=mesh, transport="halo")

    records = []
    h = logging.Handler()
    h.emit = lambda r: records.append(r)
    logging.getLogger("repro.core.stream").addHandler(h)

    overflow_seen = False
    for i, b in enumerate(batches):
        st = eng.step(b)
        ref.step(b)
        if i == 2:
            # sabotage every known rung budget: the NEXT batch's export
            # counts must overflow and fall back to all-gather
            for key in list(eng._export_budgets):
                eng._export_budgets[key] = 1
        if i > 2 and st.transport == "allgather":
            overflow_seen = True
        # correctness is transport-independent, fallback included
        assert np.array_equal(g.f, g_ref.f), i

    assert overflow_seen, "sabotaged budget never overflowed"
    assert eng.transport_overflows > 0
    warned = [r for r in records if r.levelno == logging.WARNING
              and "overflow" in r.getMessage()]
    assert warned, "overflow fallback did not log a warning"
    # warned once per rung, not once per batch
    assert len(warned) <= len(eng.bucket_keys)
    print("OK halo-overflow", eng.transport_overflows, "fallbacks,",
          len(warned), "warnings")
""")


def test_halo_export_overflow_falls_back_with_warning_8dev():
    """A batch whose export counts exceed the rung's compiled budget must
    fall back to all-gather for that Δ_t, keep labels bit-identical, and
    warn once per rung."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("REPRO_STREAM_TRANSPORT", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK halo-overflow" in out.stdout

"""Chunked (flash-style, causal-skip) attention vs the dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import _attn_chunked, _attn_dense, attention


def _qkv(rng, b, sq, sk, h, hkv, dh):
    q = jnp.asarray(rng.normal(0, 1, (b, sq, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, sk, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, sk, hkv, dh)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window,hkv,qc,kc", [
    (True, None, 4, 16, 16),   # causal-skip path (sq == sk, n_q > 1)
    (True, None, 2, 32, 16),   # GQA + skip
    (True, 24, 4, 16, 16),     # sliding window (no skip)
    (False, None, 4, 16, 32),  # bidirectional
])
def test_chunked_matches_dense(causal, window, hkv, qc, kc):
    rng = np.random.default_rng(hkv * qc + kc)
    b, s, h, dh = 2, 64, 4, 8
    q, k, v = _qkv(rng, b, s, s, h, hkv, dh)
    got = _attn_chunked(q, k, v, causal=causal, window=window,
                        q_chunk=qc, k_chunk=kc)
    want = _attn_dense(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_gradients_match_dense():
    rng = np.random.default_rng(0)
    b, s, h, dh = 1, 64, 2, 8
    q, k, v = _qkv(rng, b, s, s, h, h, dh)

    def loss_c(q, k, v):
        return jnp.sum(_attn_chunked(q, k, v, causal=True, window=None,
                                     q_chunk=16, k_chunk=16) ** 2)

    def loss_d(q, k, v):
        return jnp.sum(_attn_dense(q, k, v, causal=True, window=None) ** 2)

    gc = jax.grad(loss_c, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_d, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gc, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)


def test_attention_dispatch_fallbacks():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 10, 10, 2, 2, 4)  # non-divisible: dense fallback
    got = attention(q, k, v, causal=True, impl="chunked", q_chunk=16,
                    k_chunk=16)
    want = _attn_dense(q, k, v, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_causal_skip_flop_reduction():
    """The skip path must contain ~half the dot FLOPs of the no-skip path."""
    from repro.launch import hlo_analysis

    b, s, h, dh = 1, 128, 2, 8

    def run(q_offset):
        def f(q, k, v):
            return _attn_chunked(q, k, v, causal=True, window=None,
                                 q_chunk=16, k_chunk=16, q_offset=q_offset)
        sds = [jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32)] * 3
        c = jax.jit(f).lower(*sds).compile()
        return hlo_analysis.analyze(c.as_text())["flops"]

    skip = run(0)          # skip path active
    noskip = run(1)        # q_offset disables the static skip
    assert skip < 0.65 * noskip, (skip, noskip)

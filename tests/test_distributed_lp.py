"""Distributed (shard_map) LP vs the single-device engine.

Multi-device CPU requires XLA_FLAGS before jax initializes, so the real
check runs in a subprocess with 8 virtual devices; the in-process test
covers the 1-device degenerate mesh.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import distributed_propagate
from repro.core.propagate import propagate
from repro.launch.mesh import make_mesh

from helpers import random_problem

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_distributed_matches_single_device_1dev():
    rng = np.random.default_rng(0)
    p = random_problem(rng, 96, 2)
    f0 = jnp.full((96,), 0.5)
    fr = jnp.ones(96, bool)
    mesh = make_mesh((1,), ("graph",))
    res_d = distributed_propagate(p, f0, fr, mesh, delta=1e-5, max_iters=20_000)
    res_s = propagate(p, f0, fr, delta=1e-5, max_iters=20_000)
    assert int(res_d.iterations) == int(res_s.iterations)
    np.testing.assert_allclose(np.asarray(res_d.f), np.asarray(res_s.f),
                               rtol=1e-5, atol=1e-5)


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, sys
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {tests!r})
    from repro.core.distributed import distributed_propagate
    from repro.core.propagate import propagate
    from repro.launch.mesh import make_mesh
    from helpers import random_problem

    rng = np.random.default_rng(1)
    p = random_problem(rng, 200, 2)   # not a multiple of 8 -> padding path
    f0 = jnp.full((200,), 0.5)
    fr = jnp.ones(200, bool)
    mesh = make_mesh((2, 4), ("data", "model"))
    res_d = distributed_propagate(p, f0, fr, mesh, delta=1e-5, max_iters=20000)
    res_s = propagate(p, f0, fr, delta=1e-5, max_iters=20000)
    assert int(res_d.iterations) == int(res_s.iterations), (
        int(res_d.iterations), int(res_s.iterations))
    np.testing.assert_allclose(np.asarray(res_d.f), np.asarray(res_s.f),
                               rtol=1e-5, atol=1e-5)
    assert bool(res_d.converged)
    print("OK distributed==single", int(res_d.iterations))
""")


def test_distributed_matches_on_8_devices():
    script = SCRIPT.format(src=os.path.abspath(SRC),
                           tests=os.path.abspath(os.path.dirname(__file__)))
    env = dict(os.environ)
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK distributed==single" in out.stdout

"""Mesh-sharded StreamEngine: bit-identical labels vs the single-device
engine, per-rung partition-plan reuse, and even bucket sharding.

Multi-device CPU needs XLA_FLAGS set before jax initializes, so the
8-device checks run in a subprocess (same pattern as
tests/test_distributed_lp.py); the in-process tests cover the 1-device
degenerate mesh and the host-side padding/plan logic.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.snapshot import build_host_problem
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import DynamicGraph
from repro.launch.mesh import make_stream_mesh

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
TESTS = os.path.abspath(os.path.dirname(__file__))


def _run_pair(spec, mesh, **kw):
    g_m = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_s = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_m = StreamEngine(g_m, delta=1e-4, mesh=mesh, **kw)
    eng_s = StreamEngine(g_s, delta=1e-4, **kw)
    for i, (batch, _) in enumerate(gaussian_mixture_stream(spec)):
        st_m = eng_m.step(batch)
        st_s = eng_s.step(batch)
        assert st_m.iterations == st_s.iterations, f"batch {i}"
        assert st_m.num_unlabeled == st_s.num_unlabeled
    return g_m, g_s, eng_m, eng_s


def test_sharded_stream_matches_single_device_local_mesh():
    """Mesh over whatever devices this process has (1 in plain CPU runs,
    8 in the multi-device CI job): the sharded path must be bit-identical
    to the unsharded engine either way."""
    spec = StreamSpec(total_vertices=600, batch_size=60, seed=3,
                      class_sep=6.0, noise=0.9)
    g_m, g_s, eng_m, _ = _run_pair(spec, make_stream_mesh())
    np.testing.assert_array_equal(g_m.f, g_s.f)
    # one partition plan per rung, not per batch
    assert eng_m.plan_builds == len(eng_m.bucket_keys)
    assert eng_m.plan_builds < eng_m.batches


def test_sharded_stream_pallas_backend_local_mesh():
    """The ell_pallas update body composes with the shard_map transport."""
    spec = StreamSpec(total_vertices=300, batch_size=100, seed=4,
                      class_sep=6.0, noise=0.9)
    g_m, g_s, _, _ = _run_pair(spec, make_stream_mesh(),
                               backend="ell_pallas", block_rows=64)
    np.testing.assert_array_equal(g_m.f, g_s.f)


def test_bucket_rows_pad_to_mesh_multiple():
    """row_multiple rounds every row bucket up so shapes shard evenly."""
    spec = StreamSpec(total_vertices=700, batch_size=70, seed=2,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-3)
    for batch, _ in gaussian_mixture_stream(spec):
        eng.step(batch)
        host = build_host_problem(g, auto_bucket=True, row_multiple=8)
        assert host.bucket_key[0] % 8 == 0
        # never pads below the plain bucket (single-device shape)
        plain = build_host_problem(g, auto_bucket=True)
        assert host.bucket_key[0] >= plain.bucket_key[0]
        assert host.bucket_key[0] - plain.bucket_key[0] < 8


def test_env_backend_hint_resolves_through_registry(monkeypatch):
    """REPRO_BACKEND is a fleet-wide hint resolved through the backend
    registry: bsr now HAS a sharded form, so the hint is honored on a
    mesh too; a hint naming a backend whose spec can't run in the
    current mode would degrade to the auto scan instead of failing."""
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_BACKEND", "bsr")
    assert ops.select_backend(None, sharded=True) == "bsr"
    assert ops.select_backend(None, num_rows=64) == "bsr"
    assert ops.select_backend("bsr", sharded=True) == "bsr"  # explicit
    assert "bsr" in ops.backend_candidates(None, sharded=True)
    # the registry is the degrade decision-maker: a spec with no sharded
    # form falls back to the auto scan when the hint arrives sharded
    spec = ops.backend_spec("bsr")
    import dataclasses
    ops.register_backend(dataclasses.replace(spec, sharded=False))
    try:
        assert ops.select_backend(None, sharded=True) == "ref"
        assert ops.select_backend(None, num_rows=64) == "bsr"  # unsharded
    finally:
        ops.register_backend(spec)


def test_mesh_accepts_bsr_backend():
    """bsr is a first-class sharded backend: run_propagation(mesh=...)
    solves through the shard_map BSR body given the per-edge slot map."""
    import jax.numpy as jnp

    from helpers import random_problem
    from repro.core.propagate import propagate
    from repro.kernels import ops
    from repro.kernels.bsr_spmv import ell_bsr_layout

    rng = np.random.default_rng(0)
    p = random_problem(rng, 64, 2)
    f0, fr = jnp.full((64,), 0.5), jnp.ones(64, bool)
    layout = ell_bsr_layout(np.asarray(p.nbr), ops.bsr_block_size())
    res = ops.run_propagation(
        p, f0, fr, backend="bsr", mesh=make_stream_mesh(1),
        slot=layout.slot, num_slots=layout.num_slots)
    want = propagate(p, f0, fr)
    np.testing.assert_allclose(np.asarray(res.f), np.asarray(want.f),
                               atol=2e-3)
    # ...but the slot map is mandatory in sharded mode
    with pytest.raises(ValueError, match="slot"):
        ops.run_propagation(p, f0, fr, backend="bsr",
                            mesh=make_stream_mesh(1))




SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, {src!r}); sys.path.insert(0, {tests!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    # 50 mixed insert/delete batches crossing several ladder rungs
    spec = StreamSpec(total_vertices=1500, batch_size=30, seed=11,
                      class_sep=6.0, noise=0.9, frac_deleted=0.2,
                      frac_unlabeled=0.79)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    assert len(batches) == 50
    assert any(len(b.del_ids) for b in batches)     # deletions present

    mesh = make_stream_mesh()
    assert mesh.devices.size == 8, mesh

    g_m = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_s = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_m = StreamEngine(g_m, delta=1e-4, mesh=mesh)
    eng_s = StreamEngine(g_s, delta=1e-4)
    for i, b in enumerate(batches):
        st_m = eng_m.step(b)
        st_s = eng_s.step(b)
        assert st_m.iterations == st_s.iterations, (i, st_m, st_s)
        assert st_m.converged == st_s.converged

    # the headline: bit-identical labels across the whole stream
    assert np.array_equal(g_m.f, g_s.f), np.abs(g_m.f - g_s.f).max()

    # every sharded bucket divides the mesh evenly
    assert all(u % 8 == 0 for u, _ in eng_m.bucket_keys), eng_m.bucket_keys

    # the stream regrew across several ladder rungs ...
    rungs = len(eng_m.bucket_keys)
    assert rungs >= 3, eng_m.bucket_keys
    # ... yet partition planning happened once per rung, not per batch,
    # and compiles stayed bounded by the rungs actually touched
    assert eng_m.plan_builds == rungs, (eng_m.plan_builds, rungs)
    assert eng_m.recompile_count <= rungs, (eng_m.recompile_count, rungs)

    # pipelined submit/drain works on sharded arrays and reaches the
    # same labels (per-shard donated f0, double-buffered topology)
    g_p = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_p = StreamEngine(g_p, delta=1e-4, mesh=mesh)
    done = 0
    for b in batches:
        if eng_p.submit(b) is not None:
            done += 1
    assert eng_p.drain() is not None
    done += 1
    assert done == len(batches)
    assert np.array_equal(g_p.f, g_s.f)

    # ---- halo transport: same 50 mixed insert/delete batches ----
    g_h = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_h = StreamEngine(g_h, delta=1e-4, mesh=mesh, transport="halo")
    for b in batches:
        eng_h.step(b)
    # the headline: halo labels bit-identical to all-gather AND to the
    # single-device engine over the whole stream
    assert np.array_equal(g_h.f, g_s.f), np.abs(g_h.f - g_s.f).max()
    assert np.array_equal(g_h.f, g_m.f)
    # one halo plan per rung (no overflow on this deterministic stream:
    # every batch ran the halo collective, none fell back)
    h_rungs = len(eng_h.bucket_keys)
    assert eng_h.plan_builds <= h_rungs, (eng_h.plan_builds, h_rungs)
    assert eng_h.transport_overflows == 0, eng_h.transport_summary()
    assert eng_h.halo_batches == len(batches), eng_h.transport_summary()

    # pipelined submit/drain composes with the halo layout permutation
    g_hp = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_hp = StreamEngine(g_hp, delta=1e-4, mesh=mesh, transport="halo")
    for b in batches:
        eng_hp.submit(b)
    eng_hp.drain()
    assert np.array_equal(g_hp.f, g_s.f)

    # auto decides per rung but never changes the labels
    g_au = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_au = StreamEngine(g_au, delta=1e-4, mesh=mesh, transport="auto")
    for b in batches:
        eng_au.step(b)
    assert np.array_equal(g_au.f, g_s.f)
    assert set(eng_au.transport_summary()["rung_modes"].values()) <= {{
        "allgather", "halo"}}

    # a bucket that doesn't divide the mesh is refused at planning time
    from repro.core.distributed import build_stream_plan
    try:
        build_stream_plan(mesh, (257, 8))
    except ValueError as e:
        assert "row_multiple" in str(e)
    else:
        raise AssertionError("uneven bucket accepted")
    print("OK sharded-stream", rungs, "rungs", eng_m.recompile_count,
          "recompiles |", eng_h.halo_batches, "halo batches",
          eng_h.plan_builds, "halo plans")
""")


def test_sharded_stream_bit_identical_8dev():
    """50 mixed insert/delete batches on a forced 8-device CPU mesh:
    labels bit-identical to the single-device engine for BOTH transports
    (all-gather and halo, pipelined submit/drain included), plans reused
    per rung across a multi-rung ladder regrow, halo plan_builds <=
    rungs with zero overflow fallbacks."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC, tests=TESTS)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK sharded-stream" in out.stdout

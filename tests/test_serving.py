"""Serving engine: continuous batching must reproduce sequential decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke_config("qwen3-0.6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _sequential_greedy(model, params, prompt, max_new, s_max=64):
    """Reference: single-sequence greedy decode via decode_step."""
    cache = model.init_cache(1, s_max)
    logits = None
    pos = 0
    for tok in prompt:
        logits, cache = model.decode_step(
            params, cache,
            {"tokens": jnp.full((1, 1), int(tok), jnp.int32),
             "pos": jnp.asarray(pos, jnp.int32)})
        pos += 1
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(max_new - 1):
        logits, cache = model.decode_step(
            params, cache,
            {"tokens": jnp.full((1, 1), out[-1], jnp.int32),
             "pos": jnp.asarray(pos, jnp.int32)})
        pos += 1
        out.append(int(jnp.argmax(logits[0, -1])))
    return out


def test_engine_matches_sequential(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n) for n in (3, 5, 4)]
    reqs = [Request(uid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    engine = ServeEngine(model, params, max_batch=4, s_max=64)
    done = engine.run(reqs)
    assert len(done) == 3
    for req in done:
        want = _sequential_greedy(model, params, req.prompt, req.max_new)
        assert req.out == want, (req.uid, req.out, want)


def test_engine_handles_overflow_queue(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=3), max_new=3)
            for i in range(5)]
    engine = ServeEngine(model, params, max_batch=2, s_max=32)
    done = engine.run(reqs)
    assert len(done) == 5  # waves drain through the 2-slot pool


def test_pipeline_pseudo_labels():
    from repro.data.pipeline import PseudoLabelPipeline
    from repro.graph.dynamic import UNLABELED

    rng = np.random.default_rng(0)
    pipe = PseudoLabelPipeline(k=3)
    n, s, vocab = 120, 32, 97
    cls = rng.integers(0, 2, n).astype(np.int8)
    toks = np.zeros((n, s), np.int32)
    base = rng.integers(0, vocab, (n, 1))
    toks[cls == 1] = (base[cls == 1] + np.arange(s)) % vocab
    toks[cls == 0] = rng.integers(0, vocab, ((cls == 0).sum(), s))
    labels = np.full(n, UNLABELED, np.int8)
    labels[:6] = cls[:6]
    pipe.ingest(toks, labels)
    truth = {i: int(c) for i, c in enumerate(cls)}
    assert pipe.label_quality(truth) > 0.9
    ids, curated = pipe.select(target_class=1, confidence=0.7)
    assert len(ids) > 10
    purity = np.mean([truth[i] == 1 for i in ids])
    assert purity > 0.9

"""Property-based stream equivalence: for ANY random mixed insert/delete
stream, ``StreamEngine`` labels are bit-identical to a full per-batch
``DynLP`` recompute, on both the ``ref`` and ``ell_pallas`` backends.

Strategies use only the surface shared by real hypothesis and the
``tests/_hypothesis_fallback.py`` shim (integers / floats / booleans /
sampled_from), so the suite runs identically with either installed.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.dynlp import DynLP
from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.launch.mesh import make_stream_mesh

EMB_DIM = 8


def _random_batches(seed, n_batches, batch_size, frac_del, hostile_dels,
                    include_empty):
    """Random two-Gaussian insert/delete stream.  ``hostile_dels`` mixes
    duplicate and out-of-range ids into the delete sets (both engines
    must shrug them off identically); ``include_empty`` splices in an
    all-empty Δ_t."""
    rng = np.random.default_rng(seed)
    batches = []
    next_id = 0
    for b in range(n_batches):
        n = batch_size
        cls = rng.integers(0, 2, n).astype(np.int8)
        emb = np.zeros((n, EMB_DIM), np.float32)
        emb[:, 0] = np.where(cls == 1, 3.0, -3.0)
        emb += rng.normal(0, 0.9, (n, EMB_DIM)).astype(np.float32)
        labels = np.full(n, UNLABELED, np.int8)
        if b == 0:  # seed both classes so propagation has sources
            labels[0] = cls[0]
            labels[1] = 1 - cls[0]
            cls[1] = 1 - cls[0]
            emb[1, 0] = -emb[0, 0]
        n_del = int(round(frac_del * n)) if next_id else 0
        del_ids = rng.integers(0, next_id, n_del).astype(np.int64) \
            if n_del else np.zeros(0, np.int64)
        if hostile_dels and next_id:
            del_ids = np.concatenate([
                del_ids, del_ids[:2],  # duplicates
                np.array([next_id + 17, -1], np.int64),  # never-seen ids
            ])
        batches.append(BatchUpdate(ins_emb=emb, ins_labels=labels,
                                   del_ids=del_ids))
        next_id += n
    if include_empty:
        batches.insert(n_batches // 2 + 1, BatchUpdate(
            ins_emb=np.zeros((0, EMB_DIM), np.float32),
            ins_labels=np.zeros(0, np.int8),
            del_ids=np.zeros(0, np.int64)))
    return batches


@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(10, 30),
       st.floats(0.0, 0.3), st.booleans(), st.booleans(),
       st.sampled_from(["ref", "ell_pallas"]))
@settings(max_examples=8, deadline=None)
def test_stream_bit_identical_to_dynlp_recompute(
        seed, n_batches, batch_size, frac_del, hostile_dels, include_empty,
        backend):
    """After every Δ_t the streamed labels equal the full DynLP recompute
    bit for bit — same iteration count, same convergence, same f."""
    batches = _random_batches(seed, n_batches, batch_size, frac_del,
                              hostile_dels, include_empty)
    g_s = DynamicGraph(emb_dim=EMB_DIM, k=4)
    g_d = DynamicGraph(emb_dim=EMB_DIM, k=4)
    eng = StreamEngine(g_s, delta=1e-4, backend=backend, block_rows=64)
    dyn = DynLP(g_d, delta=1e-4, backend=backend)
    for i, batch in enumerate(batches):
        st_s = eng.step(batch)
        st_d = dyn.step(batch)
        assert st_s.iterations == st_d.iterations, f"batch {i}"
        assert st_s.converged == st_d.converged, f"batch {i}"
        assert st_s.num_unlabeled == st_d.num_unlabeled, f"batch {i}"
        np.testing.assert_array_equal(g_s.f, g_d.f,
                                      err_msg=f"batch {i} ({backend})")
        np.testing.assert_array_equal(g_s.alive, g_d.alive)
    ids_s, pred_s = eng.predictions()
    ids_d, pred_d = dyn.predictions()
    np.testing.assert_array_equal(ids_s, ids_d)
    np.testing.assert_array_equal(pred_s, pred_d)


@given(st.integers(0, 10_000), st.integers(2, 3), st.integers(10, 24),
       st.floats(0.0, 0.25), st.booleans())
@settings(max_examples=6, deadline=None)
def test_pipelined_stream_bit_identical_to_dynlp(seed, n_batches,
                                                 batch_size, frac_del,
                                                 hostile_dels):
    """The overlapped submit/drain pipeline reaches the same fixpoint as
    the recompute too — staging t+1 while t is in flight never leaks."""
    batches = _random_batches(seed, n_batches, batch_size, frac_del,
                              hostile_dels, include_empty=False)
    g_p = DynamicGraph(emb_dim=EMB_DIM, k=4)
    g_d = DynamicGraph(emb_dim=EMB_DIM, k=4)
    eng = StreamEngine(g_p, delta=1e-4)
    dyn = DynLP(g_d, delta=1e-4)
    done = 0
    for batch in batches:
        if eng.submit(batch) is not None:
            done += 1
        dyn.step(batch)
    assert eng.drain() is not None
    done += 1
    assert done == len(batches) == eng.commits
    np.testing.assert_array_equal(g_p.f, g_d.f)


@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(10, 30),
       st.floats(0.0, 0.3), st.booleans(),
       st.sampled_from(["ref", "ell_pallas"]))
@settings(max_examples=6, deadline=None)
def test_transport_equivalence_halo_allgather_single(
        seed, n_batches, batch_size, frac_del, hostile_dels, backend):
    """For ANY random insert/delete stream, the sharded transports are
    bit-interchangeable: halo ≡ all-gather ≡ single-device, for both
    update bodies.  Random streams have no locality, so this also
    exercises saturated export budgets; correctness must never depend on
    which collective a batch happened to ride (overflow fallback
    included — the assertion holds whether or not any batch fell back)."""
    batches = _random_batches(seed, n_batches, batch_size, frac_del,
                              hostile_dels, include_empty=False)
    mesh = make_stream_mesh()  # 1 device in tier-1, 8 in the matrix job
    f_ref = None
    for transport in (None, "allgather", "halo"):
        g = DynamicGraph(emb_dim=EMB_DIM, k=4)
        eng = (StreamEngine(g, delta=1e-4, backend=backend, block_rows=64)
               if transport is None else
               StreamEngine(g, delta=1e-4, backend=backend, block_rows=64,
                            mesh=mesh, transport=transport))
        for batch in batches:
            eng.step(batch)
        if f_ref is None:
            f_ref = g.f.copy()
        else:
            np.testing.assert_array_equal(
                g.f, f_ref, err_msg=f"{transport} ({backend})")


@given(st.integers(0, 10_000), st.integers(8, 40))
@settings(max_examples=8, deadline=None)
def test_committed_view_is_frozen_copy(seed, batch_size):
    """The committed LabelView must be decoupled from the live graph: a
    later (un-drained) submit can't leak into it."""
    batches = _random_batches(seed, 2, batch_size, 0.1,
                              hostile_dels=False, include_empty=False)
    g = DynamicGraph(emb_dim=EMB_DIM, k=4)
    eng = StreamEngine(g, delta=1e-4)
    eng.step(batches[0])
    view = eng.committed_view()
    f_then = view.f.copy()
    eng.submit(batches[1])  # mutates g.f (supernode inits) pre-commit
    np.testing.assert_array_equal(view.f, f_then)
    assert not view.f.flags.writeable
    assert eng.committed_view() is view  # still batch 0's commit
    eng.drain()
    assert eng.committed_view() is not view

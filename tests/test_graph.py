"""Graph substrate: CSR/ELL conversions, kNN construction, dynamic updates."""

import numpy as np
from hypothesis import given, strategies as st

from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.graph.knn import build_knn_graph, knn_edges, symmetrize
from repro.graph.structures import (
    PAD,
    coo_to_csr,
    csr_to_ell,
    csr_to_ell_fast,
)

from helpers import random_undirected_coo


@given(st.integers(0, 10_000), st.integers(1, 50), st.floats(0.5, 8.0))
def test_ell_fast_matches_reference(seed, n, avg_deg):
    rng = np.random.default_rng(seed)
    src, dst, wgt = random_undirected_coo(rng, n, avg_deg)
    csr = coo_to_csr(n, src, dst, wgt)
    a = csr_to_ell(csr)
    b = csr_to_ell_fast(csr)
    # same multiset of (nbr, wgt) per row
    for u in range(n):
        sa = sorted(zip(np.asarray(a.nbr)[u], np.asarray(a.wgt)[u]))
        sb = sorted(zip(np.asarray(b.nbr)[u], np.asarray(b.wgt)[u]))
        assert sa == sb


@given(st.integers(0, 10_000), st.integers(2, 40))
def test_csr_roundtrip_degrees(seed, n):
    rng = np.random.default_rng(seed)
    src, dst, wgt = random_undirected_coo(rng, n, 3.0)
    csr = coo_to_csr(n, src, dst, wgt)
    deg = np.bincount(src, minlength=n)
    np.testing.assert_array_equal(np.diff(csr.rowptr), deg)
    ell = csr_to_ell_fast(csr)
    np.testing.assert_array_equal(np.asarray(ell.degrees()), deg)


@given(st.integers(0, 10_000))
def test_symmetrize_is_symmetric(seed):
    rng = np.random.default_rng(seed)
    n = 20
    emb = rng.normal(0, 1, (n, 8)).astype(np.float32)
    s, d, w = knn_edges(emb, k=3)
    ss, dd, ww = symmetrize(n, s, d, w)
    pairs = {(a, b): c for a, b, c in zip(ss, dd, ww)}
    for (a, b), c in pairs.items():
        assert (b, a) in pairs
        assert pairs[(b, a)] == c


def test_knn_graph_properties():
    rng = np.random.default_rng(0)
    emb = rng.normal(0, 1, (100, 8)).astype(np.float32)
    csr = build_knn_graph(emb, k=5)
    assert csr.num_nodes == 100
    deg = np.diff(csr.rowptr)
    assert deg.min() >= 5  # out-degree at least k after symmetrization
    assert (csr.wgt >= 0).all() and (csr.wgt <= 1).all()  # cosine mapped to [0,1]
    # no self loops
    for u in range(100):
        cols, _ = csr.neighbors(u)
        assert u not in cols


def test_dynamic_graph_insert_delete_invariants():
    rng = np.random.default_rng(1)
    g = DynamicGraph(emb_dim=8, k=3)
    emb1 = rng.normal(0, 1, (50, 8)).astype(np.float32)
    labels = np.full(50, UNLABELED, np.int8)
    labels[:2] = [0, 1]
    eff1 = g.apply_batch(BatchUpdate(ins_emb=emb1, ins_labels=labels,
                                     del_ids=np.zeros(0, np.int64)))
    assert g.num_alive == 50
    assert len(eff1.new_ids) == 50
    # edges are symmetric and alive
    pairs = set(zip(g.src, g.dst))
    assert all((b, a) in pairs for a, b in pairs)

    emb2 = rng.normal(0, 1, (30, 8)).astype(np.float32)
    eff2 = g.apply_batch(
        BatchUpdate(ins_emb=emb2, ins_labels=np.full(30, UNLABELED, np.int8),
                    del_ids=np.arange(10, 20)))
    assert g.num_alive == 50 - 10 + 30
    assert not g.alive[10:20].any()
    # no edge touches a dead vertex
    assert g.alive[g.src].all() and g.alive[g.dst].all()
    # affected contains all new vertices
    assert set(eff2.new_ids).issubset(set(eff2.affected))
    # deleting a dead vertex again is a no-op
    n_edges = g.num_edges
    g.apply_batch(BatchUpdate(ins_emb=np.zeros((0, 8), np.float32),
                              ins_labels=np.zeros(0, np.int8),
                              del_ids=np.arange(10, 20)))
    assert g.num_edges == n_edges


def test_snapshot_excludes_labeled_and_dead():
    from repro.core.snapshot import build_problem

    rng = np.random.default_rng(2)
    g = DynamicGraph(emb_dim=8, k=3)
    labels = np.full(40, UNLABELED, np.int8)
    labels[:4] = [0, 0, 1, 1]
    g.apply_batch(BatchUpdate(
        ins_emb=rng.normal(0, 1, (40, 8)).astype(np.float32),
        ins_labels=labels, del_ids=np.zeros(0, np.int64)))
    g.apply_batch(BatchUpdate(
        ins_emb=np.zeros((0, 8), np.float32), ins_labels=np.zeros(0, np.int8),
        del_ids=np.array([5, 6])))
    snap = build_problem(g)
    assert len(snap.unl_ids) == 40 - 4 - 2
    nbr = np.asarray(snap.problem.nbr)
    k = nbr[nbr != PAD]
    assert (k < len(snap.unl_ids)).all()  # ELL refers only to unlabeled rows
    # wl sums positive somewhere (labeled nodes do exist in the graph)
    assert float(np.asarray(snap.problem.wl0).sum()) > 0
    assert float(np.asarray(snap.problem.wl1).sum()) > 0

"""Device-resident ingest: property tests against the host oracle.

Core claims (ISSUE 7 acceptance):

  * incremental device kNN over random insert streams is bit-identical
    to rebuilding with the host ``build_knn_graph`` oracle (CSR arrays
    compared raw, no canonicalization) — incl. displaced-edge deletes,
    empty and singleton batches;
  * mixed insert/delete streams through the device selector match the
    host staging selector batch-for-batch (lists, edges, labels);
  * ``LPService.add_points`` over a device-ingest engine produces labels
    bit-identical to the host-kNN ``BatchUpdate`` path on a 50-batch
    mixed stream — single-device here, forced 8-virtual-device mesh in
    the subprocess arm;
  * the ingest jit cache stays within the a-priori ladder bound.

Strategies use only the surface shared by real hypothesis and the
``tests/_hypothesis_fallback.py`` shim.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.graph.knn import build_knn_graph
from repro.ingest import DeviceIngestor, ingest_cache_size, \
    ingest_ladder_bound
from repro.ingest.embedding_store import EmbeddingStore, cap_bucket, dim_pad
from repro.serving.lp_service import LPService

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def _insert_stream(rng, emb_dim, n_batches, max_batch):
    sizes = [int(rng.integers(0, max_batch + 1)) for _ in range(n_batches)]
    sizes[0] = max(sizes[0], 3)
    sizes[min(1, n_batches - 1)] = 1  # force a singleton batch
    if n_batches > 2:
        sizes[2] = 0  # force an empty batch
    return [rng.normal(size=(s, emb_dim)).astype(np.float32) for s in sizes]


def _apply(g, emb, dels, selector):
    g.apply_batch(BatchUpdate(
        ins_emb=emb, ins_labels=np.full(len(emb), UNLABELED, np.int8),
        del_ids=dels), selector=selector)


@given(st.integers(0, 10_000), st.integers(3, 8), st.integers(2, 6),
       st.integers(4, 32))
@settings(max_examples=8, deadline=None)
def test_device_insert_stream_bit_identical_to_rebuild(
        seed, n_batches, k, emb_dim):
    """Random insert streams (empty + singleton batches included): the
    device-ingested graph's CSR snapshot equals a from-scratch host
    ``build_knn_graph`` bit for bit."""
    rng = np.random.default_rng(seed)
    batches = _insert_stream(rng, emb_dim, n_batches, 24)
    g = DynamicGraph(emb_dim, k=k)
    ing = DeviceIngestor(emb_dim)
    for b in batches:
        _apply(g, b, np.zeros(0, np.int64), ing)
    ref = build_knn_graph(np.concatenate(batches), k=k)
    csr, ids = g.snapshot_csr()
    np.testing.assert_array_equal(ids, np.arange(g.num_nodes))
    np.testing.assert_array_equal(csr.rowptr, ref.rowptr)
    np.testing.assert_array_equal(csr.col, ref.col)
    np.testing.assert_array_equal(csr.wgt, ref.wgt)


@given(st.integers(0, 10_000), st.integers(3, 7), st.integers(2, 5),
       st.floats(0.0, 0.3))
@settings(max_examples=8, deadline=None)
def test_device_matches_host_selector_mixed_stream(
        seed, n_batches, k, frac_del):
    """Mixed insert/delete streams: device selector == host selector
    batch-for-batch on lists AND the undirected edge arrays (the
    displaced-edge delete path is exercised by every hole refill)."""
    rng = np.random.default_rng(seed)
    emb_dim = 12
    batches = _insert_stream(rng, emb_dim, n_batches, 20)
    gh = DynamicGraph(emb_dim, k=k)
    gd = DynamicGraph(emb_dim, k=k)
    ing = DeviceIngestor(emb_dim)
    total = 0
    for b in batches:
        n_del = int(round(frac_del * len(b))) if total else 0
        dels = (rng.choice(total, size=min(n_del, total), replace=False)
                .astype(np.int64) if n_del else np.zeros(0, np.int64))
        _apply(gh, b, dels, None)
        _apply(gd, b, dels, ing)
        total += len(b)
        np.testing.assert_array_equal(gh.knn_idx, gd.knn_idx)
        np.testing.assert_array_equal(gh.knn_wgt, gd.knn_wgt)
        np.testing.assert_array_equal(gh.src, gd.src)
        np.testing.assert_array_equal(gh.dst, gd.dst)
        np.testing.assert_array_equal(gh.wgt, gd.wgt)


def test_mass_duplicates_tie_break():
    """All-identical points: deep weight ties must resolve to the same
    lowest-id neighbors on both paths."""
    dup = np.ones((20, 6), np.float32)
    gh = DynamicGraph(6, k=3)
    gd = DynamicGraph(6, k=3)
    ing = DeviceIngestor(6)
    for lo, hi in [(0, 9), (9, 20)]:
        _apply(gh, dup[lo:hi], np.zeros(0, np.int64), None)
        _apply(gd, dup[lo:hi], np.zeros(0, np.int64), ing)
    np.testing.assert_array_equal(gh.knn_idx, gd.knn_idx)
    np.testing.assert_array_equal(gh.knn_wgt, gd.knn_wgt)


def _mixed_service_stream(ingest, mesh=None, n_batches=50, seed=123):
    """Drive a service with 50 typed mixed mutations; returns the
    committed f after every sync plus the final graph."""
    rng = np.random.default_rng(seed)
    emb_dim, k = 10, 4
    g = DynamicGraph(emb_dim, k=k)
    eng = StreamEngine(g, delta=1e-4, ingest=ingest, mesh=mesh)
    svc = LPService(eng, window_ops=64, window_ms=1e9, max_pending_ops=4096)
    total = 0
    outs = []
    for t in range(n_batches):
        m = int(rng.integers(1, 10))
        cls = rng.integers(0, 2, m).astype(np.int8)
        emb = np.zeros((m, emb_dim), np.float32)
        emb[:, 0] = np.where(cls == 1, 3.0, -3.0)
        emb += rng.normal(0, 0.9, (m, emb_dim)).astype(np.float32)
        labels = np.where(rng.random(m) < 0.2, cls, UNLABELED).astype(np.int8)
        if t == 0:
            labels[0], cls[0] = 0, 0
            emb[0, 0] = -3.0
        svc.add_points(emb, labels)
        total += m
        if t % 5 == 4 and total > 8:
            svc.remove_points(
                rng.choice(total, size=3, replace=False).astype(np.int64))
        svc.sync()
        outs.append(g.f.copy())
    return outs, g


def test_service_add_points_device_bit_identical_to_host_50_batches():
    """Acceptance: 50-batch mixed insert/delete ``add_points`` stream —
    device-ingest labels bit-identical to the host-kNN path after every
    commit."""
    oh, gh = _mixed_service_stream("host")
    od, gd = _mixed_service_stream("device")
    assert len(oh) == len(od) == 50
    for i, (fh, fd) in enumerate(zip(oh, od)):
        np.testing.assert_array_equal(fh, fd, err_msg=f"batch {i}")
    np.testing.assert_array_equal(gh.knn_idx, gd.knn_idx)
    np.testing.assert_array_equal(gh.labels, gd.labels)
    np.testing.assert_array_equal(gh.alive, gd.alive)


SCRIPT_8DEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import importlib.util, sys
    sys.path.insert(0, {src!r})
    from repro.launch.mesh import make_stream_mesh
    import numpy as np
    # load this module without conftest: stub hypothesis with the shim
    spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join({tests!r}, "_hypothesis_fallback.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    sys.modules["hypothesis"] = mod
    sys.path.insert(0, {tests!r})
    from test_ingest import _mixed_service_stream

    mesh = make_stream_mesh()
    assert mesh.devices.size == 8, mesh
    oh, gh = _mixed_service_stream("host", mesh=mesh)
    od, gd = _mixed_service_stream("device", mesh=mesh)
    for i, (fh, fd) in enumerate(zip(oh, od)):
        np.testing.assert_array_equal(fh, fd, err_msg=f"batch {{i}}")
    np.testing.assert_array_equal(gh.knn_idx, gd.knn_idx)
    print("OK ingest-8dev", len(oh), "commits")
""")


def test_service_add_points_device_bit_identical_8dev():
    """Acceptance: the same 50-batch stream on a forced 8-virtual-device
    mesh (subprocess, same pattern as tests/test_stream_sharded.py)."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT_8DEV.format(
            src=os.path.abspath(SRC),
            tests=os.path.dirname(os.path.abspath(__file__)))],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK ingest-8dev" in out.stdout


# --------------------------------------------------------------------- #
# embedding store unit behavior
# --------------------------------------------------------------------- #
def test_store_ladder_growth_and_padding():
    store = EmbeddingStore(emb_dim=10)
    assert store.dp == dim_pad(10) == 16
    assert store.capacity == cap_bucket(1) == 1024
    rng = np.random.default_rng(0)
    store.append(rng.normal(size=(700, 10)).astype(np.float32))
    assert store.capacity == 1024 and store.grows == 0
    store.append(rng.normal(size=(700, 10)).astype(np.float32))
    assert store.capacity == 2048 and store.grows == 1
    assert store.count == 1400
    v = np.asarray(store.valid)
    assert v[:1400].all() and not v[1400:].any()
    # padded feature columns are zero (inert under dot products)
    e = np.asarray(store.emb)
    assert (e[:, 10:] == 0).all()


def test_store_kill_and_kth_roundtrip():
    store = EmbeddingStore(emb_dim=4)
    rng = np.random.default_rng(1)
    store.append(rng.normal(size=(50, 4)).astype(np.float32))
    store.kill(np.array([3, 7, 11], np.int64))
    v = np.asarray(store.valid)
    assert not v[[3, 7, 11]].any() and v[:50].sum() == 47
    store.set_kth(np.array([5, 9], np.int64),
                  np.array([0.25, 0.75], np.float32))
    kth = np.asarray(store.kth)
    assert kth[5] == np.float32(0.25) and kth[9] == np.float32(0.75)


def test_ingest_cache_within_ladder_bound():
    """One fixed-shape stream: live jit entries stay under the a-priori
    ladder bound (the bench ``--check`` recompile gate)."""
    rng = np.random.default_rng(2)
    emb_dim, k = 16, 4
    g = DynamicGraph(emb_dim, k=k)
    ing = DeviceIngestor(emb_dim)
    c0 = ingest_cache_size()
    total = 0
    for t in range(30):
        m = int(rng.integers(1, 33))
        dels = (rng.choice(total, size=4, replace=False).astype(np.int64)
                if t % 6 == 5 and total > 8 else np.zeros(0, np.int64))
        _apply(g, rng.normal(size=(m, emb_dim)).astype(np.float32), dels, ing)
        total += m
    assert ingest_cache_size() - c0 <= ingest_ladder_bound(total, 32)


def test_ingestor_out_of_sync_raises():
    g1 = DynamicGraph(6, k=3)
    g2 = DynamicGraph(6, k=3)
    ing = DeviceIngestor(6)
    rng = np.random.default_rng(4)
    _apply(g1, rng.normal(size=(5, 6)).astype(np.float32),
           np.zeros(0, np.int64), ing)
    _apply(g2, rng.normal(size=(3, 6)).astype(np.float32),
           np.zeros(0, np.int64), None)
    try:
        # same ingestor on a different stream: row counts disagree
        _apply(g2, rng.normal(size=(4, 6)).astype(np.float32),
               np.zeros(0, np.int64), ing)
    except RuntimeError as e:
        assert "out of sync" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected out-of-sync RuntimeError")

"""benchmarks/ci_summary.py rendering: every committed BENCH_*.json must
produce a populated section (a recorded benchmark that silently renders
"(no data)" means the summary and the artifact schema have drifted), and
missing/corrupt inputs must degrade to the placeholder, never raise.
"""

import glob
import json
import os
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(REPO, "benchmarks"))

import ci_summary  # noqa: E402

# committed artifact -> (main() kwarg, section title, row-builder)
ARTIFACTS = {
    "BENCH_stream.json": ("stream_path", "stream throughput",
                          ci_summary.stream_rows),
    "BENCH_serve.json": ("serve_path", "LP serving", ci_summary.serve_rows),
    "BENCH_ingest.json": ("ingest_path", "device ingestion",
                          ci_summary.ingest_rows),
    "BENCH_checkpoint.json": ("checkpoint_path", "checkpoint / restore",
                              ci_summary.checkpoint_rows),
    "BENCH_landmark.json": ("landmark_path", "landmark backend",
                            ci_summary.landmark_rows),
}


def test_every_committed_artifact_has_a_renderer():
    """A new BENCH_*.json landing in the repo root without a ci_summary
    section is exactly the drift this test exists to catch."""
    committed = {os.path.basename(p)
                 for p in glob.glob(os.path.join(REPO, "BENCH_*.json"))}
    assert committed, "no committed BENCH_*.json artifacts found"
    assert committed <= set(ARTIFACTS), (
        f"BENCH artifacts without a ci_summary renderer: "
        f"{sorted(committed - set(ARTIFACTS))}")


@pytest.mark.parametrize("fname", sorted(ARTIFACTS))
def test_artifact_renders_nonempty_section(fname):
    path = os.path.join(REPO, fname)
    if not os.path.exists(path):
        pytest.skip(f"{fname} not committed")
    _, title, builder = ARTIFACTS[fname]
    with open(path) as fh:
        rows = builder(json.load(fh))
    assert rows, f"{fname} rendered zero rows"
    # every cell resolved — a '—' in a committed artifact's row means the
    # builder references a key the benchmark no longer writes
    for k, v in rows:
        assert "—" not in str(v), f"{fname}: unresolved key in row {k!r}: {v}"


def test_full_summary_sections_populated():
    md = ci_summary.main(*(os.path.join(REPO, f) for f in ARTIFACTS))
    assert md.startswith("## Benchmark smoke headlines")
    for _, title, _b in ARTIFACTS.values():
        assert f"### {title}" in md
    committed = {os.path.basename(p)
                 for p in glob.glob(os.path.join(REPO, "BENCH_*.json"))}
    if committed == set(ARTIFACTS):
        assert "(no data)" not in md
    # markdown tables stay intact: no raw pipes inside cells
    for line in md.splitlines():
        if line.startswith("|") and not line.startswith("|---"):
            assert line.count("|") == 3, f"broken table row: {line}"


def test_missing_and_corrupt_inputs_degrade():
    md = ci_summary.main("/nonexistent/a.json", "/nonexistent/b.json",
                         "/nonexistent/c.json", "/nonexistent/d.json",
                         "/nonexistent/e.json")
    # stream_rows always emits its fixed arms (as "—" cells); the other
    # four builders collapse to the placeholder row
    assert md.count("(no data)") == len(ARTIFACTS) - 1
    assert ci_summary._load(os.path.join(REPO, "README.md")) == {}  # not JSON

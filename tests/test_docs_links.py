"""The docs front door stays navigable: every relative link, ``#anchor``
fragment, and ``path:line`` code reference in README.md + docs/*.md
resolves (tools/check_docs_links.py — CI runs the same check as a
tier-1 step)."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docs_links  # noqa: E402


def test_readme_and_docs_exist():
    assert (REPO / "README.md").exists()
    for doc in ("README.md", "serving.md", "streaming.md", "benchmarks.md",
                "backends.md"):
        assert (REPO / "docs" / doc).exists(), f"docs/{doc} missing"


def test_docs_index_covers_every_page():
    """docs/README.md is the index: every docs/*.md page must be linked
    from it (a page nobody can navigate to is a page nobody reads)."""
    index = (REPO / "docs" / "README.md").read_text()
    for page in sorted((REPO / "docs").glob("*.md")):
        if page.name == "README.md":
            continue
        assert f"({page.name}" in index, f"docs/README.md misses {page.name}"


def test_all_docs_references_resolve():
    errors = []
    for md in check_docs_links.md_files(REPO):
        errors += check_docs_links.check_file(md, REPO)
    assert not errors, "\n".join(errors)


def test_checker_catches_broken_references(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[gone](docs/missing.md) and `src/nope/mod.py` and "
        "[ok](docs/real.md) and `docs/real.md:99` and "
        "`docs/real.md::NoSuchSymbol`\n")
    (tmp_path / "docs" / "real.md").write_text("hi\n")
    errors = check_docs_links.check_file(tmp_path / "README.md", tmp_path)
    msgs = "\n".join(errors)
    assert "docs/missing.md" in msgs
    assert "src/nope/mod.py" in msgs
    assert "docs/real.md:99" in msgs  # line past end of file
    assert "NoSuchSymbol" in msgs  # ::symbol absent from the file
    assert "[ok](docs/real.md)" not in msgs


def test_slugify_matches_github_rendering():
    assert check_docs_links.slugify("Backends") == "backends"
    assert check_docs_links.slugify("Hot / cold split") == "hot--cold-split"
    assert (check_docs_links.slugify("`BENCH_landmark.json` schema")
            == "bench_landmarkjson-schema")
    assert (check_docs_links.slugify("§ Auto-selection rules")
            == "-auto-selection-rules")
    assert (check_docs_links.slugify("[linked](docs/x.md) heading")
            == "linked-heading")


def test_anchors_skip_fenced_code_and_number_duplicates(tmp_path):
    md = tmp_path / "page.md"
    md.write_text("# Title\n\n## Usage\n\n```bash\n# not a heading\n```\n\n"
                  "## Usage\n")
    assert (check_docs_links.anchors_of(md)
            == {"title", "usage", "usage-1"})


def test_checker_catches_broken_anchors(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "real.md").write_text(
        "# Real Page\n\n## The `bsr` backend\n")
    (tmp_path / "README.md").write_text(
        "[ok](docs/real.md#the-bsr-backend) "
        "[bad](docs/real.md#no-such-section)\n"
        "# Local\n[self-ok](#local) [self-bad](#nowhere)\n")
    msgs = "\n".join(
        check_docs_links.check_file(tmp_path / "README.md", tmp_path))
    assert "docs/real.md#no-such-section" in msgs
    assert "#nowhere" in msgs
    assert "the-bsr-backend" not in msgs
    assert "(#local)" not in msgs

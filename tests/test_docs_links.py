"""The docs front door stays navigable: every relative link and
``path:line`` code reference in README.md + docs/*.md resolves
(tools/check_docs_links.py — CI runs the same check as a tier-1 step)."""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_docs_links  # noqa: E402


def test_readme_and_docs_exist():
    assert (REPO / "README.md").exists()
    for doc in ("serving.md", "streaming.md", "benchmarks.md"):
        assert (REPO / "docs" / doc).exists(), f"docs/{doc} missing"


def test_all_docs_references_resolve():
    errors = []
    for md in check_docs_links.md_files(REPO):
        errors += check_docs_links.check_file(md, REPO)
    assert not errors, "\n".join(errors)


def test_checker_catches_broken_references(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[gone](docs/missing.md) and `src/nope/mod.py` and "
        "[ok](docs/real.md) and `docs/real.md:99` and "
        "`docs/real.md::NoSuchSymbol`\n")
    (tmp_path / "docs" / "real.md").write_text("hi\n")
    errors = check_docs_links.check_file(tmp_path / "README.md", tmp_path)
    msgs = "\n".join(errors)
    assert "docs/missing.md" in msgs
    assert "src/nope/mod.py" in msgs
    assert "docs/real.md:99" in msgs  # line past end of file
    assert "NoSuchSymbol" in msgs  # ::symbol absent from the file
    assert "[ok](docs/real.md)" not in msgs

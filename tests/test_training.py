"""Optimizer, trainer, and HLO-analyzer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import optim
from repro.training.trainer import make_train_step


class ToyModel:
    def __init__(self, d=8):
        self.d = d

    def init(self, key):
        return {"w": jax.random.normal(key, (self.d,), jnp.float32) * 0.1,
                "norm": jnp.ones((self.d,), jnp.float32)}

    def loss(self, params, batch):
        pred = batch["x"] @ (params["w"] * params["norm"])
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"xent": loss}


def _toy_batch(n=64, d=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, d)).astype(np.float32)
    w = rng.normal(0, 1, d).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(x @ w)}


def test_adamw_converges():
    model = ToyModel()
    params = model.init(jax.random.PRNGKey(0))
    state = optim.init_state(params)
    cfg = optim.OptConfig(lr=0.05, warmup_steps=5, total_steps=200,
                          weight_decay=0.0)
    step = jax.jit(make_train_step(model, cfg))
    batch = _toy_batch()
    first = None
    for _ in range(200):
        params, state, loss, _ = step(params, state, batch)
        first = first if first is not None else float(loss)
    assert float(loss) < 0.01 * first


def test_microbatch_matches_full_batch_grads():
    model = ToyModel()
    params = model.init(jax.random.PRNGKey(1))
    batch = _toy_batch(n=64)
    cfg = optim.OptConfig(lr=0.1, warmup_steps=0, total_steps=10)
    s1 = jax.jit(make_train_step(model, cfg, microbatches=1))
    s4 = jax.jit(make_train_step(model, cfg, microbatches=4))
    su = jax.jit(make_train_step(model, cfg, microbatches=4, unroll_micro=True))
    st = optim.init_state(params)
    p1, _, l1, _ = s1(params, st, batch)
    p4, _, l4, _ = s4(params, optim.init_state(params), batch)
    pu, _, lu, _ = su(params, optim.init_state(params), batch)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p4["w"]), np.asarray(pu["w"]),
                               rtol=1e-5, atol=1e-6)


def test_weight_decay_mask():
    """Norm/bias-like leaves must not decay."""
    assert optim._decay_mask("layers/attn/wq")
    assert not optim._decay_mask("layers/ln1")
    assert not optim._decay_mask("final_norm")
    assert not optim._decay_mask("layers/mamba/a_log")


def test_schedule_shape():
    cfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lr5 = float(optim.schedule(cfg, jnp.asarray(5)))
    lr10 = float(optim.schedule(cfg, jnp.asarray(10)))
    lr100 = float(optim.schedule(cfg, jnp.asarray(100)))
    assert 0.4 < lr5 < 0.6  # mid-warmup
    assert lr10 > 0.9  # warmup done
    assert abs(lr100 - 0.1) < 1e-3  # cosine floor


def test_hlo_analyzer_counts_scan_loops():
    """Loop-aware FLOPs must multiply while bodies by trip count (the
    cost_analysis undercount that motivated the analyzer)."""
    from repro.launch import hlo_analysis

    d = 64

    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        return jax.lax.scan(body, h, ws)[0].sum()

    h = jax.ShapeDtypeStruct((32, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, d, d), jnp.float32)
    c = jax.jit(f).lower(h, ws).compile()
    got = hlo_analysis.analyze(c.as_text())
    assert got["flops"] == 6 * 2 * 32 * d * d
    assert 6 in got["while_trip_counts"].values()

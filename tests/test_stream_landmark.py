"""Landmark backend: registry contract, hot/cold streaming agreement,
auto-eligibility latch, checkpoint round-trip, forced 8-device mesh.

The landmark backend is the repo's first APPROXIMATE backend — its
contract is a hot-set agreement floor vs the exact engine, not
bit-equality (docs/backends.md).  Two things still ARE exact and tested
as such: the mesh form (hot solve + cold pass are deterministic, so
sharded landmark labels match single-device landmark labels bit-for-bit)
and checkpoint/restore (a restored hot/cold stream replays identically).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.kernels import ops
from repro.kernels.landmark_propagate import LandmarkConfig

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# 50 mixed insert/delete batches (paper protocol fractions shifted
# delete-heavy, 5% ground-truth seeds so propagation is actually
# exercised) — the acceptance workload
SPEC_50 = StreamSpec(total_vertices=1500, batch_size=30, seed=11,
                     class_sep=6.0, noise=0.9, frac_deleted=0.2,
                     frac_labeled=0.05)

LM_CFG = dict(num_landmarks=32, assign_k=4, hot_ttl=3)


def _mixed_batches(spec=SPEC_50):
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    assert len(batches) == 50
    assert any(len(b.del_ids) for b in batches)
    return batches


# ------------------------------------------------------------------ #
# registry contract
# ------------------------------------------------------------------ #
def test_landmark_registry_capabilities(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)  # pure auto-scan test
    spec = ops.backend_spec("landmark")
    assert spec.sharded and spec.transports == ("allgather", "halo")
    # outranks every exact backend when eligible: scale wins
    assert spec.auto_priority > max(
        ops.backend_spec(n).auto_priority
        for n in ("ref", "ell_pallas", "bsr"))
    # eligibility needs BOTH the caller-declared hot/cold machinery and
    # a row count where exact staging pressure is real
    big, small = ops.LANDMARK_AUTO_MIN_ROWS, ops.LANDMARK_AUTO_MIN_ROWS - 1
    for hw in ("cpu", "tpu"):  # unlike bsr/ell_pallas: not TPU-gated
        assert spec.auto_eligible(
            ops.ProblemInfo(num_rows=big, landmark_ready=True), hw)
    assert not spec.auto_eligible(
        ops.ProblemInfo(num_rows=big, landmark_ready=False), "cpu")
    assert not spec.auto_eligible(
        ops.ProblemInfo(num_rows=small, landmark_ready=True), "cpu")
    # plain callers (no landmark_ready) never see it in an auto scan
    assert ops.select_backend("auto", num_rows=big) == "ref"
    assert ops.select_backend("auto", num_rows=big,
                              landmark_ready=True) == "landmark"


def test_landmark_env_hint(monkeypatch):
    """REPRO_BACKEND=landmark is a fleet-wide hint like any other."""
    monkeypatch.setenv("REPRO_BACKEND", "landmark")
    assert ops.select_backend(None) == "landmark"
    assert ops.backend_candidates(None) == ("landmark",)
    # standalone run_propagation degrades to the exact ref body — the
    # hot/cold split only exists inside the engine
    from repro.core.propagate import PropagationProblem, propagate
    nbr = np.full((4, 2), -1, np.int32)
    p = PropagationProblem(
        nbr=nbr, wgt=np.zeros((4, 2), np.float32),
        wl0=np.ones(4, np.float32), wl1=np.zeros(4, np.float32),
        valid=np.ones(4, bool))
    f0 = np.full(4, 0.5, np.float32)
    fr = np.ones(4, bool)
    res = ops.run_propagation(p, f0, fr)
    want = propagate(p, f0, fr)
    np.testing.assert_array_equal(np.asarray(res.f), np.asarray(want.f))


# ------------------------------------------------------------------ #
# hot/cold streaming (single device)
# ------------------------------------------------------------------ #
def test_landmark_stream_mixed_50_batches_agreement():
    """The acceptance workload: 50 mixed insert/delete batches through
    the exact engine and the landmark engine; hot-set binary agreement
    must clear the recorded floor, and the hot/cold machinery must have
    actually engaged (cold rows served, 'landmark' in per-batch stats)."""
    g_ref = DynamicGraph(emb_dim=SPEC_50.emb_dim, k=5)
    g_lm = DynamicGraph(emb_dim=SPEC_50.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4)
    lm = StreamEngine(g_lm, delta=1e-4, backend="landmark", landmark=LM_CFG)
    backends = []
    for b in _mixed_batches():
        ref.step(b)
        backends.append(lm.step(b).backend)
    assert backends[-1] == "landmark"
    summary = lm.transport_summary()["landmark"]
    assert summary["streaming"] and summary["batches"] > 0
    assert summary["cold_rows"] > 0  # the low-rank pass served rows
    ids = np.flatnonzero(g_ref.alive & (g_ref.labels == UNLABELED))
    hot = (lm._touched_at[ids] >= 0) & (
        lm.batches - lm._touched_at[ids] <= LM_CFG["hot_ttl"])
    assert hot.sum() > 0
    pr = g_ref.f[ids] >= 0.5
    pl = g_lm.f[ids] >= 0.5
    assert (pr[hot] == pl[hot]).mean() >= 0.98  # the agreement contract


def test_landmark_auto_latch(monkeypatch):
    """backend='auto' + a landmark config: the registry picks landmark
    once the state is ready and the row count clears the threshold, and
    the decision latches — deletions shrinking the graph back under the
    threshold must not flip later batches to an exact backend."""
    monkeypatch.setattr(ops, "LANDMARK_AUTO_MIN_ROWS", 256)
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    g = DynamicGraph(emb_dim=SPEC_50.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, landmark=LM_CFG)
    backends = [eng.step(b).backend for b in _mixed_batches()]
    assert eng._lm_streaming
    # ref until activation+threshold, landmark from the latch on
    flip = backends.index("landmark")
    assert all(b == "landmark" for b in backends[flip:] if b != "none")
    # without a config, the same auto engine NEVER picks landmark
    g2 = DynamicGraph(emb_dim=SPEC_50.emb_dim, k=5)
    eng2 = StreamEngine(g2, delta=1e-4)
    assert eng2._lm is None
    spec = StreamSpec(total_vertices=600, batch_size=100, seed=3,
                      class_sep=6.0, noise=0.9)
    assert all(eng2.step(b).backend != "landmark"
               for b, _ in gaussian_mixture_stream(spec))


def test_landmark_config_validation():
    with pytest.raises(ValueError, match="invalid LandmarkConfig"):
        LandmarkConfig(num_landmarks=0)
    with pytest.raises(ValueError, match="invalid LandmarkConfig"):
        StreamEngine(DynamicGraph(emb_dim=8, k=3), landmark=dict(assign_k=0))


# ------------------------------------------------------------------ #
# durability
# ------------------------------------------------------------------ #
def test_landmark_checkpoint_roundtrip(tmp_path):
    """Stop a hot/cold stream mid-way, checkpoint, restore, continue:
    labels bit-identical to the uninterrupted stream (PR 8's contract
    extends to the landmark state — working-set clock, assignments,
    latch all round-trip)."""
    batches = _mixed_batches()
    cut = 20

    def mk():
        g = DynamicGraph(emb_dim=SPEC_50.emb_dim, k=5)
        return StreamEngine(g, delta=1e-4, backend="landmark",
                            landmark=LM_CFG)

    full, part = mk(), mk()
    for i, b in enumerate(batches):
        full.step(b)
        if i < cut:
            part.step(b)
    assert part._lm_streaming  # the cut lands after the latch
    part.checkpoint(str(tmp_path))
    rest = StreamEngine.restore(str(tmp_path))
    assert rest._lm_streaming and rest._lm.ready
    np.testing.assert_array_equal(rest._touched_at, part._touched_at)
    for b in batches[cut:]:
        rest.step(b)
    np.testing.assert_array_equal(full.graph.f, rest.graph.f)
    s_full = full.transport_summary()["landmark"]
    s_rest = rest.transport_summary()["landmark"]
    assert s_rest["batches"] == s_full["batches"]
    assert s_rest["cold_rows"] == s_full["cold_rows"]


# ------------------------------------------------------------------ #
# forced 8-device mesh (subprocess: XLA_FLAGS must precede jax init)
# ------------------------------------------------------------------ #
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(total_vertices=1500, batch_size=30, seed=11,
                      class_sep=6.0, noise=0.9, frac_deleted=0.2,
                      frac_labeled=0.05)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    assert len(batches) == 50 and any(len(b.del_ids) for b in batches)

    mesh = make_stream_mesh()
    assert mesh.devices.size == 8, mesh
    cfg = dict(num_landmarks=32, assign_k=4, hot_ttl=3)
    g_m = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_s = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_m = StreamEngine(g_m, delta=1e-4, mesh=mesh, backend="landmark",
                         landmark=cfg)
    eng_s = StreamEngine(g_s, delta=1e-4, backend="landmark", landmark=cfg)
    for b in batches:
        st_m = eng_m.step(b)
        eng_s.step(b)
    # deterministic hot solve + cold pass: the mesh form is bit-identical
    np.testing.assert_array_equal(g_m.f, g_s.f)
    assert st_m.backend == "landmark"
    s = eng_m.transport_summary()["landmark"]
    assert s["streaming"] and s["batches"] > 0 and s["cold_rows"] > 0
    print("OK landmark-8dev")
""")


def test_landmark_stream_8dev():
    """50 mixed insert/delete batches on a forced 8-device CPU mesh:
    the landmark engine streams, and its labels are bit-identical to the
    single-device landmark engine (the approximation is in the staging,
    which is mesh-independent — the solve itself stays exact)."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK landmark-8dev" in out.stdout

"""StreamEngine edge cases: idle drains, empty batches, no-op deletes,
pre-commit reads, and the non-blocking poll."""

import time

import numpy as np

from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph


def _empty_batch(dim=4):
    return BatchUpdate(ins_emb=np.zeros((0, dim), np.float32),
                       ins_labels=np.zeros(0, np.int8),
                       del_ids=np.zeros(0, np.int64))


def _seed_batch(rng, dim=4, n=20):
    emb = rng.normal(0, 1, (n, dim)).astype(np.float32)
    emb[0, 0], emb[1, 0] = 3.0, -3.0
    labels = np.full(n, UNLABELED, np.int8)
    labels[0], labels[1] = 1, 0
    return BatchUpdate(ins_emb=emb, ins_labels=labels,
                       del_ids=np.zeros(0, np.int64))


def test_drain_with_nothing_pending_returns_none():
    eng = StreamEngine(DynamicGraph(emb_dim=4, k=3))
    assert eng.drain() is None
    assert eng.poll() is None
    assert not eng.in_flight


def test_double_drain_second_returns_none():
    rng = np.random.default_rng(0)
    eng = StreamEngine(DynamicGraph(emb_dim=4, k=3), delta=1e-4)
    eng.submit(_seed_batch(rng))
    assert eng.drain() is not None
    assert eng.drain() is None
    assert eng.commits == 1


def test_empty_batch_on_empty_graph_is_noop_without_device_work():
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4)
    st = eng.step(_empty_batch())
    assert st.converged and st.iterations == 0 and st.frontier_size == 0
    # the no-op path never touches the device: no buffers, no compiles
    assert not st.recompiled and eng.recompile_count == 0
    assert not eng.bucket_keys
    assert eng.batches == eng.commits == 1


def test_empty_batch_on_live_graph_commits_unchanged_labels():
    rng = np.random.default_rng(1)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4)
    eng.step(_seed_batch(rng))
    f_before = g.f.copy()
    compiles_before = eng.recompile_count
    st = eng.step(_empty_batch())
    assert st.converged and st.iterations == 0
    assert eng.recompile_count == compiles_before  # no dispatch at all
    np.testing.assert_array_equal(g.f, f_before)
    np.testing.assert_array_equal(eng.committed_view().f, f_before)
    assert eng.committed_view().commit_id == 2


def test_delete_of_unknown_ids_is_noop_commit():
    """Deleting never-seen / already-dead ids changes nothing but still
    commits (the view advances) without a solve."""
    rng = np.random.default_rng(2)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4)
    eng.step(_seed_batch(rng))
    alive_before = g.alive.copy()
    st = eng.step(BatchUpdate(ins_emb=np.zeros((0, 4), np.float32),
                              ins_labels=np.zeros(0, np.int8),
                              del_ids=np.array([999, -5], np.int64)))
    assert st.converged and st.frontier_size == 0 and not st.recompiled
    np.testing.assert_array_equal(g.alive, alive_before)
    assert eng.commits == 2


def test_predictions_and_view_before_any_commit():
    eng = StreamEngine(DynamicGraph(emb_dim=4, k=3))
    ids, pred = eng.predictions()
    assert len(ids) == 0 and len(pred) == 0
    view = eng.committed_view()
    assert view.commit_id == 0 and view.num_nodes == 0
    p, c = view.query([0, 7, -1])
    assert (p == UNLABELED).all() and (c == 0).all()


def test_poll_commits_only_when_ready():
    rng = np.random.default_rng(3)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4)
    assert eng.poll() is None  # nothing pending
    eng.submit(_seed_batch(rng))
    assert eng.in_flight
    deadline = time.monotonic() + 30
    st = None
    while st is None and time.monotonic() < deadline:
        st = eng.poll()
    assert st is not None and st.converged
    assert not eng.in_flight and eng.commits == 1
    assert eng.poll() is None  # already committed


def test_submit_after_empty_batch_resumes_normal_path():
    """A no-op Δ_t must not wedge the pipeline: the next real batch
    stages, solves, and commits as usual."""
    rng = np.random.default_rng(4)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4)
    eng.submit(_seed_batch(rng))
    eng.submit(_empty_batch())  # drains batch 0, queues the no-op
    more = rng.normal([3, 0, 0, 0], 0.1, (10, 4)).astype(np.float32)
    prev = eng.submit(BatchUpdate(ins_emb=more,
                                  ins_labels=np.full(10, UNLABELED, np.int8),
                                  del_ids=np.zeros(0, np.int64)))
    assert prev is not None and prev.iterations == 0  # the no-op's stats
    st = eng.drain()
    assert st is not None and st.converged and st.frontier_size > 0
    assert eng.batches == eng.commits == 3
    assert eng.bucket_keys  # the real batches DID stage device buffers
    assert eng.committed_view().commit_id == 3

"""LP serving front-end: committed queries bit-identical to full DynLP
recompute, no torn reads while a batch is in flight, admission window,
backpressure, and the forced-8-virtual-device mesh arm (subprocess)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.dynlp import DynLP
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.serving.lp_service import Backpressure, LPService

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SPEC = StreamSpec(total_vertices=300, batch_size=60, seed=7,
                  class_sep=6.0, noise=0.9)


def _service(graph, **kw):
    eng = StreamEngine(graph, delta=1e-4)
    kw.setdefault("window_ops", 10_000)
    kw.setdefault("window_ms", 1e9)  # admission only via flush()/window
    kw.setdefault("max_pending_ops", 100_000)
    return LPService(eng, **kw)


def _split_mutations(svc, batch, parts=3):
    """Feed one stream batch as ``parts`` mutations (deletes ride on the
    first) — the coalesced window must equal the original batch."""
    n = len(batch.ins_emb)
    cuts = [(i * n) // parts for i in range(parts + 1)]
    tickets = [svc.mutate(ins_emb=batch.ins_emb[cuts[0]:cuts[1]],
                          ins_labels=batch.ins_labels[cuts[0]:cuts[1]],
                          del_ids=batch.del_ids)]
    for a, b in zip(cuts[1:], cuts[2:]):
        tickets.append(svc.mutate(ins_emb=batch.ins_emb[a:b],
                                  ins_labels=batch.ins_labels[a:b]))
    return tickets


def test_committed_queries_match_full_dynlp_recompute():
    """After every commit, the served labels are bit-identical to a full
    DynLP recompute over the same coalesced batch sequence."""
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(g)
    g_ref = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    dyn = DynLP(g_ref, delta=1e-4)
    for batch, _ in gaussian_mixture_stream(SPEC):
        tickets = _split_mutations(svc, batch)
        admitted = svc.flush()
        assert len(admitted.ins_emb) == len(batch.ins_emb)
        np.testing.assert_array_equal(admitted.del_ids, batch.del_ids)
        st = svc.sync()
        assert st is not None and st.converged
        assert all(t.committed and t.latency_ms >= 0 for t in tickets)
        dyn.step(batch)

        view = svc.committed_view()
        np.testing.assert_array_equal(view.f, g_ref.f)
        np.testing.assert_array_equal(view.alive, g_ref.alive)
        # query() answers derive from the same committed state
        ids = np.flatnonzero(g_ref.alive)
        res = svc.query(ids)
        seeded = g_ref.labels[ids] != UNLABELED
        want_pred = np.where(seeded, g_ref.labels[ids],
                             (g_ref.f[ids] >= 0.5).astype(np.int8))
        want_conf = np.where(seeded, 1.0,
                             np.maximum(g_ref.f[ids], 1 - g_ref.f[ids]))
        np.testing.assert_array_equal(res.pred, want_pred)
        np.testing.assert_array_equal(res.confidence,
                                      want_conf.astype(np.float32))
        assert res.commit_id == svc.engine.commits


def test_inflight_queries_serve_previous_commit_no_torn_reads():
    """Between admission and commit the host graph is already mutated
    (new vertices appended, supernode inits written) — queries must keep
    answering from the previous committed snapshot."""
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(g)
    prev_f = g.f.copy()
    prev_alive = g.alive.copy()
    for batch, _ in gaussian_mixture_stream(SPEC):
        base = g.num_nodes
        _split_mutations(svc, batch)
        svc.flush()  # admits: solve in flight, NOT committed
        assert svc.engine.in_flight
        view = svc.committed_view()
        np.testing.assert_array_equal(view.f, prev_f)
        np.testing.assert_array_equal(view.alive, prev_alive)
        # the live graph HAS already changed under the in-flight batch...
        assert g.num_nodes > base
        # ...but its new vertices don't exist for readers yet
        new_ids = np.arange(base, g.num_nodes)
        res = svc.query(new_ids)
        assert (res.pred == UNLABELED).all()
        assert (res.confidence == 0).all()
        svc.sync()
        prev_f = g.f.copy()
        prev_alive = g.alive.copy()
    assert svc.stats().queries_while_inflight > 0


def test_pipelined_windows_match_sync_per_batch():
    """Back-to-back window admissions (submit overlapping the previous
    solve, commits harvested by poll) land on the same labels as the
    one-batch-at-a-time synchronous service."""
    g_p = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    piped = _service(g_p, window_ops=SPEC.batch_size)
    g_s = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    synced = _service(g_s)
    for batch, _ in gaussian_mixture_stream(SPEC):
        # exactly one window's worth -> auto-admits inside mutate()
        piped.mutate(ins_emb=batch.ins_emb, ins_labels=batch.ins_labels,
                     del_ids=batch.del_ids)
        synced.mutate(ins_emb=batch.ins_emb, ins_labels=batch.ins_labels,
                      del_ids=batch.del_ids)
        synced.flush()
        synced.sync()
    piped.sync()
    np.testing.assert_array_equal(piped.committed_view().f,
                                  synced.committed_view().f)
    st = piped.stats()
    assert st.batches_admitted == st.batches_committed == 5
    assert st.commit_latency_ms["count"] == st.mutations


def test_admission_window_deadline_and_size():
    rng = np.random.default_rng(0)
    g = DynamicGraph(emb_dim=4, k=3)
    svc = LPService(StreamEngine(g, delta=1e-4), window_ops=8,
                    window_ms=1e9)
    # below the size bound, nothing admits
    svc.mutate(ins_emb=rng.normal(0, 1, (3, 4)).astype(np.float32),
               ins_labels=np.array([0, 1, UNLABELED], np.int8))
    assert svc.stats().batches_admitted == 0
    assert svc.stats().pending_ops == 3
    # crossing it admits immediately
    svc.mutate(ins_emb=rng.normal(0, 1, (5, 4)).astype(np.float32))
    assert svc.stats().batches_admitted == 1
    svc.sync()
    # a zero deadline admits on the next pump even for a single op
    svc.window_ms = 0.0
    svc.mutate(del_ids=np.array([0], np.int64))
    svc.pump()
    assert svc.stats().batches_admitted == 2
    svc.sync()
    assert svc.stats().pending_ops == 0


def test_backpressure_reject_and_block(monkeypatch):
    rng = np.random.default_rng(1)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4)
    svc = LPService(eng, window_ops=4, window_ms=1e9, max_pending_ops=8,
                    reject_on_overload=True)
    # simulate a busy device: poll never commits, so admitted ops pin the
    # queue until an explicit drain
    monkeypatch.setattr(eng, "poll", lambda: None)
    svc.mutate(ins_emb=rng.normal(0, 1, (4, 4)).astype(np.float32),
               ins_labels=np.array([0, 1, UNLABELED, UNLABELED], np.int8))
    assert svc.stats().batches_admitted == 1  # window filled -> in flight
    svc.mutate(ins_emb=rng.normal(0, 1, (3, 4)).astype(np.float32))
    with pytest.raises(Backpressure):
        svc.mutate(ins_emb=rng.normal(0, 1, (2, 4)).astype(np.float32))
    assert svc.stats().rejected == 1
    # blocking mode sheds the same backlog by draining instead
    svc.reject_on_overload = False
    t = svc.mutate(ins_emb=rng.normal(0, 1, (2, 4)).astype(np.float32))
    assert svc.stats().pending_ops <= 8
    svc.sync()
    assert t.committed
    # a single oversized mutation can never fit -> always rejected (and
    # counted, even in blocking mode)
    with pytest.raises(Backpressure):
        svc.mutate(ins_emb=rng.normal(0, 1, (9, 4)).astype(np.float32))
    assert svc.stats().rejected == 2


def test_query_before_any_commit_and_validation():
    g = DynamicGraph(emb_dim=4, k=3)
    svc = _service(g)
    res = svc.query([0, 5, -3])
    assert (res.pred == UNLABELED).all()
    assert (res.confidence == 0).all()
    assert res.commit_id == 0
    assert svc.committed_view().num_nodes == 0
    with pytest.raises(ValueError, match="empty mutation"):
        svc.mutate()
    with pytest.raises(ValueError, match="ins_labels"):
        svc.mutate(ins_emb=np.zeros((2, 4), np.float32),
                   ins_labels=np.zeros(3, np.int8))


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.dynlp import DynLP
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import UNLABELED, DynamicGraph
    from repro.launch.mesh import make_stream_mesh
    from repro.serving.lp_service import LPService

    mesh = make_stream_mesh()
    assert mesh.devices.size == 8, mesh
    spec = StreamSpec(total_vertices=600, batch_size=60, seed=11,
                      class_sep=6.0, noise=0.9, frac_deleted=0.15,
                      frac_unlabeled=0.84)

    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    svc = LPService(StreamEngine(g, delta=1e-4, mesh=mesh),
                    window_ops=10_000, window_ms=1e9,
                    max_pending_ops=100_000)
    g_ref = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    dyn = DynLP(g_ref, delta=1e-4)

    prev_f = g.f.copy()
    for batch, _ in gaussian_mixture_stream(spec):
        svc.mutate(ins_emb=batch.ins_emb, ins_labels=batch.ins_labels,
                   del_ids=batch.del_ids)
        svc.flush()
        # in-flight on the mesh: readers still see the previous commit
        assert svc.engine.in_flight
        np.testing.assert_array_equal(svc.committed_view().f, prev_f)
        svc.sync()
        dyn.step(batch)
        # committed labels bit-identical to the full DynLP recompute,
        # row-sharded over the 8-device mesh
        np.testing.assert_array_equal(svc.committed_view().f, g_ref.f)
        prev_f = g.f.copy()
    st = svc.stats()
    assert st.recompiles <= st.bucket_rungs, (st.recompiles, st.bucket_rungs)
    assert svc.engine.plan_builds == st.bucket_rungs
    print("OK lp-service-8dev", st.batches_committed, "commits",
          st.recompiles, "recompiles")
""")


def test_lp_service_sharded_bit_identical_8dev():
    """Service on a forced 8-virtual-device mesh: committed queries stay
    bit-identical to the single-device DynLP recompute, in-flight reads
    still serve the previous commit."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK lp-service-8dev" in out.stdout


def test_service_stats_counts():
    g = DynamicGraph(emb_dim=SPEC.emb_dim, k=5)
    svc = _service(g)
    for batch, _ in gaussian_mixture_stream(SPEC):
        svc.mutate(ins_emb=batch.ins_emb, ins_labels=batch.ins_labels,
                   del_ids=batch.del_ids)
        svc.flush()
        svc.query(np.arange(4))
        svc.sync()
    st = svc.stats()
    assert st.mutations == 5 and st.batches_committed == 5
    assert st.queries == 5 and st.query_nodes == 20
    assert st.queries_while_inflight == 5
    assert st.pending_ops == 0 and st.rejected == 0
    assert st.commit_latency_ms["count"] == 5
    assert st.commit_latency_ms["p50"] <= st.commit_latency_ms["max"]
    assert st.recompiles <= st.bucket_rungs

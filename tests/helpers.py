"""Shared test utilities: random graph builders and a union-find oracle."""

from __future__ import annotations

import numpy as np

from repro.core.propagate import PropagationProblem
from repro.graph.structures import coo_to_csr, csr_to_ell_fast


def random_undirected_coo(rng, n: int, avg_deg: float):
    """Random symmetric weighted graph as COO (both directions)."""
    m = int(n * avg_deg / 2)
    if m == 0 or n < 2:
        z = np.zeros(0, np.int64)
        return z, z, np.zeros(0, np.float32)
    s = rng.integers(0, n, size=m)
    d = rng.integers(0, n, size=m)
    keep = s != d
    s, d = s[keep], d[keep]
    # dedupe on UNORDERED pairs so weights stay symmetric
    lo, hi = np.minimum(s, d), np.maximum(s, d)
    key = lo * np.int64(n) + hi
    _, first = np.unique(key, return_index=True)
    lo, hi = lo[first], hi[first]
    w = rng.uniform(0.1, 1.0, size=len(lo)).astype(np.float32)
    src = np.concatenate([lo, hi]).astype(np.int64)
    dst = np.concatenate([hi, lo]).astype(np.int64)
    wgt = np.concatenate([w, w])
    return src, dst, wgt


def union_find_components(n: int, src, dst) -> np.ndarray:
    """Oracle CC labels: min vertex id per component."""
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in zip(src, dst):
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)
    return np.array([find(i) for i in range(n)])


def random_problem(rng, n_unl: int, n_lab: int, avg_deg: float = 4.0):
    """Random PropagationProblem with labeled supernode weights."""
    import jax.numpy as jnp

    src, dst, wgt = random_undirected_coo(rng, n_unl, avg_deg)
    csr = coo_to_csr(n_unl, src, dst, wgt)
    ell = csr_to_ell_fast(csr, max_degree=max(1, csr.num_edges and None or 1))
    ell = csr_to_ell_fast(csr)
    wl0 = (rng.uniform(0, 1, n_unl) * (rng.uniform(0, 1, n_unl) < 0.3)).astype(
        np.float32
    )
    wl1 = (rng.uniform(0, 1, n_unl) * (rng.uniform(0, 1, n_unl) < 0.3)).astype(
        np.float32
    )
    # ensure at least one anchor so the harmonic system is well-posed
    wl0[0] = 1.0
    wl1[n_unl - 1 if n_unl > 1 else 0] = 1.0
    return PropagationProblem(
        nbr=ell.nbr,
        wgt=ell.wgt,
        wl0=jnp.asarray(wl0),
        wl1=jnp.asarray(wl1),
        valid=jnp.ones(n_unl, bool),
    )

"""SSD / mLSTM chunked cores vs sequential references (property-swept)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssd import (
    mlstm_chunked,
    mlstm_decode_step,
    ssd_chunked,
    ssd_decode_step,
)


def _ssd_seq_ref(la, q, k, v):
    b, s, h = la.shape
    n, p = q.shape[-1], v.shape[-1]
    st_ = np.zeros((b, h, n, p), np.float64)
    ys = []
    for t in range(s):
        a = np.exp(la[:, t].astype(np.float64))
        st_ = st_ * a[:, :, None, None] + np.einsum("bn,bhp->bhnp",
                                                    k[:, t], v[:, t])
        ys.append(np.einsum("bn,bhnp->bhp", q[:, t], st_))
    return np.stack(ys, 1), st_


@pytest.mark.slow
@given(st.integers(0, 10_000), st.sampled_from([2, 4, 8, 16]))
@settings(max_examples=12, deadline=None)
def test_ssd_chunked_matches_sequential(seed, chunk):
    rng = np.random.default_rng(seed)
    b, s, h, n, p = 2, 16, 2, 4, 4
    la = -np.abs(rng.normal(0.3, 0.3, (b, s, h))).astype(np.float32)
    q = rng.normal(0, 1, (b, s, n)).astype(np.float32)
    k = rng.normal(0, 1, (b, s, n)).astype(np.float32)
    v = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
    y, s_fin = ssd_chunked(jnp.asarray(la), jnp.asarray(q), jnp.asarray(k),
                           jnp.asarray(v), chunk=chunk)
    y_ref, s_ref = _ssd_seq_ref(la, q, k, v)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_fin), s_ref, rtol=3e-4, atol=3e-4)


def test_ssd_state_carry_across_calls():
    """Two chunked calls with carried state == one long call."""
    rng = np.random.default_rng(1)
    b, s, h, n, p = 1, 32, 2, 4, 4
    la = -np.abs(rng.normal(0.2, 0.2, (b, s, h))).astype(np.float32)
    q = rng.normal(0, 1, (b, s, n)).astype(np.float32)
    k = rng.normal(0, 1, (b, s, n)).astype(np.float32)
    v = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
    y_full, s_full = ssd_chunked(jnp.asarray(la), jnp.asarray(q),
                                 jnp.asarray(k), jnp.asarray(v), chunk=8)
    half = s // 2
    y1, s1 = ssd_chunked(jnp.asarray(la[:, :half]), jnp.asarray(q[:, :half]),
                         jnp.asarray(k[:, :half]), jnp.asarray(v[:, :half]),
                         chunk=8)
    y2, s2 = ssd_chunked(jnp.asarray(la[:, half:]), jnp.asarray(q[:, half:]),
                         jnp.asarray(k[:, half:]), jnp.asarray(v[:, half:]),
                         s0=s1, chunk=8)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@given(st.integers(0, 10_000), st.sampled_from([4, 8, 16]))
@settings(max_examples=10, deadline=None)
def test_mlstm_chunked_matches_decode_chain(seed, chunk):
    """Chunked parallel form == step-by-step stabilized recurrence."""
    rng = np.random.default_rng(seed)
    b, s, h, n, p = 2, 16, 2, 4, 4
    lf = np.log(1 / (1 + np.exp(-rng.normal(2, 1, (b, s, h))))).astype(np.float32)
    li = rng.normal(-0.5, 1.0, (b, s, h)).astype(np.float32)
    q = rng.normal(0, 1, (b, s, h, n)).astype(np.float32)
    k = rng.normal(0, 1, (b, s, h, n)).astype(np.float32)
    v = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
    y_chunk, _ = mlstm_chunked(jnp.asarray(lf), jnp.asarray(li),
                               jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                               chunk=chunk)
    state = (jnp.zeros((b, h, n, p)), jnp.zeros((b, h, n)),
             jnp.full((b, h), -1e30))
    ys = []
    for t in range(s):
        y_t, state = mlstm_decode_step(
            jnp.asarray(lf[:, t]), jnp.asarray(li[:, t]), jnp.asarray(q[:, t]),
            jnp.asarray(k[:, t]), jnp.asarray(v[:, t]), state)
        ys.append(np.asarray(y_t))
    np.testing.assert_allclose(np.asarray(y_chunk), np.stack(ys, 1),
                               rtol=3e-3, atol=3e-3)


def test_ssd_decode_matches_chunked_tail():
    rng = np.random.default_rng(3)
    b, h, n, p = 2, 2, 4, 4
    la = -np.abs(rng.normal(0.3, 0.2, (b, 1, h))).astype(np.float32)
    q = rng.normal(0, 1, (b, 1, n)).astype(np.float32)
    k = rng.normal(0, 1, (b, 1, n)).astype(np.float32)
    v = rng.normal(0, 1, (b, 1, h, p)).astype(np.float32)
    s0 = rng.normal(0, 1, (b, h, n, p)).astype(np.float32)
    y_c, s_c = ssd_chunked(jnp.asarray(la), jnp.asarray(q), jnp.asarray(k),
                           jnp.asarray(v), s0=jnp.asarray(s0), chunk=1)
    y_d, s_d = ssd_decode_step(jnp.asarray(la[:, 0]), jnp.asarray(q[:, 0]),
                               jnp.asarray(k[:, 0]), jnp.asarray(v[:, 0]),
                               jnp.asarray(s0))
    np.testing.assert_allclose(np.asarray(y_c[:, 0]), np.asarray(y_d),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_d),
                               rtol=1e-5, atol=1e-5)

"""``DynLabelPropagation``: the sklearn-style estimator front door.

Duck-typed protocol checks (params round-trip, re-instantiation from
``get_params`` — what sklearn's ``clone`` does), fitted-attribute
conventions, transductive/inductive accuracy on separable gaussians,
and the streaming verbs (``partial_fit`` / ``forget`` / ``relabel``).
No sklearn import anywhere — the estimator must work standalone.
"""

import numpy as np
import pytest

from repro.serving.estimator import UNLABELED, DynLabelPropagation


def _blobs(rng, n, d=8, sep=2.5, noise=0.7):
    X = np.concatenate([
        rng.normal(-sep, noise, (n // 2, d)),
        rng.normal(+sep, noise, (n - n // 2, d)),
    ]).astype(np.float32)
    truth = np.repeat([0, 1], [n // 2, n - n // 2]).astype(np.int8)
    return X, truth


def _seeded(truth, n_seeds, rng):
    y = np.full(len(truth), UNLABELED, np.int8)
    for c in (0, 1):
        ids = rng.choice(np.flatnonzero(truth == c), n_seeds, replace=False)
        y[ids] = c
    return y


def test_params_roundtrip_and_clone():
    clf = DynLabelPropagation(k=7, delta=1e-3, ingest="host")
    p = clf.get_params()
    assert p["k"] == 7 and p["delta"] == 1e-3 and p["ingest"] == "host"
    clone = DynLabelPropagation(**p)  # what sklearn.clone does
    assert clone.get_params() == p
    clone.set_params(k=3)
    assert clone.k == 3 and clf.k == 7
    with pytest.raises(ValueError, match="invalid parameter"):
        clone.set_params(nope=1)


def test_fit_transductive_accuracy():
    rng = np.random.default_rng(0)
    X, truth = _blobs(rng, 240)
    y = _seeded(truth, 4, rng)
    clf = DynLabelPropagation(k=5).fit(X, y)
    assert clf.n_features_in_ == 8
    assert np.array_equal(clf.classes_, [0, 1])
    assert len(clf.transduction_) == len(X)
    assert (clf.transduction_ != UNLABELED).all()
    assert (clf.transduction_ == truth).mean() > 0.95
    # seeds are reproduced exactly
    seeds = y != UNLABELED
    np.testing.assert_array_equal(clf.transduction_[seeds], y[seeds])


def test_predict_inductive_without_growing_the_graph():
    rng = np.random.default_rng(1)
    X, truth = _blobs(rng, 200)
    clf = DynLabelPropagation(k=5).fit(X, _seeded(truth, 4, rng))
    n0 = clf.graph_.num_alive
    Xq, tq = _blobs(rng, 40)
    pred = clf.predict(Xq)
    assert clf.graph_.num_alive == n0  # probe points removed again
    assert (pred == tq).mean() > 0.9
    assert clf.score(Xq, tq) > 0.9


def test_partial_fit_streams_and_first_call_fits():
    rng = np.random.default_rng(2)
    X, truth = _blobs(rng, 160)
    y = _seeded(truth, 4, rng)
    clf = DynLabelPropagation(k=5)
    clf.partial_fit(X[:80], y[:80])  # first call behaves like fit
    assert clf.graph_.num_alive == 80
    clf.partial_fit(X[80:], y[80:])
    assert clf.graph_.num_alive == 160
    assert (clf.transduction_ == truth).mean() > 0.95


def test_forget_and_relabel():
    rng = np.random.default_rng(3)
    X, truth = _blobs(rng, 120)
    y = _seeded(truth, 3, rng)
    clf = DynLabelPropagation(k=4).fit(X, y)
    clf.forget(np.arange(5))
    assert clf.graph_.num_alive == 115
    assert clf.transduction_[0] == UNLABELED  # dead ids read UNLABELED
    sid = int(np.flatnonzero(y == 0)[-1])
    clf.relabel([sid], [1])
    assert clf.transduction_[sid] == 1  # seed flipped, committed


def test_host_and_device_ingest_bit_identical():
    rng = np.random.default_rng(4)
    X, truth = _blobs(rng, 150)
    y = _seeded(truth, 4, rng)
    a = DynLabelPropagation(k=5, ingest="device").fit(X, y)
    b = DynLabelPropagation(k=5, ingest="host").fit(X, y)
    np.testing.assert_array_equal(a.transduction_, b.transduction_)
    np.testing.assert_array_equal(a.graph_.f, b.graph_.f)


def test_input_validation():
    clf = DynLabelPropagation()
    with pytest.raises(ValueError, match="2-D"):
        clf.fit(np.zeros(8, np.float32))

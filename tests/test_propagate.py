"""Propagation engine invariants (the paper's §5 theory, as tests)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.propagate import (
    PropagationProblem,
    harmonic_residual,
    lp_update,
    propagate,
    propagate_full,
)
from repro.core.stlp import harmonic_solve
from repro.graph.structures import PAD

from helpers import random_problem


@pytest.mark.slow
@given(st.integers(0, 10_000), st.integers(2, 50))
def test_update_equals_weighted_average(seed, n):
    """§5 equivalence: T(F)_u = Σ α_uv F_v regardless of the current F_u."""
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n, 2)
    f = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    got = np.asarray(lp_update(p, f))

    nbr, wgt = np.asarray(p.nbr), np.asarray(p.wgt)
    wl0, wl1 = np.asarray(p.wl0), np.asarray(p.wl1)
    fn = np.asarray(f)
    for u in range(n):
        mask = nbr[u] != PAD
        wall = wgt[u][mask].sum() + wl0[u] + wl1[u]
        if wall <= 0:
            assert got[u] == fn[u]
            continue
        # weighted average: labeled class-0 contributes 0, class-1 contributes 1
        avg = (wgt[u][mask] * fn[nbr[u][mask]]).sum() + wl0[u] * 0.0 + wl1[u] * 1.0
        np.testing.assert_allclose(got[u], avg / wall, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
@given(st.integers(0, 10_000), st.integers(2, 40))
def test_maximum_principle(seed, n):
    """Harmonic updates keep labels inside [0, 1] (convexity of averaging)."""
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n, 2)
    f = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    for _ in range(3):
        f = lp_update(p, f)
        assert np.all(np.asarray(f) >= -1e-6)
        assert np.all(np.asarray(f) <= 1 + 1e-6)


@pytest.mark.slow
@given(st.integers(0, 10_000), st.integers(3, 30))
def test_converges_to_harmonic_solution(seed, n):
    """Corollary 1: iteration reaches the closed-form −L_UU⁻¹ L_UL F_L."""
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n, 2)
    res = propagate_full(p, jnp.full((n,), 0.5), delta=1e-7, max_iters=50_000)
    f_exact = np.asarray(harmonic_solve(p))
    np.testing.assert_allclose(np.asarray(res.f), f_exact, atol=5e-4)
    assert float(harmonic_residual(p, res.f)) < 1e-5


@pytest.mark.slow
@given(st.integers(0, 10_000), st.integers(3, 30))
def test_frontier_matches_full_propagation(seed, n):
    """Frontier-restricted DynLP step reaches the same fixpoint as dense ITLP
    when seeded with a full frontier."""
    rng = np.random.default_rng(seed)
    p = random_problem(rng, n, 2)
    f0 = jnp.full((n,), 0.5)
    res_full = propagate_full(p, f0, delta=1e-6, max_iters=50_000)
    res_front = propagate(p, f0, jnp.ones(n, bool), delta=1e-6, max_iters=50_000)
    np.testing.assert_allclose(
        np.asarray(res_front.f), np.asarray(res_full.f), atol=1e-4
    )


def test_frontier_localized_change_stays_local():
    """A chain a-b-c-d-e with a change at one end: with a large δ the frontier
    never reaches the far end, and far labels are untouched (the paper's
    'influence decays with propagation' premise)."""
    n = 6
    nbr = np.full((n, 2), PAD, np.int32)
    wgt = np.zeros((n, 2), np.float32)
    for i in range(n - 1):
        nbr[i, 1] = i + 1
        nbr[i + 1, 0] = i
        wgt[i, 1] = wgt[i + 1, 0] = 1.0
    wl0 = np.zeros(n, np.float32)
    wl1 = np.zeros(n, np.float32)
    wl0[0] = 10.0  # strong class-0 anchor at the head
    p = PropagationProblem(
        nbr=jnp.asarray(nbr), wgt=jnp.asarray(wgt),
        wl0=jnp.asarray(wl0), wl1=jnp.asarray(wl1),
        valid=jnp.ones(n, bool),
    )
    f0 = jnp.full((n,), 0.9)
    frontier = jnp.zeros(n, bool).at[0].set(True)
    res = propagate(p, f0, frontier, delta=0.2, max_iters=100)
    f = np.asarray(res.f)
    assert f[0] < 0.2  # head pulled hard toward 0
    assert f[-1] == 0.9  # tail untouched: frontier died before reaching it
    assert bool(res.converged)


def test_padding_rows_inert():
    rng = np.random.default_rng(0)
    p = random_problem(rng, 8, 2)
    padded = PropagationProblem(
        nbr=jnp.concatenate([p.nbr, jnp.full((4, p.nbr.shape[1]), PAD, jnp.int32)]),
        wgt=jnp.concatenate([p.wgt, jnp.zeros((4, p.wgt.shape[1]))]),
        wl0=jnp.concatenate([p.wl0, jnp.zeros(4)]),
        wl1=jnp.concatenate([p.wl1, jnp.zeros(4)]),
        valid=jnp.concatenate([p.valid, jnp.zeros(4, bool)]),
    )
    f0 = jnp.full((12,), 0.5)
    res = propagate(padded, f0, jnp.ones(12, bool), delta=1e-6, max_iters=50_000)
    ref = propagate(p, f0[:8], jnp.ones(8, bool), delta=1e-6, max_iters=50_000)
    np.testing.assert_allclose(np.asarray(res.f[:8]), np.asarray(ref.f), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(res.f[8:]), 0.5)

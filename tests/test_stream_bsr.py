"""ELL→BSR streaming backend: registry capabilities, allclose-vs-ref
parity on insert/delete streams, slot-budget overflow fallback, ladder-
bounded compile accounting, and the sharded bit-equality contract.

All Pallas work runs in interpret mode on CPU (the dispatch layer's
off-TPU default); the 8-device cross-transport check forces a virtual
mesh in a subprocess like tests/test_stream_sharded.py.
"""

import logging
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.snapshot import ladder_size
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.kernels import ops

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# bsr sums edges in tile order, so residuals near the δ threshold can
# lag ref by O(δ); the registry contract is allclose, not bit-equality.
BSR_ATOL = 2e-3


def _empty_batch(dim):
    return BatchUpdate(ins_emb=np.zeros((0, dim), np.float32),
                       ins_labels=np.zeros(0, np.int8),
                       del_ids=np.zeros(0, np.int64))


# ------------------------------------------------------------------ #
# registry
# ------------------------------------------------------------------ #
def test_registry_declares_capabilities():
    """Every backend is a registry entry with declared capabilities —
    the dispatch layer has no hard-coded backend names left."""
    assert ops.backend_names() == ("ref", "ell_pallas", "bsr", "landmark")
    for name in ops.backend_names():
        spec = ops.backend_spec(name)
        assert spec.sharded  # all four have a core.distributed body
        assert spec.transports == ("allgather", "halo")
        assert callable(spec.auto_eligible) and callable(spec.run)
    with pytest.raises(ValueError, match="unknown backend"):
        ops.backend_spec("csr")
    with pytest.raises(ValueError, match="unknown backend"):
        ops.select_backend("csr")


def test_registry_auto_eligibility_rules(monkeypatch):
    """auto never picks bsr without a measured fill factor, and the fill
    threshold gates it even on (simulated) TPU."""
    monkeypatch.delenv("REPRO_BACKEND", raising=False)  # true auto
    info_nofill = ops.ProblemInfo(num_rows=4096)
    info_dense = ops.ProblemInfo(num_rows=4096, block_fill=0.9)
    info_sparse = ops.ProblemInfo(num_rows=4096, block_fill=0.01)
    bsr = ops.backend_spec("bsr")
    assert not bsr.auto_eligible(info_nofill, "tpu")
    assert bsr.auto_eligible(info_dense, "tpu")
    assert not bsr.auto_eligible(info_sparse, "tpu")
    assert not bsr.auto_eligible(info_dense, "cpu")
    # priority order: bsr outranks ell_pallas outranks ref
    prios = [ops.backend_spec(n).auto_priority
             for n in ("bsr", "ell_pallas", "ref")]
    assert prios == sorted(prios, reverse=True)
    # off-TPU auto stays on ref regardless of fill
    assert ops.select_backend("auto", num_rows=4096, block_fill=0.9) == "ref"


def test_bsr_block_size_is_per_hardware_registry_property():
    """Block edge comes from the bsr BackendSpec per hardware — MXU-sized
    on TPU, interpret-friendly elsewhere — and the auto fill threshold
    re-derives from it (break-even density ~ 2/edge)."""
    assert ops.bsr_block_size("tpu") == 128
    assert ops.bsr_block_size("cpu") == 8
    assert ops.bsr_block_size("gpu") == 8
    # the process default resolves through jax.default_backend()
    import jax
    assert ops.bsr_block_size() == ops.bsr_block_size(jax.default_backend())
    assert ops.bsr_auto_fill_min("cpu") == 2.0 / 8
    assert ops.bsr_auto_fill_min("tpu") == 2.0 / 128
    # eligibility tracks the per-hardware threshold: a fill that is too
    # sparse for 8-wide blocks clears the 128-wide TPU break-even
    bsr = ops.backend_spec("bsr")
    info = ops.ProblemInfo(num_rows=4096, block_fill=0.05)
    assert bsr.auto_eligible(info, "tpu")
    assert 0.05 < ops.bsr_auto_fill_min("cpu")


# ------------------------------------------------------------------ #
# stream parity
# ------------------------------------------------------------------ #
def test_bsr_stream_matches_ref_insert_delete():
    """Mixed insert/delete stream through backend='bsr' (component
    reorder + device-side tile fill, interpret mode) stays allclose to
    the ref engine; every solved batch reports backend='bsr'."""
    spec = StreamSpec(total_vertices=300, batch_size=60, seed=9,
                      class_sep=6.0, noise=0.9, frac_deleted=0.15,
                      frac_unlabeled=0.84)
    g_b = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_r = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng_b = StreamEngine(g_b, delta=1e-4, backend="bsr")
    eng_r = StreamEngine(g_r, delta=1e-4, backend="ref")
    stats = []
    for batch, _ in gaussian_mixture_stream(spec):
        stats.append(eng_b.step(batch))
        eng_r.step(batch)
    assert {s.backend for s in stats} == {"bsr"}
    assert eng_b.bsr_batches == len(stats)
    assert eng_b.backend_overflows == 0
    summary = eng_b.transport_summary()
    assert set(summary["rung_backends"].values()) == {"bsr"}
    assert all(b >= 1 for b in summary["slot_budgets"].values())
    np.testing.assert_allclose(g_b.f, g_r.f, atol=BSR_ATOL)


def test_bsr_empty_frontier_noop_commits():
    """A no-op Δ_t on a bsr engine stages nothing — no reorder, no tile
    fill — but still commits, and the next real batch resumes."""
    rng = np.random.default_rng(2)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-4, backend="bsr")
    emb = rng.normal(0, 1, (24, 4)).astype(np.float32)
    emb[0, 0], emb[1, 0] = 3.0, -3.0
    labels = np.full(24, UNLABELED, np.int8)
    labels[0], labels[1] = 1, 0
    eng.step(BatchUpdate(ins_emb=emb, ins_labels=labels,
                         del_ids=np.zeros(0, np.int64)))
    st = eng.step(_empty_batch(4))
    assert st.converged and st.backend == "none" and st.transport == "none"
    st = eng.step(BatchUpdate(
        ins_emb=rng.normal([3, 0, 0, 0], 0.1, (8, 4)).astype(np.float32),
        ins_labels=np.full(8, UNLABELED, np.int8),
        del_ids=np.zeros(0, np.int64)))
    assert st.converged and st.backend == "bsr"
    assert eng.commits == 3


def test_bsr_slot_budget_overflow_falls_back_with_warning(caplog):
    """A Δ_t whose tile-slot requirement exceeds the rung's compiled
    budget runs on ell_pallas instead (warned once per rung), and the
    labels still track ref — mirroring the halo-overflow contract."""
    spec = StreamSpec(total_vertices=240, batch_size=60, seed=5,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_r = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, backend="bsr", block_rows=64)
    ref = StreamEngine(g_r, delta=1e-4, backend="ref")
    stats = []
    with caplog.at_level(logging.WARNING, logger="repro.core.stream"):
        for i, (batch, _) in enumerate(gaussian_mixture_stream(spec)):
            stats.append(eng.step(batch))
            ref.step(batch)
            if i == 0:
                # sabotage every known rung budget: later batches in the
                # rung must overflow and fall back
                for key in list(eng._slot_budgets):
                    eng._slot_budgets[key] = 1
    fallbacks = [s for s in stats if s.backend == "ell_pallas"]
    assert fallbacks, "sabotaged slot budget never overflowed"
    assert eng.backend_overflows == len(fallbacks)
    warned = [r for r in caplog.records if "tile slots" in r.getMessage()]
    assert warned and len(warned) <= len(eng.bucket_keys)
    np.testing.assert_allclose(g.f, g_r.f, atol=BSR_ATOL)


def test_env_hint_pinned_at_construction(monkeypatch):
    """A mid-stream REPRO_BACKEND flip must not change (or crash) an
    already-built engine: the hint is read once, at construction, where
    the row padding and candidate set it implies are decided.  A fresh
    engine built under the flipped hint picks it up."""
    spec = StreamSpec(total_vertices=160, batch_size=40, seed=3,
                      class_sep=6.0, noise=0.9)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-3)
    eng.step(batches[0])
    monkeypatch.setenv("REPRO_BACKEND", "bsr")
    for b in batches[1:]:  # crosses a rung boundary under the flipped env
        st = eng.step(b)
        if st.backend != "none":
            assert st.backend == "ref", st.backend  # pinned, not re-read
    g2 = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng2 = StreamEngine(g2, delta=1e-3)  # built under the hint
    assert eng2.step(batches[0]).backend == "bsr"


@given(st.integers(0, 1_000))
@settings(max_examples=3, deadline=None)
def test_bsr_compile_cache_stays_ladder_bounded(seed):
    """Property arm: for ANY random stream, backend='bsr' keeps the
    registry's compile accounting within the bucket ladder (+1 per
    recorded slot-budget overflow — the ell_pallas twin)."""
    rng = np.random.default_rng(seed)
    spec = StreamSpec(total_vertices=int(rng.integers(150, 400)),
                      batch_size=int(rng.integers(40, 90)),
                      seed=int(rng.integers(0, 100)),
                      class_sep=6.0, noise=0.9,
                      frac_deleted=float(rng.uniform(0, 0.2)),
                      frac_unlabeled=0.8)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-3, backend="bsr")
    cache0 = ops.compile_cache_size()
    for batch, _ in gaussian_mixture_stream(spec):
        eng.step(batch)
    grown = ops.compile_cache_size() - cache0
    max_k = max(k for _, k in eng.bucket_keys)
    bound = ladder_size(spec.total_vertices + 256, max_k)
    assert grown <= bound + eng.backend_overflows, (
        grown, bound, eng.backend_overflows, eng.bucket_keys)
    assert eng.recompile_count <= len(eng.bucket_keys) + eng.backend_overflows


# ------------------------------------------------------------------ #
# sharded: the acceptance contract
# ------------------------------------------------------------------ #
SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys
    sys.path.insert(0, {src!r})
    import numpy as np
    from repro.core.stream import StreamEngine
    from repro.data.synth import StreamSpec, gaussian_mixture_stream
    from repro.graph.dynamic import DynamicGraph
    from repro.launch.mesh import make_stream_mesh

    spec = StreamSpec(total_vertices=400, batch_size=50, seed=11,
                      class_sep=6.0, noise=0.9, frac_deleted=0.15,
                      frac_unlabeled=0.84)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    mesh = make_stream_mesh()
    assert mesh.devices.size == 8

    g_ref = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    ref = StreamEngine(g_ref, delta=1e-4)
    engines = {{}}
    for tr in ("allgather", "halo"):
        g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
        engines[tr] = (g, StreamEngine(g, delta=1e-4, backend="bsr",
                                       mesh=mesh, transport=tr))
    for b in batches:
        ref.step(b)
        for g, e in engines.values():
            e.step(b)
    ga, ea = engines["allgather"]
    gh, eh = engines["halo"]
    # the acceptance headline: bsr rides both transports, labels
    # bit-identical across them (identical halo row layout => identical
    # tile layout => identical MXU sums) and allclose to ref
    assert np.array_equal(ga.f, gh.f), np.abs(ga.f - gh.f).max()
    assert np.abs(ga.f - g_ref.f).max() <= {atol}, (
        np.abs(ga.f - g_ref.f).max())
    # every batch solved on bsr, plans reused per rung, no overflows
    for e in (ea, eh):
        assert e.bsr_batches == len(batches), e.transport_summary()
        assert e.backend_overflows == 0
        assert e.plan_builds <= len(e.bucket_keys) + e.transport_overflows
    assert eh.halo_batches + eh.transport_overflows == len(batches)
    # sharded buckets tile evenly into both the mesh and the BSR grid
    assert all(u % (8 * 8) == 0 for u, _ in ea.bucket_keys), ea.bucket_keys
    print("OK sharded-bsr", len(ea.bucket_keys), "rungs",
          ea.plan_builds, "plans", eh.halo_batches, "halo batches")
""")


@pytest.mark.slow
def test_sharded_bsr_bit_identical_across_transports_8dev():
    """backend='bsr' through StreamEngine(mesh=..., transport=
    'halo'|'allgather') on a forced 8-device CPU mesh: labels bit-equal
    across transports, allclose to ref, plans reused per rung."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("REPRO_STREAM_TRANSPORT", None)
    env.pop("REPRO_BACKEND", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(src=SRC, atol=BSR_ATOL)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK sharded-bsr" in out.stdout

"""Compile-once streaming engine: recompile bound, DynLP parity, churn."""

import logging

import numpy as np
import pytest

from repro.core.dynlp import DynLP
from repro.core.snapshot import bucket, bucket_k, ladder_size
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph

SPEC_30 = StreamSpec(total_vertices=1800, batch_size=60, seed=5,
                     class_sep=6.0, noise=0.9)


def test_bucket_ladders_are_bounded():
    assert bucket(1) == 256 and bucket(256) == 256 and bucket(257) > 256
    # K: multiples of 8 in the dense regime, doubling past 64
    assert bucket_k(1) == 8 and bucket_k(8) == 8 and bucket_k(9) == 16
    assert bucket_k(33) == 40 and bucket_k(64) == 64
    assert bucket_k(65) == 128 and bucket_k(200) == 256
    # ladder stays small and independent of the batch count
    assert ladder_size(2000, 64) <= 80
    assert ladder_size(100_000, 512) <= 26 * 11


def test_stream_recompile_count_bounded():
    """(a) 30-batch stream: compiles ≤ bucket-ladder size, not ~30."""
    g = DynamicGraph(emb_dim=SPEC_30.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4)
    for batch, _ in gaussian_mixture_stream(SPEC_30):
        eng.step(batch)
    max_k = max(k for _, k in eng.bucket_keys)
    assert eng.batches == 30
    bound = ladder_size(SPEC_30.total_vertices + 256, max_k)
    assert eng.recompile_count <= bound
    # tighter: one compile burst per distinct shape actually seen
    assert eng.recompile_count <= len(eng.bucket_keys)
    # and the ladder itself stayed sublinear in the batch count
    assert len(eng.bucket_keys) <= eng.batches // 2


def test_stream_matches_fresh_dynlp_per_batch():
    """(b) streamed labels ≡ fresh per-batch DynLP.step results."""
    spec = StreamSpec(total_vertices=900, batch_size=90, seed=7,
                      class_sep=6.0, noise=0.9)
    g_s = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g_d = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g_s, delta=1e-4)
    dyn = DynLP(g_d, delta=1e-4)
    for i, (batch, _) in enumerate(gaussian_mixture_stream(spec)):
        s_s = eng.step(batch)
        s_d = dyn.step(batch)
        assert s_s.iterations == s_d.iterations, f"batch {i}"
        assert s_s.num_unlabeled == s_d.num_unlabeled
        np.testing.assert_allclose(g_s.f, g_d.f, atol=1e-5,
                                   err_msg=f"batch {i}")
    assert s_s.converged


def test_stream_pipelined_submit_drain_matches_step():
    """submit/drain (overlapped staging) reaches the same labels as step."""
    spec = StreamSpec(total_vertices=600, batch_size=60, seed=3,
                      class_sep=6.0, noise=0.9)
    g1 = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    g2 = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    piped = StreamEngine(g1, delta=1e-4)
    sync = StreamEngine(g2, delta=1e-4)
    stats = []
    for batch, _ in gaussian_mixture_stream(spec):
        prev = piped.submit(batch)  # drains t-1 internally
        if prev is not None:
            stats.append(prev)
        sync.step(batch)
    last = piped.drain()
    assert last is not None
    stats.append(last)
    assert len(stats) == piped.batches
    assert all(s.converged for s in stats)
    np.testing.assert_allclose(g1.f, g2.f, atol=1e-6)


def test_stream_deletes_and_inserts_roundtrip():
    """(c) deletions + inserts in the SAME Δ_t round-trip through the
    donated buffers: a hostile cluster is swapped for friendly vertices in
    one batch and the labels recover."""
    rng = np.random.default_rng(0)
    g = DynamicGraph(emb_dim=4, k=3)
    eng = StreamEngine(g, delta=1e-5)

    anchors = np.array([[1, 0, 0, 0], [-1, 0, 0, 0]], np.float32)
    cloud = rng.normal([1, 0, 0, 0], 0.1, (30, 4)).astype(np.float32)
    eng.step(BatchUpdate(
        ins_emb=np.concatenate([anchors, cloud]),
        ins_labels=np.array([1, 0] + [UNLABELED] * 30, np.int8),
        del_ids=np.zeros(0, np.int64)))
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    assert (g.f[ids] > 0.5).all()

    hostile = rng.normal([-0.6, 0, 0, 0], 0.1, (40, 4)).astype(np.float32)
    eng.step(BatchUpdate(ins_emb=hostile,
                         ins_labels=np.full(40, UNLABELED, np.int8),
                         del_ids=np.zeros(0, np.int64)))
    hostile_ids = np.arange(32, 72)
    assert g.f[hostile_ids].mean() < 0.5

    # one Δ_t: delete the hostile cluster AND insert a friendly one
    friendly = rng.normal([0.9, 0, 0, 0], 0.1, (10, 4)).astype(np.float32)
    st = eng.step(BatchUpdate(ins_emb=friendly,
                              ins_labels=np.full(10, UNLABELED, np.int8),
                              del_ids=hostile_ids))
    assert st.converged
    assert not g.alive[hostile_ids].any()
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    assert (g.f[ids] > 0.5).all()

    # same Δ_t sequence through fresh per-batch DynLP agrees
    g2 = DynamicGraph(emb_dim=4, k=3)
    dyn = DynLP(g2, delta=1e-5)
    rng2 = np.random.default_rng(0)
    anchors2 = np.array([[1, 0, 0, 0], [-1, 0, 0, 0]], np.float32)
    cloud2 = rng2.normal([1, 0, 0, 0], 0.1, (30, 4)).astype(np.float32)
    dyn.step(BatchUpdate(
        ins_emb=np.concatenate([anchors2, cloud2]),
        ins_labels=np.array([1, 0] + [UNLABELED] * 30, np.int8),
        del_ids=np.zeros(0, np.int64)))
    hostile2 = rng2.normal([-0.6, 0, 0, 0], 0.1, (40, 4)).astype(np.float32)
    dyn.step(BatchUpdate(ins_emb=hostile2,
                         ins_labels=np.full(40, UNLABELED, np.int8),
                         del_ids=np.zeros(0, np.int64)))
    friendly2 = rng2.normal([0.9, 0, 0, 0], 0.1, (10, 4)).astype(np.float32)
    dyn.step(BatchUpdate(ins_emb=friendly2,
                         ins_labels=np.full(10, UNLABELED, np.int8),
                         del_ids=hostile_ids))
    np.testing.assert_allclose(g.f, g2.f, atol=1e-6)


def test_stream_deletion_only_batch():
    """A Δ_t with zero insertions reuses buffers and still propagates."""
    spec = StreamSpec(total_vertices=300, batch_size=300, seed=9,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4)
    for batch, _ in gaussian_mixture_stream(spec):
        eng.step(batch)
    victims = np.flatnonzero(g.alive)[:50].astype(np.int64)
    st = eng.step(BatchUpdate(
        ins_emb=np.zeros((0, spec.emb_dim), np.float32),
        ins_labels=np.zeros(0, np.int8), del_ids=victims))
    assert st.converged
    assert not g.alive[victims].any()


def _hub_stream(eng, rng, batches=4, per_batch=25):
    """Insert points on a cone around one hub vertex (cos 0.9 to the hub,
    pairwise cos ≈ 0.81 to each other, high dim keeps random directions
    near-orthogonal) so the hub stays every point's nearest neighbor:
    its true-kNN in-degree — and the natural ELL K — grows with every
    batch even though each point keeps only k list slots."""
    dim = eng.graph.emb_dim
    hub = np.zeros((1, dim), np.float32)
    hub[0, 0] = 1.0
    anchors = np.zeros((2, dim), np.float32)
    anchors[0, 0], anchors[1, 0] = 1.0, -1.0
    eng.step(BatchUpdate(
        ins_emb=np.concatenate([anchors, hub]),
        ins_labels=np.array([1, 0, UNLABELED], np.int8),
        del_ids=np.zeros(0, np.int64)))
    for _ in range(batches):
        u = rng.normal(0, 1, (per_batch, dim)).astype(np.float32)
        u[:, 0] = 0.0  # orthogonal complement of the hub direction
        u /= np.linalg.norm(u, axis=1, keepdims=True)
        pts = (0.9 * hub + np.float32(np.sqrt(1.0 - 0.81)) * u
               ).astype(np.float32)
        eng.step(BatchUpdate(ins_emb=pts,
                             ins_labels=np.full(per_batch, UNLABELED, np.int8),
                             del_ids=np.zeros(0, np.int64)))


def test_max_k_caps_hub_ladder(caplog, monkeypatch):
    """A hub vertex drags the K ladder up batch after batch unless capped;
    max_k truncates its heaviest-degree row and logs that it fired."""
    from repro.core import snapshot

    # the truncation WARNING dedups per (cap, rung) process-wide — reset
    # so this test is order/rerun independent
    monkeypatch.setattr(snapshot, "_MAX_K_WARNED", set())
    rng = np.random.default_rng(0)
    g_free = DynamicGraph(emb_dim=64, k=3)
    free = StreamEngine(g_free, delta=1e-4, max_k=None)  # escape hatch
    _hub_stream(free, np.random.default_rng(0))
    assert max(k for _, k in free.bucket_keys) >= 32  # the uncapped creep

    g_cap = DynamicGraph(emb_dim=64, k=3)
    capped = StreamEngine(g_cap, delta=1e-4, max_k=8)
    with caplog.at_level(logging.WARNING, logger="repro.core.snapshot"):
        _hub_stream(capped, rng)
    assert max(k for _, k in capped.bucket_keys) <= 8
    assert len(capped.bucket_keys) < len(free.bucket_keys)
    assert any("max_k=8 truncating" in r.getMessage()
               for r in caplog.records)
    # the capped stream still converges to sane labels: everything hangs
    # off the class-1 hub
    ids = np.flatnonzero(g_cap.alive & (g_cap.labels == UNLABELED))
    assert (g_cap.f[ids] > 0.5).all()


def test_max_k_defaults_to_4x_knn_k():
    """The hub cap is on by default (4x the graph's kNN k, for both the
    stream and the DynLP recompute oracle); ``max_k=None`` is the
    explicit uncapped escape hatch."""
    from repro.core.dynlp import DynLP

    g = DynamicGraph(emb_dim=8, k=3)
    assert StreamEngine(g).max_k == 12
    assert DynLP(g).max_k == 12
    assert StreamEngine(g, max_k=None).max_k is None
    assert DynLP(g, max_k=None).max_k is None
    assert StreamEngine(g, max_k=7).max_k == 7
    # the default cap actually bounds the hub ladder (same stream as the
    # explicit-cap test, no max_k argument at all)
    g_def = DynamicGraph(emb_dim=64, k=3)
    eng = StreamEngine(g_def, delta=1e-4)
    _hub_stream(eng, np.random.default_rng(0))
    assert max(k for _, k in eng.bucket_keys) <= 16  # bucket_k(12)


def test_max_k_warning_scoped_per_engine(caplog):
    """The truncation-WARNING dedup is per engine: a fresh StreamEngine
    warns again instead of inheriting another engine's (or test's)
    module-level state; within one engine repeats still demote to
    DEBUG."""
    def run_engine():
        g = DynamicGraph(emb_dim=64, k=3)
        eng = StreamEngine(g, delta=1e-4, max_k=8)
        _hub_stream(eng, np.random.default_rng(0), batches=3)
        return eng

    with caplog.at_level(logging.WARNING, logger="repro.core.snapshot"):
        run_engine()
        first = [r for r in caplog.records if "truncating" in r.getMessage()]
        assert first, "first engine never warned"
        caplog.clear()
        run_engine()  # identical stream, FRESH engine: must warn again
        second = [r for r in caplog.records
                  if "truncating" in r.getMessage()]
        assert second, "fresh engine inherited another engine's dedup state"
        # ...but within one engine it warns once per (cap, natural-K
        # rung) — never once per Δ_t — so both runs warn identically
        assert len(second) == len(first)
        assert len(second) <= 4  # ≤ one per step of the 4-step hub stream


def test_max_k_no_log_when_inactive(caplog):
    """max_k above the natural degree neither truncates nor logs."""
    spec = StreamSpec(total_vertices=200, batch_size=100, seed=4,
                      class_sep=6.0, noise=0.9)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=1e-4, max_k=512)
    with caplog.at_level(logging.WARNING, logger="repro.core.snapshot"):
        for batch, _ in gaussian_mixture_stream(spec):
            eng.step(batch)
    assert not caplog.records


@pytest.mark.parametrize("backend", ["ref", "ell_pallas", "bsr"])
def test_stream_backend_dispatch(backend):
    """The engine reaches the same labels through every backend."""
    spec = StreamSpec(total_vertices=200, batch_size=100, seed=4,
                      class_sep=6.0, noise=0.9)
    fs = {}
    for b in ("ref", backend):
        g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
        eng = StreamEngine(g, delta=1e-4, backend=b, block_rows=64)
        for batch, _ in gaussian_mixture_stream(spec):
            eng.step(batch)
        fs[b] = g.f.copy()
    # bsr sums edges in block order, so residuals near the δ threshold can
    # differ by O(δ); the other backends are bit-compatible with ref
    atol = 2e-3 if backend == "bsr" else 1e-5
    np.testing.assert_allclose(fs[backend], fs["ref"], atol=atol)

"""Checkpoint manager, preemption, straggler monitor, gradient compression."""

import logging
import os
import shutil
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_mesh
from repro.training import optim
from repro.training.resilience import (
    PreemptionGuard,
    StragglerMonitor,
    compress_tree,
    decompress_tree,
    init_error_state,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (8, 16)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.bfloat16),
                   "step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    got = ckpt.restore(str(tmp_path), 5, like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), t, got)


def test_checkpoint_incomplete_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # a torn write: directory exists but no .complete marker
    os.makedirs(tmp_path / "step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_manager_keeps_last_n(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_sync(s, t)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_manager_async_then_restore(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save_async(10, t)
    mgr.wait()
    got = mgr.restore(t)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_manager_async_failure_surfaces(tmp_path):
    """A failed async write must NOT be silent: the worker's exception
    re-raises at the next wait()/save_async()/save_sync(), once, and the
    manager stays usable for a retry afterwards."""
    ckdir = tmp_path / "ck"
    mgr = ckpt.CheckpointManager(str(ckdir))
    t = _tree()
    mgr.save_async(1, t)
    mgr.wait()
    # sabotage: the checkpoint directory becomes a plain FILE, so every
    # write fails (robust under root, unlike permission tricks)
    shutil.rmtree(ckdir)
    ckdir.write_text("not a directory")
    mgr.save_async(2, t)  # worker hits the sabotage; no raise here
    with pytest.raises(OSError):
        mgr.wait()
    mgr.wait()  # the error was delivered once, then cleared
    # surfacing also happens at the next save_async call itself
    mgr.save_async(3, t)
    with pytest.raises(OSError):
        mgr.save_async(4, t)
    # ...and at save_sync
    mgr.save_async(5, t)
    with pytest.raises(OSError):
        mgr.save_sync(6, t)
    # un-sabotage: the same manager recovers
    ckdir.unlink()
    mgr.save_sync(7, t)
    assert mgr.latest_step() == 7


def test_save_rejects_removed_wait_param(tmp_path):
    """save() is always synchronous; the historical dead ``wait=`` knob
    is gone rather than silently accepted-and-ignored."""
    with pytest.raises(TypeError):
        ckpt.save(str(tmp_path), 1, _tree(), wait=False)


def test_latest_step_and_gc_survive_malformed_entries(tmp_path):
    """Stray files and crashed-writer ``.tmp`` staging dirs under the
    checkpoint directory must never crash latest_step/_gc; marker-less
    tmp dirs are invisible to restore and reaped by the next gc."""
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save_sync(1, t)
    # a stray non-step file, a malformed step name, and a crashed
    # writer's marker-less staging dir
    (tmp_path / "step_x").write_text("junk")
    os.makedirs(tmp_path / "step_notanumber")
    os.makedirs(tmp_path / "step_00000042.tmp")
    assert ckpt.latest_step(str(tmp_path)) == 1
    mgr.save_sync(2, t)  # runs _gc: must not raise, must reap the tmp
    assert not (tmp_path / "step_00000042.tmp").exists()
    assert (tmp_path / "step_x").exists()  # non-checkpoint junk untouched
    assert (tmp_path / "step_notanumber").exists()
    assert mgr.latest_step() == 2


def test_straggler_end_step_without_start_is_noop(caplog):
    """end_step() with no matching start_step() used to TypeError on
    ``perf_counter() - None``; now it warns and returns None, and the
    monitor keeps working afterwards."""
    mon = StragglerMonitor(threshold=2.0, window=16)
    with caplog.at_level(logging.WARNING, "repro.training.resilience"):
        assert mon.end_step() is None
    assert any("without start_step" in r.message for r in caplog.records)
    assert len(mon.times) == 0
    mon.start_step()
    assert mon.end_step() is None  # matched pair records a sample
    assert len(mon.times) == 1
    # a second unmatched call is also a no-op (start consumed above)
    assert mon.end_step() is None
    assert len(mon.times) == 1
    for _ in range(10):
        assert mon.observe(0.1) is None  # observe() path still intact
    assert mon.observe(0.5) is not None


def test_preemption_guard_installs_both_signals_and_rearms():
    """The guard registers SIGTERM AND SIGINT by default (matching its
    docstring), restore() puts the old handlers back and resets the
    flag, and the same guard re-arms — including as a context manager."""
    old_term = signal.getsignal(signal.SIGTERM)
    old_int = signal.getsignal(signal.SIGINT)
    guard = PreemptionGuard()
    try:
        assert signal.getsignal(signal.SIGTERM) == guard._handler
        assert signal.getsignal(signal.SIGINT) == guard._handler
        os.kill(os.getpid(), signal.SIGTERM)
        assert guard.requested
    finally:
        guard.restore()
    assert signal.getsignal(signal.SIGTERM) == old_term
    assert signal.getsignal(signal.SIGINT) == old_int
    assert not guard.requested  # restore() resets the flag: re-armable
    # round 2: the SAME guard via the context-manager form
    with guard as g:
        assert g is guard
        assert signal.getsignal(signal.SIGTERM) == guard._handler
        os.kill(os.getpid(), signal.SIGTERM)
        assert guard.requested
    assert signal.getsignal(signal.SIGTERM) == old_term
    assert not guard.requested


def test_preemption_guard_custom_signals():
    """A custom signal set leaves the defaults untouched (the LPService
    tests use ``signals=()`` to drive the flag manually)."""
    old_term = signal.getsignal(signal.SIGTERM)
    old_usr1 = signal.getsignal(signal.SIGUSR1)
    with PreemptionGuard(signals=(signal.SIGUSR1,)) as guard:
        assert signal.getsignal(signal.SIGTERM) == old_term
        os.kill(os.getpid(), signal.SIGUSR1)
        assert guard.requested
    assert signal.getsignal(signal.SIGUSR1) == old_usr1
    none_guard = PreemptionGuard(signals=())
    assert not none_guard.requested
    none_guard.restore()


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=2.0, window=16)
    for _ in range(10):
        assert mon.observe(0.1) is None
    ev = mon.observe(0.5)
    assert ev is not None and ev.seconds >= 0.5 and abs(ev.median - 0.1) < 0.02
    assert mon.observe(0.11) is None  # back to normal


def test_compression_error_feedback_preserves_mean():
    """Accumulated error feedback keeps the long-run compressed sum close to
    the true sum (the convergence-preserving property)."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(0, 1e-3, (64,)).astype(np.float32) for _ in range(50)]
    params = {"w": jnp.zeros((64,))}
    err = init_error_state(params)
    total_q = np.zeros(64)
    for g in g_true:
        codes, scales, err = compress_tree({"w": jnp.asarray(g)}, err)
        total_q += np.asarray(decompress_tree(codes, scales)["w"])
    total_true = np.sum(g_true, axis=0)
    # without error feedback the quantization bias would accumulate
    np.testing.assert_allclose(total_q, total_true, atol=5e-4)


def test_compressed_training_converges():
    """A linear-regression model trained with int8-compressed grads reaches
    the same loss region as uncompressed SGD."""
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (256, 8)).astype(np.float32)
    w_true = rng.normal(0, 1, (8,)).astype(np.float32)
    y = x @ w_true

    def loss_fn(w):
        return jnp.mean((x @ w - y) ** 2)

    g_fn = jax.jit(jax.grad(loss_fn))

    def train(compressed):
        w = jnp.zeros(8)
        err = init_error_state({"w": w})
        for _ in range(200):
            g = g_fn(w)
            if compressed:
                codes, scales, err = compress_tree({"w": g}, err)
                g = decompress_tree(codes, scales)["w"]
            w = w - 0.1 * g
        return float(loss_fn(w))

    assert train(True) < 1e-3
    assert abs(train(True) - train(False)) < 1e-3


def test_elastic_restore_across_meshes(tmp_path):
    """Save once, restore under a different sharding (elastic resume)."""
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, t)
    mesh = make_mesh((1,), ("x",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("x", None))}
    got = ckpt.restore(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding == sh["w"]

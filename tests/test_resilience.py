"""Checkpoint manager, preemption, straggler monitor, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_mesh
from repro.training import optim
from repro.training.resilience import (
    StragglerMonitor,
    compress_tree,
    decompress_tree,
    init_error_state,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (8, 16)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.normal(0, 1, (4,)), jnp.bfloat16),
                   "step": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    got = ckpt.restore(str(tmp_path), 5, like)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), t, got)


def test_checkpoint_incomplete_ignored(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    # a torn write: directory exists but no .complete marker
    os.makedirs(tmp_path / "step_00000009")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_manager_keeps_last_n(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_sync(s, t)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_manager_async_then_restore(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save_async(10, t)
    mgr.wait()
    got = mgr.restore(t)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=2.0, window=16)
    for _ in range(10):
        assert mon.observe(0.1) is None
    ev = mon.observe(0.5)
    assert ev is not None and ev.seconds >= 0.5 and abs(ev.median - 0.1) < 0.02
    assert mon.observe(0.11) is None  # back to normal


def test_compression_error_feedback_preserves_mean():
    """Accumulated error feedback keeps the long-run compressed sum close to
    the true sum (the convergence-preserving property)."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(0, 1e-3, (64,)).astype(np.float32) for _ in range(50)]
    params = {"w": jnp.zeros((64,))}
    err = init_error_state(params)
    total_q = np.zeros(64)
    for g in g_true:
        codes, scales, err = compress_tree({"w": jnp.asarray(g)}, err)
        total_q += np.asarray(decompress_tree(codes, scales)["w"])
    total_true = np.sum(g_true, axis=0)
    # without error feedback the quantization bias would accumulate
    np.testing.assert_allclose(total_q, total_true, atol=5e-4)


def test_compressed_training_converges():
    """A linear-regression model trained with int8-compressed grads reaches
    the same loss region as uncompressed SGD."""
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (256, 8)).astype(np.float32)
    w_true = rng.normal(0, 1, (8,)).astype(np.float32)
    y = x @ w_true

    def loss_fn(w):
        return jnp.mean((x @ w - y) ** 2)

    g_fn = jax.jit(jax.grad(loss_fn))

    def train(compressed):
        w = jnp.zeros(8)
        err = init_error_state({"w": w})
        for _ in range(200):
            g = g_fn(w)
            if compressed:
                codes, scales, err = compress_tree({"w": g}, err)
                g = decompress_tree(codes, scales)["w"]
            w = w - 0.1 * g
        return float(loss_fn(w))

    assert train(True) < 1e-3
    assert abs(train(True) - train(False)) < 1e-3


def test_elastic_restore_across_meshes(tmp_path):
    """Save once, restore under a different sharding (elastic resume)."""
    t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, t)
    mesh = make_mesh((1,), ("x",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("x", None))}
    got = ckpt.restore(str(tmp_path), 1, t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding == sh["w"]

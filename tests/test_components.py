"""Shiloach–Vishkin connected components vs a union-find oracle."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.components import compact_labels, connected_components, num_components
from repro.graph.structures import coo_to_csr, csr_to_ell_fast

from helpers import random_undirected_coo, union_find_components


@given(st.integers(0, 10_000), st.integers(2, 60), st.floats(0.5, 6.0))
def test_cc_matches_union_find(seed, n, avg_deg):
    rng = np.random.default_rng(seed)
    src, dst, wgt = random_undirected_coo(rng, n, avg_deg)
    ell = csr_to_ell_fast(coo_to_csr(n, src, dst, wgt))
    got = np.asarray(connected_components(ell.nbr).labels)
    want = union_find_components(n, src, dst)
    np.testing.assert_array_equal(got, want)


@given(st.integers(0, 10_000), st.integers(2, 40))
def test_cc_tau_threshold_drops_edges(seed, n):
    """τ-sparsification: only edges with w > τ connect components."""
    rng = np.random.default_rng(seed)
    src, dst, wgt = random_undirected_coo(rng, n, 3.0)
    ell = csr_to_ell_fast(coo_to_csr(n, src, dst, wgt))
    tau = 0.55
    got = np.asarray(connected_components(ell.nbr, ell.wgt, tau=tau).labels)
    keep = wgt > tau
    want = union_find_components(n, src[keep], dst[keep])
    np.testing.assert_array_equal(got, want)


def test_cc_two_cliques():
    # 0-1-2 triangle and 3-4 edge, 5 isolated
    src = np.array([0, 1, 1, 2, 0, 2, 3, 4], np.int64)
    dst = np.array([1, 0, 2, 1, 2, 0, 4, 3], np.int64)
    w = np.ones(8, np.float32)
    ell = csr_to_ell_fast(coo_to_csr(6, src, dst, w))
    res = connected_components(ell.nbr)
    labels = np.asarray(res.labels)
    np.testing.assert_array_equal(labels, [0, 0, 0, 3, 3, 5])
    assert int(num_components(res.labels)) == 3
    np.testing.assert_array_equal(np.asarray(compact_labels(res.labels)), [0, 0, 0, 1, 1, 2])


def test_cc_empty_graph():
    import jax.numpy as jnp

    nbr = jnp.full((4, 2), -1, jnp.int32)
    labels = np.asarray(connected_components(nbr).labels)
    np.testing.assert_array_equal(labels, np.arange(4))

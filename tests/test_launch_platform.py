"""``launch.platform``: XLA flag/env composition.

Pure env-dict tests — the helper takes ``env=`` precisely so tests (and
launcher scripts building child environments) never have to race jax's
one-shot backend init.
"""

import pytest

from repro.launch.platform import GPU_XLA_FLAGS, set_platform


def test_gpu_platform_installs_flag_set():
    env = set_platform("gpu", env={})
    assert env["JAX_PLATFORMS"] == "gpu"
    for flag in GPU_XLA_FLAGS:
        assert flag in env["XLA_FLAGS"].split()
    assert "--xla_gpu_enable_latency_hiding_scheduler=true" in env["XLA_FLAGS"]


def test_existing_flags_win_and_merge_is_idempotent():
    env = {"XLA_FLAGS": "--xla_gpu_triton_gemm_any=False"}
    set_platform("gpu", env=env)
    flags = env["XLA_FLAGS"].split()
    # the user's value survives; the helper never duplicates a flag name
    assert "--xla_gpu_triton_gemm_any=False" in flags
    assert "--xla_gpu_triton_gemm_any=True" not in flags
    before = env["XLA_FLAGS"]
    set_platform("gpu", env=env)
    assert env["XLA_FLAGS"] == before
    assert len(flags) == len({f.split("=", 1)[0] for f in flags})


def test_host_devices_forces_virtual_cpu_count():
    env = set_platform("cpu", host_devices=8, env={})
    assert env["JAX_PLATFORMS"] == "cpu"
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    # platform=None still applies host_devices (keep jax's own detection)
    env2 = set_platform(host_devices=4, env={})
    assert "JAX_PLATFORMS" not in env2
    assert "--xla_force_host_platform_device_count=4" in env2["XLA_FLAGS"]


def test_validation_and_late_call_guard():
    with pytest.raises(ValueError, match="unknown platform"):
        set_platform("quantum", env={})
    with pytest.raises(ValueError, match="host_devices"):
        set_platform("cpu", host_devices=0, env={})
    # jax is imported in this process: mutating os.environ would be dead
    with pytest.raises(RuntimeError, match="before jax"):
        set_platform("cpu")

"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

The real library is an optional test dependency (``pip install -e .[test]``).
When it is absent — e.g. a hermetic container that only ships the runtime
deps — ``conftest.py`` installs this module as ``sys.modules["hypothesis"]``
so the suite still collects and runs.  The stand-in replays each ``@given``
test ``max_examples`` times with a deterministic per-test RNG; it does no
shrinking and supports only the strategies the tests actually use
(``integers``, ``floats``, ``booleans``, ``sampled_from``, ``just``).
"""

from __future__ import annotations

import zlib
from typing import Any, Callable

import numpy as np

__version__ = "0.0-fallback"


class SearchStrategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self.draw = draw


class strategies:  # noqa: N801 — mirrors ``hypothesis.strategies`` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1))
        )

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq) -> SearchStrategy:
        items = list(seq)
        return SearchStrategy(lambda rng: items[int(rng.integers(len(items)))])

    @staticmethod
    def just(value) -> SearchStrategy:
        return SearchStrategy(lambda rng: value)


st = strategies


class settings:  # noqa: N801 — mirrors ``hypothesis.settings``
    _profiles: dict[str, dict] = {"default": {"max_examples": 25}}
    _active: dict = _profiles["default"]

    def __init__(self, max_examples: int | None = None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            fn._fallback_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name: str, max_examples: int = 25, **_ignored):
        cls._profiles[name] = {"max_examples": max_examples}

    @classmethod
    def load_profile(cls, name: str):
        cls._active = cls._profiles[name]

    @classmethod
    def default_max_examples(cls) -> int:
        return cls._active["max_examples"]


def given(*strats: SearchStrategy):
    def decorate(fn):
        # NB: no ``functools.wraps`` — pytest would follow ``__wrapped__`` and
        # treat the strategy parameters as fixture requests.
        def runner():
            n = getattr(fn, "_fallback_max_examples", None)
            n = settings.default_max_examples() if n is None else n
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = [s.draw(rng) for s in strats]
                fn(*drawn)

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__module__ = fn.__module__
        runner.__doc__ = fn.__doc__
        runner.pytestmark = list(getattr(fn, "pytestmark", []))
        runner.hypothesis_fallback = True
        return runner

    return decorate


class HealthCheck:  # pragma: no cover — accepted but unused
    all = staticmethod(lambda: [])


def assume(condition: bool) -> bool:  # pragma: no cover
    return bool(condition)

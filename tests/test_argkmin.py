"""Device argkmin kernel: XLA twin vs Pallas (interpret) agreement, and
candidate coverage of the host oracle's canonical top-k.

The bit-equality contract (``graph.knn`` module docstring) only needs
the kernel to return candidate *supersets* covering the canonical top-k
plus an exact displacement mask — canonical re-selection happens on the
host.  These tests pin both properties, including the tie/duplicate and
dead-row corners.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.knn import SELECT_MARGIN, normalize_rows, pair_weights, \
    selection_slack, topk_pairs
from repro.kernels.argkmin import argkmin_candidates


def _make(rng, c, d, m, k, dead_frac=0.1, dup=False):
    """Store of ``c`` rows whose last ``m`` are the arriving batch."""
    emb = rng.normal(size=(c, d)).astype(np.float32)
    if dup:  # mass duplicates force deep ties
        emb[: c // 2] = emb[0]
    embn = normalize_rows(emb)
    base_id = c - m
    valid = np.ones(c, bool)
    n_dead = int(dead_frac * base_id)
    if n_dead:
        valid[rng.choice(base_id, n_dead, replace=False)] = False
    # plausible existing k-th weights for the old rows; -inf = under-full
    kth = np.full(c, -np.inf, np.float32)
    kth[: base_id] = rng.uniform(0.4, 0.9, base_id).astype(np.float32)
    kth[rng.choice(c, max(1, c // 8), replace=False)] = -np.inf
    batch = embn[base_id:]
    bvalid = np.ones(m, bool)
    return embn, valid, kth, batch, bvalid, base_id


def _run(backend, embn, valid, kth, batch, bvalid, base_id, d, k, br=128):
    return argkmin_candidates(
        jnp.asarray(embn), jnp.asarray(valid), jnp.asarray(kth),
        jnp.asarray(batch), jnp.asarray(bvalid), base_id,
        selection_slack(d), k=k, backend=backend, block_rows=br,
        interpret=True)


@pytest.mark.parametrize("dup", [False, True])
@pytest.mark.parametrize("c,d,m,k", [(256, 16, 8, 5), (512, 33, 16, 3)])
def test_xla_vs_pallas_interpret_agree(c, d, m, k, dup):
    rng = np.random.default_rng(c + d + dup)
    embn, valid, kth, batch, bvalid, base_id = _make(rng, c, d, m, k, dup=dup)
    vx, ix, dx = (np.asarray(a) for a in _run(
        "xla", embn, valid, kth, batch, bvalid, base_id, d, k))
    vp, ip, dp_ = (np.asarray(a) for a in _run(
        "pallas", embn, valid, kth, batch, bvalid, base_id, d, k))
    np.testing.assert_array_equal(dx, dp_)
    for q in range(m):  # same candidate SET per query (order may differ
        # only among equal values; both keep lowest ids)
        sx = set(ix[q][np.isfinite(vx[q])])
        sp = set(ip[q][np.isfinite(vp[q])])
        assert sx == sp, q
    np.testing.assert_array_equal(np.sort(vx, 1), np.sort(vp, 1))


def test_no_self_no_dead_candidates():
    rng = np.random.default_rng(3)
    c, d, m, k = 256, 12, 16, 4
    embn, valid, kth, batch, bvalid, base_id = _make(rng, c, d, m, k,
                                                     dead_frac=0.3)
    for backend in ("xla", "pallas"):
        val, idx, disp = (np.asarray(a) for a in _run(
            backend, embn, valid, kth, batch, bvalid, base_id, d, k))
        fin = np.isfinite(val)
        rows, cols = np.nonzero(fin)
        cand = idx[rows, cols]
        assert not (cand == (base_id + rows)).any(), backend  # no self
        assert valid[cand].all(), backend  # no dead rows
        assert not disp[~valid].any() and not disp[base_id:].any(), backend


def test_candidates_cover_canonical_topk():
    """Every canonical top-k neighbor (host ``pair_weights`` total order)
    appears in the kernel's candidate superset."""
    rng = np.random.default_rng(11)
    c, d, m, k = 384, 24, 24, 5
    embn, valid, kth, batch, bvalid, base_id = _make(rng, c, d, m, k)
    # canonical neighbors over the full valid store (excluding self)
    w = pair_weights(batch[:, None, :], embn[None, :, :])
    ids = np.broadcast_to(np.arange(c, dtype=np.int64), w.shape).copy()
    w = w.copy()
    w[:, ~valid] = -np.inf
    w[np.arange(m), base_id + np.arange(m)] = -np.inf
    want_i, want_w = topk_pairs(w, ids, k)
    for backend in ("xla", "pallas"):
        val, idx, _ = (np.asarray(a) for a in _run(
            backend, embn, valid, kth, batch, bvalid, base_id, d, k,
            br=128))
        for q in range(m):
            cand = set(idx[q][np.isfinite(val[q])])
            need = set(want_i[q][want_i[q] >= 0])
            assert need <= cand, (backend, q, need - cand)


def test_displacement_mask_matches_slack_rule():
    """disp == alive old rows whose kth the batch beats within slack,
    computed straight from the definition."""
    rng = np.random.default_rng(5)
    c, d, m, k = 256, 10, 8, 4
    embn, valid, kth, batch, bvalid, base_id = _make(rng, c, d, m, k)
    w = pair_weights(batch[:, None, :], embn[None, :, :]).astype(np.float64)
    # the kernel computes (dot + 1)/2 in f32; recompute the same way
    s = batch.astype(np.float32) @ embn.T.astype(np.float32)
    w32 = (s + np.float32(1.0)) * np.float32(0.5)
    w32[np.arange(m), base_id + np.arange(m)] = np.nan  # self is still a col
    colmax = np.nanmax(w32, axis=0)
    slack = np.float32(selection_slack(d))
    want = valid & (np.arange(c) < base_id) & (colmax > kth - slack)
    for backend in ("xla", "pallas"):
        _, _, disp = _run(backend, embn, valid, kth, batch, bvalid,
                          base_id, d, k)
        np.testing.assert_array_equal(np.asarray(disp), want)
    del w  # (canonical weights unused: disp is defined on the fast path)


def test_underfull_store_pads_with_minus_inf():
    """A store smaller than k+margin returns what exists; empty slots are
    -inf and every real candidate is kept."""
    rng = np.random.default_rng(9)
    d, k = 8, 5
    embn = normalize_rows(rng.normal(size=(16, d)).astype(np.float32))
    valid = np.ones(16, bool)
    kth = np.full(16, -np.inf, np.float32)
    base_id, m = 12, 4
    for backend in ("xla", "pallas"):
        val, idx, disp = (np.asarray(a) for a in _run(
            backend, embn, valid, kth, embn[12:], np.ones(4, bool),
            base_id, d, k, br=16))
        assert val.shape[1] == min(k + SELECT_MARGIN, 16)
        assert np.isfinite(val).all()  # 15 non-self rows > topk width
        assert disp[:12].all()  # -inf kth: everything is displaced

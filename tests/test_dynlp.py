"""End-to-end DynLP behaviour: dynamic batches, deletions, harmonic fidelity."""

import numpy as np
import pytest

from repro.core.dynlp import DynLP
from repro.core.itlp import ITLP
from repro.core.snapshot import build_problem
from repro.core.stlp import STLP, harmonic_solve
from repro.data.synth import StreamSpec, accuracy, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph

SPEC = StreamSpec(
    total_vertices=1200, batch_size=400, seed=3, class_sep=6.0, noise=0.8
)


def _run_stream(engine_cls, spec=SPEC, **kw):
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = engine_cls(g, **kw)
    truth = {}
    stats = []
    for batch, cls in gaussian_mixture_stream(spec):
        base = g.num_nodes
        stats.append(eng.step(batch))
        for i, c in enumerate(cls):
            truth[base + i] = c
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    pred = (g.f[ids] >= 0.5).astype(np.int8)
    tr = np.array([truth[i] for i in ids])
    return g, ids, pred, tr, stats


def test_dynlp_tracks_harmonic_solution():
    g, ids, pred, truth, stats = _run_stream(DynLP, delta=1e-4)
    assert all(s.converged for s in stats)
    snap = build_problem(g)
    fh = np.asarray(harmonic_solve(snap.problem))[: len(snap.unl_ids)]
    pred_h = (fh >= 0.5).astype(np.int8)
    assert accuracy(pred, pred_h) > 0.98  # paper: ~99% vs harmonic optimum
    assert np.abs(g.f[snap.unl_ids] - fh).mean() < 0.05


def test_dynlp_fewer_iterations_than_itlp():
    _, _, pred_d, truth, st_d = _run_stream(DynLP, delta=1e-4)
    _, _, pred_i, _, st_i = _run_stream(ITLP, delta=1e-4)
    # paper Fig. 7: DynLP needs fewer iterations in every experiment
    assert sum(s.iterations for s in st_d) < sum(s.iterations for s in st_i)
    assert accuracy(pred_d, truth) == pytest.approx(accuracy(pred_i, truth), abs=0.05)


def test_deletions_remove_influence():
    """Insert a hostile cluster, then delete it: labels must recover."""
    rng = np.random.default_rng(0)
    g = DynamicGraph(emb_dim=4, k=3)
    dyn = DynLP(g, delta=1e-5)

    # seed: two labeled anchors + a cloud near class 1
    emb0 = np.array([[1, 0, 0, 0], [-1, 0, 0, 0]], np.float32)
    cloud = rng.normal([1, 0, 0, 0], 0.1, (20, 4)).astype(np.float32)
    dyn.step(
        BatchUpdate(
            ins_emb=np.concatenate([emb0, cloud]),
            ins_labels=np.array([1, 0] + [UNLABELED] * 20, np.int8),
            del_ids=np.zeros(0, np.int64),
        )
    )
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    assert (g.f[ids] > 0.5).all()  # cloud labeled class 1

    # hostile cluster near class 0 arrives, pulled toward the cloud ids
    hostile = rng.normal([-1, 0, 0, 0], 0.1, (30, 4)).astype(np.float32)
    dyn.step(
        BatchUpdate(
            ins_emb=hostile,
            ins_labels=np.full(30, UNLABELED, np.int8),
            del_ids=np.zeros(0, np.int64),
        )
    )
    hostile_ids = np.arange(22, 52)
    assert (g.f[hostile_ids] < 0.5).all()  # hostile cluster labeled class 0

    # delete the hostile cluster: survivors keep/recover class-1 labels
    dyn.step(
        BatchUpdate(
            ins_emb=np.zeros((0, 4), np.float32),
            ins_labels=np.zeros(0, np.int8),
            del_ids=hostile_ids,
        )
    )
    ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
    assert (g.f[ids] > 0.5).all()
    assert not g.alive[hostile_ids].any()


def test_stlp_matches_dynlp_small():
    g1, ids, pred_d, truth, _ = _run_stream(
        DynLP, StreamSpec(total_vertices=600, batch_size=300, seed=7,
                          class_sep=6.0, noise=0.8), delta=1e-5
    )
    g2, _, pred_s, _, _ = _run_stream(
        STLP, StreamSpec(total_vertices=600, batch_size=300, seed=7,
                         class_sep=6.0, noise=0.8)
    )
    assert accuracy(pred_d, pred_s) > 0.98


def test_stlp_memory_guard():
    g = DynamicGraph(emb_dim=4, k=3)
    eng = STLP(g, max_unlabeled=10)
    emb = np.random.default_rng(0).normal(0, 1, (40, 4)).astype(np.float32)
    labels = np.full(40, UNLABELED, np.int8)
    labels[:2] = [0, 1]
    with pytest.raises(MemoryError):
        eng.step(BatchUpdate(ins_emb=emb, ins_labels=labels, del_ids=np.zeros(0, np.int64)))


def test_stlp_gamma_accuracy_ordering():
    """Smaller γ (more Neumann terms) must approximate the exact harmonic
    solution at least as well as larger γ (paper Table 4 trend)."""
    spec = StreamSpec(total_vertices=500, batch_size=500, seed=11,
                      class_sep=5.0, noise=1.0)
    errs = {}
    for gamma in (None, 1.0, 10.0):
        g, ids, pred, truth, _ = _run_stream(STLP, spec, gamma=gamma)
        if gamma is None:
            f_exact = g.f[ids].copy()
        errs[gamma] = np.abs(g.f[ids] - f_exact).mean()
    assert errs[1.0] <= errs[10.0] + 1e-6
    assert errs[None] == 0.0

"""Checkpointing: atomic, shard-aware, elastic-restorable.

Layout per step:
    <dir>/step_<N>/manifest.json     tree structure + shapes/dtypes + step
    <dir>/step_<N>/arr_<i>.npy       one file per leaf
    <dir>/step_<N>/.complete         commit marker (written LAST)

Writes go to ``step_<N>.tmp`` and are renamed only after the commit marker
exists, so a preempted writer never leaves a checkpoint that ``latest_step``
would pick up.  Restore re-shards onto WHATEVER mesh is active (elastic:
the save format is mesh-independent full arrays; a 512-chip run can resume
a 256-chip checkpoint and vice versa).  ``save_async`` overlaps the host
write with the next train step.  Multi-host note: at >1 process each host
writes only its addressable shards under ``proc_<k>/`` — the single-process
container exercises the proc-0 path; the manifest format already carries
the shard grid for that extension.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _resolve_dtype(name: str) -> np.dtype:
    """np.dtype for native names, ml_dtypes for bfloat16/fp8 etc."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _save_leaf(path: str, arr: np.ndarray) -> None:
    """np.save cannot round-trip ml_dtypes (bf16 loads as void); store raw
    bytes and let the manifest carry shape+dtype."""
    raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    np.save(path, raw)


def _load_leaf(path: str, shape, dtype_name: str) -> np.ndarray:
    raw = np.load(path)
    dt = _resolve_dtype(dtype_name)
    return raw.view(dt).reshape(shape)


def save(directory: str, step: int, tree, wait: bool = True) -> str:
    """Atomic checkpoint of an arbitrary pytree of arrays."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _leaves_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        _save_leaf(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    open(os.path.join(tmp, ".complete"), "w").close()
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, ".complete")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    NamedShardings for elastic placement onto the current mesh."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = _leaves_with_paths(like_tree)
    assert len(flat) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"target tree has {len(flat)}")
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))[0]
    out = []
    for i, ((path, like), meta) in enumerate(zip(flat, manifest["leaves"])):
        assert jax.tree_util.keystr(path) == meta["path"], (
            f"leaf order mismatch at {i}: {jax.tree_util.keystr(path)} vs "
            f"{meta['path']}")
        arr = _load_leaf(os.path.join(src, f"arr_{i}.npy"), meta["shape"],
                         meta["dtype"])
        assert list(arr.shape) == list(like.shape), (meta["path"], arr.shape,
                                                     like.shape)
        if shard_flat is not None:
            out.append(jax.device_put(arr.astype(like.dtype), shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr.astype(like.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Rolling checkpoints with async save and resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree):
        """Snapshot to host, then write on a worker thread (overlaps the
        next train step's device work)."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            save(self.directory, step, host_tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree):
        self.wait()
        save(self.directory, step, tree)
        self._gc()

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, like_tree, step: int | None = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        return restore(self.directory, step, like_tree, shardings=shardings)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, ".complete")))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

"""Checkpointing: atomic, shard-aware, elastic-restorable.

Layout per step:
    <dir>/step_<N>/manifest.json     tree structure + shapes/dtypes + step
    <dir>/step_<N>/arr_<i>.npy       one file per leaf
    <dir>/step_<N>/.complete         commit marker (written LAST)

Writes go to ``step_<N>.tmp`` and are renamed only after the commit marker
exists, so a preempted writer never leaves a checkpoint that ``latest_step``
would pick up.  Restore re-shards onto WHATEVER mesh is active (elastic:
the save format is mesh-independent full arrays; a 512-chip run can resume
a 256-chip checkpoint and vice versa).  ``save_async`` overlaps the host
write with the next train step.  Multi-host note: at >1 process each host
writes only its addressable shards under ``proc_<k>/`` — the single-process
container exercises the proc-0 path; the manifest format already carries
the shard grid for that extension.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np

# canonical step-entry name: ``step_<8+ digits>`` (``save`` zero-pads to 8).
# Anything else under the checkpoint directory — a stray ``step_x`` file, a
# half-written ``step_*.tmp`` from a crashed writer — is NOT a checkpoint
# and must never crash ``latest_step``/``_gc`` (they used to ValueError on
# ``int(name.split("_")[1])``).
_STEP_RE = re.compile(r"step_(\d+)$")


def _step_of(name: str) -> int | None:
    """Step number of a well-formed ``step_<N>`` entry name, else None."""
    m = _STEP_RE.fullmatch(name)
    return int(m.group(1)) if m else None


def _leaves_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _resolve_dtype(name: str) -> np.dtype:
    """np.dtype for native names, ml_dtypes for bfloat16/fp8 etc."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _save_leaf(path: str, arr: np.ndarray) -> None:
    """np.save cannot round-trip ml_dtypes (bf16 loads as void); store raw
    bytes and let the manifest carry shape+dtype."""
    raw = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    np.save(path, raw)


def _load_leaf(path: str, shape, dtype_name: str) -> np.ndarray:
    raw = np.load(path)
    dt = _resolve_dtype(dtype_name)
    return raw.view(dt).reshape(shape)


def save(directory: str, step: int, tree) -> str:
    """Atomic checkpoint of an arbitrary pytree of arrays.

    Always synchronous — it returns only once the renamed ``step_<N>``
    directory is on disk.  (A historical ``wait=`` parameter was accepted
    but never read; async writes live in ``CheckpointManager.save_async``.)
    """
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _leaves_with_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        _save_leaf(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    open(os.path.join(tmp, ".complete"), "w").close()
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    """Largest step with a committed (``.complete``-marked) directory.

    Malformed ``step_*`` entries and in-flight ``.tmp`` staging dirs are
    ignored — a crashed writer or stray file must never make the survivor
    unreadable.
    """
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        step = _step_of(name)
        if step is not None and os.path.exists(
                os.path.join(directory, name, ".complete")):
            steps.append(step)
    return max(steps) if steps else None


def load_flat(directory: str, step: int) -> dict[str, np.ndarray]:
    """Load a checkpoint that was saved from a FLAT ``{name: array}`` dict,
    reconstructing the dict purely from the manifest.

    Unlike ``restore`` this needs no like-tree: shapes and dtypes come from
    the manifest, so a fresh process can restore state whose geometry it
    does not know in advance (the engine-persistence path).  Raises
    ``FileNotFoundError`` if the step directory or its commit marker is
    missing.
    """
    src = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(src, ".complete")):
        raise FileNotFoundError(f"no committed checkpoint at {src}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    out: dict[str, np.ndarray] = {}
    for i, meta in enumerate(manifest["leaves"]):
        m = re.fullmatch(r"\['([^']+)'\]", meta["path"])
        name = m.group(1) if m else meta["path"]
        out[name] = _load_leaf(os.path.join(src, f"arr_{i}.npy"),
                               meta["shape"], meta["dtype"])
    return out


def restore(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    NamedShardings for elastic placement onto the current mesh."""
    src = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = _leaves_with_paths(like_tree)
    assert len(flat) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"target tree has {len(flat)}")
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))[0]
    out = []
    for i, ((path, like), meta) in enumerate(zip(flat, manifest["leaves"])):
        assert jax.tree_util.keystr(path) == meta["path"], (
            f"leaf order mismatch at {i}: {jax.tree_util.keystr(path)} vs "
            f"{meta['path']}")
        arr = _load_leaf(os.path.join(src, f"arr_{i}.npy"), meta["shape"],
                         meta["dtype"])
        assert list(arr.shape) == list(like.shape), (meta["path"], arr.shape,
                                                     like.shape)
        if shard_flat is not None:
            out.append(jax.device_put(arr.astype(like.dtype), shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr.astype(like.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Rolling checkpoints with async save and resume.

    Worker-thread failures are never silent: an exception raised during an
    async write is captured and re-raised at the next ``wait()`` /
    ``save_async()`` / ``save_sync()`` call, so a caller that keeps
    submitting checkpoints finds out its state is not durable instead of
    running on indefinitely.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        """Block until the in-flight async save (if any) finishes.

        Re-raises any exception the worker thread hit — once: the error is
        cleared after raising so the manager stays usable for a retry.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree):
        """Snapshot to host, then write on a worker thread (overlaps the
        next train step's device work).  Raises here if the PREVIOUS async
        save failed."""
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), tree)

        def work():
            try:
                save(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced at the next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree):
        self.wait()
        save(self.directory, step, tree)
        self._gc()

    def latest_step(self):
        return latest_step(self.directory)

    def restore(self, like_tree, step: int | None = None, shardings=None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        return restore(self.directory, step, like_tree, shardings=shardings)

    def _gc(self):
        stale_tmp = []
        steps = []
        for n in os.listdir(self.directory):
            if n.endswith(".tmp") and _step_of(n[: -len(".tmp")]) is not None:
                stale_tmp.append(n)
                continue
            s = _step_of(n)
            if s is not None and os.path.exists(
                    os.path.join(self.directory, n, ".complete")):
                steps.append(s)
        # a crashed writer leaves a marker-less step_<N>.tmp behind; it is
        # invisible to latest_step but would leak disk forever — reap any
        # that aren't the write we just completed.
        for n in stale_tmp:
            shutil.rmtree(os.path.join(self.directory, n), ignore_errors=True)
        for s in sorted(steps)[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

"""Input specs per (architecture × shape): ShapeDtypeStruct stand-ins for the
dry-run (no allocation) and real random batches for smoke tests.

Layouts (DESIGN.md §5):
  decoder-only train : tokens (B,S) + labels (B,S)
  vlm                : vis_embeds (B,S/4,fd) + tokens (B,3S/4) + pos3 (3,B,S)
  audio (enc-dec)    : frames (B,S,fd) + tokens/labels (B,S/8)
  decode             : tokens (B,1) + pos () against a (B, S)-sized cache
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ArchConfig, ShapeSpec

I32 = jnp.int32
BF16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def vlm_split(s: int) -> tuple[int, int]:
    s_vis = s // 4
    return s_vis, s - s_vis


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct batch for ``jax.jit(...).lower(**specs)``."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {}
        if cfg.family == "vlm":
            s_vis, s_text = vlm_split(s)
            batch["vis_embeds"] = _sds((b, s_vis, cfg.frontend_dim), BF16)
            batch["tokens"] = _sds((b, s_text), I32)
            batch["pos3"] = _sds((3, b, s), I32)
            if shape.kind == "train":
                batch["labels"] = _sds((b, s_text), I32)
        elif cfg.enc_dec:
            s_dec = max(1, s // 8)
            batch["frames"] = _sds((b, s, cfg.frontend_dim), BF16)
            if shape.kind == "train":
                batch["tokens"] = _sds((b, s_dec), I32)
                batch["labels"] = _sds((b, s_dec), I32)
        else:
            batch["tokens"] = _sds((b, s), I32)
            if shape.kind == "train":
                batch["labels"] = _sds((b, s), I32)
        return batch
    # decode: one new token against an s-long cache
    batch = {"tokens": _sds((b, 1), I32), "pos": _sds((), I32)}
    if cfg.family == "vlm":
        batch["pos3"] = _sds((3, b, 1), I32)
    return batch


def batch_logical(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Logical-axis tree matching ``input_specs`` (for resolve_spec_tree)."""
    from repro.distribution.partition import Axes

    specs = input_specs(cfg, shape)
    out = {}
    for k, sds in specs.items():
        if k == "pos":
            out[k] = Axes()
        elif k == "pos3":
            out[k] = Axes(None, "dp", None)
        elif sds.ndim == 3:  # vis_embeds / frames
            out[k] = Axes("dp", None, None)
        else:  # tokens / labels
            out[k] = Axes(*(["dp"] + [None] * (sds.ndim - 1)))
    return out


def make_batch(cfg: ArchConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Concrete random batch matching ``input_specs`` (smoke tests, examples)."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for k, sds in specs.items():
        if sds.dtype == I32:
            hi = cfg.vocab if k in ("tokens", "labels") else max(shape.seq_len, 2)
            arr = rng.integers(0, hi, size=sds.shape or ())
            out[k] = jnp.asarray(arr, I32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, sds.shape), BF16)
    if "pos" in out:
        out["pos"] = jnp.asarray(min(shape.seq_len - 1, 7), I32)
    return out

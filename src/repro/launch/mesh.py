"""Production meshes (DESIGN.md §4).

Defined as FUNCTIONS so importing this module never touches jax device
state: a single pod is a 16×16 = 256-chip ("data", "model") mesh; the
multi-pod proof mesh is 2×16×16 = 512 chips with a leading "pod" axis (data
parallelism across pods — gradient all-reduce crosses the DCI).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def make_mesh(shape, axes):
    """Version-tolerant ``jax.make_mesh``: newer jax wants explicit
    ``axis_types`` (``AxisType.Auto``) to opt out of sharding-in-types;
    older jax (≤0.4.x) has neither the kwarg nor the enum."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_stream_mesh(n_devices: int | None = None, axis: str = "data"):
    """Flat 1-D mesh over the local devices — the shape sharded streaming
    wants (``StreamEngine(mesh=...)``): rows partition over one axis, and
    the bucket ladder pads row counts to a multiple of its size.  On a
    CPU host, force more virtual devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes (the multi-device CI job does exactly this)."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return make_mesh((n,), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def axis_rules(multi_pod: bool = False, layout: str = "tp") -> dict:
    """Logical→mesh axis mapping installed before tracing.

    Layouts (the §Perf hillclimb lever — the physical mesh never changes):
      tp      — batch over data axes, tensor/sequence/expert over "model".
      dp      — pure data parallel: batch over EVERY axis, weights
                replicated (the right shape for sub-1B models where TP
                collectives dwarf compute).
      tp_nosp — tensor parallel without sequence-parallel resharding.
    """
    pods = ("pod",) if multi_pod else ()
    if layout == "hybrid":
        # manual data parallelism (shard_map) — batch locality is implicit
        # inside the manual region, so "dp" must not appear in constraints.
        return {"dp": None, "tp": "model", "sp": "model", "ep": "model"}
    if layout == "dp":
        return {
            "dp": pods + ("data", "model"),
            "tp": None, "sp": None, "ep": None,
        }
    if layout == "tp_nosp":
        return {
            "dp": pods + ("data",),
            "tp": "model", "sp": None, "ep": "model",
        }
    return {
        "dp": pods + ("data",),
        "tp": "model",
        "sp": "model",  # sequence-parallel residual stream
        "ep": "model",  # expert parallelism shares the model axis
    }

"""Process-level platform setup: pick the jax backend and its XLA flags.

``set_platform`` must run BEFORE jax initializes its backends (i.e.
before the first ``jax.devices()``/array op — ideally before importing
anything that imports jax): both ``JAX_PLATFORMS`` and ``XLA_FLAGS`` are
read once at backend init and silently ignored afterwards, so this
module raises instead of letting a late call half-apply.

The GPU flag set is the community-standard performance set (async
collectives + latency-hiding scheduler + triton gemm; see
jax.readthedocs.io gpu_performance_tips): a future GPU CI lane calling
``set_platform("gpu")`` gets overlap-friendly scheduling for the
stream's per-sweep collectives for free.  On CPU,
``host_devices=N`` forces an N-virtual-device host platform — the same
``--xla_force_host_platform_device_count`` idiom the multidevice tests
and benchmarks use via subprocess env today.
"""

from __future__ import annotations

import os
import sys

# One flag per element so presence checks and joins stay trivial.
GPU_XLA_FLAGS: tuple[str, ...] = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _merge_xla_flags(env: dict, new_flags: tuple[str, ...]) -> None:
    have = env.get("XLA_FLAGS", "").split()
    names = {f.split("=", 1)[0] for f in have}
    for flag in new_flags:
        if flag.split("=", 1)[0] not in names:
            have.append(flag)
    env["XLA_FLAGS"] = " ".join(have)


def set_platform(platform: str | None = None, *,
                 host_devices: int | None = None,
                 env: dict | None = None) -> dict:
    """Select the jax platform and install its XLA flag set.

    ``platform`` is ``"cpu"``/``"gpu"``/``"tpu"`` (None keeps jax's own
    detection order while still applying ``host_devices``).  ``"gpu"``
    additionally merges ``GPU_XLA_FLAGS`` into ``XLA_FLAGS`` — existing
    flags of the same name win, so launch scripts can still override.
    ``host_devices`` forces the CPU host platform to expose N virtual
    devices (multidevice testing on one machine).

    Mutates and returns ``env`` (default ``os.environ``).  Raises
    RuntimeError when jax is already imported and ``env`` is the real
    process environment — the settings would be silently dead.
    """
    if env is None:
        if "jax" in sys.modules:
            raise RuntimeError(
                "set_platform() must run before jax is imported — "
                "JAX_PLATFORMS/XLA_FLAGS are read once at backend init. "
                "Call it first, or pass env= to build a child-process "
                "environment instead.")
        env = os.environ
    if platform is not None:
        if platform not in ("cpu", "gpu", "tpu"):
            raise ValueError(
                f"unknown platform {platform!r}; want cpu, gpu, or tpu")
        env["JAX_PLATFORMS"] = platform
        if platform == "gpu":
            _merge_xla_flags(env, GPU_XLA_FLAGS)
    if host_devices is not None:
        if host_devices < 1:
            raise ValueError(f"host_devices must be >= 1, got {host_devices}")
        _merge_xla_flags(
            env,
            (f"--xla_force_host_platform_device_count={int(host_devices)}",))
    return env

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
on the single-pod 16×16 mesh and the 2×16×16 multi-pod mesh, recording
memory_analysis / cost_analysis / the collective schedule for §Roofline.

One JSON per cell under experiments/dryrun/ so reruns are incremental:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--force]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, canonical, get_config
from repro.distribution import partition
from repro.launch import hlo_analysis
from repro.launch import mesh as meshlib
from repro.launch.specs import batch_logical, input_specs
from repro.models.api import build_model
from repro.models.common import SHAPES
from repro.training import optim
from repro.training.trainer import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# long_500k needs sub-quadratic attention / bounded state (DESIGN.md §5).
LONG_OK = {"xlstm_350m", "zamba2_7b", "h2o_danube_3_4b"}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in the compiled HLO."""
    per_op: dict[str, int] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        result_ty, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(result_ty):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_op[op] = per_op.get(op, 0) + nbytes
        counts[op] = counts.get(op, 0) + 1
    return {"bytes": per_op, "counts": counts, "total": sum(per_op.values())}


def skip_reason(arch: str, shape_name: str) -> str | None:
    if shape_name == "long_500k" and canonical(arch) not in LONG_OK:
        return ("pure full attention: 500k-token KV cache / O(S^2) prefill "
                "exceeds HBM; see DESIGN.md §5")
    return None


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, multi_pod: bool, microbatches: int = 1,
               overrides: dict | None = None, fsdp: bool | None = None,
               unroll_micro: bool = False, layout: str = "tp"):
    """Lower+compile one cell; returns the result record."""
    cfg = get_config(arch)
    if overrides:
        import dataclasses as dc
        cfg = dc.replace(cfg, **overrides)
    spec = SHAPES[shape_name]
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    # hybrid: storage specs (params/opt/batch) use the standard tp rules;
    # the manual-dp rules are installed later, just before tracing.
    spec_layout = "tp" if layout == "hybrid" else layout
    partition.set_axis_rules(meshlib.axis_rules(multi_pod, layout=spec_layout))
    partition.set_mesh_sizes(dict(zip(mesh.axis_names, mesh.devices.shape)))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    param_shapes = jax.eval_shape(model.init, key)
    pspecs = partition.param_specs(param_shapes, mesh)
    if fsdp is None:  # auto: FSDP when TP-sharded bf16 weights exceed 2 GiB/dev
        tp = mesh.devices.shape[-1]
        fsdp = spec.kind == "train" and cfg.num_params() * 2 / tp > 2 * 2**30
    zspecs = partition.zero_specs(pspecs, param_shapes, mesh)
    if fsdp:
        pspecs = zspecs
    batch = input_specs(cfg, spec)
    bspecs = partition.resolve_spec_tree(batch, batch_logical(cfg, spec), mesh)

    t0 = time.time()
    with mesh:
        if spec.kind == "train":
            opt_shapes = optim.state_shapes(param_shapes)
            # ZeRO-1: optimizer state sharded over data axes too; ZeRO-2:
            # grads constrained to the same specs => reduce-scatter.
            opt_specs = {"master": zspecs, "m": zspecs, "v": zspecs, "step": P()}
            if layout == "hybrid":
                from repro.training.trainer import make_hybrid_train_step

                dp_axes = ("pod", "data") if multi_pod else ("data",)
                # model traces inside the manual region: "dp" must vanish
                # from logical constraints there.
                partition.set_axis_rules(
                    meshlib.axis_rules(multi_pod, layout="hybrid"))
                step = make_hybrid_train_step(
                    model, optim.OptConfig(), mesh, zspecs, bspecs,
                    microbatches=microbatches, dp_axes=dp_axes, pspecs=pspecs)
            else:
                step = make_train_step(model, optim.OptConfig(),
                                       microbatches=microbatches,
                                       grad_specs=zspecs,
                                       unroll_micro=unroll_micro)
            jitted = jax.jit(
                step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs), _ns(mesh, bspecs)),
                out_shardings=(_ns(mesh, pspecs), _ns(mesh, opt_specs), None, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(param_shapes, opt_shapes, batch)
        elif spec.kind == "prefill":
            def prefill_step(params, b):
                logits, cache = model.prefill(params, b)
                return logits, cache

            jitted = jax.jit(
                prefill_step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
            )
            lowered = jitted.lower(param_shapes, batch)
        else:  # decode
            cache_shapes = model.cache_shape(spec.global_batch, spec.seq_len)
            cspecs = partition.resolve_spec_tree(
                cache_shapes, model.cache_logical(), mesh)

            def serve_step(params, cache, b):
                return model.decode_step(params, cache, b)

            jitted = jax.jit(
                serve_step,
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs), _ns(mesh, bspecs)),
                out_shardings=(None, _ns(mesh, cspecs)),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(param_shapes, cache_shapes, batch)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    # loop-aware static analysis: cost_analysis counts while bodies ONCE, so
    # scanned-layer models are undercounted by ~n_layers without this.
    deep = hlo_analysis.analyze(hlo_text)
    n_chips = int(np.prod(mesh.devices.shape))
    record = {
        "arch": canonical(arch),
        "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "multi_pod": multi_pod,
        "status": "ok",
        "seconds_lower": round(t_lower, 1),
        "seconds_compile": round(t_compile, 1),
        "num_params": cfg.num_params(),
        "num_active_params": cfg.num_active_params(),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + mem.output_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "hlo": {
            "flops": deep["flops"],
            "collective_bytes": deep["collective_bytes"],
            "collective_total": deep["collective_total"],
            "while_trip_counts": deep["while_trip_counts"],
        },
        "collectives_flat": coll,
        "n_chips": n_chips,
        "microbatches": microbatches,
        "fsdp": bool(fsdp),
        "layout": layout,
    }
    return record


def cell_path(arch, shape_name, multi_pod, tag=""):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = f"_{tag}" if tag else ""
    return os.path.join(
        OUT_DIR, f"{canonical(arch)}__{shape_name}__{mesh_tag}{suffix}.json")


HBM_BUDGET = 15.2e9  # v5e 16 GB minus runtime reserve


def run_one(arch, shape_name, multi_pod, force=False, microbatches=1, tag="",
            overrides=None, auto_fit=True, fsdp=None, layout="tp"):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = cell_path(arch, shape_name, multi_pod, tag)
    if os.path.exists(path) and not force:
        print(f"[skip] {path} exists")
        return json.load(open(path))
    reason = skip_reason(arch, shape_name)
    if reason:
        record = {"arch": canonical(arch), "shape": shape_name,
                  "multi_pod": multi_pod, "status": "skipped", "reason": reason}
    else:
        print(f"[run ] {canonical(arch)} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'} ...", flush=True)
        try:
            attempts = []
            mb = microbatches
            record = None
            while True:
                try:
                    record = lower_cell(arch, shape_name, multi_pod,
                                        microbatches=mb, overrides=overrides,
                                        fsdp=fsdp, layout=layout)
                except Exception:
                    try:  # XLA scan-unstack SPMD bug: retry with static slices
                        record = lower_cell(arch, shape_name, multi_pod,
                                            microbatches=mb, overrides=overrides,
                                            unroll_micro=True, fsdp=fsdp,
                                            layout=layout)
                        record["unrolled_micro"] = True
                    except Exception:
                        if record is not None:  # keep the last good attempt
                            record["retry_error"] = traceback.format_exc()[-800:]
                            break
                        raise
                peak = record["memory"]["peak_estimate_bytes"]
                attempts.append({"microbatches": mb, "peak_bytes": peak})
                fits = peak <= HBM_BUDGET
                # microbatch rows must still divide the data axis, or the
                # per-micro batch replicates (redundant compute per shard)
                dp = (32 if multi_pod else 16) * (16 if layout == "dp" else 1)
                gb = SHAPES[shape_name].global_batch
                can_split = (SHAPES[shape_name].kind == "train" and auto_fit
                             and gb % (mb * 2) == 0
                             and (gb // (mb * 2)) % dp == 0)
                if fits or not can_split:
                    break
                mb *= 2
                print(f"       peak {peak/2**30:.1f}GiB > budget; retry mb={mb}",
                      flush=True)
            record["fit_attempts"] = attempts
            record["fits_hbm"] = attempts[-1]["peak_bytes"] <= HBM_BUDGET
            print(f"       ok: compile={record['seconds_compile']}s "
                  f"flops/dev={record['hlo']['flops']:.3e} "
                  f"coll={record['hlo']['collective_total']:.3e}B "
                  f"peak_mem={record['memory']['peak_estimate_bytes']/2**30:.2f}GiB",
                  flush=True)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            record = {"arch": canonical(arch), "shape": shape_name,
                      "multi_pod": multi_pod, "status": "failed",
                      "error": f"{type(e).__name__}: {e}",
                      "trace": traceback.format_exc()[-2000:]}
            print(f"       FAILED: {type(e).__name__}: {str(e)[:200]}", flush=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp", "tp_nosp", "hybrid"])
    ap.add_argument("--no-auto-fit", action="store_true")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]
                rec = run_one(arch, shape_name, mp, force=args.force,
                              microbatches=args.microbatches, tag=args.tag,
                              fsdp=fsdp, layout=args.layout,
                              auto_fit=not args.no_auto_fit)
                failures += rec.get("status") == "failed"
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

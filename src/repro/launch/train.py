"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised here (and in examples/): synthetic or DynLP-pseudo-
labeled data, checkpoint/resume (fault tolerance: kill and rerun the same
command — it resumes from the latest complete step), preemption guard,
straggler monitor, optional int8 gradient compression for the data-parallel
reduction.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config, get_smoke_config
from repro.models.api import build_model
from repro.models.common import ShapeSpec
from repro.launch.specs import make_batch
from repro.training import optim
from repro.training.resilience import PreemptionGuard, StragglerMonitor
from repro.training.trainer import make_train_step


def synthetic_batch(cfg, batch: int, seq: int, step: int):
    """Deterministic synthetic LM batch (markov-ish token stream)."""
    rng = np.random.default_rng(step)
    spec = ShapeSpec("t", seq_len=seq, global_batch=batch, kind="train")
    b = make_batch(cfg, spec, seed=step)
    # make labels learnable: next-token of a periodic sequence
    if "tokens" in b and "labels" in b:
        base = rng.integers(0, cfg.vocab, size=(batch, 1))
        ramp = (base + np.arange(seq)[None, :]) % cfg.vocab
        b["tokens"] = jnp.asarray(ramp, jnp.int32)
        b["labels"] = jnp.asarray((ramp + 1) % cfg.vocab, jnp.int32)
    return b


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt_cfg = optim.OptConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches))

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = optim.init_state(params)
    start = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr is not None and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = mgr.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"[resume] from step {start}")

    guard = PreemptionGuard()
    monitor = StragglerMonitor()
    losses = []
    for step in range(start, args.steps):
        monitor.start_step()
        batch = synthetic_batch(cfg, args.batch, args.seq, step)
        params, opt_state, loss, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(loss)
        ev = monitor.end_step()
        if ev:
            print(f"[straggler] step {ev.step}: {ev.seconds:.2f}s "
                  f"(median {ev.median:.2f}s)")
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f}", flush=True)
        if mgr is not None and ((step + 1) % args.ckpt_every == 0
                                or guard.requested or step == args.steps - 1):
            mgr.save_async(step + 1, {"params": params, "opt": opt_state})
        if guard.requested:
            print("[preempt] checkpointed, exiting cleanly")
            break
    if mgr is not None:
        mgr.wait()
    guard.restore()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()

"""Static analyzer for compiled HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, so any scan-over-layers model is undercounted by ~n_layers.  This
module re-derives the true totals from ``compiled.as_text()``:

  * splits the module into computations,
  * finds every ``while``, recovers its trip count from the condition's
    ``compare(iv, constant)``,
  * counts dot/convolution FLOPs per computation from the inline operand
    types (optimized HLO carries them),
  * counts collective bytes (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute) from result shapes,
  * propagates both through the call graph (fusions, calls, while bodies ×
    trip count, conditionals take the max branch).

Numbers are PER DEVICE (SPMD-partitioned module), matching the roofline
convention compute_term = flops_per_device / peak_per_chip.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)(?: \([^)]*\))? \([^)]*\) -> .* \{",)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    coll_bytes: dict | None = None
    calls: list | None = None  # list of (callee, multiplier)

    def __post_init__(self):
        if self.coll_bytes is None:
            self.coll_bytes = {}
        if self.calls is None:
            self.calls = []


def split_computations(text: str) -> dict[str, list[str]]:
    """computation name -> list of body lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*->\s*[^{]*\{", stripped)
        if m and not stripped.startswith("ROOT"):
            name = m.group(2)
            if m.group(1):
                name = "ENTRY"
            cur = name
            comps[cur] = []
        elif stripped == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(stripped)
    return comps


_DOT_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+dot\(([^)]*)\)"
)
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|\w+\[[\d,]*\])")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONV_RE = re.compile(r"=\s+(\w+)\[([\d,]*)\][^ ]*\s+convolution\(")
_CALL_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")


def _symbol_table(lines: list[str]) -> dict[str, str]:
    """instruction name -> result type string (optimized HLO omits operand
    types inline, so dot FLOPs need this lookup)."""
    table = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = m.group(2)
    return table


def _dot_flops(line: str, table: dict[str, str]) -> float:
    m = _DOT_RE.search(line)
    if not m:
        return 0.0
    out_elems = _shape_elems(m.group(2))
    cm = _CONTRACT_RE.search(line)
    # Some HLO printers carry operand types inline (``dot(f32[32,64]{1,0}
    # %lhs, ...)``); others print bare names that need the symbol table.
    lhs_shapes = _SHAPE_RE.findall(m.group(3))[:1]
    if not lhs_shapes:
        operands = [a.strip().lstrip("%") for a in m.group(3).split(",")]
        lhs_ty = table.get(operands[0], "") if operands else ""
        lhs_shapes = _SHAPE_RE.findall(lhs_ty)
    if cm is None or not lhs_shapes:
        return 2.0 * out_elems  # degenerate fallback
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    cdims = [int(d) for d in cm.group(1).split(",") if d]
    csize = 1
    for d in cdims:
        if d < len(lhs_dims):
            csize *= lhs_dims[d]
    return 2.0 * out_elems * csize


def _conv_flops(line: str, table: dict[str, str]) -> float:
    m = _CONV_RE.search(line)
    if not m:
        return 0.0
    out_elems = _shape_elems(m.group(2))
    wm = re.search(r"window=\{size=([\dx]+)", line)
    ksize = 1
    if wm:
        for d in wm.group(1).split("x"):
            ksize *= int(d)
    args = line.split("convolution(")[1].split(")")[0]
    operands = [a.strip().lstrip("%") for a in args.split(",")]
    feat = 1
    if len(operands) > 1:
        rhs_shapes = _SHAPE_RE.findall(table.get(operands[1], ""))
        if rhs_shapes:
            dims = [int(d) for d in rhs_shapes[0][1].split(",") if d]
            if len(dims) >= 2:
                feat = dims[-2]
    return 2.0 * out_elems * ksize * feat


def analyze(text: str, default_trip: int = 1) -> dict:
    comps = split_computations(text)
    stats: dict[str, CompStats] = {}
    trip_counts: dict[str, int] = {}  # body computation -> trips

    # Pass 1: per-computation local stats + call edges
    for name, lines in comps.items():
        st = CompStats()
        table = _symbol_table(lines)
        for line in lines:
            if " dot(" in line:
                st.flops += _dot_flops(line, table)
            elif " convolution(" in line:
                st.flops += _conv_flops(line, table)
            coll = next((c for c in COLLECTIVES if f" {c}(" in line
                         or f" {c}-start(" in line), None)
            if coll:
                ty = line.split("=", 1)[1].split(coll)[0] if "=" in line else line
                st.coll_bytes[coll] = st.coll_bytes.get(coll, 0) + _type_bytes(ty)
            if " while(" in line:
                body = _CALL_RE.search(line)
                cond = _COND_RE.search(line)
                if body:
                    trips = default_trip
                    if cond and cond.group(1) in comps:
                        consts = []
                        for cl in comps[cond.group(1)]:
                            if "compare(" in cl:
                                consts += [int(c) for c in _CONST_CMP_RE.findall(cl)]
                        # fallback: constants defined in the condition comp
                        if not consts:
                            for cl in comps[cond.group(1)]:
                                consts += [int(c) for c in _CONST_CMP_RE.findall(cl)]
                        if consts:
                            trips = max(consts)
                    st.calls.append((body.group(1), trips))
                    trip_counts[body.group(1)] = trips
            elif " fusion(" in line or " call(" in line or "custom-call" in line:
                cm2 = _CALL_RE.search(line)
                if cm2:
                    st.calls.append((cm2.group(1), 1))
            elif " conditional(" in line:
                for branch in re.findall(r"%?([\w\.\-]+)", line):
                    if branch in comps and branch != name:
                        st.calls.append((branch, 1))
            elif " map(" in line or " reduce(" in line or " scatter(" in line \
                    or " sort(" in line or " select-and-scatter(" in line:
                cm2 = _CALL_RE.search(line)
                if cm2:
                    st.calls.append((cm2.group(1), 1))
        stats[name] = st

    # Pass 2: recursive totals from ENTRY (memoized)
    memo: dict[str, tuple[float, dict]] = {}

    def total(name: str, seen=()) -> tuple[float, dict]:
        if name in memo:
            return memo[name]
        if name not in stats or name in seen:
            return 0.0, {}
        st = stats[name]
        fl = st.flops
        cb = dict(st.coll_bytes)
        for callee, mult in st.calls:
            cfl, ccb = total(callee, seen + (name,))
            fl += mult * cfl
            for k, v in ccb.items():
                cb[k] = cb.get(k, 0) + mult * v
        memo[name] = (fl, cb)
        return memo[name]

    entry = "ENTRY" if "ENTRY" in stats else next(iter(stats), None)
    flops, coll = total(entry) if entry else (0.0, {})
    return {
        "flops": flops,
        "collective_bytes": coll,
        "collective_total": float(sum(coll.values())),
        "num_computations": len(comps),
        "while_trip_counts": trip_counts,
    }

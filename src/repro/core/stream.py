"""Compile-once streaming engine for dynamic batch updates (tentpole).

``DynLP.step`` rebuilds and re-stages the device ``PropagationProblem``
from scratch every Δ_t — at its exact (U, K) when ``auto_bucket=False``
(a recompile on nearly every batch, the recomputation tax the paper
eliminates), and even bucketed it allocates fresh device buffers per
batch and serializes host work against the solve.  ``StreamEngine`` is
the amortized version:

  * **Bucket ladder** — every snapshot is padded up the geometric
    ``(U_bucket, K_bucket)`` ladder (``snapshot.bucket`` ×
    ``snapshot.bucket_k``), so an unbounded stream compiles the
    propagation entry point a bounded number of times
    (``snapshot.ladder_size``).
  * **Persistent donated buffers** — per bucket the engine keeps two
    generations of device buffers for ``(nbr, wgt, wl0, wl1, valid)``
    plus the ``f``/``frontier`` vectors.  Batch t+1's snapshot is
    committed into the generation *not* referenced by the in-flight
    batch t solve, with the stale generation donated so XLA recycles
    the allocation instead of growing the arena every Δ_t.
  * **Staged transfers** — ``submit``/``drain`` split the step: ``submit``
    applies Δ_t on the host, stages its topology to the device, and
    launches the solve; it only *then* blocks on the previous batch.
    Host graph update + H2D of batch t+1 overlap device propagation of
    batch t (JAX dispatch is async on every backend).

``step`` (submit + drain) keeps the exact ``DynLP.step`` semantics and
numerics — streamed labels are allclose to fresh per-batch DynLP results
(tests/test_stream.py); the solve itself routes through the backend
registry of ``kernels.ops``: the engine resolves each ladder rung's
backend once at rung entry (``backend="auto"`` may pick the ``bsr`` MXU
path on TPU when the measured post-reorder block fill factor clears the
registry's threshold), then reuses the decision for every batch in the
rung.  A ``bsr`` rung stages snapshots in the paper's Step-1 component
order (``core.components.component_order``) so the adjacency densifies
into tiles, derives the per-edge tile-slot map per Δ_t
(``kernels.bsr_spmv.ell_bsr_layout``), and compiles one tile budget per
rung — a Δ_t whose slot requirement overflows the budget falls back to
``ell_pallas`` with a once-per-rung warning, mirroring the halo-overflow
contract.

With ``mesh=`` the same stream spans a device mesh: rows of every bucket
shard over all mesh axes through the ``core.distributed`` shard_map
transport, buckets are padded to a multiple of the device count, and one
partition plan per ladder rung is reused across every batch in that rung.
``transport=`` picks the per-sweep collective: ``"allgather"`` ships
every shard's full F block (topology-free); ``"halo"`` ships only each
shard's export prefix, with the export budget compiled once per rung
(``StreamHaloPlan``) and the export row layout re-derived per Δ_t on the
host — a batch whose exports overflow the rung's budget falls back to
all-gather for that Δ_t with a logged warning.  ``"auto"`` (default)
measures the rung's export fraction at rung entry and picks halo when it
is small enough to pay; ``"auto:measured"`` instead times one real sweep
per transport at rung entry and caches the winner (two extra probe
compiles per rung — the cost of measuring reconstruct overhead the
byte-count heuristic can't see).  Labels stay bit-identical to the
single-device engine under every transport
(tests/test_stream_sharded.py, tests/test_stream_property.py); a
``bsr`` rung stages in the halo row layout under BOTH transports so its
labels are bit-identical across them too.  See docs/streaming.md
§Transports and docs/backends.md.

The ``landmark`` backend changes the STAGING, not the solve: once its
lazily-sampled landmark state is ready and the registry resolves the
engine's knob to ``"landmark"``, snapshots restrict to the hot working
set (rows touched by a Δ_t within the last ``hot_ttl`` batches), cold
unlabeled neighbors fold their committed fractional labels into the
supernode weights (an exact boundary condition — see
``core.snapshot.build_host_problem``), and each commit additionally
runs the low-rank cold pass of ``kernels.landmark_propagate`` so the
cold tail keeps moving at O(N·R).  Staged hot problems ride the same
buffers, plans and transports as every exact backend; labels carry an
agreement-floor contract instead of bit-equality (docs/backends.md,
``benchmarks/landmark_lp.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed
from repro.core.components import compact_labels, component_order
from repro.core.dynlp import gprime_components
from repro.core.init_labels import supernode_init
from repro.core.propagate import PropagationProblem
from repro.core.snapshot import (DeviceLabelView, HostSnapshot, LabelView,
                                 apply_halo_layout, bucket, bucket_k,
                                 build_host_problem, publish_device_view,
                                 reorder_host_snapshot)
from repro.graph import partition
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.kernels import ops
from repro.kernels.bsr_spmv import ell_bsr_layout
from repro.kernels.landmark_propagate import LandmarkConfig, LandmarkState

logger = logging.getLogger(__name__)

TRANSPORTS = ("allgather", "halo", "auto", "auto:measured")

# auto picks halo for a rung iff its compiled export budget would move
# at most this fraction of the full all-gather bytes per sweep.
AUTO_EXPORT_FRACTION = 0.5


@dataclasses.dataclass
class StreamStats:
    iterations: int
    converged: bool
    num_components: int
    frontier_size: int
    num_unlabeled: int
    wall_ms: float
    max_residual: float
    bucket: tuple[int, int]  # (U_bucket, K_bucket) device shape this Δ_t;
    # (0, 0) for a no-op Δ_t whose empty frontier staged nothing
    recompiled: bool  # True iff this Δ_t triggered any XLA compile
    transport: str = "single"  # collective this Δ_t rode: "single" (no
    # mesh), "allgather", "halo", or "none" (no-op Δ_t, nothing solved)
    backend: str = "none"  # registry backend that solved this Δ_t
    # ("ref"/"ell_pallas"/"bsr"/"landmark"; "none" for a no-op Δ_t) — a
    # bsr rung's slot-budget overflow shows up here as an "ell_pallas"
    # batch; a "landmark" batch solved the hot working set only


@functools.partial(jax.jit, donate_argnums=(0,))
def _adopt(old: PropagationProblem, new: PropagationProblem) -> PropagationProblem:
    """Copy ``new`` into ``old``'s (donated) device storage."""
    return new


@dataclasses.dataclass
class _Pending:
    res: object  # PropagateResult (device, possibly still in flight);
    # None for a no-op batch whose frontier was empty (nothing to solve)
    unl_ids: np.ndarray
    t0: float
    num_components: int
    frontier_size: int
    bucket: tuple[int, int]
    recompiled: bool
    # Post-batch host state captured at submit (after the previous drain
    # folded its labels in): becomes the committed LabelView at drain,
    # with this batch's solved rows folded over view_f.
    view_labels: np.ndarray
    view_alive: np.ndarray
    view_f: np.ndarray
    transport: str = "single"
    backend: str = "none"
    # row-layout inverse (halo export-prefix or BSR component order):
    # solved row for original row i is rows[i] (None = staged unpermuted)
    rows: np.ndarray | None = None
    # landmark batches only: the cold unlabeled rows excluded from the
    # staged hot problem — drain serves them through the low-rank pass
    cold_ids: np.ndarray | None = None


@dataclasses.dataclass
class _Staging:
    """One Δ_t's resolved staging decision (plan, layout, backend)."""

    staged: HostSnapshot  # possibly row-permuted
    backend: str  # registry backend solving this Δ_t
    transport: str  # "single" | "allgather" | "halo"
    plan: object | None = None  # StreamShardPlan/StreamHaloPlan (mesh only)
    rows: np.ndarray | None = None  # old row -> staged row (fold-back)
    perm: np.ndarray | None = None  # staged row -> old row (f0/frontier)
    slot: np.ndarray | None = None  # bsr per-edge tile-slot map
    num_slots: int = 0  # bsr compiled tile budget (0 otherwise)


class StreamEngine:
    """Stateful compile-once streaming DynLP over a ``DynamicGraph``."""

    def __init__(
        self,
        graph: DynamicGraph,
        delta: float = 1e-4,
        tau: float | None = None,
        max_iters: int = 200_000,
        max_degree: int | None = None,
        backend: str | None = None,
        block_rows: int = 512,
        interpret: bool | None = None,
        mesh: jax.sharding.Mesh | None = None,
        max_k: int | None | str = "auto",
        transport: str | None = None,
        read_placement: object = "auto",
        ingest: object = None,
        landmark: object = None,
        ingest_order: str = "arrival",
    ):
        self.graph = graph
        # ingest: who nominates kNN candidates for arriving batches.
        # None/"host" = the blockwise host staging path (graph default);
        # "device" = a DeviceIngestor running the Pallas/XLA argkmin
        # kernel over the device-resident embedding store
        # (docs/ingestion.md), adopting any rows already in the graph;
        # or pass a pre-built selector instance.  Either way the labels
        # and topology are bit-identical — only where the candidate
        # search runs changes.  With a mesh, "device" picks the
        # row-sharded store automatically (move-the-batch argkmin,
        # docs/ingestion.md §Sharded store) — same labels/topology again,
        # the store just spreads over the mesh's HBM.
        if ingest in (None, "host"):
            self.ingestor = None
        elif ingest == "device":
            from repro.ingest import DeviceIngestor
            self.ingestor = DeviceIngestor(graph.emb_dim, mesh=mesh)
            if graph.num_nodes:
                self.ingestor.attach(graph)
        elif isinstance(ingest, str):
            raise ValueError(f"unknown ingest mode {ingest!r}; want "
                             "'host', 'device', or a selector instance")
        else:
            self.ingestor = ingest
        # ingest_order: how an arriving batch's rows are ordered before id
        # assignment.  "arrival" keeps the caller's order; "locality" runs
        # data.synth.cosine_locality_order over each admitted batch so
        # consecutive ids are angular neighbors — ids land halo-friendly
        # (fewer cross-shard references ⇒ smaller export prefixes; the
        # top-rung export-fraction delta is recorded in BENCH_ingest.json).
        # Reordering happens before ids exist, so engines that share a
        # stream agree bit-for-bit as long as they share this knob.
        if ingest_order not in ("arrival", "locality"):
            raise ValueError(f"unknown ingest_order {ingest_order!r}; want "
                             "'arrival' or 'locality'")
        self.ingest_order = ingest_order
        self.delta = delta
        self.tau = tau
        self.max_iters = max_iters
        self.max_degree = max_degree
        self.backend = backend
        self.block_rows = block_rows
        self.interpret = interpret
        # mesh: shard the stream — rows of every bucket are partitioned
        # over ALL mesh axes (core.distributed shard_map transport); row
        # buckets are padded to a multiple of the device count so each
        # rung shards evenly, and one partition plan per rung is reused
        # across every batch that lands in it.
        self.mesh = mesh
        # transport: per-sweep collective of the sharded solve.  An
        # explicit "halo" demands a mesh; when left unset the
        # REPRO_STREAM_TRANSPORT env var replaces the "auto" default —
        # as a fleet-wide hint it is simply ignored on mesh-less engines
        # (mirroring the REPRO_BACKEND degrade semantics).
        if transport is not None and transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; want one "
                             f"of {TRANSPORTS}")
        if transport == "halo" and mesh is None:
            raise ValueError("transport='halo' requires mesh= (a "
                             "single-device stream has no collective)")
        if transport is None:
            transport = os.environ.get("REPRO_STREAM_TRANSPORT", "auto")
            if transport not in TRANSPORTS:
                raise ValueError(
                    f"REPRO_STREAM_TRANSPORT={transport!r} invalid; want "
                    f"one of {TRANSPORTS}")
        self.transport = transport
        # max_k: cap the ELL neighbor axis (heaviest-edge truncation) so a
        # hub vertex can't drag the K-bucket ladder up (core.snapshot).
        # Default "auto" = 4x the graph's kNN k (measured at parity on
        # hub-heavy synthetics, BENCH_stream.json max_k_accuracy); pass
        # max_k=None to stream untruncated.
        if isinstance(max_k, str) and max_k != "auto":
            raise ValueError(
                f"max_k={max_k!r} invalid; want an int, None (uncapped), "
                "or 'auto' (4x the graph's kNN k)")
        self.max_k = 4 * graph.k if max_k == "auto" else max_k
        # Pin the backend knob at construction: the fleet-wide
        # REPRO_BACKEND hint is read ONCE here — row padding and the
        # candidate set below depend on it, so a mid-stream env flip must
        # not hand a later rung a backend the engine never prepared for
        # (rung resolution passes use_env=False).  A hint with no
        # sharded form degrades to auto, mirroring select_backend.
        knob = backend
        if knob in (None, "auto"):
            env = os.environ.get("REPRO_BACKEND", "auto")
            knob = (env if env != "auto" and (
                mesh is None or ops.backend_spec(env).sharded) else "auto")
        self._backend_knob = knob
        # The registry tells us up front which backends the pinned knob
        # could ever resolve to; only when bsr is among them do we pay
        # block-size row padding and per-rung fill measurement.
        self._backend_candidates = (
            ops.backend_candidates(None, sharded=mesh is not None)
            if knob == "auto" else (ops.backend_spec(knob).name,))
        self._bsr_block = ops.bsr_block_size()
        # landmark: configuration of the approximate hot/cold backend
        # (kernels.landmark_propagate).  None = off, unless the pinned
        # knob names "landmark" — then a default config activates (the
        # knob is meaningless without the state); True = default config;
        # a dict or LandmarkConfig tunes it.  With backend="auto" and a
        # config, the registry may pick landmark per its eligibility rule
        # (LANDMARK_AUTO_MIN_ROWS) once the state is ready; the decision
        # then LATCHES for the engine's lifetime so every later rung
        # carries one consistent contract (docs/backends.md).
        if landmark is None and knob == "landmark":
            landmark = True
        if landmark is True:
            landmark = LandmarkConfig()
        elif isinstance(landmark, dict):
            landmark = LandmarkConfig(**landmark)
        self._lm = (LandmarkState(landmark, graph.emb_dim)
                    if landmark is not None else None)
        self._lm_streaming = False  # the hot/cold latch (see above)
        # batch index each vertex was last touched by a Δ_t — the hot
        # working set is everything with age <= hot_ttl
        self._touched_at = np.full(graph.num_nodes, -1, np.int64)
        self.landmark_batches = 0  # batches solved on the hot/cold split
        self.landmark_cold_rows = 0  # cold rows served by the low-rank pass
        row_multiple = int(mesh.devices.size) if mesh is not None else 1
        if "bsr" in self._backend_candidates:
            # every shard's row block must tile evenly into BSR block rows
            row_multiple *= self._bsr_block
        self._row_multiple = row_multiple if row_multiple > 1 else None
        self._plans: dict[tuple, distributed.StreamShardPlan] = {}
        self._halo_plans: dict[tuple, distributed.StreamHaloPlan] = {}
        self.plan_builds = 0  # partition plans built — ≤ rungs touched
        # per-rung transport state: mode fixed at rung entry ("halo" or
        # "allgather"), export budget compiled into the rung's halo plan
        self._transport_modes: dict[tuple[int, int], str] = {}
        self._export_budgets: dict[tuple[int, int], int] = {}
        self._overflow_warned: set[tuple[int, int]] = set()
        self.halo_batches = 0  # batches solved on the halo transport
        self.transport_overflows = 0  # halo batches forced onto all-gather
        # per-rung backend state (registry decision fixed at rung entry)
        # and the bsr tile-slot budget compiled into the rung's runner
        self._backend_modes: dict[tuple[int, int], str] = {}
        self._slot_budgets: dict[tuple[int, int], int] = {}
        self._slot_overflow_warned: set[tuple[int, int]] = set()
        self.bsr_batches = 0  # batches solved on the bsr backend
        self.backend_overflows = 0  # bsr batches forced onto ell_pallas
        self._measured: dict[tuple[int, int], dict] = {}  # auto:measured
        # rungs whose auto:measured decision came from a PERSISTED probe
        # cache (core.persistence) instead of a fresh timed sweep
        self.probe_cache_hits = 0
        # per-engine max_k truncation-warning dedup (a fresh engine warns
        # again instead of inheriting another engine's state)
        self._max_k_warned: set[tuple[int, int]] = set()
        # bucket_key -> two generations of device problem buffers; the
        # generation toggles per commit so the in-flight solve never shares
        # storage with the snapshot being staged.
        self._buffers: dict[tuple[int, int], list[PropagationProblem | None]] = {}
        self._gen: dict[tuple[int, int], int] = {}
        self._pending: _Pending | None = None
        self.bucket_keys: set[tuple[int, int]] = set()
        self.recompile_count = 0  # batches that triggered any XLA compile
        self.batches = 0
        self.commits = 0  # batches whose results have been drained
        # Query-side committed snapshot (serving read path): refreshed at
        # every drain, never mutated in place — readers hold a consistent
        # view while the next batch's solve is in flight.
        self._view = LabelView.from_graph(graph, commit_id=0)
        # Device twin of the committed view: published lazily on the
        # first ``device_view()`` call, then eagerly at every drain (the
        # H2D dispatches async, overlapping the next batch's host work).
        # ``read_placement="auto"`` resolves to the mesh's read replica /
        # row sharding (core.distributed.read_placement) or the default
        # device; pass an explicit jax.Device or Sharding to override.
        self._read_placement = (distributed.read_placement(mesh)
                                if read_placement == "auto" else read_placement)
        self._device_view: DeviceLabelView | None = None

    # ------------------------------------------------------------------ #
    def _plan_for(self, key: tuple[int, int], backend: str,
                  num_slots: int = 0) -> distributed.StreamShardPlan:
        """Partition plan for one ladder rung — built once, then reused
        for every batch whose padded snapshot lands in that rung.  A bsr
        rung's slot-budget overflow additionally builds the rung's
        ell_pallas twin (+1 plan per recorded overflow, like halo)."""
        pkey = (key, backend, num_slots)
        plan = self._plans.get(pkey)
        if plan is None:
            plan = distributed.build_stream_plan(
                self.mesh, key, backend=backend,
                delta=self.delta, max_iters=self.max_iters,
                block_rows=self.block_rows, interpret=self.interpret,
                donate=True,
                block_size=self._bsr_block if backend == "bsr" else 0,
                num_slots=num_slots if backend == "bsr" else 0)
            self._plans[pkey] = plan
            self.plan_builds += 1
        return plan

    # ------------------------------------------------------------------ #
    def _halo_plan_for(self, key: tuple[int, int], export_max: int,
                       backend: str,
                       num_slots: int = 0) -> distributed.StreamHaloPlan:
        """Halo partition plan for one ladder rung — the export budget is
        fixed at rung entry, so like the all-gather plan it is built once
        and reused for every same-rung batch."""
        hkey = (key, export_max, backend, num_slots)
        plan = self._halo_plans.get(hkey)
        if plan is None:
            plan = distributed.build_stream_halo_plan(
                self.mesh, key, export_max, backend=backend,
                delta=self.delta, max_iters=self.max_iters,
                block_rows=self.block_rows, interpret=self.interpret,
                donate=True,
                block_size=self._bsr_block if backend == "bsr" else 0,
                num_slots=num_slots if backend == "bsr" else 0)
            self._halo_plans[hkey] = plan
            self.plan_builds += 1
        return plan

    # ------------------------------------------------------------------ #
    def _resolve_rung_backend(self, key: tuple[int, int],
                              nbr_staged: np.ndarray, n_valid: int):
        """Fix the rung's backend at rung entry through the registry.

        When bsr is among the candidates the post-reorder block fill
        factor is measured from this first snapshot (already permuted
        into the order bsr would stage) and fed to the registry's
        ``auto_eligible`` predicates; an explicit/env ``"bsr"`` skips the
        eligibility question but still derives the layout, whose slot
        requirement — scaled by the rung's remaining fill factor
        ``key[0] / n_valid`` (same reasoning as
        ``graph.partition.export_budget``: a rung entered at ``n_valid``
        rows grows to its padded row count, and block rows densify with
        it) and padded up the ``bucket_k`` ladder — becomes the rung's
        compiled tile budget.  Returns (backend, layout-or-None).
        """
        bl = None
        fill = None
        if "bsr" in self._backend_candidates:
            bl = ell_bsr_layout(nbr_staged, self._bsr_block)
            fill = bl.fill
        backend = ops.select_backend(
            self._backend_knob, num_rows=key[0],
            sharded=self.mesh is not None, block_fill=fill,
            use_env=False)  # the hint was pinned at construction
        self._backend_modes[key] = backend
        if backend == "bsr":
            grow = key[0] / max(1, n_valid)
            cap = min(key[0] // self._bsr_block,
                      key[1] * self._bsr_block)  # ≤ BS rows × K edges each
            self._slot_budgets[key] = min(
                bucket_k(int(np.ceil(bl.num_slots * grow))), max(cap, 1))
            logger.info(
                "stream backend: rung %s -> bsr (block fill %.2f, slot "
                "budget %d)", key, fill, self._slot_budgets[key])
        else:
            logger.info("stream backend: rung %s -> %s", key, backend)
        return backend, bl

    # ------------------------------------------------------------------ #
    def _slot_overflow(self, key: tuple[int, int], needed: int) -> None:
        """Record a bsr tile-budget overflow (warned once per rung)."""
        if key not in self._slot_overflow_warned:
            self._slot_overflow_warned.add(key)
            logger.warning(
                "stream bsr: rung %s needs %d tile slots but the compiled "
                "budget is %d — falling back to ell_pallas for this batch "
                "(warned once per rung)", key, needed,
                self._slot_budgets[key])
        self.backend_overflows += 1

    # ------------------------------------------------------------------ #
    def _note_touched(self, effect) -> None:
        """Stamp the vertices a Δ_t touched with the current batch index
        (the hot working set is everything stamped within ``hot_ttl``)."""
        g = self.graph
        if len(self._touched_at) < g.num_nodes:
            grown = np.full(g.num_nodes, -1, np.int64)
            grown[: len(self._touched_at)] = self._touched_at
            self._touched_at = grown
        self._touched_at[effect.affected] = self.batches
        self._touched_at[effect.new_ids] = self.batches

    # ------------------------------------------------------------------ #
    def _landmark_gate(self) -> np.ndarray | None:
        """Decide whether this Δ_t streams the hot/cold split; returns
        the hot row mask (or None for plain exact staging).

        The decision must precede the snapshot build (the restriction
        changes the bucket the batch lands in), so it cannot ride the
        per-rung resolution the exact backends use: the registry is
        consulted with the FULL unlabeled count and the landmark state's
        readiness, and the first "landmark" verdict latches for the
        engine's lifetime — every later batch stays on the hot/cold
        contract even when deletions shrink the graph back under the
        auto threshold (per-rung backend modes stay consistent that way).
        """
        g = self.graph
        lm = self._lm
        store = getattr(self.ingestor, "store", None)
        if not lm.ready:
            lm.refresh(g, store)  # lazy activation; cheap no-op early on
        if not self._lm_streaming:
            n_unl = int((g.alive & (g.labels == UNLABELED)).sum())
            resolved = ops.select_backend(
                self._backend_knob, num_rows=bucket(n_unl),
                sharded=self.mesh is not None,
                landmark_ready=lm.ready, use_env=False)
            if resolved != "landmark" or not lm.ready:
                return None
            self._lm_streaming = True
            logger.info(
                "stream landmark: hot/cold split active (%d landmarks, "
                "hot_ttl %d, %d unlabeled rows)", lm.num_landmarks,
                lm.cfg.hot_ttl, n_unl)
        age = self.batches - self._touched_at
        return (self._touched_at >= 0) & (age <= lm.cfg.hot_ttl)

    # ------------------------------------------------------------------ #
    def _landmark_commit(self, p: "_Pending") -> None:
        """Commit-boundary landmark work for a hot/cold batch: refresh
        the factorization incrementally (new rows get assignments; the
        landmark label vector is re-read in O(L)) and fold the low-rank
        estimates over the batch's cold unlabeled rows — rows with no
        assignment (no valid landmark yet) keep their committed labels."""
        g = self.graph
        lm = self._lm
        lm.refresh(g, getattr(self.ingestor, "store", None))
        est, wsum = lm.cold_values(lm.landmark_values(g))
        ids = p.cold_ids
        sel = ids[wsum[ids] > 0]
        g.f[sel] = est[sel]
        p.view_f[sel] = est[sel]
        self.landmark_batches += 1
        self.landmark_cold_rows += len(sel)

    # ------------------------------------------------------------------ #
    def _stage_single(self, host: HostSnapshot) -> _Staging:
        """Resolve a mesh-less Δ_t: rung backend via the registry; bsr
        rungs component-reorder the rows (Step-1 clustering) and derive
        the per-edge tile-slot map, falling back to ell_pallas when a
        batch's slot requirement overflows the rung's compiled budget."""
        key = host.bucket_key
        backend = self._backend_modes.get(key)
        order = bl = staged = inv = None
        if backend is None:
            if "bsr" in self._backend_candidates:
                order = component_order(host.nbr)
                staged, inv = reorder_host_snapshot(host, order)
                backend, bl = self._resolve_rung_backend(
                    key, staged.nbr, len(host.unl_ids))
            else:
                backend, bl = self._resolve_rung_backend(
                    key, host.nbr, len(host.unl_ids))
        if backend != "bsr":
            return _Staging(staged=host, backend=backend, transport="single")
        if order is None:
            order = component_order(host.nbr)
            staged, inv = reorder_host_snapshot(host, order)
        if bl is None:
            bl = ell_bsr_layout(staged.nbr, self._bsr_block)
        if bl.num_slots > self._slot_budgets[key]:
            self._slot_overflow(key, bl.num_slots)
            return _Staging(staged=host, backend="ell_pallas",
                            transport="single")
        self.bsr_batches += 1
        return _Staging(staged=staged, backend="bsr", transport="single",
                        rows=inv[: len(host.unl_ids)], perm=order,
                        slot=bl.slot, num_slots=self._slot_budgets[key])

    # ------------------------------------------------------------------ #
    def _stage_mesh(self, host: HostSnapshot) -> _Staging:
        """Resolve a mesh Δ_t: rung backend + transport mode + plan.

        The rung's backend, transport mode and budgets are decided once,
        at rung entry: ``"auto"`` partitions the first snapshot that
        lands in the rung and takes halo iff the budgeted export fraction
        is at most ``AUTO_EXPORT_FRACTION`` (``"auto:measured"`` times
        one real sweep per transport instead; a single-device mesh always
        takes all-gather).  Within a halo rung the export *layout* is
        re-derived from every batch's topology (the budget tolerates
        stale/extra prefix rows — they ship committed labels); a batch
        whose export counts overflow the budget runs on the rung's
        all-gather twin instead (warned once per rung).  A bsr rung
        stages in the halo row layout under BOTH transports — the tile
        layout is then identical in both programs, which is what makes
        bsr labels bit-identical across transports — and a batch whose
        tile-slot requirement overflows the rung's compiled budget runs
        on the rung's ell_pallas twin under the same transport routing
        (warned once per rung; ell_pallas is itself bit-identical across
        transports, so the cross-transport contract survives fallback).
        """
        key = host.bucket_key
        n_dev = self.mesh.devices.size
        backend = self._backend_modes.get(key)
        mode = self._transport_modes.get(key)
        allgather_only = (self.transport == "allgather"
                          or (self.transport in ("auto", "auto:measured")
                              and n_dev == 1))
        bsr_possible = (backend == "bsr" or (
            backend is None and "bsr" in self._backend_candidates))
        # the halo layout doubles as the bsr row order, so derive it
        # whenever the rung needs halo bytes OR bsr tiles
        need_layout = (bsr_possible or mode == "halo"
                       or (mode is None and not allgather_only))
        layout = (partition.build_halo_plan(host.nbr, n_dev)
                  if need_layout else None)
        bl = None
        if backend is None:
            backend, bl = self._resolve_rung_backend(
                key, layout.nbr if layout is not None else host.nbr,
                len(host.unl_ids))
        if mode is None:
            # need_layout guarantees a layout whenever this branch can
            # pick halo, so only the allgather-only case lacks one
            if allgather_only:
                mode = "allgather"
            else:
                budget = partition.export_budget(layout, len(host.unl_ids))
                if self.transport == "auto:measured":
                    mode = self._measured_mode(key)
                    if mode is None:
                        mode = self._measure_rung_transport(
                            key, host, layout, budget, backend)
                else:
                    frac = budget * n_dev / key[0]
                    mode = ("halo" if self.transport == "halo"
                            or frac <= AUTO_EXPORT_FRACTION else "allgather")
                    if mode == "allgather":
                        logger.info(
                            "stream transport: rung %s export fraction "
                            "%.2f > %.2f — auto takes all-gather", key,
                            frac, AUTO_EXPORT_FRACTION)
                if mode == "halo":
                    self._export_budgets[key] = budget
            self._transport_modes[key] = mode

        # ---- per-Δ_t staging: permute when halo bytes or bsr tiles need
        # the export-prefix row layout ----
        staged, rows, perm = host, None, None
        if backend == "bsr" or mode == "halo":
            if layout is None:
                layout = partition.build_halo_plan(host.nbr, n_dev)
            staged = apply_halo_layout(host, layout)
            rows = layout.inv_perm[: len(host.unl_ids)]
            perm = layout.perm
        slot, num_slots = None, 0
        backend_this = backend
        if backend == "bsr":
            if bl is None:
                bl = ell_bsr_layout(staged.nbr, self._bsr_block)
            if bl.num_slots > self._slot_budgets[key]:
                # slot-budget overflow: this Δ_t rides the rung's
                # ell_pallas twin but keeps the rung's TRANSPORT routing
                # below, so halo accounting (halo_batches + overflows)
                # stays exact
                self._slot_overflow(key, bl.num_slots)
                backend_this = "ell_pallas"
            else:
                slot, num_slots = bl.slot, self._slot_budgets[key]
                self.bsr_batches += 1

        if mode == "halo":
            budget = self._export_budgets[key]
            if int(layout.export_counts.max()) > budget:
                # overflow: this Δ_t's cross-shard rows exceed the rung's
                # compiled export prefix — correctness falls back to the
                # all-gather twin for this batch only
                if key not in self._overflow_warned:
                    self._overflow_warned.add(key)
                    logger.warning(
                        "stream halo: rung %s export count %d overflows "
                        "the compiled budget %d — falling back to "
                        "all-gather for this batch (warned once per rung)",
                        key, int(layout.export_counts.max()), budget)
                self.transport_overflows += 1
            else:
                self.halo_batches += 1
                return _Staging(
                    staged=staged, backend=backend_this, transport="halo",
                    plan=self._halo_plan_for(key, budget, backend_this,
                                             num_slots),
                    rows=rows, perm=perm, slot=slot, num_slots=num_slots)
        return _Staging(
            staged=staged, backend=backend_this, transport="allgather",
            plan=self._plan_for(key, backend_this, num_slots),
            rows=rows, perm=perm, slot=slot, num_slots=num_slots)

    # ------------------------------------------------------------------ #
    def _measured_mode(self, key) -> str | None:
        """Consult the persisted ``auto:measured`` probe cache: a restored
        engine re-entering a rung it (or a predecessor process) already
        timed picks the winner from the cached per-transport sweep times
        instead of paying two probe compiles + timed sweeps again
        (docs/persistence.md §Probe cache).  Returns None on a miss."""
        cached = self._measured.get(key)
        if cached is None:
            return None
        mode = "halo" if cached["halo"] <= cached["allgather"] else "allgather"
        self.probe_cache_hits += 1
        logger.info(
            "stream transport: rung %s probe-cache hit (halo %.2f ms vs "
            "all-gather %.2f ms cached) — taking %s without re-probing",
            key, cached["halo"], cached["allgather"], mode)
        return mode

    # ------------------------------------------------------------------ #
    def _measure_rung_transport(self, key, host, layout, budget,
                                backend) -> str:
        """``auto:measured``: time one real sweep per transport on the
        rung's first snapshot and cache the winner.

        Costs two probe runners (``max_iters=1``, compiled once per rung
        and counted by ``compile_cache_size``) plus two timed sweeps each
        — the price of capturing reconstruct-overhead effects the
        byte-count heuristic cannot see.  The probes never touch the
        engine's donated buffers (``donate=False``, throwaway staging).
        """
        m = key[0] // self.mesh.devices.size
        if budget >= m:
            return "allgather"  # halo ships no fewer bytes: skip the probe
        staged = apply_halo_layout(host, layout)
        slot = None
        bsr_kw = {}
        if backend == "bsr":
            bl = ell_bsr_layout(staged.nbr, self._bsr_block)
            slot = bl.slot
            bsr_kw = dict(block_size=self._bsr_block,
                          num_slots=self._slot_budgets[key])
        times = {}
        for tr in ("allgather", "halo"):
            build = (distributed.build_stream_plan if tr == "allgather"
                     else functools.partial(distributed.build_stream_halo_plan,
                                            export_max=budget))
            plan = build(self.mesh, key, backend=backend, delta=self.delta,
                         max_iters=1, block_rows=self.block_rows,
                         interpret=self.interpret, donate=False, **bsr_kw)
            problem = plan.put_problem(staged.nbr, staged.wgt, staged.wl0,
                                       staged.wl1, staged.valid)
            f0 = plan.put_row(np.full(key[0], 0.5, np.float32))
            fr = plan.put_row(staged.valid)
            kw = ({"slot": plan.put_row2(slot)} if slot is not None else {})
            jax.block_until_ready(plan(problem, f0, fr, **kw).f)  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(plan(problem, f0, fr, **kw).f)
            times[tr] = time.perf_counter() - t0
        mode = "halo" if times["halo"] <= times["allgather"] else "allgather"
        self._measured[key] = {t: round(v * 1e3, 4) for t, v in times.items()}
        logger.info(
            "stream transport: rung %s measured halo %.2f ms vs all-gather "
            "%.2f ms per sweep — taking %s", key, times["halo"] * 1e3,
            times["allgather"] * 1e3, mode)
        return mode

    # ------------------------------------------------------------------ #
    def _commit(
        self, host: HostSnapshot,
        plan: distributed.StreamShardPlan | None = None,
    ) -> PropagationProblem:
        """Stage a host snapshot into the persistent device buffers."""
        key = host.bucket_key
        if plan is not None:  # mesh mode: row-sharded staging
            new = plan.put_problem(host.nbr, host.wgt, host.wl0, host.wl1,
                                   host.valid)
        else:
            new = PropagationProblem(
                nbr=jnp.asarray(host.nbr),
                wgt=jnp.asarray(host.wgt),
                wl0=jnp.asarray(host.wl0),
                wl1=jnp.asarray(host.wl1),
                valid=jnp.asarray(host.valid),
            )
        slots = self._buffers.setdefault(key, [None, None])
        gen = self._gen.get(key, 1) ^ 1
        self._gen[key] = gen
        if slots[gen] is not None and ops.on_tpu():
            # ``slots[gen]`` last served batch t-2, whose solve has been
            # drained — safe to donate its storage to this snapshot so the
            # device arena stays flat across the stream.  Donation is a
            # no-op on CPU, where the extra copy would be pure overhead,
            # so there we simply swap the slot and drop the old arrays.
            new = _adopt(slots[gen], new)
        slots[gen] = new
        self.bucket_keys.add(key)
        return new

    # ------------------------------------------------------------------ #
    def submit(self, batch: BatchUpdate) -> StreamStats | None:
        """Apply Δ_t, stage it, launch its solve; returns the now-complete
        stats of the PREVIOUS batch (None on the first call)."""
        t0 = time.perf_counter()
        g = self.graph

        # ---- Step 0: arrival ordering (ids are assigned in row order,
        # so this must run before apply_batch) ----
        if self.ingest_order == "locality" and len(batch.ins_emb) > 2:
            from repro.data.synth import cosine_locality_order
            order = cosine_locality_order(
                np.asarray(batch.ins_emb, np.float32))
            batch = dataclasses.replace(
                batch, ins_emb=np.asarray(batch.ins_emb)[order],
                ins_labels=np.asarray(batch.ins_labels)[order])

        # ---- Step 1: change adjustment & sparsification (host) ----
        effect = g.apply_batch(batch, tau=self.tau, selector=self.ingestor)
        m = len(effect.new_ids)
        if self._lm is not None:
            self._note_touched(effect)

        # ``effect.affected`` is already alive-filtered, so the frontier
        # below is nonempty iff some affected vertex is unlabeled — an
        # O(|affected|) test, decided BEFORE the O(U·K) snapshot build.
        if not (len(effect.affected)
                and (g.labels[effect.affected] == UNLABELED).any()):
            # No-op Δ_t (empty batch, or deletions touching nothing
            # unlabeled): the solve would run zero sweeps and return f0
            # bit-identically, so skip the snapshot build, device staging
            # and dispatch entirely.  The batch still commits — drain()
            # publishes a LabelView reflecting any alive/labels changes.
            prev = self.drain()
            self.batches += 1
            unl_ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
            self._pending = _Pending(
                res=None, unl_ids=unl_ids, t0=t0,
                num_components=0, frontier_size=0,
                bucket=(0, 0),  # nothing staged this Δ_t
                recompiled=False, transport="none", backend="none",
                view_labels=g.labels.copy(), view_alive=g.alive.copy(),
                view_f=g.f.copy(),
            )
            return prev

        # ---- landmark hot/cold gate: decided BEFORE the snapshot build
        # (the hot restriction changes the bucket this Δ_t lands in) ----
        hot = self._landmark_gate() if self._lm is not None else None
        cold_ids = None
        if hot is not None:
            cold_ids = np.flatnonzero(g.alive & (g.labels == UNLABELED)
                                      & ~hot)

        # ---- stage batch-t topology while batch t-1 still propagates ----
        host = build_host_problem(g, max_degree=self.max_degree,
                                  auto_bucket=True,
                                  row_multiple=self._row_multiple,
                                  max_k=self.max_k,
                                  warned=self._max_k_warned,
                                  hot=hot)
        if hot is not None:
            # the hot/cold contract overrides the rung's registry scan —
            # a hot problem is small by design, so per-rung auto would
            # pick an exact backend and mislabel approximate batches
            self._backend_modes[host.bucket_key] = "landmark"
        u = len(host.unl_ids)
        u_pad = len(host.valid)
        frontier = np.zeros(u_pad, bool)
        aff_rows = host.remap[effect.affected]
        frontier[aff_rows[aff_rows >= 0]] = True

        # resolve this batch's backend/transport/plan through the per-rung
        # registry state; bsr and halo batches permute the snapshot (into
        # component order or the export-prefix layout) before staging —
        # row order is invisible to the fixpoint, so labels stay bit-equal.
        # ``host`` itself stays in original row order for the supernode
        # init and f0 builds below, which fold back via ``st.rows``.
        st = (self._stage_mesh(host) if self.mesh is not None
              else self._stage_single(host))
        plan = st.plan
        problem = self._commit(st.staged, plan)
        frontier_staged = frontier if st.perm is None else frontier[st.perm]
        frontier_dev = (plan.put_row(frontier_staged) if plan is not None
                        else jnp.asarray(frontier_staged))

        # ---- Step 2: supernode label initialization (host wl0/wl1) ----
        n_components = 0
        new_unl = effect.new_ids[g.labels[effect.new_ids] == UNLABELED]
        if m and len(new_unl):
            comp_local = gprime_components(effect, m)
            local_idx = new_unl - effect.new_ids[0]
            comp = compact_labels(jnp.asarray(comp_local))[local_idx]
            n_components = int(jnp.max(comp) + 1) if len(local_idx) else 0
            rows = host.remap[new_unl]
            f_init = supernode_init(
                comp, jnp.asarray(host.wl0[rows]), jnp.asarray(host.wl1[rows]),
                num_segments=max(m, 1))
            g.f[new_unl] = np.asarray(f_init)

        # ---- drain batch t-1 (first moment its result is truly needed:
        # f0 below reads the propagated labels) ----
        prev = self.drain()

        # ---- Step 3: launch this batch's solve (async) ----
        f0 = np.full(u_pad, 0.5, np.float32)
        f0[:u] = g.f[host.unl_ids]
        if st.perm is not None:
            f0 = f0[st.perm]
        # f0 is donated into the solve in both modes; in mesh mode it is
        # staged row-sharded first so each device recycles its own block.
        f0_dev = plan.put_row(f0) if plan is not None else jnp.asarray(f0)
        slot_dev = None
        if st.slot is not None:
            slot_dev = (plan.put_row2(st.slot) if plan is not None
                        else jnp.asarray(st.slot))
        before = ops.compile_cache_size()
        res = ops.run_propagation(
            problem, f0_dev, frontier_dev,
            delta=self.delta, max_iters=self.max_iters,
            backend=st.backend, block_rows=self.block_rows,
            interpret=self.interpret, donate=True, shard_plan=plan,
            slot=slot_dev, num_slots=st.num_slots or None,
            block_size=self._bsr_block if st.backend == "bsr" else None,
        )
        recompiled = ops.compile_cache_size() > before
        self.recompile_count += recompiled
        self.batches += 1
        self._pending = _Pending(
            res=res, unl_ids=host.unl_ids, t0=t0,
            num_components=n_components, frontier_size=int(frontier.sum()),
            bucket=host.bucket_key, recompiled=recompiled,
            transport=st.transport, backend=st.backend,
            rows=st.rows, cold_ids=cold_ids,
            # Batch-t host state (labels/alive fixed by apply_batch above;
            # f now holds batch t-1's committed labels plus this batch's
            # supernode inits).  drain() folds the solved rows over view_f
            # and publishes the result as the committed LabelView.
            view_labels=g.labels.copy(), view_alive=g.alive.copy(),
            view_f=g.f.copy(),
        )
        return prev

    # ------------------------------------------------------------------ #
    def drain(self) -> StreamStats | None:
        """Block on the in-flight solve and fold its labels back into the
        host graph; returns its stats (None if nothing is pending).

        Draining COMMITS the batch: the committed ``LabelView`` is
        rebuilt here (solved rows folded over the state captured at
        submit), so ``committed_view()`` readers flip atomically from
        batch t-1's labels to batch t's."""
        p, self._pending = self._pending, None
        if p is None:
            return None
        if p.res is None:  # no-op batch: nothing was solved
            iterations, converged, resid = 0, True, 0.0
        else:
            f = np.asarray(p.res.f)  # synchronizes
            # halo/bsr batches solved in a permuted row order: gather the
            # original rows back through the layout's inverse permutation
            solved = f[p.rows] if p.rows is not None else f[: len(p.unl_ids)]
            self.graph.f[p.unl_ids] = solved
            p.view_f[p.unl_ids] = solved
            iterations = int(p.res.iterations)
            converged = bool(p.res.converged)
            resid = float(p.res.max_residual)
        if p.cold_ids is not None and self._lm is not None:
            self._landmark_commit(p)
        self.commits += 1
        self._view = LabelView(f=p.view_f, labels=p.view_labels,
                               alive=p.view_alive, commit_id=self.commits)
        # Commit handoff without host copies: the view's own frozen
        # arrays feed device_put directly.  Republish eagerly only once
        # a device reader exists — engines that never serve device reads
        # pay nothing per commit.
        if self._device_view is not None:
            self._device_view = publish_device_view(self._view,
                                                    self._read_placement)
        return StreamStats(
            iterations=iterations,
            converged=converged,
            num_components=p.num_components,
            frontier_size=p.frontier_size,
            num_unlabeled=len(p.unl_ids),
            wall_ms=(time.perf_counter() - p.t0) * 1e3,
            max_residual=resid,
            bucket=p.bucket,
            recompiled=p.recompiled,
            transport=p.transport,
            backend=p.backend,
        )

    # ------------------------------------------------------------------ #
    def poll(self) -> StreamStats | None:
        """Non-blocking ``drain``: commit the in-flight batch only if its
        device solve has already finished; otherwise return None without
        waiting.  The serving layer calls this between requests so commits
        land as soon as the device is done, never stalling the caller."""
        p = self._pending
        if p is None:
            return None
        if p.res is not None and not p.res.f.is_ready():
            return None
        return self.drain()

    @property
    def in_flight(self) -> bool:
        """True while a submitted batch has not been drained (committed)."""
        return self._pending is not None

    def committed_view(self) -> LabelView:
        """The query-side snapshot of the last COMMITTED batch.

        Safe to read while a later batch is in flight: ``submit`` mutates
        the host graph immediately, but the view only advances at drain
        time, so readers never observe a torn half-applied batch.  Before
        any commit it reflects the graph the engine was built around."""
        return self._view

    def device_view(self) -> DeviceLabelView:
        """The committed snapshot ON DEVICE — query bursts run as one
        jitted gather (``DeviceLabelView.query``) instead of per-call
        host indexing.  Published lazily on first call, then refreshed
        eagerly at every drain; placement (replica device / sharded
        rows) was fixed at construction via ``read_placement``.  Safe to
        call concurrently with a drain: views are immutable and both
        ``_view`` and the cache swap atomically, so a racing reader gets
        either the previous or the new commit, never a torn mix — the
        serving read path relies on this to stay off the write lock."""
        dv = self._device_view
        if dv is None or dv.commit_id != self._view.commit_id:
            dv = publish_device_view(self._view, self._read_placement)
            self._device_view = dv
        return dv

    # ------------------------------------------------------------------ #
    def step(self, batch: BatchUpdate) -> StreamStats:
        """Synchronous Δ_t update — ``DynLP.step`` semantics, amortized
        compile.  Use ``submit``/``drain`` directly to pipeline batches."""
        self.submit(batch)
        return self.drain()

    # ------------------------------------------------------------------ #
    def transport_summary(self) -> dict:
        """JSON-friendly account of the sharded transport AND the per-rung
        backend registry decisions: the requested knobs, each rung's
        mode/backend/budgets, and how many batches actually rode
        halo/bsr vs overflowed back to their fallbacks.  Surfaced by
        ``LPService.stats()`` and the streaming benchmarks."""
        def by_rung(d):
            return {f"{u}x{k}": v for (u, k), v in sorted(d.items())}

        return {
            "requested": self.transport,
            "mesh_devices": (int(self.mesh.devices.size)
                             if self.mesh is not None else 0),
            "rung_modes": by_rung(self._transport_modes),
            "export_budgets": by_rung(self._export_budgets),
            "halo_batches": self.halo_batches,
            "overflows": self.transport_overflows,
            "requested_backend": self.backend or "auto",
            "rung_backends": by_rung(self._backend_modes),
            "slot_budgets": by_rung(self._slot_budgets),
            "bsr_batches": self.bsr_batches,
            "backend_overflows": self.backend_overflows,
            "measured_sweep_ms": by_rung(self._measured),
            "probe_cache_hits": self.probe_cache_hits,
            "landmark": {
                "configured": self._lm is not None,
                "streaming": self._lm_streaming,
                "num_landmarks": self._lm.num_landmarks if self._lm else 0,
                "batches": self.landmark_batches,
                "cold_rows": self.landmark_cold_rows,
                "resamples": self._lm.resamples if self._lm else 0,
            },
        }

    # ------------------------------------------------------------------ #
    def checkpoint(self, directory: str, step: int | None = None) -> str:
        """Write one atomic checkpoint of the full incremental state
        (graph buffers, embedding store, rung metadata, probe cache,
        commit counter) under ``directory``; step defaults to the commit
        counter.  Commit-boundary only: raises while a batch is in
        flight — ``drain()`` first.  See ``core.persistence``."""
        from repro.core import persistence

        return persistence.save_engine(self, directory, step)

    def checkpoint_state(self) -> dict:
        """The flat checkpoint tree (for ``CheckpointManager.save_async``
        off-path writes — the ``LPService`` policy path); same
        commit-boundary contract as ``checkpoint``."""
        from repro.core import persistence

        return persistence.engine_state(self)

    @classmethod
    def restore(cls, directory: str, step: int | None = None,
                **overrides) -> "StreamEngine":
        """Rebuild an engine from the latest (or given) checkpoint,
        elastically re-sharded onto whatever ``mesh=`` is active now;
        other keyword overrides replace the checkpointed engine knobs.
        See ``core.persistence.restore_engine``."""
        from repro.core import persistence

        return persistence.restore_engine(directory, step, **overrides)

    # ------------------------------------------------------------------ #
    def predictions(self, cutoff: float = 0.5) -> tuple[np.ndarray, np.ndarray]:
        """(global ids, binary predictions) for alive unlabeled vertices."""
        g = self.graph
        ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
        return ids, (g.f[ids] >= cutoff).astype(np.int8)

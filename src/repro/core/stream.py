"""Compile-once streaming engine for dynamic batch updates (tentpole).

``DynLP.step`` rebuilds and re-stages the device ``PropagationProblem``
from scratch every Δ_t — at its exact (U, K) when ``auto_bucket=False``
(a recompile on nearly every batch, the recomputation tax the paper
eliminates), and even bucketed it allocates fresh device buffers per
batch and serializes host work against the solve.  ``StreamEngine`` is
the amortized version:

  * **Bucket ladder** — every snapshot is padded up the geometric
    ``(U_bucket, K_bucket)`` ladder (``snapshot.bucket`` ×
    ``snapshot.bucket_k``), so an unbounded stream compiles the
    propagation entry point a bounded number of times
    (``snapshot.ladder_size``).
  * **Persistent donated buffers** — per bucket the engine keeps two
    generations of device buffers for ``(nbr, wgt, wl0, wl1, valid)``
    plus the ``f``/``frontier`` vectors.  Batch t+1's snapshot is
    committed into the generation *not* referenced by the in-flight
    batch t solve, with the stale generation donated so XLA recycles
    the allocation instead of growing the arena every Δ_t.
  * **Staged transfers** — ``submit``/``drain`` split the step: ``submit``
    applies Δ_t on the host, stages its topology to the device, and
    launches the solve; it only *then* blocks on the previous batch.
    Host graph update + H2D of batch t+1 overlap device propagation of
    batch t (JAX dispatch is async on every backend).

``step`` (submit + drain) keeps the exact ``DynLP.step`` semantics and
numerics — streamed labels are allclose to fresh per-batch DynLP results
(tests/test_stream.py); the solve itself routes through
``kernels.ops.run_propagation`` so ref / ell_pallas / bsr backends are
interchangeable.

With ``mesh=`` the same stream spans a device mesh: rows of every bucket
shard over all mesh axes through the ``core.distributed`` all-gather
transport, buckets are padded to a multiple of the device count, and one
partition plan per ladder rung (``StreamShardPlan``) is reused across
every batch in that rung.  Labels stay bit-identical to the single-device
engine (tests/test_stream_sharded.py).  See docs/streaming.md.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed
from repro.core.components import compact_labels
from repro.core.dynlp import gprime_components
from repro.core.init_labels import supernode_init
from repro.core.propagate import PropagationProblem
from repro.core.snapshot import HostSnapshot, LabelView, build_host_problem
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.kernels import ops


@dataclasses.dataclass
class StreamStats:
    iterations: int
    converged: bool
    num_components: int
    frontier_size: int
    num_unlabeled: int
    wall_ms: float
    max_residual: float
    bucket: tuple[int, int]  # (U_bucket, K_bucket) device shape this Δ_t;
    # (0, 0) for a no-op Δ_t whose empty frontier staged nothing
    recompiled: bool  # True iff this Δ_t triggered any XLA compile


@functools.partial(jax.jit, donate_argnums=(0,))
def _adopt(old: PropagationProblem, new: PropagationProblem) -> PropagationProblem:
    """Copy ``new`` into ``old``'s (donated) device storage."""
    return new


@dataclasses.dataclass
class _Pending:
    res: object  # PropagateResult (device, possibly still in flight);
    # None for a no-op batch whose frontier was empty (nothing to solve)
    unl_ids: np.ndarray
    t0: float
    num_components: int
    frontier_size: int
    bucket: tuple[int, int]
    recompiled: bool
    # Post-batch host state captured at submit (after the previous drain
    # folded its labels in): becomes the committed LabelView at drain,
    # with this batch's solved rows folded over view_f.
    view_labels: np.ndarray
    view_alive: np.ndarray
    view_f: np.ndarray


class StreamEngine:
    """Stateful compile-once streaming DynLP over a ``DynamicGraph``."""

    def __init__(
        self,
        graph: DynamicGraph,
        delta: float = 1e-4,
        tau: float | None = None,
        max_iters: int = 200_000,
        max_degree: int | None = None,
        backend: str | None = None,
        block_rows: int = 512,
        interpret: bool | None = None,
        mesh: jax.sharding.Mesh | None = None,
        max_k: int | None = None,
    ):
        self.graph = graph
        self.delta = delta
        self.tau = tau
        self.max_iters = max_iters
        self.max_degree = max_degree
        self.backend = backend
        self.block_rows = block_rows
        self.interpret = interpret
        # mesh: shard the stream — rows of every bucket are partitioned
        # over ALL mesh axes (core.distributed all-gather transport); row
        # buckets are padded to a multiple of the device count so each
        # rung shards evenly, and one partition plan per rung is reused
        # across every batch that lands in it.
        self.mesh = mesh
        # max_k: cap the ELL neighbor axis (heaviest-edge truncation) so a
        # hub vertex can't drag the K-bucket ladder up (core.snapshot).
        self.max_k = max_k
        self._row_multiple = int(mesh.devices.size) if mesh is not None else None
        self._plans: dict[tuple[int, int], distributed.StreamShardPlan] = {}
        self.plan_builds = 0  # partition plans built — ≤ rungs touched
        # bucket_key -> two generations of device problem buffers; the
        # generation toggles per commit so the in-flight solve never shares
        # storage with the snapshot being staged.
        self._buffers: dict[tuple[int, int], list[PropagationProblem | None]] = {}
        self._gen: dict[tuple[int, int], int] = {}
        self._pending: _Pending | None = None
        self.bucket_keys: set[tuple[int, int]] = set()
        self.recompile_count = 0  # batches that triggered any XLA compile
        self.batches = 0
        self.commits = 0  # batches whose results have been drained
        # Query-side committed snapshot (serving read path): refreshed at
        # every drain, never mutated in place — readers hold a consistent
        # view while the next batch's solve is in flight.
        self._view = LabelView.from_graph(graph, commit_id=0)

    # ------------------------------------------------------------------ #
    def _plan_for(self, key: tuple[int, int]) -> distributed.StreamShardPlan:
        """Partition plan for one ladder rung — built once, then reused
        for every batch whose padded snapshot lands in that rung."""
        plan = self._plans.get(key)
        if plan is None:
            plan = distributed.build_stream_plan(
                self.mesh, key,
                backend=ops.select_backend(self.backend, num_rows=key[0],
                                           sharded=True),
                delta=self.delta, max_iters=self.max_iters,
                block_rows=self.block_rows, interpret=self.interpret,
                donate=True)
            self._plans[key] = plan
            self.plan_builds += 1
        return plan

    # ------------------------------------------------------------------ #
    def _commit(
        self, host: HostSnapshot,
        plan: distributed.StreamShardPlan | None = None,
    ) -> PropagationProblem:
        """Stage a host snapshot into the persistent device buffers."""
        key = host.bucket_key
        if plan is not None:  # mesh mode: row-sharded staging
            new = plan.put_problem(host.nbr, host.wgt, host.wl0, host.wl1,
                                   host.valid)
        else:
            new = PropagationProblem(
                nbr=jnp.asarray(host.nbr),
                wgt=jnp.asarray(host.wgt),
                wl0=jnp.asarray(host.wl0),
                wl1=jnp.asarray(host.wl1),
                valid=jnp.asarray(host.valid),
            )
        slots = self._buffers.setdefault(key, [None, None])
        gen = self._gen.get(key, 1) ^ 1
        self._gen[key] = gen
        if slots[gen] is not None and ops.on_tpu():
            # ``slots[gen]`` last served batch t-2, whose solve has been
            # drained — safe to donate its storage to this snapshot so the
            # device arena stays flat across the stream.  Donation is a
            # no-op on CPU, where the extra copy would be pure overhead,
            # so there we simply swap the slot and drop the old arrays.
            new = _adopt(slots[gen], new)
        slots[gen] = new
        self.bucket_keys.add(key)
        return new

    # ------------------------------------------------------------------ #
    def submit(self, batch: BatchUpdate) -> StreamStats | None:
        """Apply Δ_t, stage it, launch its solve; returns the now-complete
        stats of the PREVIOUS batch (None on the first call)."""
        t0 = time.perf_counter()
        g = self.graph

        # ---- Step 1: change adjustment & sparsification (host) ----
        effect = g.apply_batch(batch, tau=self.tau)
        m = len(effect.new_ids)

        # ``effect.affected`` is already alive-filtered, so the frontier
        # below is nonempty iff some affected vertex is unlabeled — an
        # O(|affected|) test, decided BEFORE the O(U·K) snapshot build.
        if not (len(effect.affected)
                and (g.labels[effect.affected] == UNLABELED).any()):
            # No-op Δ_t (empty batch, or deletions touching nothing
            # unlabeled): the solve would run zero sweeps and return f0
            # bit-identically, so skip the snapshot build, device staging
            # and dispatch entirely.  The batch still commits — drain()
            # publishes a LabelView reflecting any alive/labels changes.
            prev = self.drain()
            self.batches += 1
            unl_ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
            self._pending = _Pending(
                res=None, unl_ids=unl_ids, t0=t0,
                num_components=0, frontier_size=0,
                bucket=(0, 0),  # nothing staged this Δ_t
                recompiled=False,
                view_labels=g.labels.copy(), view_alive=g.alive.copy(),
                view_f=g.f.copy(),
            )
            return prev

        # ---- stage batch-t topology while batch t-1 still propagates ----
        host = build_host_problem(g, max_degree=self.max_degree,
                                  auto_bucket=True,
                                  row_multiple=self._row_multiple,
                                  max_k=self.max_k)
        u = len(host.unl_ids)
        u_pad = len(host.valid)
        frontier = np.zeros(u_pad, bool)
        aff_rows = host.remap[effect.affected]
        frontier[aff_rows[aff_rows >= 0]] = True

        plan = self._plan_for(host.bucket_key) if self.mesh is not None else None
        problem = self._commit(host, plan)
        frontier_dev = (plan.put_row(frontier) if plan is not None
                        else jnp.asarray(frontier))

        # ---- Step 2: supernode label initialization (host wl0/wl1) ----
        n_components = 0
        new_unl = effect.new_ids[g.labels[effect.new_ids] == UNLABELED]
        if m and len(new_unl):
            comp_local = gprime_components(effect, m)
            local_idx = new_unl - effect.new_ids[0]
            comp = compact_labels(jnp.asarray(comp_local))[local_idx]
            n_components = int(jnp.max(comp) + 1) if len(local_idx) else 0
            rows = host.remap[new_unl]
            f_init = supernode_init(
                comp, jnp.asarray(host.wl0[rows]), jnp.asarray(host.wl1[rows]),
                num_segments=max(m, 1))
            g.f[new_unl] = np.asarray(f_init)

        # ---- drain batch t-1 (first moment its result is truly needed:
        # f0 below reads the propagated labels) ----
        prev = self.drain()

        # ---- Step 3: launch this batch's solve (async) ----
        f0 = np.full(u_pad, 0.5, np.float32)
        f0[:u] = g.f[host.unl_ids]
        # f0 is donated into the solve in both modes; in mesh mode it is
        # staged row-sharded first so each device recycles its own block.
        f0_dev = plan.put_row(f0) if plan is not None else jnp.asarray(f0)
        before = ops.compile_cache_size()
        res = ops.run_propagation(
            problem, f0_dev, frontier_dev,
            delta=self.delta, max_iters=self.max_iters,
            backend=self.backend, block_rows=self.block_rows,
            interpret=self.interpret, donate=True, shard_plan=plan,
        )
        recompiled = ops.compile_cache_size() > before
        self.recompile_count += recompiled
        self.batches += 1
        self._pending = _Pending(
            res=res, unl_ids=host.unl_ids, t0=t0,
            num_components=n_components, frontier_size=int(frontier.sum()),
            bucket=host.bucket_key, recompiled=recompiled,
            # Batch-t host state (labels/alive fixed by apply_batch above;
            # f now holds batch t-1's committed labels plus this batch's
            # supernode inits).  drain() folds the solved rows over view_f
            # and publishes the result as the committed LabelView.
            view_labels=g.labels.copy(), view_alive=g.alive.copy(),
            view_f=g.f.copy(),
        )
        return prev

    # ------------------------------------------------------------------ #
    def drain(self) -> StreamStats | None:
        """Block on the in-flight solve and fold its labels back into the
        host graph; returns its stats (None if nothing is pending).

        Draining COMMITS the batch: the committed ``LabelView`` is
        rebuilt here (solved rows folded over the state captured at
        submit), so ``committed_view()`` readers flip atomically from
        batch t-1's labels to batch t's."""
        p, self._pending = self._pending, None
        if p is None:
            return None
        if p.res is None:  # no-op batch: nothing was solved
            iterations, converged, resid = 0, True, 0.0
        else:
            f = np.asarray(p.res.f)  # synchronizes
            self.graph.f[p.unl_ids] = f[: len(p.unl_ids)]
            p.view_f[p.unl_ids] = f[: len(p.unl_ids)]
            iterations = int(p.res.iterations)
            converged = bool(p.res.converged)
            resid = float(p.res.max_residual)
        self.commits += 1
        self._view = LabelView(f=p.view_f, labels=p.view_labels,
                               alive=p.view_alive, commit_id=self.commits)
        return StreamStats(
            iterations=iterations,
            converged=converged,
            num_components=p.num_components,
            frontier_size=p.frontier_size,
            num_unlabeled=len(p.unl_ids),
            wall_ms=(time.perf_counter() - p.t0) * 1e3,
            max_residual=resid,
            bucket=p.bucket,
            recompiled=p.recompiled,
        )

    # ------------------------------------------------------------------ #
    def poll(self) -> StreamStats | None:
        """Non-blocking ``drain``: commit the in-flight batch only if its
        device solve has already finished; otherwise return None without
        waiting.  The serving layer calls this between requests so commits
        land as soon as the device is done, never stalling the caller."""
        p = self._pending
        if p is None:
            return None
        if p.res is not None and not p.res.f.is_ready():
            return None
        return self.drain()

    @property
    def in_flight(self) -> bool:
        """True while a submitted batch has not been drained (committed)."""
        return self._pending is not None

    def committed_view(self) -> LabelView:
        """The query-side snapshot of the last COMMITTED batch.

        Safe to read while a later batch is in flight: ``submit`` mutates
        the host graph immediately, but the view only advances at drain
        time, so readers never observe a torn half-applied batch.  Before
        any commit it reflects the graph the engine was built around."""
        return self._view

    # ------------------------------------------------------------------ #
    def step(self, batch: BatchUpdate) -> StreamStats:
        """Synchronous Δ_t update — ``DynLP.step`` semantics, amortized
        compile.  Use ``submit``/``drain`` directly to pipeline batches."""
        self.submit(batch)
        return self.drain()

    # ------------------------------------------------------------------ #
    def predictions(self, cutoff: float = 0.5) -> tuple[np.ndarray, np.ndarray]:
        """(global ids, binary predictions) for alive unlabeled vertices."""
        g = self.graph
        ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
        return ids, (g.f[ids] >= cutoff).astype(np.int8)

"""Compile-once streaming engine for dynamic batch updates (tentpole).

``DynLP.step`` rebuilds and re-stages the device ``PropagationProblem``
from scratch every Δ_t — at its exact (U, K) when ``auto_bucket=False``
(a recompile on nearly every batch, the recomputation tax the paper
eliminates), and even bucketed it allocates fresh device buffers per
batch and serializes host work against the solve.  ``StreamEngine`` is
the amortized version:

  * **Bucket ladder** — every snapshot is padded up the geometric
    ``(U_bucket, K_bucket)`` ladder (``snapshot.bucket`` ×
    ``snapshot.bucket_k``), so an unbounded stream compiles the
    propagation entry point a bounded number of times
    (``snapshot.ladder_size``).
  * **Persistent donated buffers** — per bucket the engine keeps two
    generations of device buffers for ``(nbr, wgt, wl0, wl1, valid)``
    plus the ``f``/``frontier`` vectors.  Batch t+1's snapshot is
    committed into the generation *not* referenced by the in-flight
    batch t solve, with the stale generation donated so XLA recycles
    the allocation instead of growing the arena every Δ_t.
  * **Staged transfers** — ``submit``/``drain`` split the step: ``submit``
    applies Δ_t on the host, stages its topology to the device, and
    launches the solve; it only *then* blocks on the previous batch.
    Host graph update + H2D of batch t+1 overlap device propagation of
    batch t (JAX dispatch is async on every backend).

``step`` (submit + drain) keeps the exact ``DynLP.step`` semantics and
numerics — streamed labels are allclose to fresh per-batch DynLP results
(tests/test_stream.py); the solve itself routes through
``kernels.ops.run_propagation`` so ref / ell_pallas / bsr backends are
interchangeable.

With ``mesh=`` the same stream spans a device mesh: rows of every bucket
shard over all mesh axes through the ``core.distributed`` shard_map
transport, buckets are padded to a multiple of the device count, and one
partition plan per ladder rung is reused across every batch in that rung.
``transport=`` picks the per-sweep collective: ``"allgather"`` ships
every shard's full F block (topology-free); ``"halo"`` ships only each
shard's export prefix, with the export budget compiled once per rung
(``StreamHaloPlan``) and the export row layout re-derived per Δ_t on the
host — a batch whose exports overflow the rung's budget falls back to
all-gather for that Δ_t with a logged warning.  ``"auto"`` (default)
measures the rung's export fraction at rung entry and picks halo when it
is small enough to pay.  Labels stay bit-identical to the single-device
engine under every transport (tests/test_stream_sharded.py,
tests/test_stream_property.py).  See docs/streaming.md §Transports.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed
from repro.core.components import compact_labels
from repro.core.dynlp import gprime_components
from repro.core.init_labels import supernode_init
from repro.core.propagate import PropagationProblem
from repro.core.snapshot import (HostSnapshot, LabelView, apply_halo_layout,
                                 build_host_problem)
from repro.graph import partition
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.kernels import ops

logger = logging.getLogger(__name__)

TRANSPORTS = ("allgather", "halo", "auto")

# auto picks halo for a rung iff its compiled export budget would move
# at most this fraction of the full all-gather bytes per sweep.
AUTO_EXPORT_FRACTION = 0.5


@dataclasses.dataclass
class StreamStats:
    iterations: int
    converged: bool
    num_components: int
    frontier_size: int
    num_unlabeled: int
    wall_ms: float
    max_residual: float
    bucket: tuple[int, int]  # (U_bucket, K_bucket) device shape this Δ_t;
    # (0, 0) for a no-op Δ_t whose empty frontier staged nothing
    recompiled: bool  # True iff this Δ_t triggered any XLA compile
    transport: str = "single"  # collective this Δ_t rode: "single" (no
    # mesh), "allgather", "halo", or "none" (no-op Δ_t, nothing solved)


@functools.partial(jax.jit, donate_argnums=(0,))
def _adopt(old: PropagationProblem, new: PropagationProblem) -> PropagationProblem:
    """Copy ``new`` into ``old``'s (donated) device storage."""
    return new


@dataclasses.dataclass
class _Pending:
    res: object  # PropagateResult (device, possibly still in flight);
    # None for a no-op batch whose frontier was empty (nothing to solve)
    unl_ids: np.ndarray
    t0: float
    num_components: int
    frontier_size: int
    bucket: tuple[int, int]
    recompiled: bool
    # Post-batch host state captured at submit (after the previous drain
    # folded its labels in): becomes the committed LabelView at drain,
    # with this batch's solved rows folded over view_f.
    view_labels: np.ndarray
    view_alive: np.ndarray
    view_f: np.ndarray
    transport: str = "single"
    # halo layout inverse: solved row for original row i is rows[i]
    # (None when rows were staged unpermuted)
    rows: np.ndarray | None = None


class StreamEngine:
    """Stateful compile-once streaming DynLP over a ``DynamicGraph``."""

    def __init__(
        self,
        graph: DynamicGraph,
        delta: float = 1e-4,
        tau: float | None = None,
        max_iters: int = 200_000,
        max_degree: int | None = None,
        backend: str | None = None,
        block_rows: int = 512,
        interpret: bool | None = None,
        mesh: jax.sharding.Mesh | None = None,
        max_k: int | None | str = "auto",
        transport: str | None = None,
    ):
        self.graph = graph
        self.delta = delta
        self.tau = tau
        self.max_iters = max_iters
        self.max_degree = max_degree
        self.backend = backend
        self.block_rows = block_rows
        self.interpret = interpret
        # mesh: shard the stream — rows of every bucket are partitioned
        # over ALL mesh axes (core.distributed shard_map transport); row
        # buckets are padded to a multiple of the device count so each
        # rung shards evenly, and one partition plan per rung is reused
        # across every batch that lands in it.
        self.mesh = mesh
        # transport: per-sweep collective of the sharded solve.  An
        # explicit "halo" demands a mesh; when left unset the
        # REPRO_STREAM_TRANSPORT env var replaces the "auto" default —
        # as a fleet-wide hint it is simply ignored on mesh-less engines
        # (mirroring the REPRO_BACKEND degrade semantics).
        if transport is not None and transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; want one "
                             f"of {TRANSPORTS}")
        if transport == "halo" and mesh is None:
            raise ValueError("transport='halo' requires mesh= (a "
                             "single-device stream has no collective)")
        if transport is None:
            transport = os.environ.get("REPRO_STREAM_TRANSPORT", "auto")
            if transport not in TRANSPORTS:
                raise ValueError(
                    f"REPRO_STREAM_TRANSPORT={transport!r} invalid; want "
                    f"one of {TRANSPORTS}")
        self.transport = transport
        # max_k: cap the ELL neighbor axis (heaviest-edge truncation) so a
        # hub vertex can't drag the K-bucket ladder up (core.snapshot).
        # Default "auto" = 4x the graph's kNN k (measured at parity on
        # hub-heavy synthetics, BENCH_stream.json max_k_accuracy); pass
        # max_k=None to stream untruncated.
        if isinstance(max_k, str) and max_k != "auto":
            raise ValueError(
                f"max_k={max_k!r} invalid; want an int, None (uncapped), "
                "or 'auto' (4x the graph's kNN k)")
        self.max_k = 4 * graph.k if max_k == "auto" else max_k
        self._row_multiple = int(mesh.devices.size) if mesh is not None else None
        self._plans: dict[tuple[int, int], distributed.StreamShardPlan] = {}
        self._halo_plans: dict[tuple, distributed.StreamHaloPlan] = {}
        self.plan_builds = 0  # partition plans built — ≤ rungs touched
        # per-rung transport state: mode fixed at rung entry ("halo" or
        # "allgather"), export budget compiled into the rung's halo plan
        self._transport_modes: dict[tuple[int, int], str] = {}
        self._export_budgets: dict[tuple[int, int], int] = {}
        self._overflow_warned: set[tuple[int, int]] = set()
        self.halo_batches = 0  # batches solved on the halo transport
        self.transport_overflows = 0  # halo batches forced onto all-gather
        # bucket_key -> two generations of device problem buffers; the
        # generation toggles per commit so the in-flight solve never shares
        # storage with the snapshot being staged.
        self._buffers: dict[tuple[int, int], list[PropagationProblem | None]] = {}
        self._gen: dict[tuple[int, int], int] = {}
        self._pending: _Pending | None = None
        self.bucket_keys: set[tuple[int, int]] = set()
        self.recompile_count = 0  # batches that triggered any XLA compile
        self.batches = 0
        self.commits = 0  # batches whose results have been drained
        # Query-side committed snapshot (serving read path): refreshed at
        # every drain, never mutated in place — readers hold a consistent
        # view while the next batch's solve is in flight.
        self._view = LabelView.from_graph(graph, commit_id=0)

    # ------------------------------------------------------------------ #
    def _plan_for(self, key: tuple[int, int]) -> distributed.StreamShardPlan:
        """Partition plan for one ladder rung — built once, then reused
        for every batch whose padded snapshot lands in that rung."""
        plan = self._plans.get(key)
        if plan is None:
            plan = distributed.build_stream_plan(
                self.mesh, key,
                backend=ops.select_backend(self.backend, num_rows=key[0],
                                           sharded=True),
                delta=self.delta, max_iters=self.max_iters,
                block_rows=self.block_rows, interpret=self.interpret,
                donate=True)
            self._plans[key] = plan
            self.plan_builds += 1
        return plan

    # ------------------------------------------------------------------ #
    def _halo_plan_for(self, key: tuple[int, int],
                       export_max: int) -> distributed.StreamHaloPlan:
        """Halo partition plan for one ladder rung — the export budget is
        fixed at rung entry, so like the all-gather plan it is built once
        and reused for every same-rung batch."""
        hkey = (key, export_max)
        plan = self._halo_plans.get(hkey)
        if plan is None:
            plan = distributed.build_stream_halo_plan(
                self.mesh, key, export_max,
                backend=ops.select_backend(self.backend, num_rows=key[0],
                                           sharded=True),
                delta=self.delta, max_iters=self.max_iters,
                block_rows=self.block_rows, interpret=self.interpret,
                donate=True)
            self._halo_plans[hkey] = plan
            self.plan_builds += 1
        return plan

    # ------------------------------------------------------------------ #
    def _mesh_plan(self, host: HostSnapshot):
        """Resolve this batch's (plan, halo layout) on the mesh.

        The rung's transport mode and export budget are decided once, at
        rung entry: ``"auto"`` partitions the first snapshot that lands
        in the rung and takes halo iff the budgeted export fraction is at
        most ``AUTO_EXPORT_FRACTION`` (a single-device mesh has nothing
        to save and always takes all-gather).  Within a halo rung the
        export *layout* is re-derived from every batch's topology (the
        budget tolerates stale/extra prefix rows — they ship committed
        labels); a batch whose export counts overflow the budget runs on
        the rung's all-gather twin instead (warned once per rung).
        Returns ``(plan, halo_layout)`` with ``halo_layout=None`` for
        all-gather batches.
        """
        key = host.bucket_key
        n_dev = self.mesh.devices.size
        mode = self._transport_modes.get(key)
        if mode is None and (
                self.transport == "allgather"
                or (self.transport == "auto" and n_dev == 1)):
            mode = self._transport_modes[key] = "allgather"
        if mode == "allgather":
            return self._plan_for(key), None
        layout = partition.build_halo_plan(host.nbr, n_dev)
        if mode is None:  # rung entry: fix budget + mode for the rung
            budget = partition.export_budget(layout, len(host.unl_ids))
            frac = budget * n_dev / key[0]
            mode = ("halo" if self.transport == "halo"
                    or frac <= AUTO_EXPORT_FRACTION else "allgather")
            self._transport_modes[key] = mode
            if mode == "allgather":
                logger.info(
                    "stream transport: rung %s export fraction %.2f > %.2f"
                    " — auto takes all-gather", key, frac,
                    AUTO_EXPORT_FRACTION)
                return self._plan_for(key), None
            self._export_budgets[key] = budget
        budget = self._export_budgets[key]
        if int(layout.export_counts.max()) > budget:
            # overflow: this Δ_t's cross-shard rows exceed the rung's
            # compiled export prefix — correctness falls back to the
            # all-gather twin for this batch only
            if key not in self._overflow_warned:
                self._overflow_warned.add(key)
                logger.warning(
                    "stream halo: rung %s export count %d overflows the "
                    "compiled budget %d — falling back to all-gather for "
                    "this batch (warned once per rung)", key,
                    int(layout.export_counts.max()), budget)
            self.transport_overflows += 1
            return self._plan_for(key), None
        self.halo_batches += 1
        return self._halo_plan_for(key, budget), layout

    # ------------------------------------------------------------------ #
    def _commit(
        self, host: HostSnapshot,
        plan: distributed.StreamShardPlan | None = None,
    ) -> PropagationProblem:
        """Stage a host snapshot into the persistent device buffers."""
        key = host.bucket_key
        if plan is not None:  # mesh mode: row-sharded staging
            new = plan.put_problem(host.nbr, host.wgt, host.wl0, host.wl1,
                                   host.valid)
        else:
            new = PropagationProblem(
                nbr=jnp.asarray(host.nbr),
                wgt=jnp.asarray(host.wgt),
                wl0=jnp.asarray(host.wl0),
                wl1=jnp.asarray(host.wl1),
                valid=jnp.asarray(host.valid),
            )
        slots = self._buffers.setdefault(key, [None, None])
        gen = self._gen.get(key, 1) ^ 1
        self._gen[key] = gen
        if slots[gen] is not None and ops.on_tpu():
            # ``slots[gen]`` last served batch t-2, whose solve has been
            # drained — safe to donate its storage to this snapshot so the
            # device arena stays flat across the stream.  Donation is a
            # no-op on CPU, where the extra copy would be pure overhead,
            # so there we simply swap the slot and drop the old arrays.
            new = _adopt(slots[gen], new)
        slots[gen] = new
        self.bucket_keys.add(key)
        return new

    # ------------------------------------------------------------------ #
    def submit(self, batch: BatchUpdate) -> StreamStats | None:
        """Apply Δ_t, stage it, launch its solve; returns the now-complete
        stats of the PREVIOUS batch (None on the first call)."""
        t0 = time.perf_counter()
        g = self.graph

        # ---- Step 1: change adjustment & sparsification (host) ----
        effect = g.apply_batch(batch, tau=self.tau)
        m = len(effect.new_ids)

        # ``effect.affected`` is already alive-filtered, so the frontier
        # below is nonempty iff some affected vertex is unlabeled — an
        # O(|affected|) test, decided BEFORE the O(U·K) snapshot build.
        if not (len(effect.affected)
                and (g.labels[effect.affected] == UNLABELED).any()):
            # No-op Δ_t (empty batch, or deletions touching nothing
            # unlabeled): the solve would run zero sweeps and return f0
            # bit-identically, so skip the snapshot build, device staging
            # and dispatch entirely.  The batch still commits — drain()
            # publishes a LabelView reflecting any alive/labels changes.
            prev = self.drain()
            self.batches += 1
            unl_ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
            self._pending = _Pending(
                res=None, unl_ids=unl_ids, t0=t0,
                num_components=0, frontier_size=0,
                bucket=(0, 0),  # nothing staged this Δ_t
                recompiled=False, transport="none",
                view_labels=g.labels.copy(), view_alive=g.alive.copy(),
                view_f=g.f.copy(),
            )
            return prev

        # ---- stage batch-t topology while batch t-1 still propagates ----
        host = build_host_problem(g, max_degree=self.max_degree,
                                  auto_bucket=True,
                                  row_multiple=self._row_multiple,
                                  max_k=self.max_k)
        u = len(host.unl_ids)
        u_pad = len(host.valid)
        frontier = np.zeros(u_pad, bool)
        aff_rows = host.remap[effect.affected]
        frontier[aff_rows[aff_rows >= 0]] = True

        # mesh: resolve this batch's transport; halo batches permute the
        # snapshot into the export-prefix row layout before staging (row
        # order is invisible to the fixpoint, so labels stay bit-equal —
        # ``host`` itself stays in original row order for the supernode
        # init and f0 builds below, which fold back via halo.inv_perm)
        halo = None
        staged = host
        if self.mesh is not None:
            plan, halo = self._mesh_plan(host)
            if halo is not None:
                staged = apply_halo_layout(host, halo)
        else:
            plan = None
        problem = self._commit(staged, plan)
        frontier_staged = frontier if halo is None else frontier[halo.perm]
        frontier_dev = (plan.put_row(frontier_staged) if plan is not None
                        else jnp.asarray(frontier_staged))

        # ---- Step 2: supernode label initialization (host wl0/wl1) ----
        n_components = 0
        new_unl = effect.new_ids[g.labels[effect.new_ids] == UNLABELED]
        if m and len(new_unl):
            comp_local = gprime_components(effect, m)
            local_idx = new_unl - effect.new_ids[0]
            comp = compact_labels(jnp.asarray(comp_local))[local_idx]
            n_components = int(jnp.max(comp) + 1) if len(local_idx) else 0
            rows = host.remap[new_unl]
            f_init = supernode_init(
                comp, jnp.asarray(host.wl0[rows]), jnp.asarray(host.wl1[rows]),
                num_segments=max(m, 1))
            g.f[new_unl] = np.asarray(f_init)

        # ---- drain batch t-1 (first moment its result is truly needed:
        # f0 below reads the propagated labels) ----
        prev = self.drain()

        # ---- Step 3: launch this batch's solve (async) ----
        f0 = np.full(u_pad, 0.5, np.float32)
        f0[:u] = g.f[host.unl_ids]
        if halo is not None:
            f0 = f0[halo.perm]
        # f0 is donated into the solve in both modes; in mesh mode it is
        # staged row-sharded first so each device recycles its own block.
        f0_dev = plan.put_row(f0) if plan is not None else jnp.asarray(f0)
        before = ops.compile_cache_size()
        res = ops.run_propagation(
            problem, f0_dev, frontier_dev,
            delta=self.delta, max_iters=self.max_iters,
            backend=self.backend, block_rows=self.block_rows,
            interpret=self.interpret, donate=True, shard_plan=plan,
        )
        recompiled = ops.compile_cache_size() > before
        self.recompile_count += recompiled
        self.batches += 1
        self._pending = _Pending(
            res=res, unl_ids=host.unl_ids, t0=t0,
            num_components=n_components, frontier_size=int(frontier.sum()),
            bucket=host.bucket_key, recompiled=recompiled,
            transport=(plan.transport if plan is not None else "single"),
            rows=None if halo is None else halo.inv_perm[:u],
            # Batch-t host state (labels/alive fixed by apply_batch above;
            # f now holds batch t-1's committed labels plus this batch's
            # supernode inits).  drain() folds the solved rows over view_f
            # and publishes the result as the committed LabelView.
            view_labels=g.labels.copy(), view_alive=g.alive.copy(),
            view_f=g.f.copy(),
        )
        return prev

    # ------------------------------------------------------------------ #
    def drain(self) -> StreamStats | None:
        """Block on the in-flight solve and fold its labels back into the
        host graph; returns its stats (None if nothing is pending).

        Draining COMMITS the batch: the committed ``LabelView`` is
        rebuilt here (solved rows folded over the state captured at
        submit), so ``committed_view()`` readers flip atomically from
        batch t-1's labels to batch t's."""
        p, self._pending = self._pending, None
        if p is None:
            return None
        if p.res is None:  # no-op batch: nothing was solved
            iterations, converged, resid = 0, True, 0.0
        else:
            f = np.asarray(p.res.f)  # synchronizes
            # halo batches solved in export-prefix row order: gather the
            # original rows back through the layout's inverse permutation
            solved = f[p.rows] if p.rows is not None else f[: len(p.unl_ids)]
            self.graph.f[p.unl_ids] = solved
            p.view_f[p.unl_ids] = solved
            iterations = int(p.res.iterations)
            converged = bool(p.res.converged)
            resid = float(p.res.max_residual)
        self.commits += 1
        self._view = LabelView(f=p.view_f, labels=p.view_labels,
                               alive=p.view_alive, commit_id=self.commits)
        return StreamStats(
            iterations=iterations,
            converged=converged,
            num_components=p.num_components,
            frontier_size=p.frontier_size,
            num_unlabeled=len(p.unl_ids),
            wall_ms=(time.perf_counter() - p.t0) * 1e3,
            max_residual=resid,
            bucket=p.bucket,
            recompiled=p.recompiled,
            transport=p.transport,
        )

    # ------------------------------------------------------------------ #
    def poll(self) -> StreamStats | None:
        """Non-blocking ``drain``: commit the in-flight batch only if its
        device solve has already finished; otherwise return None without
        waiting.  The serving layer calls this between requests so commits
        land as soon as the device is done, never stalling the caller."""
        p = self._pending
        if p is None:
            return None
        if p.res is not None and not p.res.f.is_ready():
            return None
        return self.drain()

    @property
    def in_flight(self) -> bool:
        """True while a submitted batch has not been drained (committed)."""
        return self._pending is not None

    def committed_view(self) -> LabelView:
        """The query-side snapshot of the last COMMITTED batch.

        Safe to read while a later batch is in flight: ``submit`` mutates
        the host graph immediately, but the view only advances at drain
        time, so readers never observe a torn half-applied batch.  Before
        any commit it reflects the graph the engine was built around."""
        return self._view

    # ------------------------------------------------------------------ #
    def step(self, batch: BatchUpdate) -> StreamStats:
        """Synchronous Δ_t update — ``DynLP.step`` semantics, amortized
        compile.  Use ``submit``/``drain`` directly to pipeline batches."""
        self.submit(batch)
        return self.drain()

    # ------------------------------------------------------------------ #
    def transport_summary(self) -> dict:
        """JSON-friendly account of the sharded transport: the requested
        knob, the per-rung mode/budget decisions, and how many batches
        actually rode halo vs overflowed back to all-gather.  Surfaced by
        ``LPService.stats()`` and the streaming benchmarks."""
        return {
            "requested": self.transport,
            "mesh_devices": (int(self.mesh.devices.size)
                             if self.mesh is not None else 0),
            "rung_modes": {f"{u}x{k}": m for (u, k), m
                           in sorted(self._transport_modes.items())},
            "export_budgets": {f"{u}x{k}": b for (u, k), b
                               in sorted(self._export_budgets.items())},
            "halo_batches": self.halo_batches,
            "overflows": self.transport_overflows,
        }

    # ------------------------------------------------------------------ #
    def predictions(self, cutoff: float = 0.5) -> tuple[np.ndarray, np.ndarray]:
        """(global ids, binary predictions) for alive unlabeled vertices."""
        g = self.graph
        ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
        return ids, (g.f[ids] >= cutoff).astype(np.int8)

"""DynLP core: the paper's contribution as composable JAX modules."""
from repro.core.components import CCResult, compact_labels, connected_components
from repro.core.dynlp import DynLP, StepStats
from repro.core.init_labels import supernode_init
from repro.core.itlp import ITLP, ITLPStats
from repro.core.propagate import (
    PropagateResult,
    PropagationProblem,
    harmonic_residual,
    lp_update,
    propagate,
    propagate_full,
)
from repro.core.snapshot import (
    HostSnapshot,
    Snapshot,
    bucket,
    bucket_k,
    build_host_problem,
    build_problem,
    ladder_size,
)
from repro.core.stlp import STLP, STLPStats, harmonic_solve
from repro.core.stream import StreamEngine, StreamStats

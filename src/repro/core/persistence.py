"""Durable engine state: crash-safe checkpoint/restore for the stream.

A process restart used to throw away the engine's entire incremental
state — host graph, committed labels, embedding store, measured-transport
picks — and force exactly the full recomputation DynLP exists to avoid.
This module snapshots ALL of it through the atomic ``checkpoint.manager``
format (``step_<N>/`` + manifest + ``.complete`` marker, mesh-independent
full arrays) so a restarted engine resumes bit-identically:

  * the ``DynamicGraph`` buffers (embeddings, labels, alive, fractional
    labels ``f``, kNN lists, undirected edge arrays),
  * the ``EmbeddingStore`` contents + per-row k-th weights (device
    ingest), so the restored selector prunes displacements exactly,
  * the commit/batch counters and bucket-ladder rung metadata (per-rung
    transport modes, export budgets, backend decisions, bsr slot
    budgets), and
  * the per-(rung, transport) ``auto:measured`` sweep timings — the
    persistent probe cache: a restored engine re-enters measured rungs
    without re-timing (``StreamEngine.probe_cache_hits``).

What is deliberately NOT saved: compiled plans, donated device staging
buffers, and device read views.  Those are rebuild-on-demand caches keyed
by rung, which is exactly what makes restore ELASTIC — a checkpoint from
an 8-device mesh restores onto a single device (or any other mesh) and
serves bit-identical query results, because labels are mesh-independent
by the engine's cross-transport contract.  Rung metadata whose validity
is mesh- or hardware-scoped only reinstalls when the restoring context
matches (see ``restore_engine``).

Checkpoints are commit-boundary snapshots: capturing state with a batch
in flight would mix batch t's host mutations with batch t-1's committed
labels, so ``engine_state`` refuses while ``engine.in_flight``.  The
serving-policy layer (``LPService(checkpoint_every=..., ...)``) only
snapshots at quiescent commits for the same reason.  See
docs/persistence.md.
"""

from __future__ import annotations

import json
import logging

import jax
import numpy as np

from repro.checkpoint import manager
from repro.core.snapshot import LabelView
from repro.core.stream import StreamEngine
from repro.graph.dynamic import DynamicGraph

logger = logging.getLogger(__name__)

STATE_VERSION = 1

_UNSET = object()  # "use the checkpointed value" ctor-override sentinel


def _ingest_mode(engine: StreamEngine) -> str:
    if engine.ingestor is None:
        return "host"
    return "device" if hasattr(engine.ingestor, "store") else "custom"


def engine_state(engine: StreamEngine) -> dict:
    """Flat ``{name: array}`` snapshot of the engine's full incremental
    state, ready for ``checkpoint.manager.save``/``save_async``.

    Mutable host arrays are copied here (the async writer's
    ``np.asarray(device_get(...))`` does NOT copy numpy inputs, and the
    stream mutates the graph in place while the worker writes); the
    store's jax arrays are immutable handles and pass through as-is.
    """
    if engine.in_flight:
        raise RuntimeError(
            "cannot snapshot with a batch in flight — drain() first "
            "(checkpoints are commit-boundary snapshots)")
    g = engine.graph
    state = {f"graph_{k}": v for k, v in g.state_arrays().items()}
    meta = {
        "version": STATE_VERSION,
        "platform": jax.default_backend(),
        # graph hyperparameters (reconstruct the DynamicGraph)
        "emb_dim": g.emb_dim,
        "k": g.k,
        "knn_block": g.knn_block,
        # engine hyperparameters (reconstruct the StreamEngine)
        "delta": float(engine.delta),
        "tau": None if engine.tau is None else float(engine.tau),
        "max_iters": int(engine.max_iters),
        "max_degree": engine.max_degree,
        "backend": engine.backend,
        "block_rows": int(engine.block_rows),
        "interpret": engine.interpret,
        "max_k": engine.max_k,  # resolved: int or None
        "transport": engine.transport,
        "mesh_devices": (int(engine.mesh.devices.size)
                         if engine.mesh is not None else 0),
        "backend_knob": engine._backend_knob,
        "backend_candidates": list(engine._backend_candidates),
        "ingest": _ingest_mode(engine),
        "ingest_order": engine.ingest_order,
        # stream position + ladder history
        "commits": int(engine.commits),
        "batches": int(engine.batches),
        "bucket_keys": sorted([int(u), int(k)]
                              for u, k in engine.bucket_keys),
        # per-rung metadata, keyed "UxK" (validity-scoped on restore)
        "transport_modes": {f"{u}x{k}": v for (u, k), v
                            in engine._transport_modes.items()},
        "export_budgets": {f"{u}x{k}": int(v) for (u, k), v
                           in engine._export_budgets.items()},
        "backend_modes": {f"{u}x{k}": v for (u, k), v
                          in engine._backend_modes.items()},
        "slot_budgets": {f"{u}x{k}": int(v) for (u, k), v
                         in engine._slot_budgets.items()},
        # the persistent auto:measured probe cache
        "measured": {f"{u}x{k}": v for (u, k), v
                     in engine._measured.items()},
        "halo_batches": int(engine.halo_batches),
        "transport_overflows": int(engine.transport_overflows),
        "bsr_batches": int(engine.bsr_batches),
        "backend_overflows": int(engine.backend_overflows),
    }
    store = getattr(engine.ingestor, "store", None)
    if store is not None:
        # mesh-independent by construction: a ShardedEmbeddingStore hands
        # back its row-sharded jax handles and the checkpoint writer's
        # device_get assembles them into full host arrays — restore then
        # re-shards (or not) onto whatever mesh is active, extending the
        # PR-8 elastic contract to the store
        for k, v in store.state_arrays().items():
            state[f"store_{k}"] = v
        meta["store_count"] = int(store.count)
    lm = engine._lm
    if lm is not None:
        # the landmark factorization + working-set clock: saved so a
        # restored hot/cold stream resumes with identical hot masks,
        # assignments and landmark labels (readable by older code — all
        # keys are additive and read back via meta.get)
        state["landmark_touched_at"] = engine._touched_at.copy()
        meta["landmark"] = {
            "streaming": engine._lm_streaming,
            "batches": int(engine.landmark_batches),
            "cold_rows": int(engine.landmark_cold_rows),
            "ready": lm.ready,
            **lm.state_meta(),
        }
        if lm.ready:
            for k, v in lm.state_arrays().items():
                state[f"landmark_{k}"] = v
    state["meta"] = np.frombuffer(
        json.dumps(meta).encode(), np.uint8).copy()
    return state


def save_engine(engine: StreamEngine, directory: str,
                step: int | None = None) -> str:
    """Write one atomic engine checkpoint; step defaults to the commit
    counter (one checkpoint per commit id, latest wins on restore)."""
    step = engine.commits if step is None else step
    return manager.save(directory, step, engine_state(engine))


def _rungs(d: dict, cast=lambda v: v) -> dict:
    return {tuple(int(x) for x in key.split("x")): cast(v)
            for key, v in d.items()}


def restore_engine(
    directory: str,
    step: int | None = None,
    *,
    mesh: jax.sharding.Mesh | None = None,
    transport: object = _UNSET,
    backend: object = _UNSET,
    block_rows: object = _UNSET,
    interpret: object = _UNSET,
    max_k: object = _UNSET,
    read_placement: object = "auto",
    ingest: object = _UNSET,
    landmark: object = _UNSET,
) -> StreamEngine:
    """Rebuild a ``StreamEngine`` from the latest (or given) checkpoint.

    Elastic by construction: the checkpoint holds mesh-independent full
    arrays, so ``mesh=`` is whatever mesh is active NOW — none (default),
    the original, or a different one; device buffers and plans re-stage
    on demand onto it.  Keyword overrides replace the checkpointed
    engine knobs; unset knobs restore as saved (a saved ``"halo"``
    transport degrades to the auto default when restoring mesh-less).

    Rung metadata reinstalls only where it stays valid:

      * backend decisions + bsr slot budgets — same mesh size AND same
        resolved backend knob/candidates (a bsr rung must stay a bsr
        rung for replayed labels to stay bit-identical);
      * transport modes + export budgets — same mesh size AND same
        transport knob (except ``auto:measured``, which re-derives modes
        from the probe cache below so cache hits are observable);
      * the ``auto:measured`` probe cache — same mesh size AND same
        platform (the timings are hardware-scoped).

    Anything dropped is simply re-derived at rung entry, exactly as on a
    fresh stream — labels are unaffected either way.
    """
    if step is None:
        step = manager.latest_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no committed checkpoint under {directory}")
    state = manager.load_flat(directory, step)
    meta = json.loads(bytes(state["meta"]))
    if meta.get("version") != STATE_VERSION:
        raise ValueError(
            f"checkpoint state version {meta.get('version')} != "
            f"supported {STATE_VERSION}")

    g = DynamicGraph(meta["emb_dim"], k=meta["k"],
                     knn_block=meta["knn_block"])
    g.load_state_arrays(
        {k[len("graph_"):]: v for k, v in state.items()
         if k.startswith("graph_")})

    if ingest is _UNSET:
        ingest = meta["ingest"]
        if ingest == "custom":
            raise ValueError(
                "checkpoint was taken with a custom ingest selector; pass "
                "ingest=<selector instance> (or 'host'/'device') to "
                "restore_engine")
    if ingest == "device" and "store_valid" in state:
        # pre-load the saved store instead of letting the engine ctor
        # backfill from the graph: contents are equivalent, but this
        # keeps the capacity ladder and k-th pruning thresholds exact.
        # mesh= routes the load into a ShardedEmbeddingStore when one is
        # active — the saved arrays are full host images, so they land
        # on any mesh shape (8dev → 1dev and back are both exact).
        from repro.ingest import DeviceIngestor

        ingestor = DeviceIngestor(meta["emb_dim"], mesh=mesh)
        ingestor.store.load_state_arrays(
            {"emb": state["store_emb"], "valid": state["store_valid"],
             "kth": state["store_kth"]}, count=meta["store_count"])
        ingest = ingestor

    if transport is _UNSET:
        transport = meta["transport"]
        if transport == "halo" and mesh is None:
            transport = None  # elastic: mesh-less restore degrades to auto

    lm_meta = meta.get("landmark")  # absent in pre-landmark checkpoints
    if landmark is _UNSET:
        landmark = ({key: lm_meta[key] for key in
                     ("num_landmarks", "assign_k", "hot_ttl",
                      "resample_factor", "dead_frac_max")}
                    if lm_meta is not None else None)

    engine = StreamEngine(
        g,
        delta=meta["delta"],
        tau=meta["tau"],
        max_iters=meta["max_iters"],
        max_degree=meta["max_degree"],
        backend=meta["backend"] if backend is _UNSET else backend,
        block_rows=(meta["block_rows"] if block_rows is _UNSET
                    else block_rows),
        interpret=meta["interpret"] if interpret is _UNSET else interpret,
        mesh=mesh,
        max_k=meta["max_k"] if max_k is _UNSET else max_k,
        transport=transport,
        read_placement=read_placement,
        ingest=ingest,
        landmark=landmark,
        ingest_order=meta.get("ingest_order", "arrival"),
    )

    if lm_meta is not None and engine._lm is not None:
        cfg = engine._lm.cfg
        # landmark state is mesh-independent (the hot solve is exact and
        # the cold pass deterministic), so unlike the rung metadata below
        # it reinstalls on ANY mesh — but only under the same geometry
        # (a changed L or R invalidates the assignment table)
        if (cfg.num_landmarks == lm_meta["num_landmarks"]
                and cfg.assign_k == lm_meta["assign_k"]):
            if "landmark_touched_at" in state:
                engine._touched_at = np.asarray(
                    state["landmark_touched_at"], np.int64).copy()
            engine._lm_streaming = bool(lm_meta["streaming"])
            engine.landmark_batches = int(lm_meta["batches"])
            engine.landmark_cold_rows = int(lm_meta["cold_rows"])
            if lm_meta.get("ready") and "landmark_ids" in state:
                engine._lm.load_state(
                    {"ids": state["landmark_ids"],
                     "emb": state["landmark_emb"],
                     "lm_valid": state["landmark_lm_valid"],
                     "assign_idx": state["landmark_assign_idx"],
                     "assign_w": state["landmark_assign_w"]}, lm_meta)

    engine.commits = int(meta["commits"])
    engine.batches = int(meta["batches"])
    engine.bucket_keys = {(int(u), int(k))
                          for u, k in meta["bucket_keys"]}
    # the committed read view resumes at the saved commit id, so a
    # restored DeviceLabelView answers exactly as the original's did
    engine._view = LabelView.from_graph(g, commit_id=engine.commits)

    n_dev = int(mesh.devices.size) if mesh is not None else 0
    same_mesh = meta["mesh_devices"] == n_dev
    if (same_mesh and meta["backend_knob"] == engine._backend_knob
            and list(meta["backend_candidates"])
            == list(engine._backend_candidates)):
        engine._backend_modes = _rungs(meta["backend_modes"])
        engine._slot_budgets = _rungs(meta["slot_budgets"], int)
        engine.bsr_batches = int(meta["bsr_batches"])
        engine.backend_overflows = int(meta["backend_overflows"])
    if (same_mesh and meta["transport"] == engine.transport
            and engine.transport != "auto:measured"):
        engine._transport_modes = _rungs(meta["transport_modes"])
        engine._export_budgets = _rungs(meta["export_budgets"], int)
        engine.halo_batches = int(meta["halo_batches"])
        engine.transport_overflows = int(meta["transport_overflows"])
    if same_mesh and meta["platform"] == jax.default_backend():
        engine._measured = _rungs(meta["measured"], dict)
    logger.info(
        "restored engine from %s step %d: %d nodes, %d commits, "
        "mesh %d -> %d devices, %d cached probe rungs",
        directory, step, g.num_nodes, engine.commits,
        meta["mesh_devices"], n_dev, len(engine._measured))
    return engine

"""Iterative label propagation engines (paper Alg. 2 Step 3 and ITLP).

The device representation is a ``PropagationProblem`` over the *unlabeled*
vertices only: labeled classes are folded into per-node scalar weights
``wl0``/``wl1`` (the paper's supernode decomposition, §4 "Iterative
Propagation"), and the ELL neighbor list holds unlabeled-unlabeled edges.

The frontier ("affected set" V_aff) is a dense boolean mask; the queue-based
GPU frontier of the paper maps to mask + ``segment``-style scatter expansion
on TPU (DESIGN.md §2).  The whole dynamic update jits once via
``lax.while_loop``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graph.structures import PAD


class PropagationProblem(NamedTuple):
    """Pytree describing one LP system over U unlabeled vertices.

    Attributes:
      nbr:   (U, K) int32 — unlabeled-neighbor ids (compact), PAD for empty.
      wgt:   (U, K) float32 — weights of those edges.
      wl0:   (U,) float32 — Σ w(u, v) over v ∈ L0 (class-0 supernode edge sum).
      wl1:   (U,) float32 — Σ w(u, v) over v ∈ L1.
      valid: (U,) bool — real rows (False for shard padding rows).
    """

    nbr: jax.Array
    wgt: jax.Array
    wl0: jax.Array
    wl1: jax.Array
    valid: jax.Array

    @property
    def num_unlabeled(self) -> int:
        return self.nbr.shape[0]

    def wall(self) -> jax.Array:
        """Total incident weight per node: unlabeled nbrs + label supernodes."""
        return jnp.sum(self.wgt, axis=1) + self.wl0 + self.wl1


def _gather_labels(f: jax.Array, nbr: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather neighbor labels; returns (labels, slot_mask)."""
    mask = nbr != PAD
    idx = jnp.where(mask, nbr, 0)
    return f[idx], mask


def update_island(wgt, wl0, wl1, f, f_v, mask):
    """The per-row Jacobi arithmetic, isolated between optimization
    barriers so it compiles IDENTICALLY in every program that embeds it.

    XLA freely fuses this arithmetic with whatever surrounds it —
    all-gather collectives, halo scatter reconstructions, donation copies
    — and different fusion contexts can contract multiplies/adds (FMA)
    differently, shifting a row's update by 1 ULP.  A row whose |ΔF|
    straddles the δ threshold by that ULP then makes a different frontier
    decision, and the engines' bit-equality contract (single-device ≡
    all-gather ≡ halo, tests/test_stream_sharded.py) silently breaks.
    Barriering every operand and the result pins the island's HLO to one
    shape everywhere, so the contraction decision — whatever it is — is
    the same in all engines.  The barriers are no-copy identity ops at
    runtime; they only stop cross-boundary fusion.
    """
    wgt, wl0, wl1, f, f_v = jax.lax.optimization_barrier(
        (wgt, wl0, wl1, f, f_v))
    nbr_term = jnp.sum(wgt * jnp.where(mask, f_v - f[:, None], 0.0), axis=1)
    wall = jnp.sum(wgt, axis=1) + wl0 + wl1
    d_f = (0.0 - f) * wl0 + (1.0 - f) * wl1 + nbr_term
    fu = f + jnp.where(wall > 0, d_f / jnp.maximum(wall, 1e-30), 0.0)
    return jax.lax.optimization_barrier(fu)


def bsr_update_island(y, wl1, wall, f):
    """The BSR backend's per-row update, isolated like ``update_island``.

    ``y`` is the block-sparse neighbor aggregation Σ_v w(u,v)·F_v; the
    weighted-average form F' = (y + wl1)/Wall (paper §5) replaces the
    Jacobi-delta form because the MXU matvec produces the sum directly.
    Barriered for the same reason as ``update_island``: the sharded
    transports embed this arithmetic next to different collectives, and
    the bsr-allgather ≡ bsr-halo bit-equality contract needs XLA to emit
    it identically in both programs.
    """
    y, wl1, wall, f = jax.lax.optimization_barrier((y, wl1, wall, f))
    fu = jnp.where(wall > 0, (y + wl1) / jnp.maximum(wall, 1e-30), f)
    return jax.lax.optimization_barrier(fu)


def lp_update(problem: PropagationProblem, f: jax.Array) -> jax.Array:
    """One unmasked LP update for every row (paper Eq. in §4 / Alg.2 L28).

    F'_u = F_u + (0-F_u)·wl0/Wall + (1-F_u)·wl1/Wall + Σ_v (F_v-F_u)·w(u,v)/Wall
    which §5 proves equals the classic weighted neighborhood average.
    """
    nbr_f, mask = _gather_labels(f, problem.nbr)
    fu = update_island(problem.wgt, problem.wl0, problem.wl1, f, nbr_f, mask)
    return jnp.where(problem.valid, fu, f)


def _expand_frontier(problem: PropagationProblem, changed: jax.Array) -> jax.Array:
    """Neighbors of changed vertices join the frontier (Alg.2 L30).

    The graph is undirected (both edge directions are stored), so
    "neighbors of changed" equals "rows with a changed neighbor" — a gather
    with the same regular ELL access pattern as the label update, instead of
    the GPU-style scatter into a frontier queue."""
    mask = problem.nbr != PAD
    idx = jnp.where(mask, problem.nbr, 0)
    return jnp.any(changed[idx] & mask, axis=1)


class PropagateResult(NamedTuple):
    f: jax.Array
    iterations: jax.Array  # int32 scalar
    converged: jax.Array  # bool scalar
    max_residual: jax.Array  # float32 scalar: max |ΔF| at the final iteration


@functools.partial(jax.jit, static_argnames=("max_iters",))
def propagate(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    delta: float | jax.Array = 1e-4,
    max_iters: int = 100_000,
) -> PropagateResult:
    """DynLP frontier-restricted propagation (Alg. 2 Step 3).

    Only frontier rows are *applied* each iteration; a row whose update moves
    more than ``delta`` keeps itself and enrolls its neighbors for the next
    iteration; otherwise it leaves the frontier.  Terminates when the frontier
    empties (or at ``max_iters``).
    """
    delta = jnp.asarray(delta, jnp.float32)

    def cond(state):
        _, frontier, it, _ = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        f, frontier, it, _ = state
        fu_all = lp_update(problem, f)
        fu = jnp.where(frontier, fu_all, f)
        resid = jnp.abs(fu - f)
        changed = resid > delta
        new_frontier = changed | _expand_frontier(problem, changed)
        new_frontier &= problem.valid
        return fu, new_frontier, it + 1, jnp.max(resid, initial=0.0)

    f, frontier, iters, resid = jax.lax.while_loop(
        cond, body, (f0, frontier0 & problem.valid, jnp.int32(0), jnp.float32(0))
    )
    return PropagateResult(
        f=f, iterations=iters, converged=~frontier.any(), max_residual=resid
    )


@functools.partial(jax.jit, static_argnames=("max_iters",))
def propagate_full(
    problem: PropagationProblem,
    f0: jax.Array,
    delta: float | jax.Array = 1e-4,
    max_iters: int = 100_000,
) -> PropagateResult:
    """ITLP: every unlabeled vertex updates every iteration; stop when the
    global max |ΔF| drops to ``delta`` (classic Zhu et al. iteration [40])."""
    delta = jnp.asarray(delta, jnp.float32)

    def cond(state):
        _, it, resid = state
        return jnp.logical_and(resid > delta, it < max_iters)

    def body(state):
        f, it, _ = state
        fu = lp_update(problem, f)
        return fu, it + 1, jnp.max(jnp.abs(fu - f), initial=0.0)

    f, iters, resid = jax.lax.while_loop(
        cond, body, (f0, jnp.int32(0), jnp.float32(jnp.inf))
    )
    return PropagateResult(
        f=f, iterations=iters, converged=resid <= delta, max_residual=resid
    )


def harmonic_residual(problem: PropagationProblem, f: jax.Array) -> jax.Array:
    """max_u |T(F)_u - F_u| — distance from the harmonic fixed point."""
    return jnp.max(jnp.abs(lp_update(problem, f) - f), initial=0.0)

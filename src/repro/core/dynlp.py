"""DynLP — Dynamic Batch Parallel Label Propagation (paper Algorithm 2).

Orchestrates the three steps per arriving batch Δ_t:

  1. Change adjustment & sparsification — apply Δ_t to the host graph, seed
     the affected set, build G' over the new vertices (edges with w > τ) and
     find its connected components (Shiloach–Vishkin, `core.components`).
  2. Label initialization — supernode edge sums to L0/L1 give each component
     a shared initial label (`core.init_labels`).
  3. Iterative propagation — frontier-restricted δ-thresholded LP
     (`core.propagate.propagate`) until the affected set empties.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.components import compact_labels, connected_components
from repro.core.init_labels import supernode_init
from repro.core.snapshot import Snapshot, bucket_k, build_problem
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.graph.structures import coo_to_csr, csr_to_ell_fast
from repro.kernels.ops import run_propagation


def gprime_components(effect, m: int) -> jnp.ndarray:
    """Connected components of G' (new-vertex τ-subgraph), local ids.

    Shared by ``DynLP`` and ``core.stream.StreamEngine`` (Alg. 2 Step 1).
    """
    if len(effect.gprime_src) == 0:
        return jnp.arange(m, dtype=jnp.int32)
    s = np.concatenate([effect.gprime_src, effect.gprime_dst])
    d = np.concatenate([effect.gprime_dst, effect.gprime_src])
    w = np.concatenate([effect.gprime_wgt, effect.gprime_wgt])
    csr = coo_to_csr(m, s, d, w)
    ell = csr_to_ell_fast(csr)
    k = ell.nbr.shape[1]
    kb = bucket_k(k)  # bucket K so the CC jit caches across Δ_t
    if kb != k:
        nbr = np.full((m, kb), -1, np.int32)
        wgt = np.zeros((m, kb), np.float32)
        nbr[:, :k] = np.asarray(ell.nbr)
        wgt[:, :k] = np.asarray(ell.wgt)
        return connected_components(jnp.asarray(nbr), jnp.asarray(wgt), tau=0.0).labels
    return connected_components(ell.nbr, ell.wgt, tau=0.0).labels


@dataclasses.dataclass
class StepStats:
    iterations: int
    converged: bool
    num_components: int
    frontier_size: int
    num_unlabeled: int
    wall_ms: float
    max_residual: float


class DynLP:
    """Stateful dynamic label-propagation engine over a ``DynamicGraph``."""

    def __init__(
        self,
        graph: DynamicGraph,
        delta: float = 1e-4,
        tau: float | None = None,
        max_iters: int = 200_000,
        max_degree: int | None = None,
        backend: str | None = None,
        auto_bucket: bool = True,
        max_k: int | None | str = "auto",
    ):
        self.graph = graph
        self.delta = delta
        self.tau = tau
        self.max_iters = max_iters
        self.max_degree = max_degree
        # max_k caps the ELL neighbor axis via heaviest-edge truncation
        # (core.snapshot.build_host_problem) so hub vertices can't grow
        # the K-bucket ladder unboundedly.  Default "auto" = 4x the
        # graph's kNN k — the same wiring as StreamEngine, so the
        # stream-vs-recompute bit-equality suites compare engines with
        # identical truncation; pass max_k=None for the uncapped form.
        if isinstance(max_k, str) and max_k != "auto":
            raise ValueError(
                f"max_k={max_k!r} invalid; want an int, None (uncapped), "
                "or 'auto' (4x the graph's kNN k)")
        self.max_k = 4 * graph.k if max_k == "auto" else max_k
        # backend: kernels.ops dispatch ("auto"/None, "ref", "ell_pallas",
        # "bsr").  auto_bucket=False rebuilds at the exact (U, K) every
        # batch — the paper's "redundant recomputation" baseline that
        # benchmarks/stream_throughput.py measures the engine against.
        self.backend = backend
        self.auto_bucket = auto_bucket
        self.last_snapshot: Snapshot | None = None
        # per-engine max_k truncation-warning dedup (matches StreamEngine:
        # a fresh engine warns again instead of inheriting process state)
        self._max_k_warned: set[tuple[int, int]] = set()

    # ------------------------------------------------------------------ #
    def step(self, batch: BatchUpdate) -> StepStats:
        t0 = time.perf_counter()
        g = self.graph

        # ---- Step 1: change adjustment & sparsification ----
        effect = g.apply_batch(batch, tau=self.tau)
        m = len(effect.new_ids)
        n_components = 0

        # ---- Step 2: supernode label initialization for new vertices ----
        snap = build_problem(g, max_degree=self.max_degree,
                             auto_bucket=self.auto_bucket, max_k=self.max_k,
                             warned=self._max_k_warned)
        new_unl = effect.new_ids[g.labels[effect.new_ids] == UNLABELED]
        if m and len(new_unl):
            comp_local = gprime_components(effect, m)
            # component id per *unlabeled* new vertex (local new-batch index)
            local_idx = new_unl - effect.new_ids[0]
            comp = compact_labels(jnp.asarray(comp_local))[local_idx]
            n_components = int(jnp.max(comp) + 1) if len(local_idx) else 0
            rows = snap.remap[new_unl]
            wl0 = snap.problem.wl0[rows]
            wl1 = snap.problem.wl1[rows]
            f_init = supernode_init(comp, wl0, wl1, num_segments=max(m, 1))
            g.f[new_unl] = np.asarray(f_init)

        # ---- Step 3: frontier-restricted iterative propagation ----
        u = len(snap.unl_ids)
        u_pad = snap.problem.num_unlabeled
        f0 = np.full(u_pad, 0.5, np.float32)
        f0[:u] = g.f[snap.unl_ids]
        frontier = np.zeros(u_pad, bool)
        aff_rows = snap.remap[effect.affected]
        frontier[aff_rows[aff_rows >= 0]] = True
        res = run_propagation(
            snap.problem, jnp.asarray(f0), jnp.asarray(frontier),
            delta=self.delta, max_iters=self.max_iters, backend=self.backend,
        )
        g.f[snap.unl_ids] = np.asarray(res.f)[:u]
        self.last_snapshot = snap
        return StepStats(
            iterations=int(res.iterations),
            converged=bool(res.converged),
            num_components=n_components,
            frontier_size=int(frontier.sum()),
            num_unlabeled=len(snap.unl_ids),
            wall_ms=(time.perf_counter() - t0) * 1e3,
            max_residual=float(res.max_residual),
        )

    # ------------------------------------------------------------------ #
    def predictions(self, cutoff: float = 0.5) -> tuple[np.ndarray, np.ndarray]:
        """(global ids, binary predictions) for alive unlabeled vertices."""
        g = self.graph
        ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
        return ids, (g.f[ids] >= cutoff).astype(np.int8)

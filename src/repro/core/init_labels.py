"""Supernode label initialization (paper Alg. 2 Step 2).

Each connected component c of the new-vertex graph G' is a supernode; the two
ground-truth classes are supernodes L0/L1.  With parallel-edge sums
W_c^{L0} = Σ_{u∈c} Σ_{v∈L0} w(u,v) (and likewise L1), every vertex of c is
initialized to

    F = 0.5 + (0−0.5)·W^{L0}/(W^{L0}+W^{L1}) + (1−0.5)·W^{L1}/(W^{L0}+W^{L1})
      = W^{L1} / (W^{L0} + W^{L1})            (0.5 when both sums are zero)

The per-component sums are two ``segment_sum``s keyed by component id.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def supernode_init(
    comp: jax.Array,  # (M,) int32 component id per new vertex (0..num_segments-1)
    wl0: jax.Array,  # (M,) float32 — Σ w(u, v∈L0) for each new vertex u
    wl1: jax.Array,  # (M,) float32
    num_segments: int,
) -> jax.Array:
    """Returns (M,) float32 initial labels for the new vertices."""
    cw0 = jax.ops.segment_sum(wl0, comp, num_segments=num_segments)
    cw1 = jax.ops.segment_sum(wl1, comp, num_segments=num_segments)
    tot = cw0 + cw1
    f_comp = jnp.where(tot > 0, cw1 / jnp.maximum(tot, 1e-30), 0.5)
    return f_comp[comp]

"""Build device ``PropagationProblem``s from the host ``DynamicGraph``.

Labeled classes are folded into the per-node supernode weights wl0/wl1; the
ELL tensor holds only unlabeled↔unlabeled edges (paper §4 "three kinds of
vertices that can impact the label").
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.graph.structures import ELLGraph

from repro.core.propagate import PropagationProblem
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.graph.structures import coo_to_csr, csr_to_ell_fast


@dataclasses.dataclass
class Snapshot:
    problem: PropagationProblem
    unl_ids: np.ndarray  # (U,) global ids of the unlabeled alive vertices
    remap: np.ndarray  # (num_nodes,) global -> compact (or -1)


def bucket(n: int, ratio: float = 1.3, floor: int = 256) -> int:
    """Round ``n`` up to a geometric bucket so jit caches hit across batches
    (the evolving graph would otherwise trigger one recompile per Δ_t)."""
    b = floor
    while b < n:
        b = int(np.ceil(b * ratio))
    return b


def build_problem(
    g: DynamicGraph,
    max_degree: int | None = None,
    pad_to: int | None = None,
    auto_bucket: bool = False,
) -> Snapshot:
    alive_unl = g.alive & (g.labels == UNLABELED)
    unl_ids = np.flatnonzero(alive_unl)
    u = len(unl_ids)
    remap = np.full(g.num_nodes, -1, np.int64)
    remap[unl_ids] = np.arange(u)

    src, dst, wgt = g.src, g.dst, g.wgt
    live = g.alive[src] & g.alive[dst] if len(src) else np.zeros(0, bool)
    src, dst, wgt = src[live], dst[live], wgt[live]

    s_unl = alive_unl[src]
    d_unl = alive_unl[dst]

    # unlabeled -> unlabeled edges form the ELL tensor
    uu = s_unl & d_unl
    csr = coo_to_csr(u, remap[src[uu]], remap[dst[uu]], wgt[uu])
    ell = csr_to_ell_fast(csr, max_degree=max_degree)
    if auto_bucket:
        pad_to = bucket(u)
        k = ell.nbr.shape[1]
        kb = max(8, -8 * (-k // 8))  # K rounded up to a multiple of 8
        if kb != k:
            pad_n = jnp.full((ell.nbr.shape[0], kb - k), -1, jnp.int32)
            pad_w = jnp.zeros((ell.nbr.shape[0], kb - k), jnp.float32)
            ell = ELLGraph(
                nbr=jnp.concatenate([ell.nbr, pad_n], axis=1),
                wgt=jnp.concatenate([ell.wgt, pad_w], axis=1),
            )

    # unlabeled -> labeled edges fold into wl0 / wl1
    wl0 = np.zeros(u, np.float32)
    wl1 = np.zeros(u, np.float32)
    ul = s_unl & ~d_unl
    lab = g.labels[dst[ul]]
    rows = remap[src[ul]]
    np.add.at(wl0, rows[lab == 0], wgt[ul][lab == 0])
    np.add.at(wl1, rows[lab == 1], wgt[ul][lab == 1])

    nbr, w = np.asarray(ell.nbr), np.asarray(ell.wgt)
    valid = np.ones(u, bool)
    if pad_to is not None and u < pad_to:  # shard padding rows
        k = nbr.shape[1]
        nbr = np.concatenate([nbr, np.full((pad_to - u, k), -1, np.int32)])
        w = np.concatenate([w, np.zeros((pad_to - u, k), np.float32)])
        wl0 = np.concatenate([wl0, np.zeros(pad_to - u, np.float32)])
        wl1 = np.concatenate([wl1, np.zeros(pad_to - u, np.float32)])
        valid = np.concatenate([valid, np.zeros(pad_to - u, bool)])

    problem = PropagationProblem(
        nbr=jnp.asarray(nbr),
        wgt=jnp.asarray(w),
        wl0=jnp.asarray(wl0),
        wl1=jnp.asarray(wl1),
        valid=jnp.asarray(valid),
    )
    return Snapshot(problem=problem, unl_ids=unl_ids, remap=remap)

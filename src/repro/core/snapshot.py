"""Build device ``PropagationProblem``s from the host ``DynamicGraph``.

Labeled classes are folded into the per-node supernode weights wl0/wl1; the
ELL tensor holds only unlabeled↔unlabeled edges (paper §4 "three kinds of
vertices that can impact the label").

Shape discipline: an evolving graph would trigger one XLA recompile per
Δ_t if snapshots were built at their natural ``(U, K)``.  Both axes are
therefore padded up a *geometric bucket ladder* (``bucket`` for rows,
``bucket_k`` for the neighbor axis), so an entire stream touches only
O(log U · log K) distinct shapes — the compile-once contract that
``core.stream.StreamEngine`` and the dispatch layer in ``kernels.ops``
build on (docs/streaming.md).
"""

from __future__ import annotations

import dataclasses
import functools
import logging

import jax
import jax.numpy as jnp
import numpy as np

logger = logging.getLogger(__name__)

# (max_k, natural-K rung) pairs whose truncation was already WARNed —
# repeats log at DEBUG so a persistent hub doesn't spam every Δ_t.  This
# module-level set is the fallback for bare ``build_host_problem`` calls
# only: engines (DynLP / StreamEngine) pass their own per-engine set via
# ``warned=`` so a fresh engine warns again instead of inheriting another
# engine's (or test's) dedup state.  ``reset_max_k_warnings`` clears the
# fallback for callers that need a clean slate without an engine.
_MAX_K_WARNED: set[tuple[int, int]] = set()


def reset_max_k_warnings() -> None:
    """Clear the process-wide max_k truncation-warning dedup state."""
    _MAX_K_WARNED.clear()

from repro.core.propagate import PropagationProblem
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.graph.structures import coo_to_csr, csr_to_ell_fast


@dataclasses.dataclass
class Snapshot:
    problem: PropagationProblem
    unl_ids: np.ndarray  # (U,) global ids of the unlabeled alive vertices
    remap: np.ndarray  # (num_nodes,) global -> compact (or -1)


@dataclasses.dataclass(frozen=True)
class LabelView:
    """Immutable query-side view of the labels at one commit point.

    The serving layer answers label queries from the *last committed*
    snapshot while the next batch's solve may still be in flight — and
    ``StreamEngine.submit`` mutates the host graph (new vertices, deleted
    rows, supernode label inits) *before* that solve commits.  A query
    that read the live ``DynamicGraph`` mid-flight would therefore see a
    torn state.  ``LabelView`` is the fix: plain numpy copies of
    ``(f, labels, alive)`` frozen at drain time, so reads are consistent,
    never block on the device, and vertices from a not-yet-committed
    batch simply don't exist yet.  Built by ``StreamEngine.drain`` (one
    view per commit); served by ``serving.lp_service.LPService``.
    """

    f: np.ndarray  # (num_nodes,) float32 fractional labels
    labels: np.ndarray  # (num_nodes,) int8 ground truth (UNLABELED = -1)
    alive: np.ndarray  # (num_nodes,) bool
    commit_id: int  # number of committed (drained) batches behind this view

    def __post_init__(self):
        for a in (self.f, self.labels, self.alive):
            a.setflags(write=False)

    @classmethod
    def from_graph(cls, g: DynamicGraph, commit_id: int = 0) -> "LabelView":
        return cls(f=g.f.copy(), labels=g.labels.copy(),
                   alive=g.alive.copy(), commit_id=commit_id)

    @property
    def num_nodes(self) -> int:
        return len(self.labels)

    def predictions(self, cutoff: float = 0.5) -> tuple[np.ndarray, np.ndarray]:
        """(global ids, binary predictions) for alive unlabeled vertices —
        the committed-state twin of ``StreamEngine.predictions``."""
        ids = np.flatnonzero(self.alive & (self.labels == UNLABELED))
        return ids, (self.f[ids] >= cutoff).astype(np.int8)

    def query(self, node_ids, cutoff: float = 0.5
              ) -> tuple[np.ndarray, np.ndarray]:
        """Per-node (prediction, confidence) for arbitrary global ids.

        Ground-truth seeds answer with their label at confidence 1.0;
        unlabeled alive vertices with their thresholded fractional label
        at confidence ``max(f, 1-f)``; dead or never-seen ids (including
        vertices inserted by a batch that has not committed yet) with
        ``UNLABELED`` at confidence 0.0.
        """
        ids = np.asarray(node_ids, np.int64).reshape(-1)
        pred = np.full(len(ids), UNLABELED, np.int8)
        conf = np.zeros(len(ids), np.float32)
        known = (ids >= 0) & (ids < self.num_nodes)
        live = known.copy()
        live[known] = self.alive[ids[known]]
        kn = ids[live]
        seeded = self.labels[kn] != UNLABELED
        f = self.f[kn]
        pred[live] = np.where(seeded, self.labels[kn],
                              (f >= cutoff).astype(np.int8))
        conf[live] = np.where(seeded, 1.0, np.maximum(f, 1.0 - f))
        return pred, conf


# ---------------------------------------------------------------------- #
# Device-resident read path
# ---------------------------------------------------------------------- #

# Query-axis bucket ladder: fused read batches pad their id vector up a
# doubling ladder so serving compiles O(log Q_max) gather programs, the
# same compile-once contract the solve side gets from ``bucket``.
QUERY_FLOOR = 256


def query_bucket(q: int, floor: int = QUERY_FLOOR) -> int:
    """Round a query batch size up a doubling ladder (compile-once reads)."""
    b = floor
    while b < q:
        b *= 2
    return b


@jax.jit
def _device_query(f, labels, alive, ids, cutoff):
    """Batched label lookup on device — the jitted twin of
    ``LabelView.query``.

    ``ids`` out of ``[0, len(f))`` (including the -1 padding the query
    ladder appends) and dead rows answer UNLABELED at confidence 0; the
    node-axis padding rows publish ``alive=False`` so one clamp handles
    both.  ``cutoff`` is per-element so one fused gather can serve
    tickets with different thresholds.
    """
    n = f.shape[0]
    safe = jnp.clip(ids, 0, n - 1)
    known = (ids >= 0) & (ids < n) & alive[safe]
    lab = labels[safe]
    fv = f[safe]
    seeded = lab != UNLABELED
    pred = jnp.where(
        known,
        jnp.where(seeded, lab, (fv >= cutoff).astype(jnp.int8)),
        UNLABELED)
    conf = jnp.where(
        known,
        jnp.where(seeded, jnp.float32(1.0), jnp.maximum(fv, 1.0 - fv)),
        jnp.float32(0.0))
    return pred.astype(jnp.int8), conf.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class DeviceLabelView:
    """Device twin of ``LabelView``: the committed snapshot staged once
    per commit so query bursts run as one jitted gather instead of
    per-call host indexing.

    Arrays are padded up the ``bucket`` node ladder (f→0, labels→
    UNLABELED, alive→False), so a growing graph recompiles the gather
    O(log N) times, and placed by ``placement`` — a ``jax.Device`` (a
    mesh serving deployment passes its read replica,
    ``core.distributed.read_replica_device``) or a ``Sharding`` (row-
    sharded ``f`` under a mesh when no spare device exists,
    ``core.distributed.view_sharding``).  Immutable: a commit publishes
    a NEW view (``publish_device_view``), so concurrent readers holding
    this one never observe a torn state.
    """

    f: jax.Array  # (N_pad,) float32
    labels: jax.Array  # (N_pad,) int8
    alive: jax.Array  # (N_pad,) bool
    num_nodes: int  # live prefix of the padded node axis
    commit_id: int
    host: LabelView  # the host twin this view was published from

    def query(self, node_ids, cutoff=0.5) -> tuple[np.ndarray, np.ndarray]:
        """(pred, conf) for arbitrary global ids — ``LabelView.query``
        semantics, one fused device gather.  ``cutoff`` may be a scalar
        or a per-id vector (fused multi-ticket reads)."""
        ids = np.asarray(node_ids, np.int64).reshape(-1)
        q = len(ids)
        qp = query_bucket(max(q, 1))
        ids_pad = np.full(qp, -1, np.int32)
        # ids beyond int32 can't index a device view; they are unknown by
        # construction (num_nodes < 2**31), so map them to the -1 lane
        in32 = (ids >= np.iinfo(np.int32).min) & (ids <= np.iinfo(np.int32).max)
        ids_pad[:q][in32] = ids[in32].astype(np.int32)
        cut_pad = np.zeros(qp, np.float32)
        cut_pad[:q] = np.broadcast_to(
            np.asarray(cutoff, np.float32).reshape(-1), (q,)) if q else 0.0
        pred, conf = _device_query(self.f, self.labels, self.alive,
                                   ids_pad, cut_pad)
        return np.asarray(pred[:q]), np.asarray(conf[:q])


def publish_device_view(view: LabelView, placement=None) -> DeviceLabelView:
    """Stage a committed ``LabelView`` onto the device — called at drain
    by ``StreamEngine`` (commit handoff: the view's own frozen arrays
    feed ``device_put`` directly, no extra host copies; the transfers
    dispatch async so publication overlaps the next batch's host work).

    ``placement`` is a ``jax.Device``, a ``Sharding``, or None (default
    device).  Sharded placements pad the node axis to a multiple of the
    shard count on top of the bucket ladder.
    """
    n = view.num_nodes
    n_pad = bucket(max(n, 1))
    mult = getattr(getattr(placement, "mesh", None), "devices", None)
    if mult is not None:  # NamedSharding: rows must split evenly
        d = int(mult.size)
        n_pad = -d * (-n_pad // d)
    f = np.zeros(n_pad, np.float32)
    lab = np.full(n_pad, UNLABELED, np.int8)
    alive = np.zeros(n_pad, bool)
    f[:n] = view.f
    lab[:n] = view.labels
    alive[:n] = view.alive
    put = (jax.device_put if placement is None
           else functools.partial(jax.device_put, device=placement))
    return DeviceLabelView(
        f=put(f), labels=put(lab), alive=put(alive),
        num_nodes=n, commit_id=view.commit_id, host=view)


@dataclasses.dataclass
class HostSnapshot:
    """Numpy twin of ``Snapshot`` — not yet shipped to the device.

    ``core.stream.StreamEngine`` stages these into persistent donated
    device buffers itself; ``build_problem`` converts eagerly for the
    one-shot callers.
    """

    nbr: np.ndarray  # (U_pad, K_pad) int32
    wgt: np.ndarray  # (U_pad, K_pad) float32
    wl0: np.ndarray  # (U_pad,) float32
    wl1: np.ndarray  # (U_pad,) float32
    valid: np.ndarray  # (U_pad,) bool
    unl_ids: np.ndarray  # (U,) global ids
    remap: np.ndarray  # (num_nodes,) global -> compact (or -1)

    @property
    def bucket_key(self) -> tuple[int, int]:
        return self.nbr.shape


def apply_halo_layout(host: HostSnapshot, plan) -> HostSnapshot:
    """Reorder a host snapshot's rows into a halo export-prefix layout.

    ``plan`` is a ``graph.partition.HaloPlan`` built from THIS snapshot's
    ``nbr`` (same padded row count): rows permute so every
    cross-shard-referenced row leads its shard, neighbor ids are already
    remapped by the plan.  Row order is invisible to the fixpoint — each
    row's K-axis reduction order is untouched and updates read neighbors
    by id — so the permuted snapshot converges to bit-identical labels;
    callers keep ``plan.inv_perm`` to fold solved rows back to
    ``unl_ids`` order.  ``unl_ids``/``remap`` stay in ORIGINAL row order
    (they index the pre-permutation rows, which is what the engine's
    frontier/f0 construction uses before permuting).

    Snapshot rows follow insertion order (``unl_ids`` ascends), so
    streams whose arrival order is spatially local — see
    ``data.synth.locality_stream`` — get contiguous row blocks whose kNN
    edges mostly stay inside a shard: small export sets are a property
    of the stream's locality, not of this reordering, which only makes
    whatever export set exists contiguous per shard.
    """
    if len(plan.perm) != len(host.valid):
        raise ValueError(
            f"halo plan rows {len(plan.perm)} != snapshot rows "
            f"{len(host.valid)}; build the plan from this snapshot's nbr")
    p = plan.perm
    return HostSnapshot(
        nbr=plan.nbr, wgt=host.wgt[p], wl0=host.wl0[p], wl1=host.wl1[p],
        valid=host.valid[p], unl_ids=host.unl_ids, remap=host.remap)


def reorder_host_snapshot(host: HostSnapshot,
                          order: np.ndarray) -> tuple[HostSnapshot, np.ndarray]:
    """Permute a host snapshot's rows by ``order`` (new → old), remapping
    neighbor ids to the new row space.

    The generic twin of ``apply_halo_layout`` for orderings that carry no
    precomputed remapped ``nbr`` — the BSR backend uses it with the
    Step-1 component order (``core.components.component_order``) so the
    adjacency densifies into tiles.  Row order is invisible to the
    fixpoint (same argument as the halo layout); returns the permuted
    snapshot plus ``inv`` (old → new) for folding solved rows back.
    """
    from repro.core.components import permute_ell_rows

    if len(order) != len(host.valid):
        raise ValueError(f"order has {len(order)} rows, snapshot has "
                         f"{len(host.valid)}")
    nbr, inv = permute_ell_rows(host.nbr, order)
    return HostSnapshot(
        nbr=nbr, wgt=host.wgt[order], wl0=host.wl0[order],
        wl1=host.wl1[order], valid=host.valid[order],
        unl_ids=host.unl_ids, remap=host.remap), inv


def bucket(n: int, ratio: float = 1.3, floor: int = 256) -> int:
    """Round ``n`` up to a geometric bucket so jit caches hit across batches
    (the evolving graph would otherwise trigger one recompile per Δ_t)."""
    b = floor
    while b < n:
        b = int(np.ceil(b * ratio))
    return b


def bucket_k(k: int, floor: int = 8) -> int:
    """Two-regime ladder for the neighbor axis: multiples of 8 up to 64
    (tight padding where real kNN degrees live — matching the pre-stream
    ``DynLP`` rounding so per-sweep gather work does not regress), then
    doubling so hub-degree creep can't produce an unbounded shape count."""
    b = floor
    while b < k:
        b = b + 8 if b < 64 else b * 2
    return b


def ladder_size(max_u: int, max_k: int, ratio: float = 1.3,
                floor: int = 256, k_floor: int = 8) -> int:
    """Number of distinct (U_bucket, K_bucket) shapes any stream whose
    snapshots stay within (max_u, max_k) can produce — the compile-count
    bound asserted by tests/test_stream.py.  Derived from ``bucket`` /
    ``bucket_k`` themselves so the bound can't drift from the ladders."""
    n_u = 1
    b = floor
    while b < max_u:
        b = bucket(b + 1, ratio=ratio, floor=floor)
        n_u += 1
    n_k = 1
    b = k_floor
    while b < max_k:
        b = bucket_k(b + 1, floor=k_floor)
        n_k += 1
    return n_u * n_k


def build_host_problem(
    g: DynamicGraph,
    max_degree: int | None = None,
    pad_to: int | None = None,
    k_pad: int | None = None,
    auto_bucket: bool = False,
    row_multiple: int | None = None,
    max_k: int | None = None,
    warned: set | None = None,
    hot: np.ndarray | None = None,
) -> HostSnapshot:
    """Host-side (numpy) snapshot build; see module docstring for padding.

    ``row_multiple`` rounds the (possibly bucketed) row count up to a
    multiple — mesh-sharded streams pass the device count so every bucket
    shape shards evenly (``core.distributed.build_stream_plan``) — times
    the BSR block size when the bsr backend is selectable.

    ``max_k`` caps the ELL neighbor axis: rows whose natural degree
    exceeds it keep only their ``max_k`` *heaviest* edges (the
    ``csr_to_ell_fast`` truncation policy), so a single hub vertex can't
    drag the whole K-bucket ladder — and every jit cache behind it — up.
    Unlike ``max_degree`` it is a pure cap: low-degree snapshots keep
    their tight natural K.  Truncation is logged when it fires; ``warned``
    scopes the once-per-rung WARNING dedup (engines pass their own set,
    bare calls share the module-level fallback).

    ``hot`` restricts the snapshot to a working set (the ``landmark``
    backend's hot/cold split): only alive unlabeled vertices with
    ``hot[id]`` become rows, and an edge from a hot row to a COLD
    unlabeled neighbor v folds into the supernode weights with v's
    committed fractional label — ``wl0 += w·(1−f_v)``, ``wl1 += w·f_v``.
    Because ``update_island`` computes ``d_f = (0−f)·wl0 + (1−f)·wl1 +
    Σ w·(f_v − f)``, that fold contributes exactly ``w·(f_v − f)``: the
    restricted solve is an EXACT Jacobi fixpoint on the hot subgraph
    with the cold tail as fixed boundary conditions, reusing the
    barriered arithmetic (and every backend/transport behind it)
    unchanged.
    """
    if warned is None:
        warned = _MAX_K_WARNED
    alive_unl = g.alive & (g.labels == UNLABELED)
    row_mask = alive_unl if hot is None else alive_unl & hot
    unl_ids = np.flatnonzero(row_mask)
    u = len(unl_ids)
    remap = np.full(g.num_nodes, -1, np.int64)
    remap[unl_ids] = np.arange(u)

    src, dst, wgt = g.src, g.dst, g.wgt
    live = g.alive[src] & g.alive[dst] if len(src) else np.zeros(0, bool)
    src, dst, wgt = src[live], dst[live], wgt[live]

    s_unl = row_mask[src]
    d_unl = alive_unl[dst]
    d_row = d_unl if hot is None else row_mask[dst]

    # (hot) unlabeled -> (hot) unlabeled edges form the ELL tensor
    uu = s_unl & d_row
    csr = coo_to_csr(u, remap[src[uu]], remap[dst[uu]], wgt[uu])
    if max_k is not None:
        deg = np.diff(csr.rowptr)
        nat_k = int(deg.max()) if u else 0
        if nat_k > max_k:
            n_over = int((deg > max_k).sum())
            # a persistent hub would repeat this every Δ_t: warn once per
            # (cap, natural-K rung) per process, then demote to debug
            warn_key = (max_k, bucket_k(nat_k))
            level = (logging.DEBUG if warn_key in warned
                     else logging.WARNING)
            warned.add(warn_key)
            logger.log(
                level,
                "snapshot: max_k=%d truncating %d/%d rows (natural max "
                "degree %d; heaviest-edge policy)", max_k, n_over, u, nat_k)
            max_degree = max_k if max_degree is None else min(max_degree,
                                                             max_k)
    ell = csr_to_ell_fast(csr, max_degree=max_degree)
    nbr, w = np.asarray(ell.nbr), np.asarray(ell.wgt)
    k = nbr.shape[1]
    if auto_bucket:
        pad_to = bucket(u) if pad_to is None else pad_to
        k_pad = bucket_k(k) if k_pad is None else k_pad
    if row_multiple is not None and row_multiple > 1:
        base = pad_to if pad_to is not None else u
        pad_to = -row_multiple * (-base // row_multiple)
    if k_pad is not None and k < k_pad:
        nbr = np.concatenate(
            [nbr, np.full((nbr.shape[0], k_pad - k), -1, np.int32)], axis=1
        )
        w = np.concatenate(
            [w, np.zeros((w.shape[0], k_pad - k), np.float32)], axis=1
        )

    # unlabeled -> labeled edges fold into wl0 / wl1
    wl0 = np.zeros(u, np.float32)
    wl1 = np.zeros(u, np.float32)
    ul = s_unl & ~d_unl
    lab = g.labels[dst[ul]]
    rows = remap[src[ul]]
    np.add.at(wl0, rows[lab == 0], wgt[ul][lab == 0])
    np.add.at(wl1, rows[lab == 1], wgt[ul][lab == 1])

    if hot is not None:
        # hot -> cold-unlabeled edges fold the frozen fractional label as
        # boundary conditions (see docstring: exact on the hot subgraph)
        uc = s_unl & d_unl & ~d_row
        fv = g.f[dst[uc]].astype(np.float32)
        rows_c = remap[src[uc]]
        np.add.at(wl0, rows_c, wgt[uc] * (1.0 - fv))
        np.add.at(wl1, rows_c, wgt[uc] * fv)

    valid = np.ones(u, bool)
    if pad_to is not None and u < pad_to:  # shard padding rows
        kk = nbr.shape[1]
        nbr = np.concatenate([nbr, np.full((pad_to - u, kk), -1, np.int32)])
        w = np.concatenate([w, np.zeros((pad_to - u, kk), np.float32)])
        wl0 = np.concatenate([wl0, np.zeros(pad_to - u, np.float32)])
        wl1 = np.concatenate([wl1, np.zeros(pad_to - u, np.float32)])
        valid = np.concatenate([valid, np.zeros(pad_to - u, bool)])

    return HostSnapshot(
        nbr=nbr, wgt=w, wl0=wl0, wl1=wl1, valid=valid,
        unl_ids=unl_ids, remap=remap,
    )


def build_problem(
    g: DynamicGraph,
    max_degree: int | None = None,
    pad_to: int | None = None,
    auto_bucket: bool = False,
    max_k: int | None = None,
    warned: set | None = None,
) -> Snapshot:
    host = build_host_problem(
        g, max_degree=max_degree, pad_to=pad_to, auto_bucket=auto_bucket,
        max_k=max_k, warned=warned,
    )
    problem = PropagationProblem(
        nbr=jnp.asarray(host.nbr),
        wgt=jnp.asarray(host.wgt),
        wl0=jnp.asarray(host.wl0),
        wl1=jnp.asarray(host.wl1),
        valid=jnp.asarray(host.valid),
    )
    return Snapshot(problem=problem, unl_ids=host.unl_ids, remap=host.remap)

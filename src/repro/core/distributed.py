"""Vertex-partitioned distributed label propagation (DESIGN.md §4).

Rows (vertices) are partitioned across a 1-D device view of the mesh via
``shard_map``; each device owns a contiguous ELL row block whose neighbor
ids index the GLOBAL label vector.  Per iteration:

    all-gather F  →  local fused update  →  δ-threshold + local frontier
    →  psum(any frontier) convergence flag

F is N·4 bytes total, so the all-gather is cheap relative to the edge work
(50M vertices → 200 MB across the pod, ~4 ms at ICI bandwidth — the
roofline's collective term; a halo-exchange variant that ships only
boundary labels is the documented §Perf iteration for higher-diameter
partitionings).

The body reuses the exact update semantics of ``core.propagate`` (same
fixpoint, same iteration count), so single-device tests transfer.

Two transports exist, both built by ``make_sharded_propagate_fn`` and
both wrapping the same pluggable per-shard *update* body
(``backend="ref"`` inlines the XLA Jacobi update, ``backend="ell_pallas"``
calls the fused ELL Pallas kernel over the shard's row block,
``backend="bsr"`` scatter-builds the shard's BSR tiles from the staged
ELL rows and aggregates with the ``bsr_spmv`` MXU kernel against the
reconstructed global F):

  * ``transport="allgather"`` — every shard's full F block is gathered
    per iteration.  Shape-only partitioning (contiguous row blocks),
    topology-free, the safe default.
  * ``transport="halo"`` — only each shard's EXPORT PREFIX (length
    ``export_max``) is gathered; rows must be laid out so every
    cross-shard-referenced row leads its shard
    (``graph.partition.build_halo_plan``).  The gathered prefixes are
    scattered back into a full-length substitute vector whose entries
    match the all-gathered F at every *referenced* position, so the
    update body — and therefore the fixpoint, iteration count, and the
    labels bit for bit — is identical to the all-gather transport while
    the collective ships Σ|exports| instead of N values.

``StreamShardPlan`` packages the all-gather transport for
``core.stream.StreamEngine``: one plan per bucket-ladder rung (shape),
reused across every batch that lands in that rung, holding the row
shardings for staging and the jitted (optionally f0-donating) runner.
``StreamHaloPlan`` is its halo twin: same per-rung lifecycle, plus the
rung's compiled export budget — the engine re-derives the export *layout*
per Δ_t on the host (stale exports within the budget are harmless: they
carry committed labels) and falls back to all-gather for any batch whose
exports overflow the budget.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):  # jax ≥ 0.6
    _shard_map = jax.shard_map
else:  # jax ≤ 0.4.x ships it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

# The static replication checker has no rule for ``while`` on older jax
# (and the check is advisory anyway) — disable it under whichever name
# this version spells it.
import inspect as _inspect

_smap_params = _inspect.signature(_shard_map).parameters
_CHECK_KW = (
    {"check_rep": False} if "check_rep" in _smap_params
    else {"check_vma": False} if "check_vma" in _smap_params
    else {}
)


def shard_map(f, *, mesh, in_specs, out_specs):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_CHECK_KW)

from repro.core.propagate import (PropagateResult, PropagationProblem,
                                  bsr_update_island, update_island)
from repro.graph.structures import PAD
from repro.kernels.bsr_spmv import bsr_spmv, fill_bsr_blocks
from repro.kernels.ell_propagate import ell_propagate_step

# "landmark" has no mesh body of its own: its hot solve IS the ref body
# (the hot/cold split happens at staging, in the engine), so it rides the
# ref branch of make_sharded_propagate_fn under both transports.
STREAM_BACKENDS = ("ref", "ell_pallas", "bsr", "landmark")
TRANSPORTS = ("allgather", "halo")


# ---------------------------------------------------------------------- #
# Serving read placement (device-resident LabelView under a mesh)
# ---------------------------------------------------------------------- #

def read_replica_device(mesh: jax.sharding.Mesh) -> jax.Device | None:
    """First visible device NOT in ``mesh`` — the serving read replica.

    A mesh deployment that leaves a device out of the solver mesh gets
    strictly better read behaviour than single-device serving: the
    committed ``DeviceLabelView`` is published to the replica, so query
    gathers never queue behind solve programs or snapshot staging on the
    solver devices' execution streams (programs on one device
    serialize).  Returns None when the mesh covers every device — then
    ``view_sharding`` is the fallback placement.
    """
    in_mesh = {d.id for d in mesh.devices.flat}
    for d in jax.devices():
        if d.id not in in_mesh:
            return d
    return None


def view_sharding(mesh: jax.sharding.Mesh) -> jax.sharding.NamedSharding:
    """Row-sharded placement for the committed view's node axis, over all
    mesh axes — for deployments whose ``f`` is too big for one device.
    The jitted query gather then compiles to a sharded lookup (GSPMD
    inserts the collectives); prefer ``read_replica_device`` when a
    spare device exists — a replica gather needs no collective at all.
    """
    return jax.sharding.NamedSharding(mesh, P(mesh.axis_names))


def read_placement(mesh: jax.sharding.Mesh | None):
    """Default placement for published device views: the committed-view
    device (None → jax's default) without a mesh; with one, the read
    replica if a spare device exists, else row-sharded over the mesh."""
    if mesh is None:
        return None
    return read_replica_device(mesh) or view_sharding(mesh)


class ShardedProblem(NamedTuple):
    """PropagationProblem padded to a multiple of the device count."""

    problem: PropagationProblem
    n_orig: int


def pad_problem(problem: PropagationProblem, n_devices: int) -> ShardedProblem:
    n = problem.num_unlabeled
    pad = (-n) % n_devices
    if pad == 0:
        return ShardedProblem(problem, n)
    padded = PropagationProblem(
        nbr=jnp.pad(problem.nbr, ((0, pad), (0, 0)), constant_values=PAD),
        wgt=jnp.pad(problem.wgt, ((0, pad), (0, 0))),
        wl0=jnp.pad(problem.wl0, (0, pad)),
        wl1=jnp.pad(problem.wl1, (0, pad)),
        valid=jnp.pad(problem.valid, (0, pad)),
    )
    return ShardedProblem(padded, n)


def make_sharded_propagate_fn(
    mesh,
    *,
    backend: str = "ref",
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_rows: int = 512,
    interpret: bool | None = None,
    donate: bool = False,
    transport: str = "allgather",
    export_max: int | None = None,
    block_size: int = 0,
    num_slots: int = 0,
):
    """Build the jitted sharded propagation step (lowerable with
    ShapeDtypeStructs for the LP roofline dry-run).

    The per-shard update body is the selected single-device backend:
    ``"ref"`` inlines the exact ``core.propagate`` Jacobi arithmetic (same
    per-row reduction order, so sharded labels are bit-identical to the
    single-device engine); ``"ell_pallas"`` runs the fused ELL kernel over
    the shard's row block against the gathered global F
    (``row_offset`` keys the kernel's F reads to this shard's rows);
    ``"bsr"`` scatter-builds the shard's BSR tiles from its staged ELL
    rows (``kernels.bsr_spmv.fill_bsr_blocks`` — inside the jit, so the
    tiles never exist on the host) and aggregates with the ``bsr_spmv``
    MXU kernel against the reconstructed global F.  The bsr runner takes
    one extra row-sharded input, the per-edge ``slot`` map, and its
    ``run`` signature is ``(nbr, wgt, wl0, wl1, valid, slot, f, fr)``;
    ``block_size``/``num_slots`` fix the compiled tile layout (callers
    keep snapshots whose slot requirement exceeds ``num_slots`` off this
    runner — the streaming engine falls back to ell_pallas for such a
    Δ_t).  Because the tile layout is part of the program, bsr labels
    are bit-identical across the two transports for the same row layout
    (the engine stages bsr snapshots in the halo layout under BOTH
    transports for exactly this reason).

    ``transport`` picks the per-iteration collective: ``"allgather"``
    ships every shard's full F block; ``"halo"`` ships only the leading
    ``export_max`` rows of each shard and scatters them into a
    full-length substitute vector (own block overwritten with exact local
    values).  With rows laid out so every cross-shard-referenced row sits
    inside its shard's export prefix (``graph.partition.build_halo_plan``),
    the substitute agrees with the all-gathered F at every position the
    update body reads, so both transports produce bit-identical labels —
    the halo form just moves Σ|exports|·4 instead of N·4 bytes per
    gather.  Positions outside any export prefix are zero-filled; they
    are only ever touched by PAD-masked lanes whose contribution is
    zeroed (ref) or weight-masked (ell_pallas).

    ``donate=True`` donates the f0 argument *per shard* — each device
    recycles its own label-block allocation across Δ_t (no-op on CPU).
    """
    if backend not in STREAM_BACKENDS:
        raise ValueError(
            f"sharded backend {backend!r} not supported; want one of "
            f"{STREAM_BACKENDS}")
    if transport not in TRANSPORTS:
        raise ValueError(
            f"transport {transport!r} not supported; want one of {TRANSPORTS}")
    if transport == "halo" and (export_max is None or export_max < 1):
        raise ValueError("transport='halo' needs export_max >= 1")
    if backend == "bsr" and (block_size < 1 or num_slots < 1):
        raise ValueError("sharded backend='bsr' needs block_size >= 1 and "
                         "num_slots >= 1 (the compiled tile layout)")
    axes = mesh.axis_names
    n_dev = int(mesh.devices.size)
    delta_ = jnp.float32(delta)
    row = P(axes)  # rows sharded over ALL mesh axes (flattened view)
    row2 = P(axes, None)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    # bsr takes one extra row-sharded input (the per-edge tile-slot map)
    in_specs = ((row2, row2, row, row, row, row2, row, row)
                if backend == "bsr" else
                (row2, row2, row, row, row, row, row))

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(row, P(), P(), P()),
    )
    def run(nbr, wgt, wl0, wl1, valid, *rest):
        slot = rest[0] if backend == "bsr" else None
        f_loc, fr_loc = rest[-2:]
        mask = nbr != PAD
        idx = jnp.where(mask, nbr, 0)
        m = f_loc.shape[0]

        if transport == "halo":
            e = min(export_max, m)
            my = jax.lax.axis_index(axes)
            my_row0 = my * m
            owner = idx // m  # (m, K) owning shard of each referenced row
            offset = idx % m
            # (m, K) positions into the [local block | export prefixes]
            # concat buffer built per gather below: local references read
            # their own block, cross-shard ones read inside the owner's
            # export prefix (guaranteed by the halo row layout; masked
            # PAD lanes resolve to idx 0 = shard 0's prefix row 0, a
            # defined value the update masks out).  Integer select, so
            # the floating-point values reach the update through a plain
            # gather — the same producer-op shape as the all-gather
            # transport, which keeps XLA emitting the update arithmetic
            # identically (bit-equality contract).
            pos = jnp.where(owner == my, offset,
                            m + owner * e + jnp.minimum(offset, e - 1))

            def gather_full(x_loc):
                """Full-length substitute vector (ell_pallas path: the
                fused kernel indexes F globally, so the export prefixes
                are scattered back into an (N,) buffer; own block is
                exact, so reads of local rows never go stale)."""
                ex = jax.lax.all_gather(x_loc[:e], axes, tiled=True)
                full = jnp.zeros((n_dev, m), x_loc.dtype)
                full = full.at[:, :e].set(ex.reshape(n_dev, e)).reshape(-1)
                return jax.lax.dynamic_update_slice(full, x_loc, (my_row0,))

            def gather_vals(x_loc):
                """(m, K) values of x at the referenced positions — the
                ref-body path: the collective ships only the (D, e)
                export prefixes and values are picked per reference from
                a small (m + D·e) concat buffer, never a full-length
                temporary."""
                ex = jax.lax.all_gather(x_loc[:e], axes, tiled=True)
                return jnp.concatenate([x_loc, ex])[pos]
        else:
            def gather_full(x_loc):
                return jax.lax.all_gather(x_loc, axes, tiled=True)

            def gather_vals(x_loc):
                return gather_full(x_loc)[idx]

        if backend == "ell_pallas":
            # Pad the shard's row block to a multiple of the kernel tile
            # (the sharded twin of ops._pad_rows).  Pad rows never enter
            # the frontier, so their outputs are discarded by the slice.
            r = min(block_rows, m)
            m_pad = -r * (-m // r)
            rpad = ((0, m_pad - m), (0, 0))
            nbr_k = jnp.pad(nbr, rpad, constant_values=PAD)
            wgt_k = jnp.pad(wgt, rpad)
            wl0_k = jnp.pad(wl0, (0, m_pad - m))
            wl1_k = jnp.pad(wl1, (0, m_pad - m))
        elif backend == "bsr":
            # Scatter the shard's staged ELL rows into its BSR tiles once
            # per solve (loop-invariant; block columns stay GLOBAL so the
            # SpMV consumes the reconstructed full-length F directly).
            # Tiles whose columns fall outside any export prefix carry
            # exact-zero weights, so the halo transport's zero-filled
            # substitute positions contribute identical bits to the
            # all-gathered values — the cross-transport equality argument.
            blocks, bcols = fill_bsr_blocks(
                nbr, wgt, slot, block_size=block_size, num_slots=num_slots)
            wall = jnp.sum(wgt, axis=1) + wl0 + wl1

        def update(f_l, fr_l):
            if backend == "bsr":
                f_full = gather_full(f_l)  # (N,) — the collective
                y = bsr_spmv(blocks, bcols, f_full, interpret=interpret)[:m]
                f_all = bsr_update_island(y, wl1, wall, f_l)
                f_new = jnp.where(fr_l & valid, f_all, f_l)
                changed = (jnp.abs(f_new - f_l) > delta_) & valid
                return f_new, changed
            if backend == "ell_pallas":
                f_full = gather_full(f_l)  # (N,) — the collective
                row0 = jax.lax.axis_index(axes) * m
                f_new, changed = ell_propagate_step(
                    nbr_k, wgt_k, wl0_k, wl1_k,
                    jnp.pad(fr_l, (0, m_pad - m)), f_full, delta=delta,
                    block_rows=r, interpret=interpret, row_offset=row0)
                return f_new[:m], changed[:m] & valid
            f_u = f_l
            # the barrier-isolated Jacobi island — the exact HLO shared
            # with the single-device engine, so every transport contracts
            # the arithmetic identically (bit-equality contract); the
            # transports differ only in how the (m, K) neighbor values
            # are fetched, never in their bits
            f_new = update_island(wgt, wl0, wl1, f_u, gather_vals(f_l), mask)
            f_new = jnp.where(fr_l, f_new, f_u)
            changed = (jnp.abs(f_new - f_u) > delta_) & valid
            return f_new, changed

        def body(state):
            f_l, fr_l, it, _ = state
            f_new, changed_l = update(f_l, fr_l)
            nbr_changed = jnp.any(gather_vals(changed_l) & mask, axis=1)
            fr_new = (changed_l | nbr_changed) & valid
            resid = jax.lax.pmax(
                jnp.max(jnp.abs(f_new - f_l), initial=0.0), axes)
            return f_new, fr_new, it + 1, resid

        def cond(state):
            _, fr_l, it, _ = state
            any_frontier = jax.lax.pmax(fr_l.any().astype(jnp.int32), axes)
            return jnp.logical_and(any_frontier > 0, it < max_iters)

        f_l, fr_l, iters, resid = jax.lax.while_loop(
            cond, body, (f_loc, fr_loc, jnp.int32(0), jnp.float32(0)))
        done = jax.lax.pmax(fr_l.any().astype(jnp.int32), axes) == 0
        return f_l, iters, done, resid

    f0_idx = 6 if backend == "bsr" else 5  # slot shifts the arg list
    return jax.jit(run, donate_argnums=(f0_idx,) if donate else ())


def make_propagate_fn(mesh, delta: float = 1e-4, max_iters: int = 100_000):
    """All-gather ``ref`` transport with the historical one-shot signature."""
    return make_sharded_propagate_fn(mesh, backend="ref", delta=delta,
                                     max_iters=max_iters)


def distributed_propagate(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    mesh: jax.sharding.Mesh,
    delta: float = 1e-4,
    max_iters: int = 100_000,
) -> PropagateResult:
    """Run DynLP Step 3 with vertices sharded over every mesh device."""
    n_dev = mesh.devices.size
    sp = pad_problem(problem, n_dev)
    p = sp.problem
    n = p.num_unlabeled
    f0 = jnp.pad(f0.astype(jnp.float32), (0, n - len(f0)))
    frontier0 = jnp.pad(frontier0, (0, n - len(frontier0))) & p.valid
    run = make_propagate_fn(mesh, delta=delta, max_iters=max_iters)
    f, iters, converged, resid = run(
        p.nbr, p.wgt, p.wl0, p.wl1, p.valid, f0, frontier0)
    return PropagateResult(
        f=f[: sp.n_orig], iterations=iters, converged=converged,
        max_residual=resid)


# --------------------------------------------------------------------- #
# Streaming partition plans (core.stream.StreamEngine mesh mode)
# --------------------------------------------------------------------- #
# One jitted runner per (mesh, backend, hyperparams) — rungs of the same
# stream share it (each rung is one more shape specialization in its jit
# cache, which is exactly what ``sharded_cache_size`` counts).  Both
# caches are process-lifetime, like the module-level jits in kernels.ops.
_FN_CACHE: dict = {}
_PLAN_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class StreamShardPlan:
    """Shape-keyed partition plan: one per bucket-ladder rung.

    Holds everything a stream needs to run batches of one bucket shape on
    a mesh — the row shardings used to stage host snapshots/vectors and
    the jitted all-gather runner.  Plans are topology-independent
    (contiguous row blocks), so a single plan serves every batch whose
    padded snapshot lands in its rung; only a ladder regrow builds a new
    one (``StreamEngine.plan_builds`` ≤ rungs touched, asserted in
    tests/test_stream_sharded.py).
    """

    mesh: jax.sharding.Mesh
    bucket_key: tuple[int, int]
    backend: str
    delta: float
    max_iters: int
    block_rows: int
    interpret: bool | None
    row_sharding: jax.sharding.NamedSharding
    row2_sharding: jax.sharding.NamedSharding
    run: object  # jitted shard_map propagation fn
    # bsr plans carry their compiled tile layout (0 for other backends):
    # the streaming engine memoizes one plan per rung and checks each
    # Δ_t's slot requirement against num_slots before running on it.
    block_size: int = 0
    num_slots: int = 0

    transport = "allgather"

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    def put_row(self, x) -> jax.Array:
        """Stage a per-row host vector with this plan's row sharding."""
        return jax.device_put(x, self.row_sharding)

    def put_row2(self, x) -> jax.Array:
        """Stage a (rows, K) host array row-sharded, K replicated."""
        return jax.device_put(x, self.row2_sharding)

    def put_problem(self, nbr, wgt, wl0, wl1, valid) -> PropagationProblem:
        return PropagationProblem(
            nbr=self.put_row2(nbr), wgt=self.put_row2(wgt),
            wl0=self.put_row(wl0), wl1=self.put_row(wl1),
            valid=self.put_row(valid))

    def __call__(self, problem: PropagationProblem, f0: jax.Array,
                 frontier0: jax.Array, slot=None) -> PropagateResult:
        if tuple(problem.nbr.shape) != self.bucket_key:
            raise ValueError(
                f"problem shape {problem.nbr.shape} does not match plan "
                f"rung {self.bucket_key}")
        if f0.dtype != jnp.float32:
            f0 = f0.astype(jnp.float32)
        if self.backend == "bsr":
            if slot is None:
                raise ValueError("bsr shard plan needs the per-edge slot "
                                 "map (stage it with put_row2)")
            args = (problem.nbr, problem.wgt, problem.wl0, problem.wl1,
                    problem.valid, slot, f0, frontier0)
        else:
            args = (problem.nbr, problem.wgt, problem.wl0, problem.wl1,
                    problem.valid, f0, frontier0)
        f, iters, done, resid = self.run(*args)
        return PropagateResult(f=f, iterations=iters, converged=done,
                               max_residual=resid)


@dataclasses.dataclass(frozen=True)
class StreamHaloPlan(StreamShardPlan):
    """Per-rung halo-exchange plan: ``StreamShardPlan`` + the rung's
    compiled export-prefix budget.

    The export *budget* (``export_max``) is fixed once per rung so the
    jitted runner compiles once; the export *layout* (which rows lead
    each shard) is re-derived per Δ_t on the host by the engine and is
    allowed to overshoot the real export set — stale/extra prefix rows
    ship committed labels, which is harmless.  A batch whose export
    counts exceed the budget can't run on this plan; the engine falls
    back to its all-gather twin for that Δ_t.
    """

    export_max: int = 0

    transport = "halo"


def _sharded_run_for(mesh, *, backend, delta, max_iters, block_rows,
                     interpret, donate, transport="allgather",
                     export_max=None, block_size=0, num_slots=0):
    """Fetch (or build, memoized) the jitted runner for one hyperparameter
    set.  All-gather runners are shared across every rung (each rung is
    one shape specialization in the jit cache); halo runners additionally
    key on the rung's export budget, bsr runners on the compiled tile
    layout."""
    fn_key = (mesh, backend, float(delta), max_iters, block_rows, interpret,
              donate, transport, export_max, block_size, num_slots)
    run = _FN_CACHE.get(fn_key)
    if run is None:
        run = make_sharded_propagate_fn(
            mesh, backend=backend, delta=delta, max_iters=max_iters,
            block_rows=block_rows, interpret=interpret, donate=donate,
            transport=transport, export_max=export_max,
            block_size=block_size, num_slots=num_slots)
        _FN_CACHE[fn_key] = run
    return fn_key, run


def _check_bucket(bucket_key, mesh, block_size=0):
    u_pad, _ = bucket_key
    n_dev = mesh.devices.size
    if u_pad % n_dev != 0:
        raise ValueError(
            f"bucket rows {u_pad} not divisible by mesh device count "
            f"{n_dev}; build snapshots with row_multiple={n_dev}")
    if block_size and (u_pad // n_dev) % block_size != 0:
        raise ValueError(
            f"bsr needs each shard's {u_pad // n_dev} rows to be a "
            f"multiple of block_size {block_size}; build snapshots with "
            f"row_multiple={n_dev * block_size}")


def build_stream_plan(
    mesh,
    bucket_key: tuple[int, int],
    *,
    backend: str = "ref",
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_rows: int = 512,
    interpret: bool | None = None,
    donate: bool = True,
    block_size: int = 0,
    num_slots: int = 0,
) -> StreamShardPlan:
    """Build (or fetch, memoized) the all-gather partition plan for one
    ladder rung.

    Rows must shard evenly: ``bucket_key[0]`` has to be a multiple of the
    mesh's device count (``core.snapshot.build_host_problem`` pads buckets
    with ``row_multiple=mesh.devices.size`` to guarantee it — times
    ``block_size`` for bsr plans, whose shards must also tile evenly).
    """
    _check_bucket(bucket_key, mesh, block_size if backend == "bsr" else 0)
    fn_key, run = _sharded_run_for(
        mesh, backend=backend, delta=delta, max_iters=max_iters,
        block_rows=block_rows, interpret=interpret, donate=donate,
        block_size=block_size, num_slots=num_slots)
    key = (fn_key, tuple(bucket_key))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        axes = mesh.axis_names
        plan = StreamShardPlan(
            mesh=mesh, bucket_key=tuple(bucket_key), backend=backend,
            delta=float(delta), max_iters=max_iters, block_rows=block_rows,
            interpret=interpret,
            row_sharding=jax.sharding.NamedSharding(mesh, P(axes)),
            row2_sharding=jax.sharding.NamedSharding(mesh, P(axes, None)),
            run=run, block_size=block_size, num_slots=num_slots)
        _PLAN_CACHE[key] = plan
    return plan


def build_stream_halo_plan(
    mesh,
    bucket_key: tuple[int, int],
    export_max: int,
    *,
    backend: str = "ref",
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_rows: int = 512,
    interpret: bool | None = None,
    donate: bool = True,
    block_size: int = 0,
    num_slots: int = 0,
) -> StreamHaloPlan:
    """Halo twin of ``build_stream_plan``: one plan per (rung, export
    budget), memoized.  Callers stage problems in the export-prefix row
    layout of ``graph.partition.build_halo_plan`` and guarantee
    ``export_counts.max() <= export_max`` for every batch they run on it.
    """
    _check_bucket(bucket_key, mesh, block_size if backend == "bsr" else 0)
    m = bucket_key[0] // mesh.devices.size
    export_max = int(min(max(1, export_max), m))
    fn_key, run = _sharded_run_for(
        mesh, backend=backend, delta=delta, max_iters=max_iters,
        block_rows=block_rows, interpret=interpret, donate=donate,
        transport="halo", export_max=export_max,
        block_size=block_size, num_slots=num_slots)
    key = (fn_key, tuple(bucket_key))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        axes = mesh.axis_names
        plan = StreamHaloPlan(
            mesh=mesh, bucket_key=tuple(bucket_key), backend=backend,
            delta=float(delta), max_iters=max_iters, block_rows=block_rows,
            interpret=interpret,
            row_sharding=jax.sharding.NamedSharding(mesh, P(axes)),
            row2_sharding=jax.sharding.NamedSharding(mesh, P(axes, None)),
            run=run, block_size=block_size, num_slots=num_slots,
            export_max=export_max)
        _PLAN_CACHE[key] = plan
    return plan


def sharded_cache_size() -> int:
    """Summed jit-cache entries of every streaming shard_map runner —
    folded into ``kernels.ops.compile_cache_size`` so the stream's
    recompile accounting covers the mesh path too."""
    total = 0
    for fn in _FN_CACHE.values():
        try:
            total += fn._cache_size()
        except AttributeError:  # pragma: no cover — future jax rename
            pass
    return total


# --------------------------------------------------------------------- #
# Sharded embedding-store sweep plans (ingest.ShardedEmbeddingStore)
# --------------------------------------------------------------------- #
# Same lifecycle as the stream plans above: one jitted shard_map runner
# per (mesh, argkmin hyperparams) in _STORE_FN_CACHE — every capacity
# rung / batch bucket is one more shape specialization in its jit cache,
# which is what ``store_sweep_cache_size`` counts and
# ``ingest.ingest_ladder_bound(sharded=True)`` bounds — plus one
# lightweight StoreShardPlan per (runner, rung) holding the staging
# shardings.
_STORE_FN_CACHE: dict = {}
_STORE_PLAN_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class StoreShardPlan:
    """Per-rung plan for the move-the-batch argkmin sweep over a
    row-sharded embedding store.

    Each device keeps its ``cap / D`` store rows resident and receives
    the replicated batch; the runner executes
    ``kernels.argkmin.shard_sweep_body`` under shard_map — per-shard
    top-(k+margin) with global row ids, one packed all-gather of the
    per-shard lists, device-side ``merge_topk`` reduction — and returns
    ``(val, idx)`` and the displacement mask replicated (the mask's
    shards gather back into exactly the single-device mask, so the host
    pull is one local copy).  The merged lists are bit-identical to the
    single-device ``argkmin_candidates`` (see the argkmin module
    docstring for the tie argument), so canonical host re-selection
    keeps every graph byte-identical to the unsharded path.
    """

    mesh: jax.sharding.Mesh
    cap_key: tuple[int, int]  # (capacity rung, padded emb dim)
    backend: str              # resolved: "pallas" | "xla"
    block_rows: int
    interpret: bool | None
    row_sharding: jax.sharding.NamedSharding
    row2_sharding: jax.sharding.NamedSharding
    rep_sharding: jax.sharding.NamedSharding
    run: object  # jitted shard_map sweep fn (static topk)

    @property
    def n_devices(self) -> int:
        return self.mesh.devices.size

    def sweep(self, emb, valid, kth, batch, bvalid, base_id, slack, *,
              topk: int):
        """Run the sharded candidate sweep for one appended batch."""
        if tuple(emb.shape) != self.cap_key:
            raise ValueError(
                f"store shape {tuple(emb.shape)} does not match plan rung "
                f"{self.cap_key}")
        return self.run(emb, valid, kth, batch, bvalid,
                        jnp.int32(base_id), jnp.float32(slack), topk=topk)


def _store_sweep_for(mesh, *, backend, block_rows, interpret):
    """Fetch (or build, memoized) the jitted sharded-sweep runner for one
    (mesh, argkmin hyperparams) set; rungs/batches share it."""
    key = (mesh, backend, block_rows, interpret)
    run = _STORE_FN_CACHE.get(key)
    if run is None:
        # lazy: argkmin pulls graph.knn, which ingest-only processes may
        # never need until a sharded store exists
        from repro.kernels.argkmin import shard_sweep_body
        axes = mesh.axis_names

        def sweep(emb, valid, kth, batch, bvalid, base_id, slack, *, topk):
            body = shard_map(
                functools.partial(
                    shard_sweep_body, axes=axes, topk=topk, backend=backend,
                    block_rows=block_rows, interpret=interpret),
                mesh=mesh,
                in_specs=(P(axes, None), P(axes), P(axes),
                          P(), P(), P(), P()),
                out_specs=(P(), P(), P()))
            return body(emb, valid, kth, batch, bvalid, base_id, slack)

        run = jax.jit(sweep, static_argnames=("topk",))
        _STORE_FN_CACHE[key] = run
    return key, run


def build_store_shard_plan(
    mesh,
    cap_key: tuple[int, int],
    *,
    backend: str = "auto",
    block_rows: int = 256,
    interpret: bool | None = None,
) -> StoreShardPlan:
    """Build (or fetch, memoized) the sharded-store sweep plan for one
    capacity rung.

    ``cap_key`` is ``(capacity, dim_pad)``; capacity must divide evenly
    over the mesh (the store ladder floor guarantees it for power-of-two
    meshes).  ``backend="auto"`` resolves to Pallas on TPU, XLA elsewhere
    — resolution happens here so auto and explicit callers share runners.
    """
    cap, dp = cap_key
    n_dev = int(mesh.devices.size)
    if cap % n_dev:
        raise ValueError(
            f"store capacity {cap} not divisible by mesh device count "
            f"{n_dev}")
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "pallas" and interpret is None:
        interpret = jax.default_backend() != "tpu"
    fn_key, run = _store_sweep_for(
        mesh, backend=backend, block_rows=block_rows, interpret=interpret)
    key = (fn_key, (int(cap), int(dp)))
    plan = _STORE_PLAN_CACHE.get(key)
    if plan is None:
        axes = mesh.axis_names
        plan = StoreShardPlan(
            mesh=mesh, cap_key=(int(cap), int(dp)), backend=backend,
            block_rows=block_rows, interpret=interpret,
            row_sharding=jax.sharding.NamedSharding(mesh, P(axes)),
            row2_sharding=jax.sharding.NamedSharding(mesh, P(axes, None)),
            rep_sharding=jax.sharding.NamedSharding(mesh, P()),
            run=run)
        _STORE_PLAN_CACHE[key] = plan
    return plan


def store_sweep_cache_size() -> int:
    """Summed jit-cache entries of every sharded store-sweep runner —
    folded into ``ingest.ingest_cache_size`` so the ingest recompile gate
    covers the mesh path too."""
    total = 0
    for fn in _STORE_FN_CACHE.values():
        try:
            total += fn._cache_size()
        except AttributeError:  # pragma: no cover — future jax rename
            pass
    return total


def make_propagate_halo_fn(mesh, rows_per_shard: int, export_max: int,
                           delta: float = 1e-4, max_iters: int = 100_000):
    """Historical one-shot halo entry point — now a thin wrapper over the
    unified ``make_sharded_propagate_fn(transport="halo")`` builder, so
    the one-shot API and the streaming ``StreamHaloPlan`` path exercise
    the same code.  ``rows_per_shard`` is kept for signature compat (the
    traced shapes imply it)."""
    del rows_per_shard
    return make_sharded_propagate_fn(
        mesh, backend="ref", delta=delta, max_iters=max_iters,
        transport="halo", export_max=export_max)


def distributed_propagate_halo(
    problem: PropagationProblem,  # rows already in HaloPlan layout
    f0: jax.Array,
    frontier0: jax.Array,
    mesh: jax.sharding.Mesh,
    export_max: int,
    delta: float = 1e-4,
    max_iters: int = 100_000,
) -> PropagateResult:
    n_dev = mesh.devices.size
    n = problem.num_unlabeled
    assert n % n_dev == 0, "caller pads via build_halo_plan"
    run = make_propagate_halo_fn(mesh, n // n_dev, export_max,
                                 delta=delta, max_iters=max_iters)
    p = problem
    f, iters, converged, resid = run(
        p.nbr, p.wgt, p.wl0, p.wl1, p.valid, f0.astype(jnp.float32), frontier0)
    return PropagateResult(f=f, iterations=iters, converged=converged,
                           max_residual=resid)

"""Vertex-partitioned distributed label propagation (DESIGN.md §4).

Rows (vertices) are partitioned across a 1-D device view of the mesh via
``shard_map``; each device owns a contiguous ELL row block whose neighbor
ids index the GLOBAL label vector.  Per iteration:

    all-gather F  →  local fused update  →  δ-threshold + local frontier
    →  psum(any frontier) convergence flag

F is N·4 bytes total, so the all-gather is cheap relative to the edge work
(50M vertices → 200 MB across the pod, ~4 ms at ICI bandwidth — the
roofline's collective term; a halo-exchange variant that ships only
boundary labels is the documented §Perf iteration for higher-diameter
partitionings).

The body reuses the exact update semantics of ``core.propagate`` (same
fixpoint, same iteration count), so single-device tests transfer.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

if hasattr(jax, "shard_map"):  # jax ≥ 0.6
    _shard_map = jax.shard_map
else:  # jax ≤ 0.4.x ships it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

# The static replication checker has no rule for ``while`` on older jax
# (and the check is advisory anyway) — disable it under whichever name
# this version spells it.
import inspect as _inspect

_smap_params = _inspect.signature(_shard_map).parameters
_CHECK_KW = (
    {"check_rep": False} if "check_rep" in _smap_params
    else {"check_vma": False} if "check_vma" in _smap_params
    else {}
)


def shard_map(f, *, mesh, in_specs, out_specs):
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **_CHECK_KW)

from repro.core.propagate import PropagateResult, PropagationProblem
from repro.graph.structures import PAD


class ShardedProblem(NamedTuple):
    """PropagationProblem padded to a multiple of the device count."""

    problem: PropagationProblem
    n_orig: int


def pad_problem(problem: PropagationProblem, n_devices: int) -> ShardedProblem:
    n = problem.num_unlabeled
    pad = (-n) % n_devices
    if pad == 0:
        return ShardedProblem(problem, n)
    padded = PropagationProblem(
        nbr=jnp.pad(problem.nbr, ((0, pad), (0, 0)), constant_values=PAD),
        wgt=jnp.pad(problem.wgt, ((0, pad), (0, 0))),
        wl0=jnp.pad(problem.wl0, (0, pad)),
        wl1=jnp.pad(problem.wl1, (0, pad)),
        valid=jnp.pad(problem.valid, (0, pad)),
    )
    return ShardedProblem(padded, n)


def make_propagate_fn(mesh, delta: float = 1e-4, max_iters: int = 100_000):
    """Build the jitted all-gather propagation step (lowerable with
    ShapeDtypeStructs for the LP roofline dry-run)."""
    axes = mesh.axis_names
    delta_ = jnp.float32(delta)
    row = P(axes)  # rows sharded over ALL mesh axes (flattened view)
    row2 = P(axes, None)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(row2, row2, row, row, row, row, row),
        out_specs=(row, P(), P(), P()),
    )
    def run(nbr, wgt, wl0, wl1, valid, f_loc, fr_loc):
        mask = nbr != PAD
        idx = jnp.where(mask, nbr, 0)

        def gather_full(x_loc):
            return jax.lax.all_gather(x_loc, axes, tiled=True)

        def body(state):
            f_l, fr_l, it, _ = state
            f_full = gather_full(f_l)  # (N,) — the collective
            f_u = f_l
            f_v = f_full[idx]
            nbr_term = jnp.sum(wgt * jnp.where(mask, f_v - f_u[:, None], 0.0),
                               axis=1)
            wall = jnp.sum(wgt, axis=1) + wl0 + wl1
            d_f = (0.0 - f_u) * wl0 + (1.0 - f_u) * wl1 + nbr_term
            f_new = f_u + jnp.where(wall > 0, d_f / jnp.maximum(wall, 1e-30), 0)
            f_new = jnp.where(fr_l, f_new, f_u)
            resid_l = jnp.abs(f_new - f_u)
            changed_l = (resid_l > delta_) & valid
            changed_full = gather_full(changed_l)
            nbr_changed = jnp.any(changed_full[idx] & mask, axis=1)
            fr_new = (changed_l | nbr_changed) & valid
            resid = jax.lax.pmax(jnp.max(resid_l, initial=0.0), axes)
            return f_new, fr_new, it + 1, resid

        def cond(state):
            _, fr_l, it, _ = state
            any_frontier = jax.lax.pmax(fr_l.any().astype(jnp.int32), axes)
            return jnp.logical_and(any_frontier > 0, it < max_iters)

        f_l, fr_l, iters, resid = jax.lax.while_loop(
            cond, body, (f_loc, fr_loc, jnp.int32(0), jnp.float32(0)))
        done = jax.lax.pmax(fr_l.any().astype(jnp.int32), axes) == 0
        return f_l, iters, done, resid

    return jax.jit(run)


def distributed_propagate(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    mesh: jax.sharding.Mesh,
    delta: float = 1e-4,
    max_iters: int = 100_000,
) -> PropagateResult:
    """Run DynLP Step 3 with vertices sharded over every mesh device."""
    n_dev = mesh.devices.size
    sp = pad_problem(problem, n_dev)
    p = sp.problem
    n = p.num_unlabeled
    f0 = jnp.pad(f0.astype(jnp.float32), (0, n - len(f0)))
    frontier0 = jnp.pad(frontier0, (0, n - len(frontier0))) & p.valid
    run = make_propagate_fn(mesh, delta=delta, max_iters=max_iters)
    f, iters, converged, resid = run(
        p.nbr, p.wgt, p.wl0, p.wl1, p.valid, f0, frontier0)
    return PropagateResult(
        f=f[: sp.n_orig], iterations=iters, converged=converged,
        max_residual=resid)


def make_propagate_halo_fn(mesh, rows_per_shard: int, export_max: int,
                           delta: float = 1e-4, max_iters: int = 100_000):
    """Build the jitted halo-exchange propagation step.

    Only each shard's EXPORT PREFIX is all-gathered per iteration
    (cross-shard-referenced rows lead each shard —
    ``graph.partition.build_halo_plan``).  For locality-ordered graphs the
    exchanged bytes drop from N·4 to Σ|exports|·4 — the §Perf iteration on
    the collective term.  Fixpoint and iteration count are identical to
    the all-gather transport (same Jacobi update)."""
    axes = mesh.axis_names
    m = rows_per_shard
    delta_ = jnp.float32(delta)
    row = P(axes)
    row2 = P(axes, None)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(row2, row2, row, row, row, row, row),
        out_specs=(row, P(), P(), P()),
    )
    def run(nbr, wgt, wl0, wl1, valid, f_loc, fr_loc):
        mask = nbr != PAD
        gid = jnp.where(mask, nbr, 0)
        owner = gid // m  # (m, K) owning shard of each neighbor
        offset = gid % m
        my = jax.lax.axis_index(axes)  # linearized index over all mesh axes
        local_ref = owner == my

        def body(state):
            f_l, fr_l, it, _ = state
            exports = jax.lax.all_gather(f_l[:export_max], axes)  # (D, E)
            f_local_v = f_l[offset]  # own-shard values
            f_remote_v = exports[owner, jnp.minimum(offset, export_max - 1)]
            f_v = jnp.where(local_ref, f_local_v, f_remote_v)
            f_u = f_l
            nbr_term = jnp.sum(wgt * jnp.where(mask, f_v - f_u[:, None], 0.0),
                               axis=1)
            wall = jnp.sum(wgt, axis=1) + wl0 + wl1
            d_f = (0.0 - f_u) * wl0 + (1.0 - f_u) * wl1 + nbr_term
            f_new = f_u + jnp.where(wall > 0, d_f / jnp.maximum(wall, 1e-30), 0)
            f_new = jnp.where(fr_l, f_new, f_u)
            resid_l = jnp.abs(f_new - f_u)
            changed_l = (resid_l > delta_) & valid
            # frontier expansion needs changed flags of remote neighbors too
            ch_exp = jax.lax.all_gather(changed_l[:export_max], axes)
            ch_local = changed_l[offset]
            ch_remote = ch_exp[owner, jnp.minimum(offset, export_max - 1)]
            ch_v = jnp.where(local_ref, ch_local, ch_remote)
            nbr_changed = jnp.any(ch_v & mask, axis=1)
            fr_new = (changed_l | nbr_changed) & valid
            resid = jax.lax.pmax(jnp.max(resid_l, initial=0.0), axes)
            return f_new, fr_new, it + 1, resid

        def cond(state):
            _, fr_l, it, _ = state
            any_frontier = jax.lax.pmax(fr_l.any().astype(jnp.int32), axes)
            return jnp.logical_and(any_frontier > 0, it < max_iters)

        f_l, fr_l, iters, resid = jax.lax.while_loop(
            cond, body, (f_loc, fr_loc, jnp.int32(0), jnp.float32(0)))
        done = jax.lax.pmax(fr_l.any().astype(jnp.int32), axes) == 0
        return f_l, iters, done, resid

    return jax.jit(run)


def distributed_propagate_halo(
    problem: PropagationProblem,  # rows already in HaloPlan layout
    f0: jax.Array,
    frontier0: jax.Array,
    mesh: jax.sharding.Mesh,
    export_max: int,
    delta: float = 1e-4,
    max_iters: int = 100_000,
) -> PropagateResult:
    n_dev = mesh.devices.size
    n = problem.num_unlabeled
    assert n % n_dev == 0, "caller pads via build_halo_plan"
    run = make_propagate_halo_fn(mesh, n // n_dev, export_max,
                                 delta=delta, max_iters=max_iters)
    p = problem
    f, iters, converged, resid = run(
        p.nbr, p.wgt, p.wl0, p.wl1, p.valid, f0.astype(jnp.float32), frontier0)
    return PropagateResult(f=f, iterations=iters, converged=converged,
                           max_residual=resid)

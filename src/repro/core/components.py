"""Connected components — Shiloach–Vishkin re-derived for TPU (paper §6.2).

The CUDA version uses per-thread hook (compare-and-swap to the min adjacent
parent) and jump (pointer halving) kernels.  On TPU we express the same
fixpoint with data-parallel primitives over the ELL neighbor tensor:

  hook:  par'[u] = min(par[u], min_v∈N(u) par[v])   — a masked row min-reduce
  jump:  par''   = par'[par']                        — a gather (path halving)

Both are dense regular ops (VPU-friendly); the loop runs under
``lax.while_loop`` until no parent changes, which matches SV's convergence
criterion ("no changes after a Jump step").

Requires a *symmetric* adjacency (both directions present and identically
masked) — guaranteed by ``graph.knn.symmetrize`` — because the min-hook only
pulls labels down-edge; with one-directional edges the max endpoint would
never observe the min.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graph.structures import PAD


class CCResult(NamedTuple):
    labels: jax.Array  # (N,) int32 — component id = min vertex id in component
    iterations: jax.Array  # int32


@functools.partial(jax.jit, static_argnames=("max_iters",))
def connected_components(
    nbr: jax.Array,
    wgt: jax.Array | None = None,
    tau: float | jax.Array = 0.0,
    max_iters: int = 10_000,
) -> CCResult:
    """Components of the graph whose edges satisfy ``wgt > tau``.

    The τ-thresholding implements the paper's sparsification step (Alg.2
    L10 / Fig.2a): instead of negating CSR ``col`` entries we mask ELL slots.

    Args:
      nbr: (N, K) int32 ELL neighbor ids (PAD empty).
      wgt: optional (N, K) float32 weights; edges with w <= tau are ignored.
      tau: similarity threshold.
    """
    n = nbr.shape[0]
    mask = nbr != PAD
    if wgt is not None:
        mask &= wgt > tau
    own = jnp.arange(n, dtype=jnp.int32)
    idx = jnp.where(mask, nbr, own[:, None])  # masked slots point at self

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        par, _, it = state
        # Hook: adopt the smallest parent among self and neighbors.
        nbr_par = par[idx]
        hooked = jnp.minimum(par, jnp.min(nbr_par, axis=1))
        # Jump (path halving), twice for faster contraction.
        jumped = hooked[hooked]
        jumped = jumped[jumped]
        changed = jnp.any(jumped != par)
        return jumped, changed, it + 1

    par, _, iters = jax.lax.while_loop(
        cond, body, (own, jnp.bool_(True), jnp.int32(0))
    )
    return CCResult(labels=par, iterations=iters)


def host_components(nbr, max_iters: int = 10_000):
    """Numpy twin of ``connected_components`` for host-side passes.

    Same min-hook / path-halving fixpoint, vectorized over the ELL
    tensor — used by the snapshot pipeline (ELL→BSR component reorder)
    where a device round-trip per Δ_t would serialize against the
    in-flight solve.  Requires the same symmetric adjacency.
    """
    import numpy as np

    n = len(nbr)
    own = np.arange(n)
    idx = np.where(nbr >= 0, nbr, own[:, None])
    par = own.copy()
    for _ in range(max_iters):
        hooked = np.minimum(par, par[idx].min(axis=1))
        jumped = hooked[hooked]
        jumped = jumped[jumped]
        if np.array_equal(jumped, par):
            break
        par = jumped
    return par


def component_order(nbr):
    """Step-1 clustering order: row permutation (new → old) grouping rows
    by connected component (stable within a component, so insertion
    order — and with it stream locality — survives inside each group).
    This is the ordering that makes the adjacency block-dense for the
    ELL→BSR build (``kernels.bsr_spmv``)."""
    import numpy as np

    return np.argsort(host_components(nbr), kind="stable")


def permute_ell_rows(nbr, order):
    """Permute ELL rows by ``order`` (new → old), remapping neighbor ids
    into the new row space (-1 lanes stay -1).

    The one primitive behind every row reordering that must stay
    self-consistent — ``core.snapshot.reorder_host_snapshot`` and the
    bsr one-shot path both call it.  Returns ``(nbr', inv)`` with
    ``inv`` the old → new map (``inv[order] == arange``).
    """
    import numpy as np

    inv = np.empty(len(order), np.int64)
    inv[order] = np.arange(len(order))
    p = nbr[order]
    out = np.where(p >= 0, inv[np.where(p >= 0, p, 0)], -1).astype(np.int32)
    return out, inv


def compact_labels(labels: jax.Array) -> jax.Array:
    """Make component ids sequential 0..C-1 (paper: thrust prefix scan)."""
    n = labels.shape[0]
    is_root = labels == jnp.arange(n, dtype=labels.dtype)
    rank = jnp.cumsum(is_root.astype(jnp.int32)) - 1  # prefix scan over roots
    return rank[labels]


def num_components(labels: jax.Array) -> jax.Array:
    n = labels.shape[0]
    return jnp.sum(labels == jnp.arange(n, dtype=labels.dtype))

"""STLP baseline — temporal label propagation via short-circuiting
(Wagner et al. [34]) and its approximate-inverse variant STLP(γ) [22].

Short-circuiting contracts each ground-truth class to one representative
node with parallel-edge sums.  In our ``PropagationProblem`` form the
contraction is already materialized: ``wl0``/``wl1`` are exactly the
contracted edge weights.  The harmonic solution on the contracted graph is

    F_U = L_UU⁻¹ · wl1          (since F_L = [0, 1] makes −L_UL F_L = wl1)

with L_UU = diag(Wall) − W_UU.  The dense solve reproduces the paper's
observation that STLP is O(U²)-memory bound (Table 5: caps at ~50K nodes).

STLP(γ) replaces the exact inverse with a truncated Neumann series
L_UU⁻¹ ≈ Σ_{i<T} (D⁻¹A)ⁱ D⁻¹ — a sparse generalized inverse whose density /
accuracy trade-off is steered by γ (larger γ ⇒ fewer terms ⇒ sparser,
poorer approximation), mirroring [22].  We map T = max(1, ⌈10/γ⌉).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.propagate import PropagationProblem
from repro.core.snapshot import build_problem
from repro.graph.dynamic import BatchUpdate, DynamicGraph
from repro.graph.structures import PAD


def problem_to_dense(problem: PropagationProblem) -> jax.Array:
    """Densify the unlabeled-unlabeled adjacency (O(U²) — by design)."""
    u = problem.num_unlabeled
    mask = problem.nbr != PAD
    rows = jnp.broadcast_to(jnp.arange(u)[:, None], problem.nbr.shape)
    cols = jnp.where(mask, problem.nbr, 0)
    w = jnp.where(mask, problem.wgt, 0.0)
    dense = jnp.zeros((u, u), jnp.float32)
    return dense.at[rows.reshape(-1), cols.reshape(-1)].add(w.reshape(-1))


@jax.jit
def harmonic_solve(problem: PropagationProblem) -> jax.Array:
    """Exact harmonic solution on the short-circuited graph (dense solve)."""
    w_uu = problem_to_dense(problem)
    wall = jnp.sum(w_uu, axis=1) + problem.wl0 + problem.wl1
    isolated = wall <= 0
    l_uu = jnp.diag(jnp.where(isolated, 1.0, wall)) - w_uu
    rhs = jnp.where(isolated, 0.5, problem.wl1)
    f = jnp.linalg.solve(l_uu, rhs)
    return jnp.clip(f, 0.0, 1.0)


@jax.jit
def _neumann_solve(problem: PropagationProblem, t: jax.Array) -> jax.Array:
    w_uu = problem_to_dense(problem)
    wall = jnp.sum(w_uu, axis=1) + problem.wl0 + problem.wl1
    isolated = wall <= 0
    d_inv = jnp.where(isolated, 0.0, 1.0 / jnp.maximum(wall, 1e-30))
    rhs = problem.wl1

    def body(_, carry):
        x, acc = carry
        x = d_inv * (w_uu @ x)
        return x, acc + x

    x0 = d_inv * rhs
    _, f = jax.lax.fori_loop(0, t - 1, body, (x0, x0))
    return jnp.clip(jnp.where(isolated, 0.5, f), 0.0, 1.0)


@dataclasses.dataclass
class STLPStats:
    num_unlabeled: int
    wall_ms: float
    dense_bytes: int  # the O(U²) footprint this method materializes


class STLP:
    """Per-batch harmonic recomputation on the short-circuited graph.

    ``gamma=None`` is exact STLP; a float enables the approximate variant.
    ``max_unlabeled`` guards the dense O(U²) allocation (the paper could not
    run exact STLP past 50K vertices either).
    """

    def __init__(
        self,
        graph: DynamicGraph,
        gamma: float | None = None,
        tau: float | None = None,
        max_degree: int | None = None,
        max_unlabeled: int = 60_000,
    ):
        self.graph = graph
        self.gamma = gamma
        self.tau = tau
        self.max_degree = max_degree
        self.max_unlabeled = max_unlabeled

    def step(self, batch: BatchUpdate) -> STLPStats:
        t0 = time.perf_counter()
        g = self.graph
        g.apply_batch(batch, tau=self.tau)
        snap = build_problem(g, max_degree=self.max_degree, auto_bucket=True)
        u = len(snap.unl_ids)
        if u > self.max_unlabeled:
            raise MemoryError(
                f"STLP dense solve needs {u}² floats = "
                f"{u * u * 4 / 2**30:.1f} GiB (> cap); the paper hits the same "
                "wall at 50K vertices (Table 5)."
            )
        if self.gamma is None:
            f = harmonic_solve(snap.problem)
        else:
            t = max(1, int(np.ceil(10.0 / self.gamma)))
            f = _neumann_solve(snap.problem, jnp.int32(t))
        g.f[snap.unl_ids] = np.asarray(f)[:u]
        return STLPStats(
            num_unlabeled=u,
            wall_ms=(time.perf_counter() - t0) * 1e3,
            dense_bytes=u * u * 4,
        )

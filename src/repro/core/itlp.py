"""ITLP baseline — full iterative label propagation from scratch per batch
(Zhu et al. [40]; the paper's primary speed baseline, §7.3).

After every Δ_t the labels of *all* unlabeled vertices are recomputed:
uniform 0.5 initialization, dense (no frontier) iteration until the global
max |ΔF| falls below δ.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.propagate import propagate_full
from repro.core.snapshot import build_problem
from repro.graph.dynamic import BatchUpdate, DynamicGraph


@dataclasses.dataclass
class ITLPStats:
    iterations: int
    converged: bool
    num_unlabeled: int
    wall_ms: float


class ITLP:
    def __init__(
        self,
        graph: DynamicGraph,
        delta: float = 1e-4,
        tau: float | None = None,
        max_iters: int = 200_000,
        max_degree: int | None = None,
    ):
        self.graph = graph
        self.delta = delta
        self.tau = tau
        self.max_iters = max_iters
        self.max_degree = max_degree

    def step(self, batch: BatchUpdate) -> ITLPStats:
        t0 = time.perf_counter()
        g = self.graph
        g.apply_batch(batch, tau=self.tau)
        snap = build_problem(g, max_degree=self.max_degree, auto_bucket=True)
        f0 = jnp.full((snap.problem.num_unlabeled,), 0.5, jnp.float32)
        res = propagate_full(
            snap.problem, f0, delta=self.delta, max_iters=self.max_iters
        )
        g.f[snap.unl_ids] = np.asarray(res.f)[: len(snap.unl_ids)]
        return ITLPStats(
            iterations=int(res.iterations),
            converged=bool(res.converged),
            num_unlabeled=len(snap.unl_ids),
            wall_ms=(time.perf_counter() - t0) * 1e3,
        )

"""qwen3-0.6b [hf:Qwen/Qwen3-8B family; hf].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936; qk-norm; head_dim
128 (Qwen3 uses explicit 128 regardless of d_model/n_heads).
"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1e6,
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128)

"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512, MoE 32 experts top-8,
vocab 49155.
"""
import dataclasses
from repro.models.common import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155,
    moe=MoECfg(num_experts=32, top_k=8, d_expert=512),
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab=128,
        moe=MoECfg(num_experts=4, top_k=2, d_expert=32))

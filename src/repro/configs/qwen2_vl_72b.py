"""qwen2-vl-72b [arXiv:2409.12191; hf].  M-RoPE, dynamic resolution.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  Vision frontend is
a STUB: input_specs provides precomputed patch embeddings (dim 1280); the
backbone projects them and prepends to the text tokens.  M-RoPE rotates the
head dim in (temporal, height, width) sections from 3-axis position ids.
"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, mrope=True, frontend="vision_stub",
    frontend_dim=1280, rope_theta=1e6,
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, frontend_dim=32)

"""xlstm-350m [arXiv:2405.04517; unverified].

24L d_model=1024 4 heads; sLSTM + mLSTM blocks at 7:1 (one sLSTM per 8
layers), vocab 50304.  d_ff=0 per assignment: the xLSTM blocks carry their
own 2x up-projections instead of a separate FFN.
"""
import dataclasses
from repro.models.common import ArchConfig, XLSTMCfg

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    xlstm=XLSTMCfg(slstm_every=8, proj_factor=2.0),
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, vocab=128,
        xlstm=XLSTMCfg(slstm_every=2, proj_factor=2.0, chunk=16))

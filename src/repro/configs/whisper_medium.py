"""whisper-medium [arXiv:2212.04356; unverified].  Enc-dec; conv frontend stub.

24L encoder + 24L decoder, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
The conv frontend is a STUB: input_specs provides post-conv frame embeddings
(dim 80 mel -> we use frontend_dim=1024 post-conv features).  Decoder length
is seq_len // 8 for train/prefill shapes (DESIGN.md).
"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, enc_dec=True, n_enc_layers=24,
    frontend="audio_stub", frontend_dim=1024,
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=128, frontend_dim=32)

"""h2o-danube-3-4b [arXiv:2401.16818; unverified].  llama+mistral mix, SWA.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; sliding window 4096.
"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, sliding_window=4096,
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=128, sliding_window=16)

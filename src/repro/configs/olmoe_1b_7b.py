"""olmoe-1b-7b [arXiv:2409.02060; hf].

16L d_model=2048 16H (MHA kv=16) per-expert d_ff=1024, MoE 64 experts top-8,
vocab 50304.
"""
import dataclasses
from repro.models.common import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    moe=MoECfg(num_experts=64, top_k=8, d_expert=1024),
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, vocab=128,
        moe=MoECfg(num_experts=8, top_k=2, d_expert=32))

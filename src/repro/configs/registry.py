"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Each assigned architecture lives in its own module exposing ``CONFIG``
(exact published dimensions, see the per-file source citations) and
``smoke()`` (a reduced same-family variant for CPU tests).
"""

from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = [
    "granite_moe_1b_a400m",
    "olmoe_1b_7b",
    "xlstm_350m",
    "qwen3_0_6b",
    "deepseek_67b",
    "yi_6b",
    "h2o_danube_3_4b",
    "zamba2_7b",
    "qwen2_vl_72b",
    "whisper_medium",
]

ALIASES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "xlstm-350m": "xlstm_350m",
    "qwen3-0.6b": "qwen3_0_6b",
    "deepseek-67b": "deepseek_67b",
    "yi-6b": "yi_6b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "zamba2-7b": "zamba2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "whisper-medium": "whisper_medium",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}


def override(cfg, **kw):
    return dataclasses.replace(cfg, **kw)

"""deepseek-67b [arXiv:2401.02954; hf].  LLaMA-arch.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
import dataclasses
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400,
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab=128)

"""zamba2-7b [arXiv:2411.15242; unverified].  Mamba2 + shared attn blocks.

81L d_model=3584; the assignment's 32H (kv=32) d_ff=14336 describe the
SHARED attention/MLP block; ssm_state=64.  We map the 81 layers onto 12
macro-blocks of 6 Mamba2 layers + 1 shared-block invocation (72 Mamba2
layers + 12 shared applications ~ 81 published layers; the shared block
has ONE set of parameters, Zamba2's hallmark).
"""
import dataclasses
from repro.models.common import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, attn_every=6,
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2),
)

def smoke():
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, attn_every=2, ssm=SSMCfg(d_state=8, head_dim=8, expand=2, chunk=16))

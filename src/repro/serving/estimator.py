"""scikit-learn-compatible front door for streaming label propagation.

``DynLabelPropagation`` wraps graph construction, the streaming engine
and the serving layer behind the estimator API every sklearn user knows:

    clf = DynLabelPropagation(k=5)
    clf.fit(X, y)                  # y: 0/1, -1 (UNLABELED) for unlabeled
    clf.partial_fit(X2, y2)        # stream more points in
    pred = clf.predict(Xq)         # inductive: label unseen embeddings
    seen = clf.predict_ids(ids)    # transductive: read committed labels

Callers hand over raw embeddings; the estimator derives every graph
delta itself through ``LPService.add_points`` — on device when
``ingest="device"`` (the default; docs/ingestion.md) — so ``BatchUpdate``
stays an internal/advanced type.  sklearn itself is NOT imported: the
class follows the estimator protocol (``get_params`` / ``set_params`` /
trailing-underscore fitted attributes) structurally, so it composes with
sklearn tooling when sklearn is installed and works standalone when not.

Labels are binary 0/1 with ``UNLABELED`` (-1) marking points the
propagation should label — the same convention as sklearn's
``LabelPropagation``.
"""

from __future__ import annotations

import inspect

import numpy as np

from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.serving.lp_service import LPService


class DynLabelPropagation:
    """Streaming semi-supervised label propagation (DynLP), estimator-style.

    Parameters mirror the engine/service knobs: ``k`` (kNN graph degree),
    ``delta`` (propagation convergence threshold), ``tau`` (G' supernode
    edge threshold; None = mean edge weight), ``max_iters``, ``ingest``
    ("device" = Pallas/XLA argkmin over the device embedding store,
    "host" = blockwise BLAS staging; labels are bit-identical either
    way), ``cutoff`` (decision threshold on the propagated score) and
    ``engine_opts`` / ``service_opts`` dicts passed through verbatim.

    Fitted attributes: ``graph_`` / ``engine_`` / ``service_`` (the live
    stack), ``transduction_`` (committed labels of every point so far),
    ``classes_``, ``n_features_in_``.
    """

    def __init__(
        self,
        k: int = 5,
        delta: float = 1e-4,
        tau: float | None = None,
        max_iters: int = 200_000,
        ingest: str = "device",
        cutoff: float = 0.5,
        engine_opts: dict | None = None,
        service_opts: dict | None = None,
    ):
        # sklearn convention: __init__ only stores hyper-parameters
        self.k = k
        self.delta = delta
        self.tau = tau
        self.max_iters = max_iters
        self.ingest = ingest
        self.cutoff = cutoff
        self.engine_opts = engine_opts
        self.service_opts = service_opts

    # ------------------------------------------------------------------ #
    # estimator protocol (structural — no sklearn import)
    # ------------------------------------------------------------------ #
    @classmethod
    def _param_names(cls) -> list[str]:
        sig = inspect.signature(cls.__init__)
        return [p for p in sig.parameters if p != "self"]

    def get_params(self, deep: bool = True) -> dict:
        """Constructor parameters, sklearn-style (``deep`` is accepted
        for API compatibility; there are no nested estimators)."""
        return {name: getattr(self, name) for name in self._param_names()}

    def set_params(self, **params) -> "DynLabelPropagation":
        """Set constructor parameters in place, sklearn-style."""
        valid = set(self._param_names())
        for key, val in params.items():
            if key not in valid:
                raise ValueError(
                    f"invalid parameter {key!r} for DynLabelPropagation; "
                    f"valid parameters: {sorted(valid)}")
            setattr(self, key, val)
        return self

    # ------------------------------------------------------------------ #
    def _init_stack(self, n_features: int) -> None:
        self.graph_ = DynamicGraph(emb_dim=n_features, k=self.k)
        self.engine_ = StreamEngine(
            self.graph_, delta=self.delta, tau=self.tau,
            max_iters=self.max_iters, ingest=self.ingest,
            **(self.engine_opts or {}))
        self.service_ = LPService(
            self.engine_, cutoff=self.cutoff, **(self.service_opts or {}))
        self.classes_ = np.array([0, 1], np.int8)
        self.n_features_in_ = n_features

    def _check_x(self, X) -> np.ndarray:
        X = np.asarray(X, np.float32)
        if X.ndim != 2:
            raise ValueError(f"X must be 2-D (n_samples, n_features), "
                             f"got shape {X.shape}")
        return X

    def _refresh_transduction(self) -> None:
        n = self.graph_.num_nodes
        res = self.service_.query(np.arange(n, dtype=np.int64))
        self.transduction_ = res.pred

    def fit(self, X, y=None) -> "DynLabelPropagation":
        """Build a fresh graph from ``X`` and propagate.  ``y`` holds 0/1
        seeds with -1 (``UNLABELED``) everywhere the model should infer;
        ``y=None`` means all points unlabeled (no seeds yet — stream them
        in later via ``partial_fit``)."""
        X = self._check_x(X)
        self._init_stack(X.shape[1])
        self.service_.add_points(X, y)
        self.service_.sync()
        self._refresh_transduction()
        return self

    def partial_fit(self, X, y=None) -> "DynLabelPropagation":
        """Stream more points into the fitted model (first call behaves
        like ``fit``).  Only the affected subgraph re-propagates — this
        is DynLP's batch update, not a refit."""
        X = self._check_x(X)
        if not hasattr(self, "service_"):
            return self.fit(X, y)
        self.service_.add_points(X, y)
        self.service_.sync()
        self._refresh_transduction()
        return self

    def forget(self, ids) -> "DynLabelPropagation":
        """Delete points by global id (the streaming counterpart of
        refitting without them)."""
        self.service_.remove_points(ids)
        self.service_.sync()
        self._refresh_transduction()
        return self

    def relabel(self, ids, labels) -> "DynLabelPropagation":
        """Change ground-truth seeds on existing points (0/1, or -1 to
        demote a seed back to propagated)."""
        self.service_.relabel(ids, labels)
        self.service_.sync()
        self._refresh_transduction()
        return self

    # ------------------------------------------------------------------ #
    def predict(self, X) -> np.ndarray:
        """Inductive prediction for unseen embeddings: the points join
        the graph as unlabeled vertices, one batch update labels them,
        and they are removed again — the fitted points' labels are
        unchanged (their lists may re-rank, but their seeds and the
        committed predictions the model reports are refreshed)."""
        X = self._check_x(X)
        base = self.graph_.num_nodes
        self.service_.add_points(X)
        self.service_.sync()
        ids = np.arange(base, base + len(X), dtype=np.int64)
        res = self.service_.query(ids)
        self.service_.remove_points(ids)
        self.service_.sync()
        self._refresh_transduction()
        return res.pred

    def predict_ids(self, ids) -> np.ndarray:
        """Transductive read: committed labels of existing points."""
        return self.service_.query(np.asarray(ids, np.int64)).pred

    def score(self, X, y) -> float:
        """Mean accuracy of ``predict(X)`` against ``y``."""
        y = np.asarray(y).reshape(-1)
        pred = self.predict(X)
        return float((pred == y).mean()) if len(y) else 0.0


__all__ = ["DynLabelPropagation", "UNLABELED"]

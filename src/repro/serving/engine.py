"""Serving engines: the LP service driver + the LM continuous batcher.

Two independent serving shapes live here:

  * ``ServiceDriver`` / ``ReadBatcher`` / ``ReadTicket`` — the async
    machinery behind ``serving.lp_service.LPService``.  A background
    thread clocks the service (admission-window deadlines fire with zero
    caller traffic, finished solves commit off every caller's critical
    path) and fuses the read tickets of concurrent callers into ONE
    jitted device gather against the committed ``DeviceLabelView``
    (docs/serving.md §The background driver).
  * ``ServeEngine`` — slot-based continuous batching over an LM
    ``decode_step``: a fixed pool of B slots, prefill into a free slot,
    then the whole pool decodes one token per step.  The batch axis of
    every cache leaf is probed once at init by differencing
    ``cache_shape(b)`` vs ``cache_shape(b+1)``, so it works unchanged
    for KV caches, recurrent states and enc-dec caches.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------- #
# LP serving: read tickets, the fusing batcher, and the service driver
# ---------------------------------------------------------------------- #

class ReadTicket:
    """One caller's pending read: ids in, (QueryResult | error) out.

    Handed out by ``LPService.query_async``; the driver fulfils batches
    of these with one fused device gather.  ``wait`` blocks the caller;
    ``completed_at`` stamps fulfilment time so open-loop benchmarks can
    measure latency from the *scheduled* arrival, not the wait call
    (coordinated-omission-free, see benchmarks/serve_lp.py).
    """

    __slots__ = ("ids", "cutoff", "enqueued_at", "completed_at",
                 "result", "error", "_done")

    def __init__(self, ids: np.ndarray, cutoff: float):
        self.ids = ids
        self.cutoff = cutoff
        self.enqueued_at = time.perf_counter()
        self.completed_at: float | None = None
        self.result = None
        self.error: BaseException | None = None
        self._done = threading.Event()

    def _fulfil(self, result=None, error=None):
        self.result = result
        self.error = error
        self.completed_at = time.perf_counter()
        self._done.set()

    @property
    def done(self) -> bool:
        """True once the driver has fulfilled (or failed) this ticket."""
        return self._done.is_set()

    def wait(self, timeout: float | None = None):
        """Block until fulfilled; returns the ``QueryResult`` (raises the
        driver-side error, or TimeoutError on timeout)."""
        if not self._done.wait(timeout):
            raise TimeoutError("read ticket not fulfilled in time")
        if self.error is not None:
            raise self.error
        return self.result


class ReadBatcher:
    """Thread-safe queue of pending ``ReadTicket``s.

    Callers ``submit``; the driver ``take_all``s and serves the whole
    batch from ONE committed view in one fused gather — which is also
    the coherence argument: every ticket in a batch is answered from
    the same immutable snapshot, so a commit landing mid-burst flips
    readers atomically between views, never within one.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._tickets: list[ReadTicket] = []
        self._wake = threading.Event()
        self._closed = False

    def submit(self, ids: np.ndarray, cutoff: float) -> ReadTicket:
        """Queue a read for the driver's next fused gather."""
        t = ReadTicket(ids, cutoff)
        with self._lock:
            if self._closed:
                raise RuntimeError("read batcher is closed (driver stopped)")
            self._tickets.append(t)
        self._wake.set()
        return t

    def take_all(self) -> list[ReadTicket]:
        """Drain the queue (driver side): all tickets, atomically."""
        with self._lock:
            tickets, self._tickets = self._tickets, []
        return tickets

    @property
    def pending(self) -> int:
        """Tickets queued but not yet taken by the driver."""
        with self._lock:
            return len(self._tickets)

    def close(self) -> list[ReadTicket]:
        """Refuse new submissions; returns whatever was still queued so
        the driver can drain it."""
        with self._lock:
            self._closed = True
            tickets, self._tickets = self._tickets, []
        return tickets

    def wait_for_work(self, timeout: float):
        """Park the driver until a submit arrives or ``timeout`` lapses."""
        self._wake.wait(timeout)
        self._wake.clear()


class ServiceDriver(threading.Thread):
    """Background clock for an ``LPService`` (docs/serving.md).

    One loop iteration: fulfil every queued read ticket with a single
    fused gather, then ``pump`` the service under its lock — committing
    a finished solve and force-admitting the open window once its
    ``window_ms`` deadline passes, with NO caller traffic required.
    Between iterations the thread sleeps on the batcher's wake event,
    capped by the time to the next admission deadline (so deadlines
    fire promptly) and ``poll_ms`` (so finished solves commit promptly).

    ``stop`` drains: in-flight tickets are fulfilled before the thread
    exits, and the batcher is closed so late submitters get a clean
    error instead of hanging.
    """

    def __init__(self, service, batcher: ReadBatcher, poll_ms: float = 2.0):
        super().__init__(name="lp-service-driver", daemon=True)
        self._svc = service
        self._batcher = batcher
        self._poll_s = poll_ms / 1e3
        self._halt = threading.Event()
        self.read_batches = 0  # fused gathers executed
        self.read_tickets = 0  # tickets fulfilled by those gathers
        self.deadline_admissions = 0  # windows admitted by the clock

    def run(self):
        """Driver loop: fuse queued reads, pump the service's admission
        clock, exit only after a halt request has drained stragglers."""
        while True:
            tickets = self._batcher.take_all()
            if tickets:
                self._serve(tickets)
            admitted = self._svc._driver_pump()
            self.deadline_admissions += admitted
            if self._halt.is_set():
                if self._batcher.pending:
                    continue  # drain stragglers before exiting
                break
            self._batcher.wait_for_work(
                min(self._poll_s, self._svc._time_to_deadline()))

    def _serve(self, tickets: list[ReadTicket]):
        try:
            results = self._svc._serve_reads(tickets)
        except BaseException as e:  # noqa: BLE001 — tickets must not hang
            for t in tickets:
                t._fulfil(error=e)
            return
        self.read_batches += 1
        self.read_tickets += len(tickets)
        for t, r in zip(tickets, results):
            t._fulfil(result=r)

    def halt(self):
        """Signal the loop to exit WITHOUT joining.  Safe to call from
        the driver thread itself — the service's preemption handler runs
        inside ``pump()``, which the driver may be clocking — where
        ``stop()``'s self-join would deadlock.  The loop still drains
        queued tickets before exiting; call ``stop()`` from another
        thread afterwards to join and close the batcher."""
        self._halt.set()
        self._batcher._wake.set()

    def stop(self, timeout: float = 30.0):
        """Signal, drain in-flight tickets, join; then fulfil anything
        that raced past the close with an error so no caller hangs."""
        self.halt()
        self.join(timeout)
        for t in self._batcher.close():
            t._fulfil(error=RuntimeError("service driver stopped"))


@dataclasses.dataclass
class Request:
    """One decode request: prompt tokens in, generated tokens out."""

    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous-batching decode loop (the KV-cache serving
    exemplar the ServiceDriver's fused-read design borrows from)."""

    def __init__(self, model, params, max_batch: int = 4, s_max: int = 256):
        self.model = model
        self.params = params
        self.b = max_batch
        self.s_max = s_max
        self.cache = model.init_cache(max_batch, s_max)
        sa = model.cache_shape(max_batch, s_max)
        sb = model.cache_shape(max_batch + 1, s_max)
        self.batch_axes = jax.tree.map(
            lambda a, b_: next(i for i, (x, y) in enumerate(
                zip(a.shape, b_.shape)) if x != y), sa, sb)
        self.pos = np.zeros(max_batch, np.int64)
        self.slots: list[Request | None] = [None] * max_batch
        self._decode = jax.jit(model.decode_step)
        self.steps = 0

    # ------------------------------------------------------------------ #
    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _commit_slot(self, new_cache, slot: int):
        """Adopt only ``slot``'s rows from new_cache (other slots frozen)."""

        def leaf(new, old, axis):
            """Copy one slot's rows along this leaf's batch axis."""
            idx = [slice(None)] * new.ndim
            idx[axis] = slice(slot, slot + 1)
            return old.at[tuple(idx)].set(new[tuple(idx)])

        self.cache = jax.tree.map(leaf, new_cache, self.cache, self.batch_axes)

    def submit(self, req: Request) -> bool:
        """Prefill ``req`` into a free slot; False when all slots busy."""
        slot = self._free_slot()
        if slot is None:
            return False
        self.pos[slot] = 0
        self.slots[slot] = req
        logits = None
        for tok in req.prompt:  # slot-local prefill at the slot's own pos
            pos_vec = self.pos.copy()
            pos_vec[slot] = self.pos[slot]
            batch = {
                "tokens": jnp.full((self.b, 1), int(tok), jnp.int32),
                "pos": jnp.asarray(pos_vec, jnp.int32),
            }
            logits, cache = self._decode(self.params, self.cache, batch)
            self._commit_slot(cache, slot)
            self.pos[slot] += 1
        req.out.append(int(jnp.argmax(logits[slot, -1])))
        return True

    # ------------------------------------------------------------------ #
    def step(self):
        """One batched decode step for every active slot."""
        if not any(s is not None for s in self.slots):
            return
        toks = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out:
                toks[i, 0] = req.out[-1]
        # per-slot positions: continuous batching, every slot at its own pos
        batch = {"tokens": jnp.asarray(toks),
                 "pos": jnp.asarray(self.pos, jnp.int32)}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None

    def run(self, requests: list[Request], max_steps: int = 1_000):
        """Drive all ``requests`` to completion (admit-as-slots-free)."""
        pending = list(requests)
        while (pending or any(s is not None for s in self.slots)) \
                and self.steps < max_steps:
            while pending and self._free_slot() is not None:
                self.submit(pending.pop(0))
            self.step()
        return [r for r in requests if r.done]

"""Batched serving engine: slot-based continuous batching over decode_step.

A fixed pool of B slots; each slot holds one sequence's cache region.  New
requests prefill into their slot, then the whole pool decodes one token per
step — the standard TPU serving shape (decode_32k's ``serve_step`` is
exactly one such pooled step).  The batch axis of every cache leaf is
probed once at init by differencing ``cache_shape(b)`` vs
``cache_shape(b+1)``, so the engine works unchanged for KV caches
(transformers), recurrent states (xLSTM/Mamba2) and enc-dec caches.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, max_batch: int = 4, s_max: int = 256):
        self.model = model
        self.params = params
        self.b = max_batch
        self.s_max = s_max
        self.cache = model.init_cache(max_batch, s_max)
        sa = model.cache_shape(max_batch, s_max)
        sb = model.cache_shape(max_batch + 1, s_max)
        self.batch_axes = jax.tree.map(
            lambda a, b_: next(i for i, (x, y) in enumerate(
                zip(a.shape, b_.shape)) if x != y), sa, sb)
        self.pos = np.zeros(max_batch, np.int64)
        self.slots: list[Request | None] = [None] * max_batch
        self._decode = jax.jit(model.decode_step)
        self.steps = 0

    # ------------------------------------------------------------------ #
    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _commit_slot(self, new_cache, slot: int):
        """Adopt only ``slot``'s rows from new_cache (other slots frozen)."""

        def leaf(new, old, axis):
            idx = [slice(None)] * new.ndim
            idx[axis] = slice(slot, slot + 1)
            return old.at[tuple(idx)].set(new[tuple(idx)])

        self.cache = jax.tree.map(leaf, new_cache, self.cache, self.batch_axes)

    def submit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        self.pos[slot] = 0
        self.slots[slot] = req
        logits = None
        for tok in req.prompt:  # slot-local prefill at the slot's own pos
            pos_vec = self.pos.copy()
            pos_vec[slot] = self.pos[slot]
            batch = {
                "tokens": jnp.full((self.b, 1), int(tok), jnp.int32),
                "pos": jnp.asarray(pos_vec, jnp.int32),
            }
            logits, cache = self._decode(self.params, self.cache, batch)
            self._commit_slot(cache, slot)
            self.pos[slot] += 1
        req.out.append(int(jnp.argmax(logits[slot, -1])))
        return True

    # ------------------------------------------------------------------ #
    def step(self):
        """One batched decode step for every active slot."""
        if not any(s is not None for s in self.slots):
            return
        toks = np.zeros((self.b, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is not None and req.out:
                toks[i, 0] = req.out[-1]
        # per-slot positions: continuous batching, every slot at its own pos
        batch = {"tokens": jnp.asarray(toks),
                 "pos": jnp.asarray(self.pos, jnp.int32)}
        logits, self.cache = self._decode(self.params, self.cache, batch)
        self.steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            req.out.append(int(nxt[i]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.slots[i] = None

    def run(self, requests: list[Request], max_steps: int = 1_000):
        pending = list(requests)
        while (pending or any(s is not None for s in self.slots)) \
                and self.steps < max_steps:
            while pending and self._free_slot() is not None:
                self.submit(pending.pop(0))
            self.step()
        return [r for r in requests if r.done]

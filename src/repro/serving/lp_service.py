"""Request-level label-propagation serving on the streaming engine.

``LPService`` turns the batch-oriented ``core.stream.StreamEngine`` into
a front-end for the two request kinds a label service sees:

  * **queries** — predict labels/confidences for arbitrary node sets.
    Served entirely from the engine's last *committed* ``LabelView``
    (the read side of the double buffer), so reads never block on an
    in-flight propagation and never observe a torn half-applied batch.
  * **mutations** — the typed embedding-first entry points
    ``add_points(embeddings, labels=...)`` / ``remove_points(ids)`` /
    ``relabel(ids, labels)`` (callers never construct edge lists; with
    ``StreamEngine(ingest="device")`` the kNN delta is derived on
    device — docs/ingestion.md).  Mutations are coalesced into one
    ``BatchUpdate`` per *admission window* — the window closes when it
    reaches ``window_ops`` operations or ``window_ms`` milliseconds,
    whichever first — and admitted through ``StreamEngine.submit`` so
    host staging of window t+1 overlaps device propagation of window t.

Commit flow: ``submit`` pipelines; ``poll`` (called from ``pump`` /
``mutate``) commits a finished solve without blocking; ``sync`` flushes
the open window and blocks until everything admitted has committed —
after ``sync()`` returns, queries see every prior mutation
(read-your-writes).  Each mutation gets a ``MutationTicket`` whose
commit latency feeds the service stats (``benchmarks/serve_lp.py``
reports the percentiles).

Async serving: ``start()`` (or ``with service:``) launches a background
``serving.engine.ServiceDriver`` thread.  The driver clocks admission —
window deadlines fire with ZERO caller traffic — commits finished
solves off every caller's critical path, and fuses concurrent readers'
tickets into one jitted device gather against the engine's committed
``DeviceLabelView`` (``query_async`` returns the ticket; ``query``
submits one and waits).  Reads stay never-torn: each fused batch is
answered from a single immutable snapshot.  Without the driver the
service is caller-clocked exactly as before, and ``query`` serves a
single-shot device gather.  See docs/serving.md.

Backpressure: when queued + in-flight operations would exceed
``max_pending_ops``, ``mutate`` either blocks draining the backlog
(default) or raises ``Backpressure`` (``reject_on_overload=True``) so
callers can shed load.  See docs/serving.md.

Durability: ``checkpoint_every``/``checkpoint_dir`` snapshot the FULL
engine state (``core.persistence``) off the caller path at quiescent
commit boundaries via ``CheckpointManager.save_async``, and
``arm_preemption()`` turns SIGTERM/SIGINT into a "drain in-flight,
checkpoint, exit clean" shutdown; a restarted process resumes
bit-identically with ``StreamEngine.restore``.  Async write failures
re-raise at the next ``mutate``/``sync`` — a service whose snapshots
are failing never pretends its state is durable.  See
docs/persistence.md.

Engine-level knobs ride along with the engine the service wraps: a
mesh-sharded engine serves through the ``transport`` it was built with
("allgather"/"halo"/"auto" — docs/streaming.md §Transports;
``ServiceStats.transport`` surfaces its per-rung decisions and halo
traffic), and the default ``max_k`` hub cap (4x the graph's kNN k,
``max_k=None`` to disable) bounds the compile ladder under hub-heavy
mutation streams.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.snapshot import LabelView
from repro.core.stream import StreamEngine, StreamStats
from repro.graph.dynamic import UNLABELED, BatchUpdate
from repro.serving.engine import ReadBatcher, ReadTicket, ServiceDriver
from repro.training.resilience import PreemptionGuard


class Backpressure(RuntimeError):
    """Raised when the mutation queue bound would be exceeded and the
    service was configured to reject rather than block."""


@dataclasses.dataclass
class MutationTicket:
    """Tracks one mutation from enqueue to commit."""

    ticket: int
    ops: int  # inserted vertices + delete requests in this mutation
    enqueued_at: float  # perf_counter at enqueue
    committed_at: float | None = None
    commit_id: int | None = None  # engine commit that made it visible

    @property
    def committed(self) -> bool:
        """Whether the mutation has landed in a committed view."""
        return self.committed_at is not None

    @property
    def latency_ms(self) -> float | None:
        """Enqueue-to-commit latency, or None while still pending."""
        if self.committed_at is None:
            return None
        return (self.committed_at - self.enqueued_at) * 1e3


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Answer for one query request, consistent as of ``commit_id``."""

    ids: np.ndarray  # (Q,) the requested global ids
    pred: np.ndarray  # (Q,) int8 — 0/1, or UNLABELED for dead/unknown ids
    confidence: np.ndarray  # (Q,) float32 — 1.0 for seeds, 0.0 dead/unknown
    commit_id: int  # committed batch the answer reflects


@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Point-in-time counters for one LPService instance."""

    queries: int
    query_nodes: int
    queries_while_inflight: int  # reads served while a solve was pending
    driver_running: bool  # background driver alive right now
    read_batches: int  # fused device gathers the driver executed
    read_tickets: int  # read tickets those gathers fulfilled
    deadline_admissions: int  # windows the driver's clock force-admitted
    mutations: int
    ops_accepted: int
    rejected: int  # mutations refused by backpressure
    batches_admitted: int
    batches_committed: int
    pending_ops: int  # queued (window) + in-flight right now
    recompiles: int  # engine recompile count (bucket-ladder bounded)
    bucket_rungs: int
    commit_latency_ms: dict  # p50/p95/p99/max over the last <=4096 commits
    transport: dict  # StreamEngine.transport_summary(): requested knob,
    # per-rung allgather/halo decisions, halo batch + overflow counts
    checkpoints_written: int = 0  # policy snapshots taken (async + final)
    last_checkpoint_commit: int = 0  # engine commit the newest covers
    preempted: bool = False  # drain-checkpoint-halt shutdown has run


@dataclasses.dataclass
class _QueuedMutation:
    ticket: MutationTicket
    ins_emb: np.ndarray
    ins_labels: np.ndarray
    del_ids: np.ndarray
    rel_ids: np.ndarray
    rel_labels: np.ndarray


class LPService:
    """Query/mutation front-end over a ``StreamEngine`` (see module doc).

    Caller-clocked by default: ``mutate`` and ``pump`` check the
    admission deadline and harvest finished solves; ``query`` is a pure
    read (one jitted gather against the committed device view).  With
    the background driver running (``start()`` / ``with service:``),
    the clock moves off the callers: deadlines fire on their own,
    commits land as soon as the device finishes, and concurrent reads
    fuse into one device gather.
    """

    def __init__(
        self,
        engine: StreamEngine,
        *,
        window_ops: int = 64,
        window_ms: float = 50.0,
        max_pending_ops: int = 1024,
        reject_on_overload: bool = False,
        cutoff: float = 0.5,
        driver_poll_ms: float = 2.0,
        checkpoint_every: int | None = None,
        checkpoint_dir: str | None = None,
        checkpoint_keep: int = 3,
    ):
        if window_ops < 1:
            raise ValueError("window_ops must be >= 1")
        if max_pending_ops < window_ops:
            raise ValueError("max_pending_ops must be >= window_ops")
        # checkpoint policy: every ``checkpoint_every`` commits the full
        # engine state snapshots to ``checkpoint_dir`` OFF the caller
        # path (CheckpointManager.save_async — callers only pay the host
        # copy), always at a quiescent commit boundary.  A directory
        # without a cadence still arms the preemption/shutdown final
        # snapshot.  See docs/persistence.md.
        if checkpoint_every is not None:
            if checkpoint_every < 1:
                raise ValueError("checkpoint_every must be >= 1")
            if checkpoint_dir is None:
                raise ValueError(
                    "checkpoint_every requires checkpoint_dir")
        self.checkpoint_every = checkpoint_every
        self._ckpt_mgr = (CheckpointManager(checkpoint_dir,
                                            keep=checkpoint_keep)
                          if checkpoint_dir is not None else None)
        self._last_ckpt_commit = engine.commits
        self.checkpoints_written = 0
        self._ckpt_error: BaseException | None = None
        self._guard: PreemptionGuard | None = None
        self.preempted = False
        self.engine = engine
        self.window_ops = window_ops
        self.window_ms = window_ms
        self.max_pending_ops = max_pending_ops
        self.reject_on_overload = reject_on_overload
        self.cutoff = cutoff
        self.driver_poll_ms = driver_poll_ms

        self._window: list[_QueuedMutation] = []
        self._window_ops = 0
        self._window_t0: float | None = None  # opened when first op queued
        self._inflight: list[MutationTicket] = []
        self._inflight_ops = 0
        self._next_ticket = 0
        # Rolling window: a long-lived service must not grow a per-
        # mutation history (or re-percentile it) without bound.
        self._commit_latency_ms: collections.deque[float] = \
            collections.deque(maxlen=4096)
        # One reentrant lock guards the engine's WRITE side (window
        # state, submit/poll/drain) — callers and the driver thread both
        # clock the service through it.  Reads deliberately take only
        # ``_stats_lock``: committed views are immutable and swapped
        # atomically at drain, so the read path never queues behind a
        # mutation's host staging (which holds ``_lock`` for the whole
        # ``submit``).
        self._lock = threading.RLock()
        self._stats_lock = threading.Lock()
        self._driver: ServiceDriver | None = None
        self._batcher: ReadBatcher | None = None
        # (read_batches, read_tickets, deadline_admissions) accumulated
        # over stopped drivers — stats survive stop/start cycles
        self._drained_reads = (0, 0, 0)

        self.queries = 0
        self.query_nodes = 0
        self.queries_while_inflight = 0
        self.mutations = 0
        self.ops_accepted = 0
        self.rejected = 0
        self.batches_admitted = 0
        self.batches_committed = 0

    # ------------------------------------------------------------------ #
    # driver lifecycle
    # ------------------------------------------------------------------ #
    def start(self) -> "LPService":
        """Launch the background driver (idempotent).  From here on,
        admission deadlines fire and solves commit without caller
        traffic, and reads batch across concurrent callers."""
        with self._lock:
            if self._driver is None:
                self._batcher = ReadBatcher()
                self._driver = ServiceDriver(self, self._batcher,
                                             poll_ms=self.driver_poll_ms)
                self._driver.start()
        return self

    def stop(self):
        """Stop the driver: in-flight read tickets are drained (every
        ticket is fulfilled), then the service is caller-clocked again.
        Queued mutations stay queued — ``close``/``sync`` flushes them."""
        with self._lock:
            driver, self._driver = self._driver, None
            self._batcher = None
        if driver is not None:
            driver.stop()
            rb, rt, da = self._drained_reads
            self._drained_reads = (rb + driver.read_batches,
                                   rt + driver.read_tickets,
                                   da + driver.deadline_admissions)

    def close(self):
        """Stop the driver and flush: every queued mutation is admitted
        and every admitted batch committed (read-your-writes for any
        subsequent direct reads)."""
        self.stop()
        self.sync()

    def __enter__(self) -> "LPService":
        return self.start()

    def __exit__(self, *exc):
        self.close()

    @property
    def driver_running(self) -> bool:
        """Whether the background commit driver thread is alive."""
        d = self._driver
        return d is not None and d.is_alive()

    # ------------------------------------------------------------------ #
    # durability: checkpoint policy + preemption-driven shutdown
    # ------------------------------------------------------------------ #
    def arm_preemption(self, guard: PreemptionGuard | None = None
                       ) -> PreemptionGuard:
        """Install (or adopt) a ``PreemptionGuard``: once SIGTERM/SIGINT
        is delivered, the next ``pump()`` tick — the driver's, or any
        caller's — drains in-flight work, writes one final synchronous
        checkpoint (when a ``checkpoint_dir`` is configured), and halts
        the driver so the process can exit clean.  Afterwards
        ``preempted`` is True and new mutations are refused; restart and
        ``StreamEngine.restore`` to resume.  Returns the guard (use it
        as a context manager to guarantee handler restoration)."""
        with self._lock:
            self._guard = guard if guard is not None else PreemptionGuard()
            return self._guard

    def shutdown(self) -> int | None:
        """Graceful "drain in-flight, checkpoint, exit clean": stop the
        driver, flush + commit every queued mutation, then write one
        final SYNCHRONOUS checkpoint.  Returns the checkpointed commit
        id (None when no ``checkpoint_dir`` is configured).  The
        preemption path does the same dance from inside ``pump()``."""
        self.stop()
        self.sync()
        if self._ckpt_mgr is None:
            return None
        with self._lock:
            return self._checkpoint_sync()

    def _checkpoint_sync(self) -> int:
        """Final/forced snapshot at the current (quiescent) commit."""
        step = self.engine.commits
        self._ckpt_mgr.save_sync(step, self.engine.checkpoint_state())
        self._last_ckpt_commit = step
        self.checkpoints_written += 1
        return step

    def _maybe_checkpoint(self):
        """Policy snapshot at a commit boundary (called from ``_resolve``
        with ``_lock`` held).  Only fires when the engine is quiescent —
        ``_admit`` resolves the PREVIOUS batch's tickets with the next
        already in flight, and a snapshot there would tear — so a cadence
        point reached mid-pipeline simply waits for the next quiescent
        commit.  Write failures never kill the driver thread: they are
        recorded and re-raised to the next ``mutate``/``sync`` caller."""
        if (self._ckpt_mgr is None or self.checkpoint_every is None
                or self.preempted or self.engine.in_flight):
            return
        if (self.engine.commits - self._last_ckpt_commit
                < self.checkpoint_every):
            return
        try:
            self._ckpt_mgr.save_async(self.engine.commits,
                                      self.engine.checkpoint_state())
        except Exception as e:  # surfaced at the next mutate()/sync()
            if self._ckpt_error is None:
                self._ckpt_error = e
            return
        self._last_ckpt_commit = self.engine.commits
        self.checkpoints_written += 1

    def _raise_ckpt_error(self):
        """Surface an async checkpoint-write failure to the caller (the
        durability contract: a service whose snapshots are failing must
        not keep accepting writes as if its state were durable)."""
        if self._ckpt_error is not None:
            err, self._ckpt_error = self._ckpt_error, None
            raise RuntimeError(
                "engine checkpointing failed; durable state is stale "
                f"(last good commit {self._last_ckpt_commit})") from err

    def _handle_preemption(self):
        """Drain in-flight, checkpoint, halt — with ``_lock`` held.

        Runs on whichever thread's ``pump()`` first observes the guard:
        possibly the driver's own, so the driver is HALTED (flag only),
        never joined here — ``stop()``/``shutdown()`` from another
        thread completes the join."""
        self.preempted = True
        self._admit()
        st = self.engine.drain()
        if st is not None:
            self._resolve(st)
        if self._ckpt_mgr is not None:
            try:
                self._checkpoint_sync()
            except Exception as e:  # the exit path must still halt
                if self._ckpt_error is None:
                    self._ckpt_error = e
        d = self._driver
        if d is not None:
            d.halt()

    # ------------------------------------------------------------------ #
    # read path
    # ------------------------------------------------------------------ #
    def query(self, node_ids, cutoff: float | None = None) -> QueryResult:
        """Labels + confidences for ``node_ids`` from the last committed
        snapshot (one jitted device gather; ids from a batch that has
        not committed yet answer ``UNLABELED`` at confidence 0).  With
        the driver running this enqueues a ticket and waits — concurrent
        callers' bursts fuse into one gather; reads never block on an
        in-flight solve either way."""
        ticket = self.query_async(node_ids, cutoff)
        if ticket is not None:
            return ticket.wait()
        ids = np.asarray(node_ids, np.int64).reshape(-1)
        # lock-free view fetch: ``_view``/``_device_view`` swap atomically
        # at drain, so reads never wait on a mutation's staging
        view = self.engine.device_view()
        inflight = self.engine.in_flight
        pred, conf = view.query(ids, self.cutoff if cutoff is None else cutoff)
        with self._stats_lock:
            self.queries += 1
            self.query_nodes += len(ids)
            self.queries_while_inflight += inflight
        return QueryResult(ids=ids, pred=pred, confidence=conf,
                           commit_id=view.commit_id)

    def query_async(self, node_ids, cutoff: float | None = None
                    ) -> ReadTicket | None:
        """Enqueue a read for the driver's next fused gather; returns the
        ticket (``.wait()`` for the ``QueryResult``), or None when the
        driver is not running — use ``query`` for the synchronous path."""
        batcher = self._batcher
        if batcher is None:
            return None
        ids = np.asarray(node_ids, np.int64).reshape(-1)
        try:
            return batcher.submit(
                ids, self.cutoff if cutoff is None else cutoff)
        except RuntimeError:
            return None  # raced a stop(): caller falls back to sync path

    def _serve_reads(self, tickets) -> list[QueryResult]:
        """Driver-side: answer a batch of tickets with ONE fused gather
        from ONE committed view — the never-torn guarantee: a commit
        landing mid-burst flips whole batches between immutable views,
        never individual lanes."""
        view = self.engine.device_view()
        inflight = self.engine.in_flight
        ids_cat = np.concatenate([t.ids for t in tickets]) \
            if tickets else np.zeros(0, np.int64)
        cut_cat = np.concatenate(
            [np.full(len(t.ids), t.cutoff, np.float32) for t in tickets]) \
            if tickets else np.zeros(0, np.float32)
        pred, conf = view.query(ids_cat, cut_cat)
        out, off = [], 0
        for t in tickets:
            q = len(t.ids)
            out.append(QueryResult(
                ids=t.ids, pred=pred[off:off + q],
                confidence=conf[off:off + q], commit_id=view.commit_id))
            off += q
        with self._stats_lock:
            self.queries += len(tickets)
            self.query_nodes += len(ids_cat)
            self.queries_while_inflight += inflight * len(tickets)
        return out

    def committed_view(self) -> LabelView:
        """Snapshot handle over the last committed labels."""
        return self.engine.committed_view()

    # ------------------------------------------------------------------ #
    # write path
    # ------------------------------------------------------------------ #
    def add_points(
        self,
        embeddings: np.ndarray,
        labels: np.ndarray | None = None,
    ) -> MutationTicket:
        """Insert points by embedding — the embedding-first front door.

        ``embeddings`` is (M, D); ``labels`` is (M,) ground truth (0/1,
        or ``UNLABELED``/None for points the propagation should label).
        The service derives the graph delta itself — on device when the
        engine was built with ``ingest="device"`` (docs/ingestion.md) —
        so callers never construct edge lists.  Returns the mutation's
        ticket; ``sync()`` for read-your-writes."""
        return self.mutate(ins_emb=embeddings, ins_labels=labels)

    def remove_points(self, ids) -> MutationTicket:
        """Delete points by global id (their edges vanish with them)."""
        return self.mutate(del_ids=ids)

    def relabel(self, ids, labels) -> MutationTicket:
        """Change the ground-truth labels of existing points (0/1, or
        ``UNLABELED`` to demote a seed back to propagated)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        labels = np.asarray(labels, np.int8).reshape(-1)
        if len(ids) != len(labels):
            raise ValueError(
                f"relabel ids length {len(ids)} != labels {len(labels)}")
        return self.mutate(rel_ids=ids, rel_labels=labels)

    def mutate(
        self,
        ins_emb: np.ndarray | None = None,
        ins_labels: np.ndarray | None = None,
        del_ids: np.ndarray | None = None,
        rel_ids: np.ndarray | None = None,
        rel_labels: np.ndarray | None = None,
    ) -> MutationTicket:
        """Enqueue one mutation (inserts, deletes and/or relabels) for
        the current admission window; returns its ticket.  May admit a
        batch (window full or deadline passed) and, under backpressure,
        may block until the backlog drains — or raise ``Backpressure``
        if configured to reject.

        Prefer the typed ``add_points`` / ``remove_points`` / ``relabel``
        wrappers; constructing raw ``BatchUpdate`` deltas and calling
        ``engine.submit`` directly is deprecated for service callers —
        it bypasses admission windows, backpressure and tickets."""
        dim = self.engine.graph.emb_dim
        emb = (np.zeros((0, dim), np.float32) if ins_emb is None
               else np.asarray(ins_emb, np.float32).reshape(-1, dim))
        if ins_labels is None:
            labels = np.full(len(emb), UNLABELED, np.int8)
        else:
            labels = np.asarray(ins_labels, np.int8).reshape(-1)
        if len(labels) != len(emb):
            raise ValueError(
                f"ins_labels length {len(labels)} != ins_emb rows {len(emb)}")
        dels = (np.zeros(0, np.int64) if del_ids is None
                else np.asarray(del_ids, np.int64).reshape(-1))
        rels = (np.zeros(0, np.int64) if rel_ids is None
                else np.asarray(rel_ids, np.int64).reshape(-1))
        rlabs = (np.zeros(0, np.int8) if rel_labels is None
                 else np.asarray(rel_labels, np.int8).reshape(-1))
        if len(rels) != len(rlabs):
            raise ValueError(
                f"rel_labels length {len(rlabs)} != rel_ids {len(rels)}")
        ops = len(emb) + len(dels) + len(rels)
        if ops == 0:
            raise ValueError(
                "empty mutation: no inserts, deletes or relabels")

        with self._lock:
            if self.preempted:
                raise RuntimeError(
                    "service preempted: state was checkpointed and the "
                    "driver halted — restart and restore to resume")
            self._raise_ckpt_error()
            self.pump()  # harvest a finished solve / deadline-flush first
            if self._pending_ops() + ops > self.max_pending_ops:
                if self.reject_on_overload:
                    self.rejected += 1
                    raise Backpressure(
                        f"mutation of {ops} ops over bound: "
                        f"{self._pending_ops()} pending, "
                        f"max_pending_ops={self.max_pending_ops}")
                self._relieve(ops)

            ticket = MutationTicket(ticket=self._next_ticket, ops=ops,
                                    enqueued_at=time.perf_counter())
            self._next_ticket += 1
            self._window.append(
                _QueuedMutation(ticket, emb, labels, dels, rels, rlabs))
            self._window_ops += ops
            if self._window_t0 is None:
                self._window_t0 = time.perf_counter()
            self.mutations += 1
            self.ops_accepted += ops
            if self._window_ops >= self.window_ops:
                self._admit()
            return ticket

    def pump(self) -> StreamStats | None:
        """Advance the service without blocking: commit the in-flight
        batch if its solve finished, then admit the open window if it hit
        the size or deadline bound.  Returns commit stats if one landed.
        With the driver running this happens continuously on its own."""
        with self._lock:
            st = self.engine.poll()
            if st is not None:
                self._resolve(st)
            if self._window and (
                    self._window_ops >= self.window_ops
                    or (time.perf_counter() - self._window_t0) * 1e3
                    >= self.window_ms):
                self._admit()
            if (self._guard is not None and self._guard.requested
                    and not self.preempted):
                self._handle_preemption()
            return st

    def _driver_pump(self) -> int:
        """One driver clock tick; returns 1 iff the deadline (not size)
        force-admitted the window — the driver's admission counter.

        Non-blocking on the write lock: a mutation mid-staging holds it
        for tens of milliseconds, and stalling the driver there would
        queue every fused read behind the write path — the exact
        coordinated delay the async model exists to remove.  A skipped
        tick costs nothing: the mutating caller's own ``pump`` runs on
        lock release, and the driver retries within ``poll_ms``."""
        if not self._lock.acquire(blocking=False):
            return 0
        try:
            was_open = self._window_t0 is not None
            under = self._window_ops < self.window_ops
            self.pump()
            return int(was_open and under and self._window_t0 is None)
        finally:
            self._lock.release()

    def _time_to_deadline(self) -> float:
        """Seconds until the open window's ``window_ms`` deadline (driver
        sleep bound); 1s when no window is open.  Lock-free: ``_window_t0``
        is read once (atomic), and a stale value only mistimes one tick."""
        t0 = self._window_t0
        if t0 is None:
            return 1.0
        return max(0.0, t0 + self.window_ms / 1e3 - time.perf_counter())

    def flush(self) -> BatchUpdate | None:
        """Force-admit the open window regardless of size/deadline;
        returns the coalesced ``BatchUpdate`` (None if nothing queued)."""
        with self._lock:
            st = self.engine.poll()
            if st is not None:
                self._resolve(st)
            return self._admit()

    def sync(self) -> StreamStats | None:
        """Flush + block until every admitted batch has committed.  After
        ``sync()`` returns, queries observe all prior mutations
        (read-your-writes) — including reads fused by the driver, which
        are answered from the view this drain publishes.  Returns the
        last commit's stats."""
        with self._lock:
            self._raise_ckpt_error()
            self._admit()
            st = self.engine.drain()
            if st is not None:
                self._resolve(st)
            return st

    # ------------------------------------------------------------------ #
    def _pending_ops(self) -> int:
        return self._window_ops + self._inflight_ops

    def _relieve(self, incoming: int):
        """Blockingly shrink the backlog until ``incoming`` fits."""
        if incoming > self.max_pending_ops:
            self.rejected += 1  # can never fit: rejected even in block mode
            raise Backpressure(
                f"single mutation of {incoming} ops exceeds "
                f"max_pending_ops={self.max_pending_ops}")
        while self._pending_ops() + incoming > self.max_pending_ops:
            if self._inflight:
                st = self.engine.drain()
                if st is not None:
                    self._resolve(st)
            elif self._window:
                self._admit()
            else:  # pragma: no cover — nothing left to shed
                break

    def _admit(self) -> BatchUpdate | None:
        """Coalesce the window into one BatchUpdate and submit it."""
        if not self._window:
            return None
        window, self._window = self._window, []
        ops, self._window_ops = self._window_ops, 0
        self._window_t0 = None
        batch = BatchUpdate(
            ins_emb=np.concatenate([q.ins_emb for q in window]),
            ins_labels=np.concatenate([q.ins_labels for q in window]),
            del_ids=np.concatenate([q.del_ids for q in window]),
            rel_ids=np.concatenate([q.rel_ids for q in window]),
            rel_labels=np.concatenate([q.rel_labels for q in window]),
        )
        # submit internally drains the previous batch — those are the
        # current in-flight tickets, resolved below if that drain ran.
        prev = self.engine.submit(batch)
        if prev is not None:
            self._resolve(prev)
        self._inflight = [q.ticket for q in window]
        self._inflight_ops = ops
        self.batches_admitted += 1
        return batch

    def _resolve(self, stats: StreamStats):
        """Mark the in-flight tickets committed (their batch drained)."""
        now = time.perf_counter()
        for t in self._inflight:
            t.committed_at = now
            t.commit_id = self.engine.commits
            self._commit_latency_ms.append(t.latency_ms)
        self._inflight = []
        self._inflight_ops = 0
        self.batches_committed += 1
        self._maybe_checkpoint()

    # ------------------------------------------------------------------ #
    def stats(self) -> ServiceStats:
        """Current service counters plus commit-latency percentiles."""
        lat = self._commit_latency_ms
        pct = {}
        if lat:
            arr = np.asarray(lat)
            pct = {
                "p50": round(float(np.percentile(arr, 50)), 3),
                "p95": round(float(np.percentile(arr, 95)), 3),
                "p99": round(float(np.percentile(arr, 99)), 3),
                "max": round(float(arr.max()), 3),
                "count": len(lat),
            }
        d = self._driver
        rb, rt, da = self._drained_reads
        if d is not None:
            rb += d.read_batches
            rt += d.read_tickets
            da += d.deadline_admissions
        return ServiceStats(
            queries=self.queries,
            query_nodes=self.query_nodes,
            queries_while_inflight=self.queries_while_inflight,
            driver_running=self.driver_running,
            read_batches=rb,
            read_tickets=rt,
            deadline_admissions=da,
            mutations=self.mutations,
            ops_accepted=self.ops_accepted,
            rejected=self.rejected,
            batches_admitted=self.batches_admitted,
            batches_committed=self.batches_committed,
            pending_ops=self._pending_ops(),
            recompiles=self.engine.recompile_count,
            bucket_rungs=len(self.engine.bucket_keys),
            commit_latency_ms=pct,
            transport=self.engine.transport_summary(),
            checkpoints_written=self.checkpoints_written,
            last_checkpoint_commit=self._last_ckpt_commit,
            preempted=self.preempted,
        )

"""DynLP-powered data pipeline: semi-supervised pseudo-labeling of a
streaming corpus (the paper's motivating application — dataset annotation
with few ground-truth labels) as a first-class training-data stage.

Documents arrive in batches; each is embedded (pluggable ``embed_fn``),
inserted into the dynamic kNN similarity graph, and labeled incrementally
by DynLP.  ``select()`` yields confidently-labeled documents of a target
class for the training loop — data curation driven by the paper's
algorithm instead of a full recompute per arriving batch.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.dynlp import DynLP
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph


def default_embed(tokens: np.ndarray, dim: int = 32) -> np.ndarray:
    """Cheap order-sensitive hash embedding (B, dim): hashed histograms of
    successive-token DIFFS plus token-level hashes.  Diff features make
    sequence structure (walks, loops, periodicity) linearly separable from
    i.i.d. noise while remaining vocabulary-agnostic."""
    b, s = tokens.shape
    out = np.zeros((b, dim), np.float32)
    diffs = (tokens[:, 1:].astype(np.int64) - tokens[:, :-1]) % 65_536
    toks = tokens.astype(np.int64)
    half = dim // 2
    for j in range(half):
        out[:, j] = ((diffs * (j * 2_654_435_761 + 1)) % 997 / 997.0).mean(axis=1)
    for j in range(half, dim):
        out[:, j] = ((toks * (j * 40_503 + 7)) % 991 / 991.0).mean(axis=1)
    return out - out.mean(axis=0, keepdims=True)


@dataclasses.dataclass
class IngestStats:
    num_docs: int
    lp_iterations: int
    lp_ms: float


class PseudoLabelPipeline:
    def __init__(self, embed_fn: Callable | None = None, k: int = 5,
                 delta: float = 1e-4, emb_dim: int = 32):
        self.embed_fn = embed_fn or (lambda t: default_embed(t, emb_dim))
        self.graph = DynamicGraph(emb_dim=emb_dim, k=k)
        self.lp = DynLP(self.graph, delta=delta)
        self.docs: dict[int, np.ndarray] = {}

    def ingest(self, tokens: np.ndarray, labels: np.ndarray | None = None,
               drop_ids: np.ndarray | None = None) -> IngestStats:
        """tokens: (B, S) int32; labels: (B,) with 0/1/UNLABELED."""
        b = len(tokens)
        labels = np.full(b, UNLABELED, np.int8) if labels is None else labels
        emb = self.embed_fn(tokens)
        base = self.graph.num_nodes
        st = self.lp.step(BatchUpdate(
            ins_emb=emb, ins_labels=labels.astype(np.int8),
            del_ids=drop_ids if drop_ids is not None else np.zeros(0, np.int64)))
        for i in range(b):
            self.docs[base + i] = tokens[i]
        return IngestStats(num_docs=b, lp_iterations=st.iterations,
                           lp_ms=st.wall_ms)

    def select(self, target_class: int = 1, confidence: float = 0.8,
               limit: int | None = None) -> tuple[np.ndarray, np.ndarray]:
        """(doc ids, stacked tokens) of confidently pseudo-labeled docs."""
        g = self.graph
        ids = np.flatnonzero(g.alive)
        f = g.f[ids]
        score = f if target_class == 1 else 1.0 - f
        picked = ids[score >= confidence]
        labeled = ids[g.labels[ids] == target_class]
        picked = np.unique(np.concatenate([picked, labeled]))
        picked = np.array([i for i in picked if i in self.docs], np.int64)
        if limit is not None:
            picked = picked[:limit]
        toks = np.stack([self.docs[i] for i in picked]) if len(picked) else \
            np.zeros((0, 0), np.int32)
        return picked, toks

    def label_quality(self, truth: dict[int, int]) -> float:
        g = self.graph
        ids = np.flatnonzero(g.alive & (g.labels == UNLABELED))
        if not len(ids):
            return 1.0
        pred = (g.f[ids] >= 0.5).astype(np.int8)
        tr = np.array([truth[i] for i in ids])
        return float((pred == tr).mean())

"""Synthetic dataset / stream generators mirroring the paper's §7.1 setup.

Two families:
  * ``gaussian_mixture_stream`` — embedding-space data (mimics the
    IMDB/ImageNet/Yelp pipelines: feature vectors → cosine kNN graph).  Two
    class centroids; class determines the ground-truth binary label.
  * ``erdos_renyi_graph`` — planted-partition sparse random graph with a
    target average degree (the paper's "Random Dataset", degrees {3,5,7});
    used through a synthetic-embedding trick so the same kNN machinery
    applies: we emit embeddings whose kNN graph has the requested degree by
    sampling per-class Gaussians with controlled spread.

The paper's batch protocol: each Δ_t is 90% unlabeled insertions, 1%
ground-truth insertions, 9% deletions of existing vertices.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.graph.dynamic import UNLABELED, BatchUpdate


@dataclasses.dataclass
class StreamSpec:
    total_vertices: int
    batch_size: int
    emb_dim: int = 16
    frac_unlabeled: float = 0.90
    frac_labeled: float = 0.01
    frac_deleted: float = 0.09
    class_sep: float = 4.0  # distance between class centroids
    noise: float = 1.0
    seed: int = 0


def _sample_points(
    rng: np.random.Generator, n: int, spec: StreamSpec
) -> tuple[np.ndarray, np.ndarray]:
    """Two-Gaussian mixture; returns (embeddings, true class)."""
    cls = rng.integers(0, 2, size=n).astype(np.int8)
    centers = np.zeros((2, spec.emb_dim), np.float32)
    centers[0, 0] = -spec.class_sep / 2
    centers[1, 0] = +spec.class_sep / 2
    emb = centers[cls] + rng.normal(0, spec.noise, size=(n, spec.emb_dim)).astype(
        np.float32
    )
    return emb, cls


def gaussian_mixture_stream(
    spec: StreamSpec,
) -> Iterator[tuple[BatchUpdate, np.ndarray]]:
    """Yields (BatchUpdate, true_classes_of_inserted) until ``total_vertices``
    have been inserted.  Deletions sample uniformly from previously inserted
    vertices (the caller's graph ignores already-dead ids)."""
    rng = np.random.default_rng(spec.seed)
    inserted = 0
    next_id = 0
    while inserted < spec.total_vertices:
        b = min(spec.batch_size, spec.total_vertices - inserted)
        n_lab = max(1, int(round(b * spec.frac_labeled))) if inserted == 0 else int(
            round(b * spec.frac_labeled)
        )
        n_del = int(round(b * spec.frac_deleted)) if next_id > 0 else 0
        n_unl = b - n_lab
        emb, cls = _sample_points(rng, b, spec)
        labels = np.full(b, UNLABELED, np.int8)
        lab_idx = rng.choice(b, size=n_lab, replace=False) if n_lab else np.zeros(0, int)
        labels[lab_idx] = cls[lab_idx]
        del_ids = (
            rng.integers(0, next_id, size=n_del).astype(np.int64)
            if n_del
            else np.zeros(0, np.int64)
        )
        yield BatchUpdate(ins_emb=emb, ins_labels=labels, del_ids=del_ids), cls
        inserted += b
        next_id += b
        del n_unl


def cosine_locality_order(emb: np.ndarray) -> np.ndarray:
    """Arrival order matched to the graph's COSINE kNN metric: an angular
    sweep over the normalized embeddings' dominant 2-plane (top-2 right
    singular vectors), so consecutive ids are angular — i.e. cosine —
    neighbors.  A Euclidean space-filling order is the wrong curve here:
    ``graph.knn.knn_edges`` compares directions, not positions, so only
    an angular order makes kNN references id-local.  Exact for 2-d
    embeddings (the sweep IS the metric); an approximation in higher
    dimensions, where neighborhoods spread over axes outside the
    dominant plane."""
    q = emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-12)
    _, _, vt = np.linalg.svd(q, full_matrices=False)
    xy = q @ vt[:2].T
    return np.argsort(np.arctan2(xy[:, 1], xy[:, 0]), kind="stable")


def locality_stream(
    spec: StreamSpec,
    delete_window: int = 2,
) -> Iterator[tuple[BatchUpdate, np.ndarray]]:
    """Locality-ordered variant of ``gaussian_mixture_stream``: the same
    two-Gaussian population, but vertices arrive in cosine-locality order
    (``cosine_locality_order``), so insertion ids — and therefore the
    snapshot's bucket rows — are kNN-contiguous.  Cross-shard references
    then concentrate at contiguous-shard boundaries and halo export sets
    stay small (<2% of rows for 2-d mixtures): this is the stream shape
    the ``transport="halo"`` arm of ``benchmarks/stream_throughput.py``
    measures (real analogues: time-ordered event streams, CC-clustered /
    partition-ordered ingest).  Use ``emb_dim=2`` when the kNN topology
    itself must be id-local.  Deletions sample only from the trailing
    ``delete_window`` batches so they do not break the locality of old
    shards.  Ground-truth labels are still sprinkled uniformly per batch
    (batch 0 guarantees at least one seed).
    """
    rng = np.random.default_rng(spec.seed)
    emb, cls = _sample_points(rng, spec.total_vertices, spec)
    order = cosine_locality_order(emb)
    emb, cls = emb[order], cls[order]
    next_id = 0
    while next_id < spec.total_vertices:
        b = min(spec.batch_size, spec.total_vertices - next_id)
        e = emb[next_id:next_id + b]
        c = cls[next_id:next_id + b]
        n_lab = int(round(b * spec.frac_labeled))
        if next_id == 0:
            n_lab = max(1, n_lab)
        labels = np.full(b, UNLABELED, np.int8)
        lab_idx = (rng.choice(b, size=n_lab, replace=False) if n_lab
                   else np.zeros(0, int))
        labels[lab_idx] = c[lab_idx]
        n_del = int(round(b * spec.frac_deleted)) if next_id else 0
        lo = max(0, next_id - delete_window * spec.batch_size)
        del_ids = (rng.integers(lo, next_id, size=n_del).astype(np.int64)
                   if n_del else np.zeros(0, np.int64))
        yield BatchUpdate(ins_emb=e, ins_labels=labels, del_ids=del_ids), c
        next_id += b


def hub_stream(
    n_batches: int = 5,
    per_hub: int = 20,
    hubs: int = 2,
    emb_dim: int = 8,
    class_sep: float = 2.0,
    spread: float = 0.02,
    seed: int = 0,
) -> Iterator[tuple[BatchUpdate, np.ndarray]]:
    """Hub-heavy stream: every batch drops ``per_hub`` vertices into a
    tight cloud around each of ``hubs`` fixed centers, so the hub
    vertices' kNN in-degree — and the snapshot's natural ELL K — grows
    with every batch.  The stress case for the ``max_k`` heaviest-edge
    cap (ROADMAP follow-up): without a cap the K-bucket ladder climbs
    batch after batch; with one, truncation must not change the label a
    hub neighborhood converges to.  Hubs alternate classes along axis 0
    (ground truth = nearest hub's class); batch 0 seeds one labeled
    anchor per class at the hub centers.
    """
    rng = np.random.default_rng(seed)
    centers = np.zeros((hubs, emb_dim), np.float32)
    cls = (np.arange(hubs) % 2).astype(np.int8)
    centers[:, 0] = np.where(cls == 1, class_sep / 2, -class_sep / 2)
    centers[:, 1] = np.arange(hubs)  # separate hubs within a class
    for b in range(n_batches):
        emb = np.repeat(centers, per_hub, axis=0) + rng.normal(
            0, spread, (hubs * per_hub, emb_dim)).astype(np.float32)
        truth = np.repeat(cls, per_hub)
        labels = np.full(len(emb), UNLABELED, np.int8)
        if b == 0:  # seed the hub centers themselves, ground-truth labeled
            emb = np.concatenate([centers, emb])
            truth = np.concatenate([cls, truth])
            labels = np.concatenate([cls, labels])
        yield BatchUpdate(ins_emb=emb, ins_labels=labels,
                          del_ids=np.zeros(0, np.int64)), truth


def seeded_graph(
    n: int, spec: StreamSpec, frac_labeled: float = 0.01
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One-shot dataset: (embeddings, labels-with-ground-truth-mask, classes)."""
    rng = np.random.default_rng(spec.seed)
    emb, cls = _sample_points(rng, n, spec)
    labels = np.full(n, UNLABELED, np.int8)
    n_lab = max(2, int(round(n * frac_labeled)))
    idx = rng.choice(n, size=n_lab, replace=False)
    labels[idx] = cls[idx]
    # guarantee both classes are seeded
    if not (labels == 0).any():
        labels[np.flatnonzero(cls == 0)[0]] = 0
    if not (labels == 1).any():
        labels[np.flatnonzero(cls == 1)[0]] = 1
    return emb, labels, cls


def accuracy(pred: np.ndarray, truth: np.ndarray) -> float:
    """Fraction of matching binary labels (paper's accuracy metric)."""
    return float((pred == truth).mean()) if len(pred) else 1.0

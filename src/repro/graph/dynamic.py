"""Host-side dynamic similarity graph (paper §3.2, §6.3).

The paper keeps the evolving graph in CPU memory (growable 2-D vectors) and
ships per-batch subgraphs to the device.  We mirror that: numpy arrays grow
per batch; every batch produces (i) the updated topology, (ii) the
affected-vertex set, and (iii) the new-vertex subgraph G' used for
connected-component label initialization (Alg. 2 Step 1).

Topology is maintained *incrementally* as a true kNN graph: every alive
vertex keeps its directed top-k neighbor list (canonical order: weight
desc, index asc; see ``graph.knn``), and an arriving batch both builds the
new rows' lists and **displaces** the weakest entries of existing rows it
beats — so after any insert-only stream the graph is bit-identical to a
from-scratch ``build_knn_graph`` rebuild.  Deletions drop a vertex and
every list entry pointing at it (holes refill as later arrivals merge in).
The undirected edge arrays (both directions stored) are regenerated from
the lists after each batch.

*Where* the candidate search runs is pluggable: ``apply_batch`` takes a
selector — ``HostKNNSelector`` (the blockwise-BLAS staging path, default)
or ``ingest.incremental_knn.DeviceIngestor`` (the Pallas/XLA argkmin path
over the device-resident embedding store).  Selectors only nominate
candidate *supersets*; the canonical re-selection and list merges here are
shared, which is what makes the two paths bit-identical (``graph.knn``
module docstring).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .knn import (
    normalize_rows,
    pair_weights,
    select_candidates,
    selection_slack,
    topk_pairs,
)
from .structures import CSRGraph, ELLGraph, coo_to_csr, csr_to_ell_fast

UNLABELED = -1

# flagged-row merges are chunked so the (rows, batch, dim) canonical
# weight tensor stays bounded regardless of how many rows a batch displaces
_MERGE_CHUNK = 4096


@dataclasses.dataclass
class BatchUpdate:
    """One Δ_t = {Δ_ins, Δ_del[, Δ_rel]}.

    Advanced/internal type: service callers should prefer the typed
    ``LPService.add_points`` / ``remove_points`` / ``relabel`` entry points
    (embedding-first API) over constructing deltas by hand.
    """

    ins_emb: np.ndarray  # (M, D) float32 — embeddings of inserted vertices
    ins_labels: np.ndarray  # (M,) int8 — ground truth 0/1 or UNLABELED
    del_ids: np.ndarray  # (R,) int64 — global ids to delete
    rel_ids: np.ndarray | None = None  # (S,) int64 — ids to relabel
    rel_labels: np.ndarray | None = None  # (S,) int8 — new labels (or UNLABELED)


@dataclasses.dataclass
class BatchEffect:
    """What the batch touched — inputs to DynLP's update."""

    new_ids: np.ndarray  # global ids assigned to inserted vertices
    affected: np.ndarray  # global ids requiring label updates (V_aff seed)
    gprime_src: np.ndarray  # COO among new vertices, *local* new-vertex ids
    gprime_dst: np.ndarray
    gprime_wgt: np.ndarray


@dataclasses.dataclass
class Selection:
    """A selector's nomination for one batch (global ids everywhere).

    ``cand_idx`` (M, W) int64: per new row, a candidate superset covering
    its canonical top-k (−1 padding; never self, never dead).  ``flagged``
    (A,) int64: alive pre-batch rows whose current k-th weight the batch
    may beat (superset — pruned against each row's k-th similarity plus
    ``selection_slack``); only these rows pay a merge.
    """

    cand_idx: np.ndarray
    flagged: np.ndarray


class HostKNNSelector:
    """Blockwise host staging path (the ``graph.knn`` economics).

    Every batch re-stages the full candidate base on the host: gather the
    alive embeddings, astype, row-normalize, concatenate with the batch,
    then blockwise sgemm + top-(k+margin).  This is the reference selector
    the device ingest path is measured and bit-checked against.
    """

    def __init__(self, block: int = 4096):
        self.block = block

    def on_delete(self, g: "DynamicGraph", del_ids: np.ndarray) -> None:
        pass

    def finalize(self, g: "DynamicGraph", rows: np.ndarray, kth: np.ndarray) -> None:
        pass

    def select(
        self, g: "DynamicGraph", new_ids: np.ndarray, embn_new: np.ndarray
    ) -> Selection:
        base_id = int(new_ids[0])
        old_alive = np.flatnonzero(g.alive[:base_id])
        n_old = len(old_alive)
        # host staging: raw gather + astype + normalize, every batch
        base_raw = np.concatenate([g.emb[old_alive], g.emb[base_id:]])
        base = normalize_rows(base_raw.astype(np.float32))
        base_map = np.concatenate([old_alive, new_ids])
        q = base[n_old:]
        m = len(q)
        slack = selection_slack(g.emb_dim)
        kth = g.kth_weights(old_alive)
        colmax = np.full(n_old, -np.inf, np.float32)
        cands: list[np.ndarray] = []
        for lo in range(0, m, self.block):
            hi = min(lo + self.block, m)
            sim = q[lo:hi] @ base.T  # (blk, n_old + m)
            self_col = n_old + np.arange(lo, hi)
            sim[np.arange(hi - lo), self_col] = -np.inf
            if n_old:
                colmax = np.maximum(colmax, sim[:, :n_old].max(axis=0))
            cand = select_candidates(sim, g.k)
            # map local → global; drop -inf-similarity slots (self / masked)
            cw = np.where(cand >= 0, sim[np.arange(hi - lo)[:, None], cand], -np.inf)
            cand = np.where(np.isfinite(cw), base_map[np.maximum(cand, 0)], -1)
            cands.append(cand)
        cand_idx = _stack_ragged(cands)
        flagged = old_alive[((colmax + 1.0) * 0.5) > kth - slack] if n_old else (
            np.zeros(0, np.int64))
        return Selection(cand_idx=cand_idx, flagged=flagged)


def _stack_ragged(blocks: list[np.ndarray]) -> np.ndarray:
    """Stack (Ri, Wi) candidate blocks, right-padding widths with -1."""
    if not blocks:
        return np.zeros((0, 1), np.int64)
    w = max(b.shape[1] for b in blocks)
    out = []
    for b in blocks:
        if b.shape[1] < w:
            pad = np.full((b.shape[0], w - b.shape[1]), -1, np.int64)
            b = np.concatenate([b, pad], axis=1)
        out.append(b)
    return np.concatenate(out).astype(np.int64)


class DynamicGraph:
    """Evolving undirected weighted similarity graph (incremental kNN)."""

    # (buffer attr, fill value) — grown together on the doubling ladder
    _BUFS = (("_emb_b", 0.0), ("_embn_b", 0.0), ("_labels_b", 0),
             ("_alive_b", False), ("_f_b", 0.0), ("_ki_b", -1),
             ("_kw_b", -np.inf))

    def __init__(self, emb_dim: int, k: int = 5, knn_block: int = 4096):
        self.emb_dim = emb_dim
        self.k = k
        self.knn_block = knn_block
        # per-vertex state lives in capacity-doubling private buffers; the
        # public arrays (emb/embn/labels/alive/f/knn_idx/knn_wgt) are views
        # of the first num_nodes rows, re-sliced on append — so a stream of
        # B-sized batches pays O(B) per append amortized, not O(N) copies
        self._cap = 0
        self._emb_b = np.zeros((0, emb_dim), np.float32)
        self._embn_b = np.zeros((0, emb_dim), np.float32)  # row-normalized
        self._labels_b = np.zeros((0,), np.int8)
        self._alive_b = np.zeros((0,), bool)
        self._f_b = np.zeros((0,), np.float32)  # current fractional labels
        # directed per-row top-k lists, canonical order, holes at the tail
        self._ki_b = np.zeros((0, k), np.int64)
        self._kw_b = np.zeros((0, k), np.float32)
        self._reslice(0)
        # undirected edge arrays (both directions stored), maintained in
        # (src asc, dst asc) order incrementally per batch
        self.src = np.zeros((0,), np.int64)
        self.dst = np.zeros((0,), np.int64)
        self.wgt = np.zeros((0,), np.float32)
        self._host_selector = HostKNNSelector(block=knn_block)

    def _reslice(self, n: int) -> None:
        self.emb = self._emb_b[:n]
        self.embn = self._embn_b[:n]
        self.labels = self._labels_b[:n]
        self.alive = self._alive_b[:n]
        self.f = self._f_b[:n]
        self.knn_idx = self._ki_b[:n]
        self.knn_wgt = self._kw_b[:n]

    def _ensure_capacity(self, n: int) -> None:
        if n <= self._cap:
            return
        cap = max(256, self._cap)
        while cap < n:
            cap *= 2
        old = self.num_nodes
        for name, fill in self._BUFS:
            buf = getattr(self, name)
            grown = np.full((cap,) + buf.shape[1:], fill, buf.dtype)
            grown[:old] = buf[:old]
            setattr(self, name, grown)
        self._cap = cap

    # ------------------------------------------------------------------ #
    @property
    def num_nodes(self) -> int:
        return len(self.labels)

    @property
    def num_alive(self) -> int:
        return int(self.alive.sum())

    @property
    def num_edges(self) -> int:
        """Undirected edge count."""
        return len(self.src) // 2

    def mean_edge_weight(self) -> float:
        return float(self.wgt.mean()) if len(self.wgt) else 0.0

    def kth_weights(self, rows: np.ndarray) -> np.ndarray:
        """Current k-th (weakest kept) weight per row; -inf while a row has
        spare capacity — such rows accept any candidate."""
        if self.k == 0 or not len(rows):
            return np.full(len(rows), -np.inf, np.float32)
        return self.knn_wgt[rows, self.k - 1]

    # ------------------------------------------------------------------ #
    def state_arrays(self) -> dict[str, np.ndarray]:
        """Host COPIES of the full mutable state (per-vertex buffers sliced
        to ``num_nodes`` + the undirected edge arrays) for persistence.

        Copies are load-bearing: the checkpoint writer runs on a worker
        thread while the stream keeps mutating these arrays in place, so
        handing out views would tear the snapshot
        (``core.persistence``/docs/persistence.md).
        """
        return {name: getattr(self, name).copy() for name in
                ("emb", "embn", "labels", "alive", "f", "knn_idx",
                 "knn_wgt", "src", "dst", "wgt")}

    def load_state_arrays(self, arrays: dict[str, np.ndarray]) -> None:
        """Adopt a ``state_arrays`` snapshot (restore path).  Capacity
        regrows on the same doubling ladder, so a restored graph appends
        with identical amortized economics."""
        n = len(arrays["labels"])
        self._ensure_capacity(n)
        for name, attr in (("emb", "_emb_b"), ("embn", "_embn_b"),
                           ("labels", "_labels_b"), ("alive", "_alive_b"),
                           ("f", "_f_b"), ("knn_idx", "_ki_b"),
                           ("knn_wgt", "_kw_b")):
            getattr(self, attr)[:n] = arrays[name]
        self._reslice(n)
        self.src = np.asarray(arrays["src"], np.int64)
        self.dst = np.asarray(arrays["dst"], np.int64)
        self.wgt = np.asarray(arrays["wgt"], np.float32)

    # ------------------------------------------------------------------ #
    def apply_batch(
        self,
        batch: BatchUpdate,
        tau: float | None = None,
        selector=None,
    ) -> BatchEffect:
        """Apply Δ_t; returns the affected set and G' (Alg. 2 Step 1)."""
        sel_impl = selector if selector is not None else self._host_selector
        affected: list[np.ndarray] = []
        changed_lists: list[np.ndarray] = []

        # --- deletions: kill rows, drop every list entry pointing at them ---
        del_ids = np.unique(np.asarray(batch.del_ids, np.int64))
        del_ids = del_ids[(del_ids >= 0) & (del_ids < self.num_nodes)]
        del_ids = del_ids[self.alive[del_ids]]
        if len(del_ids):
            sel_impl.on_delete(self, del_ids)
            out_nbr = self.knn_idx[del_ids]
            affected.append(out_nbr[out_nbr >= 0])  # their undirected edges vanish
            self.alive[del_ids] = False
            self.knn_idx[del_ids] = -1
            self.knn_wgt[del_ids] = -np.inf
            hit = np.isin(self.knn_idx, del_ids)
            hole_rows = np.flatnonzero(hit.any(axis=1))
            if len(hole_rows):
                hw = self.knn_wgt[hole_rows]
                hidx = self.knn_idx[hole_rows]
                hw[hit[hole_rows]] = -np.inf
                hidx[hit[hole_rows]] = -1
                ti, tw = topk_pairs(hw, hidx, self.k)  # compact holes to the tail
                self.knn_idx[hole_rows] = ti
                self.knn_wgt[hole_rows] = tw
                affected.append(hole_rows)
                changed_lists.append(hole_rows)
                # push the weakened thresholds now: this batch's own
                # displacement pruning must see the holes, not the
                # pre-deletion k-th weights
                live = hole_rows[self.alive[hole_rows]]
                sel_impl.finalize(self, live, self.kth_weights(live))

        # --- insertions: append rows, select candidates, merge lists ---
        m = len(batch.ins_emb)
        base_id = self.num_nodes
        new_ids = np.arange(base_id, base_id + m, dtype=np.int64)
        if m:
            ins_emb = np.asarray(batch.ins_emb, np.float32)
            embn_new = normalize_rows(ins_emb)
            ins_labels = np.asarray(batch.ins_labels, np.int8)
            n = base_id + m
            self._ensure_capacity(n)
            self._emb_b[base_id:n] = ins_emb
            self._embn_b[base_id:n] = embn_new
            self._labels_b[base_id:n] = ins_labels
            self._alive_b[base_id:n] = True
            self._f_b[base_id:n] = np.where(
                ins_labels == 1, 1.0, np.where(ins_labels == 0, 0.0, 0.5)
            ).astype(np.float32)
            self._ki_b[base_id:n] = -1
            self._kw_b[base_id:n] = -np.inf
            self._reslice(n)

            sel = sel_impl.select(self, new_ids, embn_new)

            # canonical re-selection for the new rows' lists
            cand = np.asarray(sel.cand_idx, np.int64)
            cw = np.full(cand.shape, -np.inf, np.float32)
            qr, qc = np.nonzero(cand >= 0)
            if len(qr):
                cw[qr, qc] = pair_weights(
                    embn_new[qr], self.embn[cand[qr, qc]])
            ti, tw = topk_pairs(cw, cand, self.k)
            self.knn_idx[new_ids] = ti
            self.knn_wgt[new_ids] = tw
            affected.append(new_ids)
            affected.append(ti[ti >= 0])  # rows gaining an in-edge from the batch
            changed_lists.append(new_ids)

            # displaced merges: flagged rows race the batch against their list
            flagged = np.asarray(sel.flagged, np.int64)
            for lo in range(0, len(flagged), _MERGE_CHUNK):
                rows = flagged[lo:lo + _MERGE_CHUNK]
                bw = pair_weights(self.embn[rows][:, None, :], embn_new[None, :, :])
                merged_w = np.concatenate([self.knn_wgt[rows], bw], axis=1)
                merged_i = np.concatenate(
                    [self.knn_idx[rows],
                     np.broadcast_to(new_ids, (len(rows), m))], axis=1)
                mi, mw = topk_pairs(merged_w, merged_i, self.k)
                changed = (mi != self.knn_idx[rows]).any(axis=1)
                if not changed.any():
                    continue
                crows = rows[changed]
                old_i = self.knn_idx[crows]
                mi, mw = mi[changed], mw[changed]
                # displaced-out ex-neighbors lose an undirected edge
                still = (old_i[:, :, None] == mi[:, None, :]).any(axis=2)
                dropped = old_i[(old_i >= 0) & ~still]
                self.knn_idx[crows] = mi
                self.knn_wgt[crows] = mw
                affected.append(crows)
                affected.append(dropped)
                changed_lists.append(crows)

        # --- refresh the undirected edge arrays from the lists ---
        touched = np.unique(np.concatenate(changed_lists + [del_ids]))
        self._rebuild_edges(touched)

        # --- G': edges among new vertices with w > τ (local ids) ---
        if m:
            tau = self.mean_edge_weight() if tau is None else tau
            ni, nw = self.knn_idx[new_ids], self.knn_wgt[new_ids]
            both_new = (ni >= base_id) & (nw > tau)
            gp_s = np.repeat(np.arange(m, dtype=np.int64), self.k)[both_new.ravel()]
            gp_d = (ni[both_new] - base_id).astype(np.int64)
            gp_w = nw[both_new].astype(np.float32)
        else:
            gp_s = gp_d = np.zeros((0,), np.int64)
            gp_w = np.zeros((0,), np.float32)

        # --- relabels: ground-truth changes on existing vertices ---
        if batch.rel_ids is not None and len(batch.rel_ids):
            rel = np.asarray(batch.rel_ids, np.int64)
            rlab = np.asarray(batch.rel_labels, np.int8)
            ok = (rel >= 0) & (rel < self.num_nodes) & self.alive[rel]
            rel, rlab = rel[ok], rlab[ok]
            if len(rel):
                self.labels[rel] = rlab
                self.f[rel] = np.where(
                    rlab == 1, 1.0, np.where(rlab == 0, 0.0, 0.5)
                ).astype(np.float32)
                out = self.knn_idx[rel]
                in_rows = np.flatnonzero(np.isin(self.knn_idx, rel).any(axis=1))
                affected.append(rel)
                affected.append(out[out >= 0])
                affected.append(in_rows)

        aff = (
            np.unique(np.concatenate(affected)) if affected else np.zeros(0, np.int64)
        )
        aff = aff[self.alive[aff]]
        changed = (
            np.unique(np.concatenate(changed_lists))
            if changed_lists else np.zeros(0, np.int64)
        )
        changed = changed[self.alive[changed]]
        if len(changed):
            sel_impl.finalize(self, changed, self.kth_weights(changed))
        return BatchEffect(
            new_ids=new_ids, affected=aff, gprime_src=gp_s, gprime_dst=gp_d,
            gprime_wgt=gp_w,
        )

    # ------------------------------------------------------------------ #
    def _rebuild_edges(self, touched: np.ndarray | None = None) -> None:
        """Refresh the undirected (both-directions) COO edge arrays.

        The invariant: edges are the unique pairs ``{a, b}`` with ``b ∈
        list(a)`` or ``a ∈ list(b)`` (weights agree bit-for-bit because
        both sides store the same canonical ``pair_weights`` value),
        stored in (src asc, dst asc) order — snapshots come out
        bit-identical to the ``build_knn_graph`` oracle, whose symmetrize
        emits ascending columns per row.

        With ``touched`` (rows whose lists or aliveness this batch
        changed) the refresh is incremental: only T-incident edges are
        recomputed and spliced back into the retained sorted run — one
        O(E) pass plus O(|T|·k) work instead of a global per-batch sort.
        An edge {a, b} with both endpoints untouched cannot change (both
        lists are unchanged), and a surviving in-edge into a touched row
        from an untouched row y must already be present in the old edge
        array (y's list is unchanged), so old T-incident edges plus the
        touched rows' fresh out-lists cover every candidate pair.
        """
        if touched is None or not len(self.src) or (
                2 * len(touched) * max(self.k, 1) >= len(self.src)):
            self._rebuild_edges_full()
            return
        if not len(touched):  # lists unchanged -> edges unchanged
            return
        n = self.num_nodes
        t_mask = np.zeros(n, bool)
        t_mask[touched] = True
        inc = t_mask[self.src] | t_mask[self.dst]
        # surviving in-edges into touched rows from untouched rows: the
        # pair {y, t} persists iff t is still in y's (unchanged) list —
        # verified by membership, weight read from y's list entry
        cin = inc & ~t_mask[self.src]
        ys, ts = self.src[cin], self.dst[cin]
        hit = self.knn_idx[ys] == ts[:, None]
        keep = hit.any(axis=1)
        ys, ts = ys[keep], ts[keep]
        ww = self.knn_wgt[ys, hit.argmax(axis=1)[keep]]
        # fresh out-edges of touched alive rows
        talive = touched[self.alive[touched]]
        li, lw = self.knn_idx[talive], self.knn_wgt[talive]
        rows, cols = np.nonzero(li >= 0)
        a = np.concatenate([ys, talive[rows]])
        b = np.concatenate([ts, li[rows, cols]])
        w = np.concatenate([ww, lw[rows, cols]]).astype(np.float32)
        # dedup to unique undirected pairs (reciprocated lists and the
        # in-edge pass nominate the same pair with the same weight)
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        _, first = np.unique(lo << np.int64(32) | hi, return_index=True)
        lo, hi, w = lo[first], hi[first], w[first]
        new_src = np.concatenate([lo, hi])
        new_dst = np.concatenate([hi, lo])
        new_wgt = np.concatenate([w, w])
        order = np.argsort(new_src << np.int64(32) | new_dst)
        new_src, new_dst, new_wgt = (
            new_src[order], new_dst[order], new_wgt[order])
        # splice into the retained (still sorted) non-incident run
        ret = ~inc
        r_src, r_dst, r_wgt = self.src[ret], self.dst[ret], self.wgt[ret]
        pos = np.searchsorted(
            r_src << np.int64(32) | r_dst, new_src << np.int64(32) | new_dst)
        tgt = pos + np.arange(len(new_src))
        out_mask = np.ones(len(r_src) + len(new_src), bool)
        out_mask[tgt] = False
        for name, retained, fresh in (("src", r_src, new_src),
                                      ("dst", r_dst, new_dst),
                                      ("wgt", r_wgt, new_wgt)):
            out = np.empty(len(out_mask), retained.dtype)
            out[tgt] = fresh
            out[out_mask] = retained
            setattr(self, name, out)

    def _rebuild_edges_full(self) -> None:
        """From-scratch edge regeneration (first batch, or a batch that
        touched a large fraction of all rows).  No global sort of the
        directed entries is needed for dedup — that is an O(N·k²)
        membership test against the k-wide lists — but the final
        canonical order costs one lexsort."""
        valid = self.knn_idx >= 0
        s, col = np.nonzero(valid)
        s = s.astype(np.int64)
        d = self.knn_idx[s, col]
        w = self.knn_wgt[s, col]
        dup = (self.knn_idx[d] == s[:, None]).any(axis=1)
        keep = ~dup | (s < d)
        s, d, w = s[keep], d[keep], w[keep]
        src = np.concatenate([s, d])
        dst = np.concatenate([d, s])
        wgt = np.concatenate([w, w]).astype(np.float32)
        order = np.lexsort((dst, src))
        self.src, self.dst, self.wgt = src[order], dst[order], wgt[order]

    # ------------------------------------------------------------------ #
    def snapshot_csr(self) -> tuple[CSRGraph, np.ndarray]:
        """CSR over alive vertices (compact ids); returns (csr, global_ids)."""
        alive_ids = np.flatnonzero(self.alive)
        remap = np.full(self.num_nodes, -1, np.int64)
        remap[alive_ids] = np.arange(len(alive_ids))
        keep = self.alive[self.src] & self.alive[self.dst]
        csr = coo_to_csr(
            len(alive_ids), remap[self.src[keep]], remap[self.dst[keep]], self.wgt[keep]
        )
        return csr, alive_ids

    def snapshot_ell(self, max_degree: int | None = None) -> tuple[ELLGraph, np.ndarray]:
        csr, alive_ids = self.snapshot_csr()
        return csr_to_ell_fast(csr, max_degree=max_degree), alive_ids

"""Host-side dynamic similarity graph (paper §3.2, §6.3).

The paper keeps the evolving graph in CPU memory (growable 2-D vectors) and
ships per-batch subgraphs to the device.  We mirror that: numpy edge arrays
grow per batch; every batch produces (i) the updated topology, (ii) the
affected-vertex set, and (iii) the new-vertex subgraph G' used for
connected-component label initialization (Alg. 2 Step 1).

Vertices carry an embedding; edges of inserted vertices come from kNN against
the current population (the paper's dataset construction: cosine similarity +
kNN sparsification, §7.1).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .knn import knn_edges, normalize_rows
from .structures import CSRGraph, ELLGraph, coo_to_csr, csr_to_ell_fast

UNLABELED = -1


@dataclasses.dataclass
class BatchUpdate:
    """One Δ_t = {Δ_ins, Δ_del}."""

    ins_emb: np.ndarray  # (M, D) float32 — embeddings of inserted vertices
    ins_labels: np.ndarray  # (M,) int8 — ground truth 0/1 or UNLABELED
    del_ids: np.ndarray  # (R,) int64 — global ids to delete


@dataclasses.dataclass
class BatchEffect:
    """What the batch touched — inputs to DynLP's update."""

    new_ids: np.ndarray  # global ids assigned to inserted vertices
    affected: np.ndarray  # global ids requiring label updates (V_aff seed)
    gprime_src: np.ndarray  # COO among new vertices, *local* new-vertex ids
    gprime_dst: np.ndarray
    gprime_wgt: np.ndarray


class DynamicGraph:
    """Evolving undirected weighted similarity graph."""

    def __init__(self, emb_dim: int, k: int = 5, knn_block: int = 4096):
        self.emb_dim = emb_dim
        self.k = k
        self.knn_block = knn_block
        self.emb = np.zeros((0, emb_dim), np.float32)
        self.labels = np.zeros((0,), np.int8)
        self.alive = np.zeros((0,), bool)
        self.f = np.zeros((0,), np.float32)  # current fractional labels
        # directed edge arrays (both directions stored)
        self.src = np.zeros((0,), np.int64)
        self.dst = np.zeros((0,), np.int64)
        self.wgt = np.zeros((0,), np.float32)

    # ------------------------------------------------------------------ #
    @property
    def num_nodes(self) -> int:
        return len(self.labels)

    @property
    def num_alive(self) -> int:
        return int(self.alive.sum())

    @property
    def num_edges(self) -> int:
        """Undirected edge count."""
        return len(self.src) // 2

    def mean_edge_weight(self) -> float:
        return float(self.wgt.mean()) if len(self.wgt) else 0.0

    # ------------------------------------------------------------------ #
    def apply_batch(self, batch: BatchUpdate, tau: float | None = None) -> BatchEffect:
        """Apply Δ_t; returns the affected set and G' (Alg. 2 Step 1)."""
        affected: list[np.ndarray] = []

        # --- deletions: mark dead, drop incident edges, flag neighbors ---
        del_ids = np.unique(np.asarray(batch.del_ids, np.int64))
        del_ids = del_ids[(del_ids >= 0) & (del_ids < self.num_nodes)]
        del_ids = del_ids[self.alive[del_ids]]
        if len(del_ids):
            dead = np.zeros(self.num_nodes, bool)
            dead[del_ids] = True
            incident = dead[self.src] | dead[self.dst]
            affected.append(self.dst[incident & dead[self.src]])  # nbrs of deleted
            self.src, self.dst, self.wgt = (
                self.src[~incident],
                self.dst[~incident],
                self.wgt[~incident],
            )
            self.alive[del_ids] = False

        # --- insertions: assign ids, kNN edges against current population ---
        m = len(batch.ins_emb)
        base_id = self.num_nodes
        new_ids = np.arange(base_id, base_id + m, dtype=np.int64)
        if m:
            ins_emb = np.asarray(batch.ins_emb, np.float32)
            self.emb = np.concatenate([self.emb, ins_emb])
            self.labels = np.concatenate(
                [self.labels, np.asarray(batch.ins_labels, np.int8)]
            )
            self.alive = np.concatenate([self.alive, np.ones(m, bool)])
            init_f = np.where(
                batch.ins_labels == 1, 1.0, np.where(batch.ins_labels == 0, 0.0, 0.5)
            ).astype(np.float32)
            self.f = np.concatenate([self.f, init_f])

            # candidate base = alive old vertices + the new batch itself
            old_alive = np.flatnonzero(self.alive[:base_id])
            if len(old_alive):
                base = np.concatenate([self.emb[old_alive], ins_emb])
                base_map = np.concatenate([old_alive, new_ids])
            else:
                base = ins_emb
                base_map = new_ids
            s, d, w = knn_edges(
                ins_emb, k=self.k, block=self.knn_block, base=base,
                base_offset=0, self_offset=len(base) - m,
            )
            # map local base indices to global ids; s is an index into the
            # query block offset by (len(base)-m) so it already matches base_map
            gs, gd = base_map[s], base_map[d]
            # dedupe + symmetrize against the *batch's* new edges only
            und_src = np.concatenate([gs, gd])
            und_dst = np.concatenate([gd, gs])
            und_w = np.concatenate([w, w])
            key = und_src * np.int64(self.num_nodes) + und_dst
            _, first = np.unique(key, return_index=True)
            und_src, und_dst, und_w = und_src[first], und_dst[first], und_w[first]
            self.src = np.concatenate([self.src, und_src])
            self.dst = np.concatenate([self.dst, und_dst])
            self.wgt = np.concatenate([self.wgt, und_w])
            affected.append(new_ids)
            affected.append(und_dst)  # neighbors of inserted

            # --- G': edges among new vertices with w > τ (local ids) ---
            tau = self.mean_edge_weight() if tau is None else tau
            both_new = (gs >= base_id) & (gd >= base_id) & (w > tau)
            gp_s = (gs[both_new] - base_id).astype(np.int64)
            gp_d = (gd[both_new] - base_id).astype(np.int64)
            gp_w = w[both_new]
        else:
            gp_s = gp_d = np.zeros((0,), np.int64)
            gp_w = np.zeros((0,), np.float32)

        aff = (
            np.unique(np.concatenate(affected)) if affected else np.zeros(0, np.int64)
        )
        aff = aff[self.alive[aff]]
        return BatchEffect(
            new_ids=new_ids, affected=aff, gprime_src=gp_s, gprime_dst=gp_d,
            gprime_wgt=gp_w,
        )

    # ------------------------------------------------------------------ #
    def snapshot_csr(self) -> tuple[CSRGraph, np.ndarray]:
        """CSR over alive vertices (compact ids); returns (csr, global_ids)."""
        alive_ids = np.flatnonzero(self.alive)
        remap = np.full(self.num_nodes, -1, np.int64)
        remap[alive_ids] = np.arange(len(alive_ids))
        keep = self.alive[self.src] & self.alive[self.dst]
        csr = coo_to_csr(
            len(alive_ids), remap[self.src[keep]], remap[self.dst[keep]], self.wgt[keep]
        )
        return csr, alive_ids

    def snapshot_ell(self, max_degree: int | None = None) -> tuple[ELLGraph, np.ndarray]:
        csr, alive_ids = self.snapshot_csr()
        return csr_to_ell_fast(csr, max_degree=max_degree), alive_ids

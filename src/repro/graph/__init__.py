from repro.graph.dynamic import UNLABELED, BatchUpdate, BatchEffect, DynamicGraph
from repro.graph.knn import build_knn_graph, knn_edges, symmetrize
from repro.graph.structures import (
    PAD,
    CSRGraph,
    ELLGraph,
    coo_to_csr,
    csr_to_ell,
    csr_to_ell_fast,
)

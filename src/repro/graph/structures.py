"""Graph containers.

Host side we keep a dynamic CSR-like structure (numpy, growable) mirroring the
paper's CPU-resident 2-D vector graph (§6.3).  Device side we use ELL
(padded neighbor lists): kNN similarity graphs have bounded degree, so padding
to ``max_degree`` turns every irregular CSR loop of the paper into dense
``(N, K)`` tensor ops — the central TPU adaptation (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = -1  # ELL padding sentinel for absent neighbor slots.


class ELLGraph(NamedTuple):
    """Device-resident padded-neighbor-list graph (a JAX pytree).

    Attributes:
      nbr:  (N, K) int32 neighbor ids, ``PAD`` marks empty slots.
      wgt:  (N, K) float32 edge weights, 0 in empty slots.
    """

    nbr: jax.Array
    wgt: jax.Array

    @property
    def num_nodes(self) -> int:
        return self.nbr.shape[0]

    @property
    def max_degree(self) -> int:
        return self.nbr.shape[1]

    @property
    def slot_mask(self) -> jax.Array:
        return self.nbr != PAD

    def degrees(self) -> jax.Array:
        return jnp.sum(self.slot_mask, axis=1)


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR snapshot (numpy)."""

    rowptr: np.ndarray  # (N+1,) int64
    col: np.ndarray  # (E,) int32
    wgt: np.ndarray  # (E,) float32

    @property
    def num_nodes(self) -> int:
        return len(self.rowptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.col)

    def neighbors(self, u: int) -> tuple[np.ndarray, np.ndarray]:
        lo, hi = self.rowptr[u], self.rowptr[u + 1]
        return self.col[lo:hi], self.wgt[lo:hi]


def coo_to_csr(
    num_nodes: int, src: np.ndarray, dst: np.ndarray, wgt: np.ndarray
) -> CSRGraph:
    """Build CSR from (possibly unsorted) COO edge list."""
    order = np.argsort(src, kind="stable")
    src, dst, wgt = src[order], dst[order], wgt[order]
    counts = np.bincount(src, minlength=num_nodes)
    rowptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=rowptr[1:])
    return CSRGraph(rowptr=rowptr, col=dst.astype(np.int32), wgt=wgt.astype(np.float32))


def csr_to_ell(csr: CSRGraph, max_degree: int | None = None) -> ELLGraph:
    """Pad CSR rows to a fixed K.  Rows longer than K keep the K *heaviest*
    edges (kNN graphs rarely exceed 2k after symmetrization; truncation is
    logged by the caller if it happens)."""
    n = csr.num_nodes
    deg = np.diff(csr.rowptr)
    k = int(max_degree or (deg.max() if n else 1) or 1)
    nbr = np.full((n, k), PAD, dtype=np.int32)
    wgt = np.zeros((n, k), dtype=np.float32)
    for u in range(n):
        lo, hi = csr.rowptr[u], csr.rowptr[u + 1]
        cols, ws = csr.col[lo:hi], csr.wgt[lo:hi]
        if len(cols) > k:  # keep heaviest
            top = np.argsort(-ws)[:k]
            cols, ws = cols[top], ws[top]
        nbr[u, : len(cols)] = cols
        wgt[u, : len(cols)] = ws
    return ELLGraph(nbr=jnp.asarray(nbr), wgt=jnp.asarray(wgt))


def csr_to_ell_fast(csr: CSRGraph, max_degree: int | None = None) -> ELLGraph:
    """Vectorized csr_to_ell (no per-row Python loop); used for large graphs.

    Rows longer than K are truncated keeping the heaviest edges.
    """
    n = csr.num_nodes
    deg = np.diff(csr.rowptr).astype(np.int64)
    k = int(max_degree or (deg.max() if n else 1) or 1)
    # slot index of each edge within its row
    edge_row = np.repeat(np.arange(n, dtype=np.int64), deg)
    slot = np.arange(csr.num_edges, dtype=np.int64) - np.repeat(csr.rowptr[:-1], deg)
    if deg.max(initial=0) > k:
        # sort edges within each row by descending weight, then take first k
        order = np.lexsort((-csr.wgt, edge_row))
        edge_row = edge_row[order]
        col_s, wgt_s = csr.col[order], csr.wgt[order]
        slot = np.arange(csr.num_edges, dtype=np.int64) - np.repeat(
            csr.rowptr[:-1], deg
        )
        keep = slot < k
        edge_row, slot, col_s, wgt_s = edge_row[keep], slot[keep], col_s[keep], wgt_s[keep]
    else:
        col_s, wgt_s = csr.col, csr.wgt
    nbr = np.full((n, k), PAD, dtype=np.int32)
    wgt = np.zeros((n, k), dtype=np.float32)
    nbr[edge_row, slot] = col_s
    wgt[edge_row, slot] = wgt_s
    return ELLGraph(nbr=jnp.asarray(nbr), wgt=jnp.asarray(wgt))


def ell_to_host(g: ELLGraph) -> tuple[np.ndarray, np.ndarray]:
    return np.asarray(g.nbr), np.asarray(g.wgt)

"""Similarity-graph construction (paper §7.1 datasets pipeline).

Non-graph data is modeled as a graph: embeddings → pairwise cosine
similarity → kNN sparsification (k=5 default, following [19] as the paper
does).  We compute blockwise top-k so construction is O(N²/B) memory and runs
for hundreds of thousands of points on the host.
"""

from __future__ import annotations

import numpy as np

from .structures import CSRGraph, coo_to_csr


def normalize_rows(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def knn_edges(
    emb: np.ndarray,
    k: int = 5,
    block: int = 4096,
    base: np.ndarray | None = None,
    base_offset: int = 0,
    self_offset: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Top-k cosine neighbors of ``emb`` within ``base`` (defaults to emb).

    Returns COO (src, dst, wgt) with global ids ``src+self_offset`` /
    ``dst+base_offset``.  Self matches are excluded when the id spaces
    overlap.  Similarities are shifted into [0, 1]: w = (cos + 1) / 2.
    """
    q = normalize_rows(emb.astype(np.float32))
    b = q if base is None else normalize_rows(base.astype(np.float32))
    n = len(q)
    srcs, dsts, ws = [], [], []
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        sim = q[lo:hi] @ b.T  # (blk, M)
        # mask self-similarity where id spaces overlap
        for i in range(lo, hi):
            gi = i + self_offset
            j = gi - base_offset
            if 0 <= j < sim.shape[1]:
                sim[i - lo, j] = -np.inf
        kk = min(k, sim.shape[1] - 1) if sim.shape[1] > 1 else 1
        idx = np.argpartition(-sim, kth=kk - 1, axis=1)[:, :kk]
        rows = np.arange(lo, hi)[:, None]
        vals = sim[rows - lo, idx]
        valid = np.isfinite(vals)
        srcs.append((rows + self_offset).repeat(kk, axis=1)[valid])
        dsts.append((idx + base_offset)[valid])
        ws.append(((vals + 1.0) * 0.5)[valid])
    if not srcs:
        z = np.zeros(0)
        return z.astype(np.int64), z.astype(np.int64), z.astype(np.float32)
    return (
        np.concatenate(srcs).astype(np.int64),
        np.concatenate(dsts).astype(np.int64),
        np.concatenate(ws).astype(np.float32),
    )


def symmetrize(
    num_nodes: int, src: np.ndarray, dst: np.ndarray, wgt: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Union of directed kNN edges; duplicate (u,v) keeps the max weight."""
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    w = np.concatenate([wgt, wgt])
    key = u * np.int64(num_nodes) + v
    order = np.argsort(key, kind="stable")
    key, u, v, w = key[order], u[order], v[order], w[order]
    first = np.ones(len(key), dtype=bool)
    first[1:] = key[1:] != key[:-1]
    # max weight within duplicate group
    grp = np.cumsum(first) - 1
    wmax = np.zeros(grp[-1] + 1 if len(grp) else 0, dtype=np.float32)
    np.maximum.at(wmax, grp, w)
    return u[first], v[first], wmax


def build_knn_graph(emb: np.ndarray, k: int = 5, block: int = 4096) -> CSRGraph:
    src, dst, wgt = knn_edges(emb, k=k, block=block)
    s, d, w = symmetrize(len(emb), src, dst, wgt)
    return coo_to_csr(len(emb), s, d, w)

"""Similarity-graph construction (paper §7.1 datasets pipeline).

Non-graph data is modeled as a graph: embeddings → pairwise cosine
similarity → kNN sparsification (k=5 default, following [19] as the paper
does).  We compute blockwise top-k so construction is O(N²/B) memory and runs
for hundreds of thousands of points on the host.

Bit-equality contract (shared with ``ingest/``): every path — this host
oracle, the host staging selector in ``graph.dynamic``, and the device
argkmin kernel in ``kernels.argkmin`` — splits neighbor search into

  1. *candidate selection*: any fast similarity (BLAS sgemm here, an XLA or
     Pallas matmul on device) ranks a superset of ``k + SELECT_MARGIN``
     candidates per query; boundary ties keep the lowest index, and a
     ``selection_slack`` tolerance keeps near-ties in the superset; then
  2. *canonical re-selection*: ``pair_weights`` recomputes the weight of
     every surviving (query, candidate) pair with one fixed summation order,
     and the final top-k is taken under the total order (weight desc,
     index asc).

Step 2 is the only place weights that reach the graph are produced, so two
paths agree bit-for-bit whenever their candidate supersets both cover the
canonical top-k — which step 1's margin + slack guarantees for anything
short of an adversarial >MARGIN-deep rank inversion.
"""

from __future__ import annotations

import numpy as np

from .structures import CSRGraph, coo_to_csr

# Candidate supersets carry this many extra entries beyond k; canonical
# re-selection prunes them.  8 absorbs any realistic fast-path/canonical
# rank divergence (observed divergences are 1-2 deep).
SELECT_MARGIN = 8


def selection_slack(dim: int) -> float:
    """Similarity tolerance for candidate pruning tests (e.g. "does this
    batch displace row i's k-th neighbor?").  Scales with the summation
    length so float32 accumulation drift can never hide a true candidate."""
    return 1e-5 + 1e-7 * dim


def normalize_rows(x: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(x, axis=1, keepdims=True)
    return x / np.maximum(n, 1e-12)


def pair_weights(qn: np.ndarray, bn: np.ndarray) -> np.ndarray:
    """Canonical cosine weight for (query, base) pairs — THE edge weight.

    ``qn`` / ``bn`` are row-normalized float32 and broadcast against each
    other; the product is materialized C-contiguous and reduced over the
    last axis, so the summation order depends only on ``D`` — every caller
    (host oracle, staging selector, device merge) gets bit-identical
    weights for the same pair.  Weights are shifted into [0, 1]:
    w = (cos + 1) / 2.
    """
    prod = np.multiply(qn, bn, dtype=np.float32)
    cos = prod.sum(axis=-1, dtype=np.float32)
    return ((cos + np.float32(1.0)) * np.float32(0.5)).astype(np.float32, copy=False)


def topk_pairs(
    wgt: np.ndarray, idx: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-row top-k under the canonical order (weight desc, index asc).

    ``wgt`` (R, C) float32 with ``-inf`` marking invalid slots, ``idx``
    (R, C) int64 candidate ids.  Returns (idx, wgt) of shape (R, k), rows
    sorted by the canonical order, invalid tail padded with (-1, -inf).
    """
    r, c = wgt.shape
    kc = min(k, c)
    order = np.lexsort((idx, -wgt), axis=-1)[:, :kc]
    rows = np.arange(r)[:, None]
    top_w = wgt[rows, order]
    top_i = np.where(np.isfinite(top_w), idx[rows, order], -1)
    if kc < k:
        top_i = np.concatenate([top_i, np.full((r, k - kc), -1, top_i.dtype)], axis=1)
        top_w = np.concatenate(
            [top_w, np.full((r, k - kc), -np.inf, np.float32)], axis=1)
    return top_i, top_w


def candidate_mask_to_pairs(
    mask: np.ndarray, wgt_fill: float = -np.inf
) -> tuple[np.ndarray, np.ndarray]:
    """Rectangularize a ragged per-row candidate mask.

    ``mask`` (R, C) bool → (cand_idx (R, W) int64 with -1 padding, valid
    (R, W) bool) where W = max row population.  Row-major order preserves
    ascending column ids per row.
    """
    counts = mask.sum(axis=1)
    w = int(counts.max()) if len(counts) else 0
    r, c = np.nonzero(mask)
    pos = np.arange(len(c)) - np.repeat(np.cumsum(counts) - counts, counts)
    cand = np.full((mask.shape[0], max(w, 1)), -1, np.int64)
    cand[r, pos] = c
    return cand, cand >= 0


def select_candidates(sim: np.ndarray, k: int) -> np.ndarray:
    """Candidate superset per query row from a fast similarity block.

    Takes every column whose similarity reaches the (k + SELECT_MARGIN)-th
    largest value — *including all boundary ties*, so mass-duplicate inputs
    can never evict a canonically-preferred (lower-index) candidate from
    the superset.  Returns (R, W) int64 column ids, -1 padded.
    """
    r, m = sim.shape
    t = min(k + SELECT_MARGIN, m)
    if t >= m:
        thr = np.full(r, -np.inf, np.float32)
    else:
        part = np.argpartition(-sim, t - 1, axis=1)[:, :t]
        thr = sim[np.arange(r)[:, None], part].min(axis=1)
    cand, _ = candidate_mask_to_pairs(sim >= thr[:, None])
    return cand


def knn_edges(
    emb: np.ndarray,
    k: int = 5,
    block: int = 4096,
    base: np.ndarray | None = None,
    base_offset: int = 0,
    self_offset: int = 0,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Top-k cosine neighbors of ``emb`` within ``base`` (defaults to emb).

    Returns COO (src, dst, wgt) with global ids ``src+self_offset`` /
    ``dst+base_offset``.  Self matches are excluded when the id spaces
    overlap.  Weights are canonical ``pair_weights`` values; per-row order
    is the canonical (weight desc, index asc) total order.
    """
    q = normalize_rows(emb.astype(np.float32))
    b = q if base is None else normalize_rows(base.astype(np.float32))
    n, mb = len(q), len(b)
    srcs, dsts, ws = [], [], []
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        sim = q[lo:hi] @ b.T  # (blk, Mb)
        # mask self-similarity where id spaces overlap (vectorized: the
        # self column of query row i is i + self_offset - base_offset)
        self_col = np.arange(lo, hi) + (self_offset - base_offset)
        inside = (self_col >= 0) & (self_col < mb)
        sim[np.flatnonzero(inside), self_col[inside]] = -np.inf
        kk = min(k, mb - 1) if mb > 1 else 1
        cand = select_candidates(sim, kk)
        # canonical re-selection on the superset
        cw = np.full(cand.shape, -np.inf, np.float32)
        valid = cand >= 0
        qr, qc = np.nonzero(valid)
        cw[qr, qc] = pair_weights(q[lo + qr], b[cand[qr, qc]])
        # re-apply the self mask in canonical space
        if inside.any():
            self_hit = cand == self_col[:, None]
            cw[self_hit & valid] = -np.inf
        top_i, top_w = topk_pairs(cw, cand, kk)
        keep = np.isfinite(top_w)
        rows = np.broadcast_to(np.arange(lo, hi)[:, None], top_i.shape)
        srcs.append((rows + self_offset)[keep].astype(np.int64))
        dsts.append((top_i + base_offset)[keep])
        ws.append(top_w[keep])
    if not srcs:
        z = np.zeros(0)
        return z.astype(np.int64), z.astype(np.int64), z.astype(np.float32)
    return (
        np.concatenate(srcs).astype(np.int64),
        np.concatenate(dsts).astype(np.int64),
        np.concatenate(ws).astype(np.float32),
    )


def symmetrize(
    num_nodes: int, src: np.ndarray, dst: np.ndarray, wgt: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Union of directed kNN edges; duplicate (u,v) keeps the max weight."""
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    w = np.concatenate([wgt, wgt])
    key = u * np.int64(num_nodes) + v
    order = np.argsort(key, kind="stable")
    key, u, v, w = key[order], u[order], v[order], w[order]
    first = np.ones(len(key), dtype=bool)
    first[1:] = key[1:] != key[:-1]
    # max weight within duplicate group
    grp = np.cumsum(first) - 1
    wmax = np.zeros(grp[-1] + 1 if len(grp) else 0, dtype=np.float32)
    np.maximum.at(wmax, grp, w)
    return u[first], v[first], wmax


def build_knn_graph(emb: np.ndarray, k: int = 5, block: int = 4096) -> CSRGraph:
    src, dst, wgt = knn_edges(emb, k=k, block=block)
    s, d, w = symmetrize(len(emb), src, dst, wgt)
    return coo_to_csr(len(emb), s, d, w)

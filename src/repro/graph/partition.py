"""Graph partitioning for distributed LP: contiguous row shards with
export-prefix reordering (the halo-exchange layout).

Shard s owns rows [s·m, (s+1)·m).  A row is EXPORTED if any other shard
references it.  Rows are permuted so each shard's exports form a prefix;
then one all-gather of the (padded) export prefixes replaces the full-vector
all-gather — the §Perf iteration on the collective term of the LP roofline
(the paper's CC-clustered ordering gives exactly the locality this exploits).

Plans are built per call from a concrete ELL topology.  The streaming
engine (``core.stream.StreamEngine(transport="halo")``) rebuilds the
layout per Δ_t (an O(U·K) host pass, same order as the snapshot build it
rides along with) but compiles only one halo runner per bucket-ladder
rung, sized by ``export_budget`` so in-rung topology drift doesn't force
a recompile.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HaloPlan:
    nbr: np.ndarray  # (N_pad, K) int32 — remapped neighbor ids
    perm: np.ndarray  # (N_pad,) new_id -> old_id (identity on padding)
    inv_perm: np.ndarray  # old_id -> new_id
    n_shards: int
    rows_per_shard: int
    export_max: int  # padded export-prefix length per shard
    export_counts: np.ndarray  # (n_shards,)


def build_halo_plan(nbr: np.ndarray, n_shards: int) -> HaloPlan:
    """Reorder rows so cross-shard-referenced rows lead each shard."""
    n = len(nbr)
    pad = (-n) % n_shards
    n_pad = n + pad
    m = n_pad // n_shards
    if pad:
        nbr = np.concatenate([nbr, np.full((pad, nbr.shape[1]), -1, np.int32)])

    owner = np.arange(n_pad) // m
    valid = nbr >= 0
    src_owner = np.repeat(owner[:, None], nbr.shape[1], axis=1)
    tgt = np.where(valid, nbr, 0)
    cross = valid & (owner[tgt] != src_owner)
    exported = np.zeros(n_pad, bool)
    exported[np.unique(tgt[cross])] = True

    # permutation: within each shard, exported rows first (stable sort on
    # (shard, not-exported) keeps the original order inside both groups —
    # the vectorized twin of a per-shard partition loop, run per Δ_t by
    # the streaming halo transport so it must stay O(n log n))
    perm = np.argsort(owner * 2 + (~exported), kind="stable")  # new -> old
    counts = np.bincount(owner[exported], minlength=n_shards).astype(np.int64)
    inv = np.empty(n_pad, np.int64)
    inv[perm] = np.arange(n_pad)

    remapped = np.where(nbr[perm] >= 0, inv[np.where(nbr[perm] >= 0, nbr[perm], 0)], -1)
    e_max = int(max(1, counts.max()))
    # round up for alignment
    e_max = -8 * (-e_max // 8)
    return HaloPlan(nbr=remapped.astype(np.int32), perm=perm, inv_perm=inv,
                    n_shards=n_shards, rows_per_shard=m, export_max=min(e_max, m),
                    export_counts=counts)


def apply_plan(plan: HaloPlan, arr: np.ndarray, fill=0) -> np.ndarray:
    """Reorder a per-row array into the plan's layout (padding with fill)."""
    n_pad = len(plan.perm)
    out_shape = (n_pad,) + arr.shape[1:]
    out = np.full(out_shape, fill, arr.dtype)
    valid = plan.perm < len(arr)
    out[valid] = arr[plan.perm[valid]]
    return out


def unapply_plan(plan: HaloPlan, arr: np.ndarray, n_orig: int) -> np.ndarray:
    """Inverse reordering back to original row ids."""
    return arr[plan.inv_perm[:n_orig]]


def export_budget(plan: HaloPlan, n_valid: int, headroom: float = 3.0) -> int:
    """Per-shard export-prefix length a ladder rung should COMPILE for.

    The streaming halo transport fixes one ``export_max`` per bucket rung
    and reuses the compiled runner for every batch in that rung, so the
    budget must absorb in-rung growth: the observed max export count is
    scaled by the rung's remaining fill factor (a rung entered at
    ``n_valid`` rows can grow to its full padded row count, and export
    sets grow roughly with it) times ``headroom`` for topology drift —
    sized for the incremental kNN graph, where displacement merges churn
    existing rows' neighbor lists (and so cross-shard edges) in place,
    not just append new ones —
    then rounded up for lane alignment and capped at the shard size.  A
    batch that still exceeds it falls back to all-gather for that Δ_t
    (logged by the engine), so the budget is a perf knob, never a
    correctness one.
    """
    n_pad = len(plan.perm)
    fill = n_pad / max(1, n_valid)
    want = int(np.ceil(max(1, int(plan.export_counts.max())) * fill * headroom))
    want = -8 * (-want // 8)  # lane-align like build_halo_plan
    return int(min(want, plan.rows_per_shard))

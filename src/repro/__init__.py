"""DynLP reproduction: parallel dynamic batch update for label propagation."""

__version__ = "0.1.0"

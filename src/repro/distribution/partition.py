"""Logical-axis partitioning.

Models annotate activations with *logical* axes ("dp", "sp", "tp", "ep",
None); the launcher installs a rule set mapping logical → mesh axes before
tracing.  With no rules installed (unit tests, single device) every
annotation is a no-op, so the same model code runs everywhere.

Parameter shardings are derived from leaf *names* + shapes by
``param_specs`` — a rule table in the spirit of MaxText's logical axis rules,
but resolved at pytree level so the optimizer/checkpoint layers can reuse the
spec tree directly.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_RULES: dict[str, Any] | None = None


def set_axis_rules(rules: dict[str, Any] | None) -> None:
    """rules e.g. {"dp": ("pod", "data"), "tp": "model", "sp": "model",
    "ep": "model"}.  None disables all constraints."""
    global _RULES
    _RULES = rules


def get_axis_rules() -> dict[str, Any] | None:
    return _RULES


def logical_to_spec(*logical: str | None) -> P:
    assert _RULES is not None
    return P(*[_RULES.get(a) if a is not None else None for a in logical])


_MESH_SIZES: dict[str, int] | None = None


def set_mesh_sizes(sizes: dict[str, int] | None) -> None:
    """Axis sizes for divisibility-aware constraint resolution."""
    global _MESH_SIZES
    _MESH_SIZES = sizes


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate an intermediate with logical axes (no-op without rules).

    Divisibility-aware when mesh sizes are installed: a non-dividing "tp"
    shifts right to the next free dividing dim (e.g. 8 kv-heads under
    16-way TP falls through to the 128-wide head dim); other non-dividing
    axes drop to replication."""
    if _RULES is None:
        return x
    if _MESH_SIZES is None:
        return jax.lax.with_sharding_constraint(x, logical_to_spec(*logical))
    resolved: list = [None] * x.ndim
    for i, ax in enumerate(logical):
        if ax is None:
            continue
        mesh_ax = _RULES.get(ax)
        size = _axis_size(mesh_ax, _MESH_SIZES)
        if size and x.shape[i] % size == 0:
            resolved[i] = mesh_ax
        elif ax == "tp" and size:
            for j in range(i + 1, x.ndim):
                if (j >= len(logical) or logical[j] is None) and \
                        resolved[j] is None and x.shape[j] % size == 0:
                    resolved[j] = mesh_ax
                    break
    return jax.lax.with_sharding_constraint(x, P(*resolved))


# --------------------------------------------------------------------- #
# Parameter partitioning rules
# --------------------------------------------------------------------- #
# (regex on the leaf path, rule) — first match wins.  The rule is a tuple of
# logical axes for the *trailing* dims of the leaf; leading stacked-layer
# dims are padded with None automatically.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", (None, "tp")),  # (V, D): shard D
    (r"lm_head$", (None, "tp")),  # (D, V): shard V
    (r"pos_embed$", (None, None)),
    (r"frontend_proj$", (None, "tp")),
    (r"router$", (None, None)),
    # MoE expert banks (E, D, F) / (E, F, D): expert-parallel over tp
    (r"moe/w[123]$", ("ep", None, None)),
    # attention
    (r"w[qkv]$", (None, "tp")),
    (r"wo$", ("tp", None)),
    # dense mlp
    (r"mlp/w[13]$", (None, "tp")),
    (r"mlp/w2$", ("tp", None)),
    (r"w_ff1$", (None, "tp")),
    (r"w_ff2$", ("tp", None)),
    # mamba / mlstm projections
    (r"w[xz]$", (None, "tp")),
    (r"w[xz]_up$", (None, "tp")),
    (r"wbc$", (None, None)),
    (r"wdt$", (None, None)),
    (r"out_proj$", ("tp", None)),
    (r"down_proj$", ("tp", None)),
    (r"conv_x$", (None, "tp")),
    (r"conv_x_b$", ("tp",)),
    (r"conv_w$", (None, "tp")),
    (r"conv_b$", ("tp",)),
    # sLSTM recurrent (H, hd, 4hd): shard heads
    (r"/r$", ("tp", None, None)),
    (r"w_in$", (None, "tp")),
    # everything else (norm scales, biases, gates, a_log, ...): replicate
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for_leaf(path: str, ndim: int, shape, mesh_axis_sizes) -> P:
    for pat, rule in _PARAM_RULES:
        if re.search(pat, path):
            trailing = list(rule)
            lead = [None] * (ndim - len(trailing))
            axes = lead + trailing
            # drop shardings that do not divide the dim evenly
            resolved = []
            for dim, ax in zip(shape, axes):
                if ax is None:
                    resolved.append(None)
                    continue
                mesh_ax = _RULES.get(ax) if _RULES else None
                size = _axis_size(mesh_ax, mesh_axis_sizes)
                resolved.append(mesh_ax if size and dim % size == 0 else None)
            return P(*resolved)
    return P(*([None] * ndim))


def _axis_size(mesh_ax, sizes) -> int:
    if mesh_ax is None or sizes is None:
        return 0
    if isinstance(mesh_ax, tuple):
        n = 1
        for a in mesh_ax:
            n *= sizes[a]
        return n
    return sizes[mesh_ax]


def resolve_spec(shape, logical, mesh) -> P:
    """Resolve logical axes against concrete dims: a sharding that does not
    divide its dim evenly is shifted right ("tp" only) or dropped.  Used for
    KV-cache / state trees where the natural shard target (kv-heads) may be
    smaller than the tensor-parallel degree."""
    assert _RULES is not None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = [None] * len(shape)
    for i, ax in enumerate(logical):
        if ax is None:
            continue
        mesh_ax = _RULES.get(ax)
        size = _axis_size(mesh_ax, sizes)
        if size and shape[i] % size == 0:
            resolved[i] = mesh_ax
        elif ax == "tp" and size:
            for j in range(i + 1, len(shape)):
                if logical[j] is None and resolved[j] is None and shape[j] % size == 0:
                    resolved[j] = mesh_ax
                    break
    return P(*resolved)


class Axes:
    """Leaf wrapper for logical-axis tuples (tuples are pytree nodes)."""

    def __init__(self, *axes):
        self.axes = axes

    def __repr__(self):
        return f"Axes{self.axes}"


def resolve_spec_tree(shapes_tree, logical_tree, mesh):
    """Map ``resolve_spec`` over matching (shape, logical) trees; the logical
    tree mirrors the shapes tree with ``Axes(...)`` leaves."""
    s_flat, treedef = jax.tree.flatten(shapes_tree)
    l_flat = jax.tree.flatten(
        logical_tree, is_leaf=lambda x: isinstance(x, Axes))[0]
    assert len(s_flat) == len(l_flat), (len(s_flat), len(l_flat))
    specs = [resolve_spec(s.shape, l.axes, mesh) for s, l in zip(s_flat, l_flat)]
    return jax.tree.unflatten(treedef, specs)


def zero_specs(pspecs_tree, params_tree, mesh):
    """ZeRO-style specs: extend each param spec by sharding the first
    unsharded, divisible dim over the data axes.  Used for optimizer state
    (ZeRO-1) and gradient reduce-scatter (ZeRO-2): a 67B model's fp32
    master+m+v would otherwise replicate 12 B/param across the data axis."""
    assert _RULES is not None
    dp_ax = _RULES.get("dp")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = _axis_size(dp_ax, sizes)

    def leaf(spec, arr):
        shape = arr.shape
        parts = list(spec) + [None] * (len(shape) - len(spec))
        if dp_size <= 1:
            return P(*parts)
        dp_entry = dp_ax if isinstance(dp_ax, str) else tuple(dp_ax)
        dp_names = {dp_ax} if isinstance(dp_ax, str) else set(dp_ax)

        def axes_of(p):
            if p is None:
                return set()
            return set(p) if isinstance(p, tuple) else {p}

        if any(axes_of(p) & dp_names for p in parts):  # idempotent
            return P(*parts)
        for i, (dim, cur) in enumerate(zip(shape, parts)):
            if cur is None and dim % dp_size == 0:
                parts[i] = dp_entry
                break
        return P(*parts)

    return jax.tree.map(leaf, pspecs_tree, params_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_specs(params_tree, mesh=None):
    """PartitionSpec tree matching ``params_tree`` (arrays or
    ShapeDtypeStructs).  Dims that don't divide the mesh axis evenly fall
    back to replication (logged by the caller)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else None

    def leaf_spec(path, leaf):
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        return _spec_for_leaf(_path_str(path), len(shape), shape, sizes)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)

"""AdamW with fp32 master weights, global-norm clipping and cosine schedule.

Functional (no optax): state = {master, m, v, step}; ``update`` returns the
new state plus the working (bf16) params cast from the fp32 masters.  The
spec tree for every state leaf mirrors the param spec tree, so checkpointing
and the dry-run shard optimizer state identically to params.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def init_state(params) -> dict:
    f32 = lambda t: jax.tree.map(lambda a: a.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), t)
    return {
        "master": f32(params),
        "m": zeros(params),
        "v": zeros(params),
        "step": jnp.zeros((), jnp.int32),
    }


def state_shapes(param_shapes) -> dict:
    """ShapeDtypeStruct version of ``init_state`` (dry-run, no allocation)."""
    f32 = lambda t: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32), t)
    return {
        "master": f32(param_shapes),
        "m": f32(param_shapes),
        "v": f32(param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


_NO_DECAY = ("norm", "ln", "bias", "b_if", "a_log", "dt_bias", "d_skip", "scale")


def _decay_mask(path: str) -> bool:
    return not any(t in path for t in _NO_DECAY)


def update(opt_cfg: OptConfig, state: dict, grads, param_dtypes) -> tuple[Any, dict]:
    """Returns (new working params, new state).  ``param_dtypes`` is a tree of
    dtypes so the working copy matches the model's storage dtypes."""
    step = state["step"] + 1
    lr = schedule(opt_cfg, step)
    g32, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
    b1, b2 = opt_cfg.beta1, opt_cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf(path, master, m, v, g):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + opt_cfg.eps)
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        if _decay_mask(pstr):
            upd = upd + opt_cfg.weight_decay * master
        return master - lr * upd, m_new, v_new

    flat = jax.tree_util.tree_map_with_path(
        lambda p, ms, m, v, g: leaf(p, ms, m, v, g),
        state["master"], state["m"], state["v"], g32,
    )
    master = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    params = jax.tree.map(lambda ms, d: ms.astype(d), master, param_dtypes)
    return params, {"master": master, "m": m, "v": v, "step": step}

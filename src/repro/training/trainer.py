"""Train-step factory: loss → grad → clip → AdamW, with optional microbatch
gradient accumulation (a memory knob for the perf loop).

The returned ``train_step(params, opt_state, batch)`` is pure and jittable;
the launcher wraps it in ``jax.jit`` with explicit in/out shardings.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import optim


def make_train_step(
    model, opt_cfg: optim.OptConfig, microbatches: int = 1, grad_specs=None,
    unroll_micro: bool = False,
) -> Callable:
    """``grad_specs`` (a PartitionSpec tree, ZeRO-2) constrains gradients to
    data-axis shards so the cross-replica reduction lowers to reduce-scatter
    instead of all-reduce and fp32 grads never replicate."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(grads):
        if grad_specs is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, grad_specs)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain(grads)
        else:
            # split the leading batch dim into (n_micro, b/n) and lax.scan,
            # accumulating fp32 grads — activation memory drops ~n_micro×.
            def split(x):
                if x.ndim == 0:
                    return jnp.broadcast_to(x, (microbatches,))
                b = x.shape[0]
                # pos3 is (3, B, S): split axis 1
                if x.ndim >= 2 and b == 3 and x.shape[1] % microbatches == 0:
                    return jnp.moveaxis(
                        x.reshape(3, microbatches, x.shape[1] // microbatches,
                                  *x.shape[2:]), 1, 0)
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            # NOTE: the accumulator is NOT sharding-constrained inside the
            # loop — a dp-sharded fp32 accumulator forces per-layer fp32
            # all-gather/all-reduce churn in every microbatch's backward
            # (measured 2e13 B/step on deepseek-67b).  Accumulate in param
            # dtype, constrain ONCE after the loop (ZeRO-2 reduce-scatter).
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)

            def body(acc, mb):
                (l, met), g = grad_fn(params, mb)
                acc_g = jax.tree.map(jnp.add, acc[0], g)
                return (acc_g, acc[1] + l), met

            if unroll_micro:
                # static-slice accumulation: works around an XLA SPMD bug
                # where scan's dynamic-slice unstacking fails to partition
                # under nested-scan recurrent models (HLO grows ×mb).
                acc, mets = (zeros, jnp.float32(0)), []
                for i in range(microbatches):
                    acc, met = body(acc, jax.tree.map(lambda x: x[i], micro))
                    mets.append(met)
                gsum, lsum = acc
                metrics = jax.tree.map(lambda *m: jnp.stack(m).mean(), *mets)
            else:
                (gsum, lsum), mets = jax.lax.scan(
                    body, (zeros, jnp.float32(0)), micro)
                metrics = jax.tree.map(lambda m: m.mean(), mets)
            grads = constrain(jax.tree.map(
                lambda g: g.astype(jnp.float32) / microbatches, gsum))
            loss = lsum / microbatches

        dtypes = jax.tree.map(lambda a: a.dtype, params)
        new_params, new_state = optim.update(opt_cfg, opt_state, grads, dtypes)
        return new_params, new_state, loss, metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    return eval_step


def make_hybrid_train_step(
    model, opt_cfg: optim.OptConfig, mesh, zspecs, batch_inspecs,
    microbatches: int = 1, dp_axes: tuple = ("data",), pspecs=None,
) -> Callable:
    """Hybrid parallelism: MANUAL data parallelism via shard_map (gradients
    accumulate locally across layers AND microbatches with zero cross-replica
    traffic, then ONE reduce-scatter per step), tensor parallelism left to
    the auto partitioner inside.

    This removes the per-layer-per-microbatch gradient all-reduce that pjit
    semantics force with replicated parameters (measured 8e12 B/step on
    deepseek-67b at mb=16 — the dominant §Perf collective).

    ``zspecs``: ZeRO param-spec tree; its dp-axis entry per leaf is both the
    psum_scatter dimension and the shard_map out_spec, so the returned grads
    land already optimizer-sharded.
    ``batch_inspecs``: PartitionSpec tree for the batch (dp axes only).
    """
    from jax.sharding import PartitionSpec as P

    dp_set = set()
    for ax in dp_axes:
        dp_set.add(ax)

    def scatter_info(spec: P):
        """(dim, manual_out_spec) for the dp-sharded dim of a zspec leaf."""
        for i, entry in enumerate(spec):
            axes = entry if isinstance(entry, tuple) else (entry,)
            if entry is not None and set(a for a in axes if a) & dp_set:
                manual = [None] * len(spec)
                manual[i] = tuple(a for a in axes if a in dp_set) or None
                return i, P(*manual)
        return None, P()

    def tp_specs_of(spec: P) -> P:
        """Strip manual (dp) axes from a physical spec — what remains is the
        tensor-parallel sharding the AUTO partitioner should keep INSIDE the
        manual region (without this, params enter replicated and every temp
        blows up to full model size)."""
        out = []
        for entry in spec:
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = tuple(a for a in axes if a is not None and a not in dp_set)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)

    grad_fn = jax.value_and_grad(lambda p, b: model.loss(p, b), has_aux=True)
    inner_pspecs = None if pspecs is None else jax.tree.map(
        tp_specs_of, pspecs, is_leaf=lambda x: isinstance(x, P))

    def local_step(params, batch):
        if inner_pspecs is not None:
            params = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                params, inner_pspecs)
        if microbatches <= 1:
            (loss, met), g = grad_fn(params, batch)
        else:
            def split(x):
                if x.ndim == 0:
                    return jnp.broadcast_to(x, (microbatches,))
                if x.ndim >= 2 and x.shape[0] == 3:
                    return jnp.moveaxis(
                        x.reshape(3, microbatches, x.shape[1] // microbatches,
                                  *x.shape[2:]), 1, 0)
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)

            def body(acc, mb):
                (l, met), g = grad_fn(params, mb)
                return (jax.tree.map(jnp.add, acc[0], g), acc[1] + l), met

            (g, lsum), mets = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
            g = jax.tree.map(lambda x: x / microbatches, g)
            loss = lsum / microbatches
            met = jax.tree.map(lambda m: m.mean(), mets)
        if inner_pspecs is not None:  # keep grads tp-sharded pre-reduction
            g = jax.tree.map(
                lambda x, s: jax.lax.with_sharding_constraint(x, s),
                g, inner_pspecs)

        # the ONLY cross-replica gradient traffic: one scatter-mean per leaf
        def reduce_leaf(x, spec):
            dim, _ = scatter_info(spec)
            x = x.astype(jnp.float32)
            if dim is None:
                return jax.lax.pmean(x, dp_axes)
            return jax.lax.psum_scatter(
                x, dp_axes, scatter_dimension=dim, tiled=True
            ) / jax.lax.psum(1, dp_axes)

        g = jax.tree.map(reduce_leaf, g, zspecs,
                         is_leaf=lambda x: isinstance(x, P))
        loss = jax.lax.pmean(loss, dp_axes)
        met = jax.tree.map(lambda m: jax.lax.pmean(m, dp_axes), met)
        return g, loss, met

    grad_outspecs = jax.tree.map(lambda s: scatter_info(s)[1], zspecs,
                                 is_leaf=lambda x: isinstance(x, P))
    sm = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), zspecs,
                               is_leaf=lambda x: isinstance(x, P)),
                  batch_inspecs),
        out_specs=(grad_outspecs, P(), P()),
        axis_names=frozenset(dp_set), check_vma=False,
    )

    def train_step(params, opt_state, batch):
        grads, loss, metrics = sm(params, batch)
        dtypes = jax.tree.map(lambda a: a.dtype, params)
        new_params, new_state = optim.update(opt_cfg, opt_state, grads, dtypes)
        return new_params, new_state, loss, metrics

    return train_step

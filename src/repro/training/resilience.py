"""Fault tolerance & distributed-optimization tricks.

* ``PreemptionGuard`` — SIGTERM/SIGINT turn into a "checkpoint now, then
  exit cleanly" flag the train loop polls between steps (TPU preemption
  notice pattern).
* ``StragglerMonitor`` — per-step wall times; a step slower than
  ``threshold ×`` the rolling median flags a straggler.  On a real fleet
  the flag feeds the scheduler (hot-spare swap / data re-balancing); here
  it logs and counts, and its decision logic is unit-tested.
* ``compress_grads`` / ``decompress_grads`` — int8 error-feedback gradient
  compression for the cross-replica reduction (≈4× less DCI traffic for
  multi-pod data parallelism).  The error buffer carries quantization
  residuals into the next step, preserving convergence (Seide et al.;
  tested end-to-end in test_resilience.py).
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import signal
import statistics
import time
from typing import Any

import jax
import jax.numpy as jnp

logger = logging.getLogger(__name__)


class PreemptionGuard:
    """Turn SIGTERM/SIGINT into a "checkpoint now, then exit cleanly" flag.

    Handlers install on construction (both signals by default, matching the
    module docstring) and are re-armable: ``restore()`` puts the previous
    handlers back AND resets ``requested``, so the same guard can be
    installed again with ``install()``.  The context-manager form guarantees
    handler restoration even if the guarded block raises::

        with PreemptionGuard() as guard:
            ...
            if guard.requested:
                checkpoint_and_exit()
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._signals = tuple(signals)
        self._old = {}
        self.install()

    def install(self):
        """(Re-)register the signal handlers.  Idempotent."""
        for sig in self._signals:
            if sig not in self._old:
                self._old[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        """Restore the pre-install handlers and reset ``requested`` so the
        guard can be re-armed with ``install()``."""
        for sig, old in self._old.items():
            signal.signal(sig, old)
        self._old = {}
        self.requested = False

    def __enter__(self):
        return self.install()

    def __exit__(self, exc_type, exc, tb):
        self.restore()
        return False


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    median: float


class StragglerMonitor:
    """Rolling-median step-time watchdog."""

    def __init__(self, threshold: float = 2.5, window: int = 32):
        self.threshold = threshold
        self.times = collections.deque(maxlen=window)
        self.events: list[StragglerEvent] = []
        self._t0 = None
        self._step = 0

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self) -> StragglerEvent | None:
        if self._t0 is None:
            # end_step() without a matching start_step() used to TypeError
            # on ``perf_counter() - None``; an unmatched call carries no
            # timing signal, so warn and no-op instead of crashing the loop.
            logger.warning("StragglerMonitor.end_step() without start_step();"
                           " ignoring this step")
            return None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._step += 1
        event = None
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                event = StragglerEvent(self._step, dt, med)
                self.events.append(event)
        self.times.append(dt)
        return event

    def observe(self, seconds: float) -> StragglerEvent | None:
        """Test/offline path: feed a duration directly."""
        self._t0 = time.perf_counter() - seconds
        return self.end_step()


# --------------------------------------------------------------------- #
# int8 error-feedback gradient compression
# --------------------------------------------------------------------- #
def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, err: jax.Array):
    """Returns (int8 codes, fp32 scale, new error).  g+err is quantized to
    symmetric int8; the quantization residual becomes the next error."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, err_state):
    """Tree version; returns (codes, scales, new_err)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    qs, ss, es = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        qs.append(q)
        ss.append(s)
        es.append(ne)
    return (jax.tree.unflatten(treedef, qs), jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, es))


def decompress_tree(codes, scales):
    return jax.tree.map(decompress, codes, scales)


def make_compressed_allreduce(axis_name: str):
    """shard_map-compatible compressed mean-reduce over ``axis_name``:
    each replica contributes int8 codes; scales reduce in fp32.  Traffic is
    1 byte/param + one scalar per leaf instead of 4 bytes/param."""

    def allreduce(codes, scales):
        def leaf(q, s):
            contrib = q.astype(jnp.float32) * s
            return jax.lax.pmean(contrib, axis_name)

        return jax.tree.map(leaf, codes, scales)

    return allreduce

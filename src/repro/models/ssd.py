"""Chunked state-space / linear-attention cores.

Both Mamba2's SSD and xLSTM's mLSTM share a decayed outer-product recurrence

    S_t = a_t · S_{t-1} + b_t · (k_t ⊗ v_t),     y_t = q_t · S_t

whose chunked parallel form (intra-chunk masked matmul + inter-chunk state
carry) is the TPU-native formulation: every op is an MXU matmul over (Q, Q)
or (N, P) tiles, and states materialize only at chunk boundaries.

``ssd_chunked``  — Mamba2 (decay a ∈ (0,1], no normalizer, no stabilizer).
``mlstm_chunked`` — xLSTM mLSTM (exp input gates ⇒ log-space stabilizer m and
                    normalizer n carried across chunks).
Both return the final state so prefill can seed decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# Mamba2 SSD
# --------------------------------------------------------------------- #
def ssd_chunked(
    la: jax.Array,  # (B, S, H) log decay per token (<= 0)
    q: jax.Array,  # (B, S, N)  C_t (shared across heads, G=1)
    k: jax.Array,  # (B, S, N)  B_t
    v: jax.Array,  # (B, S, H, P) dt-scaled inputs
    s0: jax.Array | None = None,  # (B, H, N, P) initial state
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    b, s, h = la.shape
    n = q.shape[-1]
    p = v.shape[-1]
    cq = min(chunk, s)
    assert s % cq == 0, (s, cq)
    nc = s // cq
    if s0 is None:
        s0 = jnp.zeros((b, h, n, p), jnp.float32)

    la_c = jnp.moveaxis(la.reshape(b, nc, cq, h), 1, 0)
    q_c = jnp.moveaxis(q.reshape(b, nc, cq, n), 1, 0)
    k_c = jnp.moveaxis(k.reshape(b, nc, cq, n), 1, 0)
    v_c = jnp.moveaxis(v.reshape(b, nc, cq, h, p), 1, 0)

    idx = jnp.arange(cq)
    tri = idx[:, None] >= idx[None, :]  # j >= s (inclusive of diagonal)

    def step(state, blk):
        la_b, q_b, k_b, v_b = blk  # (B,Q,H) (B,Q,N) (B,Q,N) (B,Q,H,P)
        lcum = jnp.cumsum(la_b.astype(jnp.float32), axis=1)  # (B,Q,H) inclusive
        # intra-chunk: w_{js} = exp(L_j - L_s) for s <= j  (decay from s to j)
        diff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,Q,Q,H) L_j - L_s
        w = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        qk = jnp.einsum("bjn,bsn->bjs", q_b.astype(jnp.float32), k_b.astype(jnp.float32))
        scores = qk[:, :, :, None] * w  # (B,Q,Q,H)
        y_intra = jnp.einsum("bjsh,bshp->bjhp", scores, v_b.astype(jnp.float32))
        # inter-chunk: y_j += exp(L_j) q_j · S_prev
        qdec = q_b.astype(jnp.float32)[:, :, None, :] * jnp.exp(lcum)[..., None]  # (B,Q,H,N)
        y_inter = jnp.einsum("bjhn,bhnp->bjhp", qdec, state)
        # state update: S = exp(L_Q) S_prev + Σ_s exp(L_Q - L_s) k_s v_s
        ltot = lcum[:, -1, :]  # (B,H)
        kdec = k_b.astype(jnp.float32)[:, :, None, :] * jnp.exp(
            ltot[:, None, :] - lcum
        )[..., None]  # (B,Q,H,N)
        s_new = state * jnp.exp(ltot)[:, :, None, None] + jnp.einsum(
            "bshn,bshp->bhnp", kdec, v_b.astype(jnp.float32)
        )
        return s_new, (y_intra + y_inter).astype(v.dtype)

    # remat per chunk: without it the scan saves the (B,Q,Q,H) decay/score
    # tensors of EVERY chunk for the backward pass (gigabytes per layer);
    # with it only the (B,H,N,P) carry states persist.
    s_final, y = jax.lax.scan(jax.checkpoint(step), s0, (la_c, q_c, k_c, v_c))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, p)
    return y, s_final


def ssd_decode_step(
    la: jax.Array,  # (B, H) log decay for this token
    q: jax.Array,  # (B, N)
    k: jax.Array,  # (B, N)
    v: jax.Array,  # (B, H, P)
    state: jax.Array,  # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    a = jnp.exp(la.astype(jnp.float32))[:, :, None, None]
    new_state = a * state + jnp.einsum(
        "bn,bhp->bhnp", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhnp->bhp", q.astype(jnp.float32), new_state)
    return y.astype(v.dtype), new_state


# --------------------------------------------------------------------- #
# mLSTM (stabilized, chunked)
# --------------------------------------------------------------------- #
def mlstm_chunked(
    lf: jax.Array,  # (B, S, H) log forget gate (log sigmoid or raw, <= 0 not req.)
    li: jax.Array,  # (B, S, H) log input gate (unbounded — stabilized)
    q: jax.Array,  # (B, S, H, N)
    k: jax.Array,  # (B, S, H, N)
    v: jax.Array,  # (B, S, H, P)
    state: tuple[jax.Array, jax.Array, jax.Array] | None = None,
    chunk: int = 256,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    """Stabilized chunked mLSTM.

    Carried state is (S̃, ñ, m) with true S = S̃·eᵐ, n = ñ·eᵐ:
      C_t = f_t C_{t-1} + i_t k_t v_tᵀ,  n_t = f_t n_{t-1} + i_t k_t,
      y_t = (q_t ᵀ C_t) / max(|q_tᵀ n_t|, 1).
    """
    b, s, h = lf.shape
    n = q.shape[-1]
    p = v.shape[-1]
    cq = min(chunk, s)
    assert s % cq == 0
    nc = s // cq
    if state is None:
        st = jnp.zeros((b, h, n, p), jnp.float32)
        nt = jnp.zeros((b, h, n), jnp.float32)
        mt = jnp.full((b, h), NEG_INF, jnp.float32)
    else:
        st, nt, mt = state

    lf_c = jnp.moveaxis(lf.reshape(b, nc, cq, h), 1, 0)
    li_c = jnp.moveaxis(li.reshape(b, nc, cq, h), 1, 0)
    q_c = jnp.moveaxis(q.reshape(b, nc, cq, h, n), 1, 0)
    k_c = jnp.moveaxis(k.reshape(b, nc, cq, h, n), 1, 0)
    v_c = jnp.moveaxis(v.reshape(b, nc, cq, h, p), 1, 0)

    idx = jnp.arange(cq)
    tri = idx[:, None] >= idx[None, :]
    scale = 1.0 / jnp.sqrt(jnp.float32(n))

    def step(carry, blk):
        st, nt, mt = carry  # (B,H,N,P), (B,H,N), (B,H)
        lf_b, li_b, q_b, k_b, v_b = blk
        lcum = jnp.cumsum(lf_b.astype(jnp.float32), axis=1)  # (B,Q,H)
        # log weight of source s at target j: d_js = L_j - L_s + li_s   (s<=j)
        # carry-in exponent at j: e_j = L_j + m_prev
        c_src = li_b.astype(jnp.float32) - lcum  # (B,Q,H): li_s - L_s
        run_max = jax.lax.cummax(c_src, axis=1)  # max_{s<=j} (li_s - L_s)
        e_carry = mt[:, None, :]  # m_prev (B,1,H)
        m_new = jnp.maximum(lcum + run_max, lcum + e_carry)  # (B,Q,H)
        # intra weights: exp(L_j - L_s + li_s - m_j)
        d = lcum[:, :, None, :] + c_src[:, None, :, :] - m_new[:, :, None, :]
        w = jnp.where(tri[None, :, :, None], jnp.exp(d), 0.0)  # (B,Q,Q,H)
        qs = q_b.astype(jnp.float32) * scale
        qk = jnp.einsum("bjhn,bshn->bjsh", qs, k_b.astype(jnp.float32))
        scores = qk * w  # (B,Q,Q,H)
        y_num = jnp.einsum("bjsh,bshp->bjhp", scores, v_b.astype(jnp.float32))
        den = jnp.sum(scores, axis=2)  # q_j · n_j, intra part  (B,Q,H)
        # carry-in contribution, scaled by exp(L_j + m_prev - m_j)
        cw = jnp.exp(lcum + e_carry - m_new)  # (B,Q,H)
        y_num += jnp.einsum("bjhn,bhnp->bjhp", qs, st) * cw[..., None]
        den += jnp.einsum("bjhn,bhn->bjh", qs, nt) * cw
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
        y = y_num / denom[..., None]
        # ---- state update to end of chunk ----
        ltot = lcum[:, -1, :]  # (B,H)
        m_end = m_new[:, -1, :]
        # source weight into end-state: exp(L_Q - L_s + li_s - m_end)
        d_end = ltot[:, None, :] + c_src - m_end[:, None, :]
        w_end = jnp.exp(d_end)  # (B,Q,H)
        kv = jnp.einsum(
            "bshn,bshp->bhnp", k_b.astype(jnp.float32) * w_end[..., None],
            v_b.astype(jnp.float32),
        )
        ksum = jnp.einsum("bshn->bhn", k_b.astype(jnp.float32) * w_end[..., None])
        carry_scale = jnp.exp(ltot + mt - m_end)[:, :, None]
        st_new = st * carry_scale[..., None] + kv
        nt_new = nt * carry_scale + ksum
        return (st_new, nt_new, m_end), y.astype(v.dtype)

    (st, nt, mt), y = jax.lax.scan(
        jax.checkpoint(step), (st, nt, mt), (lf_c, li_c, q_c, k_c, v_c))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, p)
    return y, (st, nt, mt)


def mlstm_decode_step(
    lf: jax.Array,  # (B, H)
    li: jax.Array,  # (B, H)
    q: jax.Array,  # (B, H, N)
    k: jax.Array,  # (B, H, N)
    v: jax.Array,  # (B, H, P)
    state: tuple[jax.Array, jax.Array, jax.Array],
) -> tuple[jax.Array, tuple[jax.Array, jax.Array, jax.Array]]:
    st, nt, mt = state
    lf = lf.astype(jnp.float32)
    li = li.astype(jnp.float32)
    m_new = jnp.maximum(lf + mt, li)
    f = jnp.exp(lf + mt - m_new)[:, :, None]
    i = jnp.exp(li - m_new)[:, :, None]
    k32 = k.astype(jnp.float32)
    st_new = st * f[..., None] + i[..., None] * jnp.einsum(
        "bhn,bhp->bhnp", k32, v.astype(jnp.float32)
    )
    nt_new = nt * f + i * k32
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    qs = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhn,bhnp->bhp", qs, st_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhn,bhn->bh", qs, nt_new)), jnp.exp(-m_new)
    )
    return (num / den[..., None]).astype(v.dtype), (st_new, nt_new, m_new)

"""Decoder-only transformer LM (dense / MoE / VLM families).

Layers are stacked into a single pytree with a leading L dim and driven by
``lax.scan`` + ``jax.checkpoint`` so HLO size and compile time are
depth-independent (95-layer configs compile in seconds) and activation
memory is O(1) in depth.  Activations carry logical shardings:
residual stream ("dp", "sp", None) — sequence-parallel between blocks —
and tensor-parallel ("tp") inside attention/FFN via the param shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distribution.partition import shard
from repro.models import blocks
from repro.models.common import ArchConfig, dense_init, rms_norm, split_keys


def _embed_init(key, cfg: ArchConfig) -> dict:
    ks = split_keys(key, 3)
    p = {
        "embed": dense_init(ks[0], (cfg.vocab, cfg.d_model), scale=1.0),
        "final_norm": jnp.ones((cfg.d_model,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab))
    if cfg.frontend:
        p["frontend_proj"] = dense_init(ks[2], (cfg.frontend_dim, cfg.d_model))
    return p


def _logits(p, h, cfg: ArchConfig):
    head = p["lm_head"] if not cfg.tie_embeddings else p["embed"].T
    return shard(h @ head, "dp", None, "tp")


def _xent(logits, labels, mask=None):
    """Mean next-token cross entropy; logits (B,S,V) possibly vocab-sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


class TransformerLM:
    """Families: dense (llama-style), moe (per-layer top-k MoE), vlm
    (patch-embedding prefix + M-RoPE)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ----------------------------- init ------------------------------ #
    def _layer_init(self, key) -> dict:
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "ln2": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "attn": blocks.attn_init(k1, cfg),
        }
        if cfg.family == "moe":
            p["moe"] = blocks.moe_init(k2, cfg)
        else:
            p["mlp"] = blocks.mlp_init(k2, cfg)
        return p

    def init(self, key):
        cfg = self.cfg
        k_emb, k_layers = jax.random.split(key)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(self._layer_init)(layer_keys)
        return {**_embed_init(k_emb, cfg), "layers": layers}

    # --------------------------- embedding --------------------------- #
    def _embed_inputs(self, p, batch):
        cfg = self.cfg
        tok_emb = p["embed"][batch["tokens"]]  # (B, S_text, D)
        if cfg.family == "vlm":
            vis = batch["vis_embeds"] @ p["frontend_proj"]  # (B, S_vis, D)
            h = jnp.concatenate([vis.astype(tok_emb.dtype), tok_emb], axis=1)
        else:
            h = tok_emb
        return shard(h, "dp", "sp", None)

    # ---------------------------- forward ---------------------------- #
    def _run_layers(self, p, h, positions, pos3):
        cfg = self.cfg

        def layer_fn(carry, lp):
            x = shard(carry, "dp", "sp", None)
            # explicit SP→TP transition: gather the SEQUENCE before the
            # matmuls, or XLA's partitioner may all-gather the (much larger)
            # weights instead (measured 6.6e12 B/step on deepseek-67b).
            attn_in = shard(rms_norm(x, lp["ln1"], cfg.norm_eps),
                            "dp", None, None)
            a, _ = blocks.attn_apply(
                lp["attn"], attn_in, cfg, positions=positions, pos3=pos3,
            )
            x = x + shard(a, "dp", "sp", None)
            hin = shard(rms_norm(x, lp["ln2"], cfg.norm_eps), "dp", None, None)
            if cfg.family == "moe":
                m, aux = blocks.moe_apply(lp["moe"], hin, cfg)
            else:
                m, aux = blocks.mlp_apply(lp["mlp"], hin), 0.0
            x = x + shard(m, "dp", "sp", None)
            return x, aux

        fn = jax.checkpoint(layer_fn) if cfg.remat == "full" else layer_fn
        h, auxs = jax.lax.scan(fn, h, p["layers"])
        return h, jnp.sum(jnp.asarray(auxs))

    def loss(self, params, batch):
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        pos3 = batch.get("pos3") if cfg.mrope else None
        h, aux = self._run_layers(params, h, positions, pos3)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = _logits(params, h, cfg)
        if cfg.family == "vlm":  # labels cover the text tail only
            s_text = batch["labels"].shape[1]
            logits = logits[:, -s_text:]
        loss = _xent(logits, batch["labels"], batch.get("loss_mask"))
        return loss + 0.01 * aux, {"xent": loss, "aux": aux}

    # ---------------------------- serving ----------------------------- #
    def cache_shape(self, batch_size: int, s_max: int):
        cfg = self.cfg
        s_kv = min(s_max, cfg.sliding_window) if cfg.sliding_window else s_max
        kv = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, s_kv, cfg.n_kv_heads, cfg.hd), jnp.bfloat16
        )
        return {"k": kv, "v": kv}

    def init_cache(self, batch_size: int, s_max: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_shape(batch_size, s_max)
        )

    def cache_logical(self):
        from repro.distribution.partition import Axes

        kv = Axes(None, "dp", None, "tp", None)  # (L, B, S, Hkv, hd)
        return {"k": kv, "v": kv}

    def prefill(self, params, batch):
        """Full-sequence forward; returns (last-token logits, cache)."""
        cfg = self.cfg
        h = self._embed_inputs(params, batch)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        pos3 = batch.get("pos3") if cfg.mrope else None

        def layer_fn(carry, lp):
            x = shard(carry, "dp", "sp", None)
            attn_in = shard(rms_norm(x, lp["ln1"], cfg.norm_eps),
                            "dp", None, None)
            a, (k, v) = blocks.attn_apply(
                lp["attn"], attn_in, cfg, positions=positions, pos3=pos3,
            )
            x = x + shard(a, "dp", "sp", None)
            hin = shard(rms_norm(x, lp["ln2"], cfg.norm_eps), "dp", None, None)
            if cfg.family == "moe":
                m, _ = blocks.moe_apply(lp["moe"], hin, cfg)
            else:
                m = blocks.mlp_apply(lp["mlp"], hin)
            if cfg.sliding_window:
                k, v = k[:, -cfg.sliding_window :], v[:, -cfg.sliding_window :]
            kv = {
                "k": shard(k.astype(jnp.bfloat16), "dp", None, "tp", None),
                "v": shard(v.astype(jnp.bfloat16), "dp", None, "tp", None),
            }
            return x + shard(m, "dp", "sp", None), kv

        fn = jax.checkpoint(layer_fn) if cfg.remat == "full" else layer_fn
        h, cache = jax.lax.scan(fn, h, params["layers"])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = _logits(params, h[:, -1:, :], cfg)
        return logits, cache

    def decode_step(self, params, cache, batch):
        """One token for every sequence; batch = {tokens (B,1), pos ()}."""
        cfg = self.cfg
        pos = batch["pos"]
        h = params["embed"][batch["tokens"]]  # (B, 1, D)
        h = shard(h, "dp", None, None)
        pos3 = batch.get("pos3")  # (3, B, 1) for vlm

        def layer_fn(carry, scanned):
            lp, kv = scanned
            x = carry
            a, kv_new = blocks.attn_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, kv, pos,
                pos3=pos3,
            )
            x = x + a
            hin = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                m, _ = blocks.moe_apply(lp["moe"], hin, cfg)
            else:
                m = blocks.mlp_apply(lp["mlp"], hin)
            return x + shard(m, "dp", "sp", None), kv_new

        h, new_cache = jax.lax.scan(layer_fn, h, (params["layers"], cache))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, h, cfg), new_cache

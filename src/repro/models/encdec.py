"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_frames, frontend_dim); we project to
d_model.  Encoder = bidirectional attention; decoder = causal self-attention
+ cross-attention into the encoder memory.  Decoder length is seq_len // 8
for training shapes (declared in DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.partition import shard
from repro.models import blocks
from repro.models.common import ArchConfig, dense_init, rms_norm, split_keys
from repro.models.transformer import _embed_init, _logits, _xent

DEC_FRAC = 8  # decoder seq = encoder seq // DEC_FRAC for train/prefill shapes
DEC_MAX = 1024  # decoder self-cache length during decode


class EncDecModel:
    def __init__(self, cfg: ArchConfig):
        assert cfg.enc_dec and cfg.n_enc_layers > 0
        self.cfg = cfg

    # ----------------------------- init ------------------------------ #
    def _enc_layer_init(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "ln2": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "attn": blocks.attn_init(k1, cfg, bias=True),
            "mlp": blocks.mlp_init(k2, cfg, gelu=True),
        }

    def _dec_layer_init(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "ln2": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "ln3": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "attn": blocks.attn_init(k1, cfg, bias=True),
            "xattn": blocks.attn_init(k2, cfg, bias=True),
            "mlp": blocks.mlp_init(k3, cfg, gelu=True),
        }

    def init(self, key):
        cfg = self.cfg
        k_emb, k_enc, k_dec = jax.random.split(key, 3)
        enc = jax.vmap(self._enc_layer_init)(jax.random.split(k_enc, cfg.n_enc_layers))
        dec = jax.vmap(self._dec_layer_init)(jax.random.split(k_dec, cfg.n_layers))
        return {
            **_embed_init(k_emb, cfg),
            "enc_layers": enc,
            "dec_layers": dec,
            "enc_norm": jnp.ones((cfg.d_model,), jnp.bfloat16),
        }

    # ---------------------------- encoder ----------------------------- #
    def encode(self, params, frames):
        cfg = self.cfg
        h = shard(frames @ params["frontend_proj"], "dp", "sp", None)

        def layer_fn(carry, lp):
            x = shard(carry, "dp", "sp", None)
            a, _ = blocks.attn_apply(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                     cfg, positions=None, causal=False)
            x = x + shard(a, "dp", "sp", None)
            m = blocks.mlp_apply(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x + shard(m, "dp", "sp", None), None

        fn = jax.checkpoint(layer_fn) if cfg.remat == "full" else layer_fn
        h, _ = jax.lax.scan(fn, h, params["enc_layers"])
        return rms_norm(h, params["enc_norm"], cfg.norm_eps)

    # ---------------------------- decoder ----------------------------- #
    def _decoder(self, params, tokens, memory, positions):
        cfg = self.cfg
        h = shard(params["embed"][tokens], "dp", None, None)

        def layer_fn(carry, lp):
            x = carry
            a, _ = blocks.attn_apply(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                     cfg, positions=positions, causal=True)
            x = x + shard(a, "dp", None, None)
            mem_kv = blocks.memory_kv_init(lp["xattn"], memory, cfg)
            c = blocks.cross_attn_apply(lp["xattn"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                                        cfg, mem_kv)
            x = x + shard(c, "dp", None, None)
            m = blocks.mlp_apply(lp["mlp"], rms_norm(x, lp["ln3"], cfg.norm_eps))
            return x + shard(m, "dp", None, None), None

        fn = jax.checkpoint(layer_fn) if cfg.remat == "full" else layer_fn
        h, _ = jax.lax.scan(fn, h, params["dec_layers"])
        return h

    def loss(self, params, batch):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h = self._decoder(params, tokens, memory, positions)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        loss = _xent(_logits(params, h, cfg), batch["labels"], batch.get("loss_mask"))
        return loss, {"xent": loss}

    # ---------------------------- serving ----------------------------- #
    def cache_shape(self, batch_size: int, s_max: int):
        cfg = self.cfg
        kv = lambda s: jax.ShapeDtypeStruct(
            (cfg.n_layers, batch_size, s, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
        return {
            "self": {"k": kv(DEC_MAX), "v": kv(DEC_MAX)},
            "cross": {"k": kv(s_max), "v": kv(s_max)},
        }

    def init_cache(self, batch_size: int, s_max: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shape(batch_size, s_max))

    def cache_logical(self):
        from repro.distribution.partition import Axes

        kv = lambda: Axes(None, "dp", None, "tp", None)
        return {
            "self": {"k": kv(), "v": kv()},
            "cross": {"k": kv(), "v": kv()},
        }

    def prefill(self, params, batch):
        """Encode frames and project per-layer cross KV; empty self cache."""
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])

        def xkv(lp, _):
            return None, blocks.memory_kv_init(lp["xattn"], memory, cfg)

        _, (ks, vs) = jax.lax.scan(lambda c, lp: xkv(lp, c), None, params["dec_layers"])
        b = memory.shape[0]
        cache = {
            "self": jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype),
                self.cache_shape(b, 1)["self"],
            ),
            "cross": {"k": ks.astype(jnp.bfloat16), "v": vs.astype(jnp.bfloat16)},
        }
        bos = jnp.zeros((b, 1), jnp.int32)
        logits, cache = self.decode_step(
            params, cache, {"tokens": bos, "pos": jnp.int32(0)})
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        pos = batch["pos"]
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)

        def layer_fn(carry, scanned):
            lp, self_kv, cross_kv = scanned
            x = carry
            a, self_new = blocks.attn_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), cfg, self_kv, pos)
            x = x + a
            c = blocks.cross_attn_apply(
                lp["xattn"], rms_norm(x, lp["ln2"], cfg.norm_eps), cfg,
                (cross_kv["k"], cross_kv["v"]))
            x = x + c
            m = blocks.mlp_apply(lp["mlp"], rms_norm(x, lp["ln3"], cfg.norm_eps))
            return x + m, self_new

        h, self_new = jax.lax.scan(
            layer_fn, h, (params["dec_layers"], cache["self"], cache["cross"]))
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, h, cfg), {"self": self_new, "cross": cache["cross"]}

"""Layer blocks: GQA attention, dense MLP, MoE, Mamba2, mLSTM, sLSTM.

Every block is a pair of pure functions ``<block>_init(key, cfg) -> params``
and ``<block>_apply(params, x, ...) -> y`` (+ decode variants threading
explicit state).  Params are dicts of arrays so stacks of layers vmap/scan
cleanly.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import (
    ArchConfig,
    attention,
    dense_init,
    gelu_mlp,
    mrope,
    rms_norm,
    rope,
    split_keys,
    swiglu,
)
from repro.models.ssd import (
    mlstm_chunked,
    mlstm_decode_step,
    ssd_chunked,
    ssd_decode_step,
)


# --------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------- #
def attn_init(key, cfg: ArchConfig, bias: bool = False) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, hkv * hd)),
        "wv": dense_init(ks[2], (d, hkv * hd)),
        "wo": dense_init(ks[3], (h * hd, d), scale=1.0 / math.sqrt(h * hd)),
    }
    if bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.bfloat16)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.bfloat16)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.bfloat16)
        p["bo"] = jnp.zeros((d,), jnp.bfloat16)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.bfloat16)
        p["k_norm"] = jnp.ones((hd,), jnp.bfloat16)
    return p


def _project_qkv(p, x, cfg: ArchConfig):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, s, h, hd)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _apply_rope(q, k, cfg: ArchConfig, positions, pos3=None):
    if cfg.mrope and pos3 is not None:
        return mrope(q, pos3, cfg.rope_theta), mrope(k, pos3, cfg.rope_theta)
    if positions is None:
        return q, k
    return rope(q, positions, cfg.rope_theta), rope(k, positions, cfg.rope_theta)


def attn_apply(p, x, cfg: ArchConfig, *, positions=None, pos3=None, causal=True):
    """Full-sequence attention (train / prefill).  Returns (y, (k, v))."""
    q, k, v = _project_qkv(p, x, cfg)
    q, k = _apply_rope(q, k, cfg, positions, pos3)
    y = attention(
        q, k, v, causal=causal, window=cfg.sliding_window,
        impl=cfg.attn_impl, q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk,
    )
    b, s, _, _ = y.shape
    out = y.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"] + p.get("bo", 0)
    return out, (k, v)


def attn_decode(p, x, cfg: ArchConfig, cache, pos, *, pos3=None):
    """One-token decode against a KV cache.

    cache: dict(k=(B, S, Hkv, hd), v=...); ``pos`` is the write index —
    scalar int32 (uniform decode wave; the dry-run's serve_step) OR an (B,)
    vector (continuous batching: every slot at its own position).  Sliding
    -window layers treat the cache as a ring buffer of size ``window``.
    Returns (y (B,1,D), new_cache).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg)  # s == 1
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q, k = _apply_rope(q, k, cfg, pos_b[:, None], pos3)
    s_max = cache["k"].shape[1]
    write = pos_b % s_max if cfg.sliding_window else pos_b
    rows = jnp.arange(b)
    ck = cache["k"].at[rows, write].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[rows, write].set(v[:, 0].astype(cache["v"].dtype))
    # mask out slots beyond each row's position
    kpos = jnp.arange(s_max)
    if cfg.sliding_window:
        valid = (kpos[None, :] <= write[:, None]) | (pos_b >= s_max)[:, None]
    else:
        valid = kpos[None, :] <= pos_b[:, None]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    g = h // hkv
    qg = q.reshape(b, hkv, g, hd)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, ck, preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    y = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(cv.dtype), cv)
    out = y.reshape(b, 1, h * hd) @ p["wo"] + p.get("bo", 0)
    return out, {"k": ck, "v": cv}


def cross_attn_apply(p, x, cfg: ArchConfig, memory_kv):
    """Cross attention for enc-dec decode/train; memory_kv = (k, v) of the
    encoder output, precomputed per layer."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(b, s, h, hd)
    k, v = memory_kv
    y = attention(q, k, v, causal=False, impl=cfg.attn_impl,
                  q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
    return y.reshape(b, s, h * hd) @ p["wo"] + p.get("bo", 0)


def memory_kv_init(p, memory, cfg: ArchConfig):
    """Project encoder output into (k, v) once per layer."""
    b, s, _ = memory.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (memory @ p["wk"] + p.get("bk", 0)).reshape(b, s, hkv, hd)
    v = (memory @ p["wv"] + p.get("bv", 0)).reshape(b, s, hkv, hd)
    return k, v


# --------------------------------------------------------------------- #
# Dense MLP (SwiGLU / GELU)
# --------------------------------------------------------------------- #
def mlp_init(key, cfg: ArchConfig, gelu: bool = False) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    if gelu:
        return {
            "w1": dense_init(ks[0], (d, f)),
            "b1": jnp.zeros((f,), jnp.bfloat16),
            "w2": dense_init(ks[1], (f, d)),
            "b2": jnp.zeros((d,), jnp.bfloat16),
        }
    return {
        "w1": dense_init(ks[0], (d, f)),
        "w3": dense_init(ks[1], (d, f)),
        "w2": dense_init(ks[2], (f, d)),
    }


def mlp_apply(p, x):
    if "w3" in p:
        return swiglu(x, p["w1"], p["w3"], p["w2"])
    return gelu_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"])


# --------------------------------------------------------------------- #
# Mixture of Experts (token-choice top-k, scatter dispatch)
# --------------------------------------------------------------------- #
def moe_init(key, cfg: ArchConfig) -> dict:
    assert cfg.moe is not None
    d, e, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_expert
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w1": dense_init(ks[1], (e, d, f)),
        "w3": dense_init(ks[2], (e, d, f)),
        "w2": dense_init(ks[3], (e, f, d)),
    }


def moe_apply(p, x, cfg: ArchConfig):
    """Scatter-based dispatch with ROW-LOCAL capacity: each sequence (batch
    row) dispatches its own tokens into per-expert buffers, so the position
    cumsum never crosses the data-parallel shard boundary (a global-token
    cumsum would serialize the mesh).  Capacity-dropped tokens fall through
    via the residual.  Decode (S==1) regroups the batch into one row."""
    assert cfg.moe is not None
    b, s, d = x.shape
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    if s == 1:  # decode: one group of B tokens (tiny cumsum)
        y, aux = _moe_grouped(p, x.reshape(1, b, d), cfg)
        return y.reshape(b, s, d), aux
    return _moe_grouped(p, x, cfg)


def _moe_grouped(p, x, cfg: ArchConfig):
    g, t, d = x.shape  # groups × tokens-per-group × dim
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    cap = min(t, max(4, int(cfg.moe.capacity_factor * t * k / e)))

    logits = x.astype(jnp.float32) @ p["router"]  # (G, T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)  # (G, T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    def dispatch_row(topi_r, topw_r):
        """Index-only dispatch: scatter TOKEN IDS, never the 8×-expanded
        hidden states (the data-scatter version kept a (T·k, D) buffer + its
        gradient live — gigabytes per layer)."""
        flat_e = topi_r.reshape(-1)  # (T*k,)
        oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        my_pos = jnp.sum(pos * oh, axis=-1)
        keep = my_pos < cap
        idx_e = jnp.where(keep, flat_e, 0)
        idx_c = jnp.where(keep, my_pos, 0)
        tok = jnp.where(keep, jnp.arange(t * k, dtype=jnp.int32) // k, -1)
        buf_idx = jnp.full((e, cap), -1, jnp.int32)
        buf_idx = buf_idx.at[idx_e, idx_c].max(tok)  # slots unique; -1 = empty
        flat_w = (topw_r.reshape(-1) * keep).astype(jnp.float32)
        return buf_idx, idx_e, idx_c, flat_w

    buf_idx, idx_e, idx_c, flat_w = jax.vmap(dispatch_row)(topi, topw)

    def gather_row(xr, buf_idx_r):
        mask = (buf_idx_r >= 0)[..., None].astype(xr.dtype)
        return xr[jnp.clip(buf_idx_r, 0)] * mask  # (E, C, D)

    from repro.distribution.partition import shard

    xe = shard(jax.vmap(gather_row)(x, buf_idx), "dp", "ep", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w1"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["w3"]
    )
    ye = shard(jnp.einsum("gecf,efd->gecd", h, p["w2"]), "dp", "ep", None, None)

    def combine_row(ye_r, idx_e_r, idx_c_r, flat_w_r):
        # per-choice gathers: peak (T, D) instead of (T·k, D)
        idx_e2 = idx_e_r.reshape(t, k)
        idx_c2 = idx_c_r.reshape(t, k)
        w2 = flat_w_r.reshape(t, k)
        y = jnp.zeros((t, ye_r.shape[-1]), jnp.float32)
        for j in range(k):
            y += ye_r[idx_e2[:, j], idx_c2[:, j]].astype(jnp.float32) * w2[:, j:j + 1]
        return y.astype(ye_r.dtype)

    y = jax.vmap(combine_row)(ye, idx_e, idx_c, flat_w)
    # auxiliary load-balance loss (Switch-style)
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return y, aux


# --------------------------------------------------------------------- #
# Mamba2
# --------------------------------------------------------------------- #
def _mamba_dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    n_heads = d_in // ssm.head_dim
    return d_in, n_heads, ssm.d_state, ssm.head_dim, ssm.conv_width


def mamba_init(key, cfg: ArchConfig) -> dict:
    """Projections are separate leaves (z / x / BC / dt) so tensor-parallel
    sharding rules apply per-leaf; the depthwise conv splits likewise."""
    d = cfg.d_model
    d_in, h, n, p_, cw = _mamba_dims(cfg)
    ks = split_keys(key, 6)
    return {
        "wz": dense_init(ks[0], (d, d_in)),
        "wx": dense_init(ks[1], (d, d_in)),
        "wbc": dense_init(ks[2], (d, 2 * n)),
        "wdt": dense_init(ks[3], (d, h)),
        "conv_x": dense_init(ks[4], (cw, d_in), scale=1.0 / math.sqrt(cw)),
        "conv_x_b": jnp.zeros((d_in,), jnp.bfloat16),
        "conv_bc": dense_init(ks[5], (cw, 2 * n), scale=1.0 / math.sqrt(cw)),
        "conv_bc_b": jnp.zeros((2 * n,), jnp.bfloat16),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((d_in,), jnp.bfloat16),
        "out_proj": dense_init(jax.random.fold_in(ks[0], 7), (d_in, d)),
    }


def _causal_conv(x, w, b, hist=None):
    """Depthwise causal conv; x (B,S,C), w (W,C); ``hist`` (B,W-1,C) carries
    the previous tokens' tail across prefill/decode boundaries (zeros when
    None).  Returns (y (B,S,C), new_tail (B,W-1,C))."""
    wsz = w.shape[0]
    s = x.shape[1]
    if hist is None:
        ext = jnp.pad(x, ((0, 0), (wsz - 1, 0), (0, 0)))
    else:
        ext = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    out = sum(ext[:, i : i + s, :] * w[i][None, None, :] for i in range(wsz))
    return out + b, ext[:, -(wsz - 1) :, :]


def mamba_apply(p, u, cfg: ArchConfig, state=None):
    """Full-sequence Mamba2; returns (y, (conv_tail_x, conv_tail_bc, ssm))."""
    b, s, d = u.shape
    d_in, h, n, p_, cw = _mamba_dims(cfg)
    z = u @ p["wz"]
    x_raw = u @ p["wx"]
    bc_raw = u @ p["wbc"]
    dt = u @ p["wdt"]
    hx = None if state is None else state[0]
    hbc = None if state is None else state[1]
    x_c, tail_x = _causal_conv(x_raw, p["conv_x"], p["conv_x_b"], hist=hx)
    bc_c, tail_bc = _causal_conv(bc_raw, p["conv_bc"], p["conv_bc_b"], hist=hbc)
    x = jax.nn.silu(x_c)
    bc = jax.nn.silu(bc_c)
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    la = -jnp.exp(p["a_log"]) * dt  # (B,S,H) log decay
    v = (x.reshape(b, s, h, p_).astype(jnp.float32) * dt[..., None]).astype(u.dtype)
    s0 = None if state is None else state[2]
    y, s_final = ssd_chunked(la, cmat, bmat, v, s0=s0, chunk=cfg.ssm.chunk)
    y = y + p["d_skip"][None, None, :, None] * x.reshape(b, s, h, p_)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ p["out_proj"]).astype(u.dtype)
    return out, (tail_x.astype(jnp.bfloat16), tail_bc.astype(jnp.bfloat16), s_final)


def mamba_decode(p, u, cfg: ArchConfig, state):
    """Single-token decode; state = (tail_x, tail_bc, ssm (B,H,N,P))."""
    b = u.shape[0]
    d_in, h, n, p_, cw = _mamba_dims(cfg)
    tail_x, tail_bc, ssm = state
    z = u @ p["wz"]
    x_raw = u @ p["wx"]
    bc_raw = u @ p["wbc"]
    dt = u @ p["wdt"]
    win_x = jnp.concatenate([tail_x.astype(x_raw.dtype), x_raw], axis=1)  # (B,cw,C)
    win_bc = jnp.concatenate([tail_bc.astype(bc_raw.dtype), bc_raw], axis=1)
    x = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_x, p["conv_x"]) + p["conv_x_b"])
    bc = jax.nn.silu(jnp.einsum("bwc,wc->bc", win_bc, p["conv_bc"]) + p["conv_bc_b"])
    bmat, cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    la = -jnp.exp(p["a_log"]) * dt
    v = (x.reshape(b, h, p_).astype(jnp.float32) * dt[..., None]).astype(u.dtype)
    y, ssm_new = ssd_decode_step(la, cmat, bmat, v, ssm)
    y = y + p["d_skip"][None, :, None] * x.reshape(b, h, p_)
    y = y.reshape(b, 1, d_in)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ p["out_proj"]).astype(u.dtype)
    return out, (
        win_x[:, 1:].astype(jnp.bfloat16),
        win_bc[:, 1:].astype(jnp.bfloat16),
        ssm_new,
    )


# --------------------------------------------------------------------- #
# mLSTM (xLSTM)
# --------------------------------------------------------------------- #
def _mlstm_dims(cfg: ArchConfig):
    d_in = int(cfg.d_model * cfg.xlstm.proj_factor)
    h = cfg.n_heads
    hd = d_in // h
    return d_in, h, hd, cfg.xlstm.conv_width


def mlstm_init(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, h, hd, cw = _mlstm_dims(cfg)
    ks = split_keys(key, 8)
    return {
        "wx_up": dense_init(ks[0], (d, d_in)),
        "wz_up": dense_init(ks[7], (d, d_in)),
        "conv_w": dense_init(ks[1], (cw, d_in), scale=1.0 / math.sqrt(cw)),
        "conv_b": jnp.zeros((d_in,), jnp.bfloat16),
        "wq": dense_init(ks[2], (d_in, d_in)),
        "wk": dense_init(ks[3], (d_in, d_in)),
        "wv": dense_init(ks[4], (d_in, d_in)),
        "wif": dense_init(ks[5], (d_in, 2 * h), dtype=jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((h,), jnp.float32), jnp.full((h,), 3.0, jnp.float32)]
        ),
        "norm": jnp.ones((d_in,), jnp.bfloat16),
        "down_proj": dense_init(ks[6], (d_in, d)),
    }


def _mlstm_gates(p, xc, b, s, h):
    gif = xc.astype(jnp.float32) @ p["wif"] + p["b_if"]
    li = gif[..., :h]
    lf = jax.nn.log_sigmoid(gif[..., h:])
    return li.reshape(b, s, h), lf.reshape(b, s, h)


def mlstm_apply(p, u, cfg: ArchConfig, state=None):
    b, s, d = u.shape
    d_in, h, hd, cw = _mlstm_dims(cfg)
    x_in = u @ p["wx_up"]
    z = u @ p["wz_up"]
    conv_out, conv_tail = _causal_conv(
        x_in, p["conv_w"], p["conv_b"], hist=None if state is None else state[0]
    )
    xc = jax.nn.silu(conv_out)
    q = (xc @ p["wq"]).reshape(b, s, h, hd)
    k = (xc @ p["wk"]).reshape(b, s, h, hd)
    v = (x_in @ p["wv"]).reshape(b, s, h, hd)
    li, lf = _mlstm_gates(p, xc, b, s, h)
    mstate = state[1] if state is not None else None
    y, mstate_new = mlstm_chunked(lf, li, q, k, v, state=mstate, chunk=cfg.xlstm.chunk)
    y = y.reshape(b, s, d_in)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ p["down_proj"]).astype(u.dtype)
    return out, (conv_tail.astype(jnp.bfloat16), mstate_new)


def mlstm_decode(p, u, cfg: ArchConfig, state):
    b = u.shape[0]
    d_in, h, hd, cw = _mlstm_dims(cfg)
    conv_tail, mstate = state
    x_in = u @ p["wx_up"]
    z = u @ p["wz_up"]
    window = jnp.concatenate([conv_tail.astype(x_in.dtype), x_in], axis=1)  # (B,cw,C)
    xc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])
    q = (xc @ p["wq"]).reshape(b, h, hd)
    k = (xc @ p["wk"]).reshape(b, h, hd)
    v = (x_in[:, 0] @ p["wv"]).reshape(b, h, hd)
    li, lf = _mlstm_gates(p, xc[:, None, :], b, 1, h)
    y, mstate_new = mlstm_decode_step(lf[:, 0], li[:, 0], q, k, v, mstate)
    y = y.reshape(b, 1, d_in)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = (y @ p["down_proj"]).astype(u.dtype)
    return out, (window[:, 1:].astype(jnp.bfloat16), mstate_new)


# --------------------------------------------------------------------- #
# sLSTM (xLSTM) — inherently sequential scalar-memory LSTM
# --------------------------------------------------------------------- #
def slstm_init(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = split_keys(key, 3)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d)),
        "r": dense_init(ks[1], (h, hd, 4 * hd), scale=1.0 / math.sqrt(hd)),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm": jnp.ones((d,), jnp.bfloat16),
        "w_ff1": dense_init(ks[2], (d, int(d * 4 / 3))),
        "w_ff2": dense_init(jax.random.fold_in(ks[2], 1), (int(d * 4 / 3), d)),
    }


def _slstm_cell(p, wx_t, state, h_, hd):
    """wx_t: (B, 4D) pre-computed input projection at step t."""
    hprev, c, n, m = state  # each (B, H, hd) except m (B, H)
    rec = jnp.einsum("bhd,hdk->bhk", hprev.astype(jnp.float32), p["r"].astype(jnp.float32))
    gates = wx_t.astype(jnp.float32).reshape(-1, h_, 4 * hd) + rec  # (B,H,4hd)
    zi, ii, fi, oi = jnp.split(gates, 4, axis=-1)
    # per-head scalar gates (mean over head dim keeps shapes (B,H,1))
    it = ii.mean(-1)
    ft = fi.mean(-1)
    m_new = jnp.maximum(ft + m, it)
    i_g = jnp.exp(it - m_new)[..., None]
    f_g = jnp.exp(ft + m - m_new)[..., None]
    c_new = f_g * c + i_g * jnp.tanh(zi)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(oi) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_apply(p, u, cfg: ArchConfig, state=None, time_chunk: int = 64):
    b, s, d = u.shape
    h_ = cfg.n_heads
    hd = d // h_
    wx = u @ p["w_in"] + p["b"].astype(u.dtype)  # (B,S,4D)
    if state is None:
        z = jnp.zeros((b, h_, hd), jnp.float32)
        state = (z, z, z, jnp.full((b, h_), -1e30, jnp.float32))

    def step(carry, wx_t):
        new = _slstm_cell(p, wx_t, carry, h_, hd)
        return new, new[0]

    if s % time_chunk == 0 and s > time_chunk:
        # remat per time-chunk: without this the scan saves 4 recurrent
        # states per step for the backward pass (gigabytes at S=4096).
        wxc = jnp.moveaxis(
            wx.reshape(b, s // time_chunk, time_chunk, 4 * d), 1, 0)

        @jax.checkpoint
        def chunk_fn(carry, wx_blk):  # wx_blk: (B, C, 4D)
            return jax.lax.scan(step, carry, jnp.moveaxis(wx_blk, 1, 0))

        state, hs = jax.lax.scan(chunk_fn, state, wxc)  # hs (nc, C, B, H, hd)
        hs = hs.reshape(s, b, h_, hd)
    else:
        state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(u.dtype)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = (jax.nn.gelu(y @ p["w_ff1"]) @ p["w_ff2"]).astype(u.dtype)
    return y, state


def slstm_decode(p, u, cfg: ArchConfig, state):
    y, new_state = slstm_apply(p, u, cfg, state=state)
    return y, new_state

"""Model factory: ``build_model(cfg)`` dispatches on family."""

from __future__ import annotations

from repro.models.common import ArchConfig
from repro.models.encdec import EncDecModel
from repro.models.recurrent import XLSTMModel, ZambaModel
from repro.models.transformer import TransformerLM


def build_model(cfg: ArchConfig):
    if cfg.enc_dec:
        return EncDecModel(cfg)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return XLSTMModel(cfg)
    if cfg.family == "hybrid":
        return ZambaModel(cfg)
    return TransformerLM(cfg)

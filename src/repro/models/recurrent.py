"""Recurrent-family models: xLSTM (mLSTM+sLSTM) and Zamba2 (Mamba2 hybrid).

Both are built from *macro-blocks* so heterogeneous layer types still scan:
  xLSTM : macro = (slstm_every-1) mLSTM layers + 1 sLSTM layer   (7:1 ratio)
  Zamba2: macro = attn_every Mamba2 layers + 1 invocation of a single
          SHARED attention+MLP block (Zamba2's parameter-sharing hallmark).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distribution.partition import shard
from repro.models import blocks
from repro.models.common import ArchConfig, rms_norm
from repro.models.transformer import _embed_init, _logits, _xent


# ===================================================================== #
# xLSTM
# ===================================================================== #
class XLSTMModel:
    def __init__(self, cfg: ArchConfig):
        assert cfg.xlstm is not None
        self.cfg = cfg
        se = cfg.xlstm.slstm_every
        self.n_macro = max(1, cfg.n_layers // se)
        self.m_per_macro = se - 1

    # ----------------------------- init ------------------------------ #
    def init(self, key):
        cfg = self.cfg
        k_emb, k_m, k_s = jax.random.split(key, 3)

        def macro_init(k):
            km, ks = jax.random.split(k)
            m_keys = jax.random.split(km, self.m_per_macro)
            return {
                "mlstm": jax.vmap(lambda kk: blocks.mlstm_init(kk, cfg))(m_keys),
                "mlstm_ln": jnp.ones((self.m_per_macro, cfg.d_model), jnp.bfloat16),
                "slstm": blocks.slstm_init(ks, cfg),
                "slstm_ln": jnp.ones((cfg.d_model,), jnp.bfloat16),
            }

        macros = jax.vmap(macro_init)(jax.random.split(k_m, self.n_macro))
        return {**_embed_init(k_emb, cfg), "macros": macros}

    # ---------------------------- forward ----------------------------- #
    def _run(self, p, h, states=None):
        """states: None (fresh) or pytree of per-layer states."""
        cfg = self.cfg

        def macro_fn(carry, scanned):
            x = carry
            mp = scanned["params"]
            mstates = scanned.get("states")

            def mlstm_fn(cx, inner):
                lp, ln, st = inner["p"], inner["ln"], inner.get("st")
                y, st_new = blocks.mlstm_apply(lp, rms_norm(cx, ln, cfg.norm_eps),
                                               cfg, state=st)
                return cx + shard(y, "dp", None, None), st_new

            inner_xs = {"p": mp["mlstm"], "ln": mp["mlstm_ln"]}
            if mstates is not None:
                inner_xs["st"] = mstates["mlstm"]
            x, m_states = jax.lax.scan(mlstm_fn, x, inner_xs)
            y, s_state = blocks.slstm_apply(
                mp["slstm"], rms_norm(x, mp["slstm_ln"], cfg.norm_eps), cfg,
                state=None if mstates is None else mstates["slstm"],
            )
            x = x + shard(y, "dp", None, None)
            return x, {"mlstm": m_states, "slstm": s_state}

        fn = jax.checkpoint(macro_fn) if cfg.remat == "full" else macro_fn
        xs = {"params": p["macros"]}
        if states is not None:
            xs["states"] = states
        h, new_states = jax.lax.scan(fn, h, xs)
        return h, new_states

    def loss(self, params, batch):
        cfg = self.cfg
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)
        h, _ = self._run(params, h)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        loss = _xent(_logits(params, h, cfg), batch["labels"], batch.get("loss_mask"))
        return loss, {"xent": loss}

    # ---------------------------- serving ----------------------------- #
    def cache_shape(self, batch_size: int, s_max: int):
        cfg = self.cfg
        d_in = int(cfg.d_model * cfg.xlstm.proj_factor)
        h = cfg.n_heads
        hd_i = d_in // h
        hd = cfg.d_model // h
        cw = cfg.xlstm.conv_width
        nm, mm = self.n_macro, self.m_per_macro
        f32 = jnp.float32
        return {
            "mlstm": (
                jax.ShapeDtypeStruct((nm, mm, batch_size, cw - 1, d_in), jnp.bfloat16),
                (
                    jax.ShapeDtypeStruct((nm, mm, batch_size, h, hd_i, hd_i), f32),
                    jax.ShapeDtypeStruct((nm, mm, batch_size, h, hd_i), f32),
                    jax.ShapeDtypeStruct((nm, mm, batch_size, h), f32),
                ),
            ),
            "slstm": tuple(
                jax.ShapeDtypeStruct((nm, batch_size, h, hd), f32) for _ in range(3)
            )
            + (jax.ShapeDtypeStruct((nm, batch_size, h), f32),),
        }

    def init_cache(self, batch_size: int, s_max: int):
        shapes = self.cache_shape(batch_size, s_max)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        # stabilizers start at -inf
        cache["mlstm"] = (
            cache["mlstm"][0],
            (cache["mlstm"][1][0], cache["mlstm"][1][1],
             jnp.full_like(cache["mlstm"][1][2], -1e30)),
        )
        sl = cache["slstm"]
        cache["slstm"] = (sl[0], sl[1], sl[2], jnp.full_like(sl[3], -1e30))
        return cache

    def cache_logical(self):
        from repro.distribution.partition import Axes

        return {
            "mlstm": (
                Axes(None, None, "dp", None, "tp"),  # conv tail
                (
                    Axes(None, None, "dp", "tp", None, None),  # S̃ (falls to hd)
                    Axes(None, None, "dp", "tp", None),  # ñ
                    Axes(None, None, "dp", "tp"),  # m
                ),
            ),
            "slstm": (
                Axes(None, "dp", "tp", None),
                Axes(None, "dp", "tp", None),
                Axes(None, "dp", "tp", None),
                Axes(None, "dp", "tp"),
            ),
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)
        states = self.init_cache(h.shape[0], 0)
        h, new_states = self._run(params, h, states=states)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, h[:, -1:, :], cfg), new_states

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)
        h, new_states = self._run(params, h, states=cache)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, h, cfg), new_states


# ===================================================================== #
# Zamba2 hybrid
# ===================================================================== #
class ZambaModel:
    def __init__(self, cfg: ArchConfig):
        assert cfg.ssm is not None and cfg.attn_every > 0
        self.cfg = cfg
        self.m_per_macro = cfg.attn_every
        self.n_macro = max(1, round(cfg.n_layers / (cfg.attn_every + 1)))

    # ----------------------------- init ------------------------------ #
    def init(self, key):
        cfg = self.cfg
        k_emb, k_m, k_sh = jax.random.split(key, 3)

        def macro_init(k):
            m_keys = jax.random.split(k, self.m_per_macro)
            return {
                "mamba": jax.vmap(lambda kk: blocks.mamba_init(kk, cfg))(m_keys),
                "mamba_ln": jnp.ones((self.m_per_macro, cfg.d_model), jnp.bfloat16),
            }

        macros = jax.vmap(macro_init)(jax.random.split(k_m, self.n_macro))
        k1, k2 = jax.random.split(k_sh)
        shared = {
            "ln1": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "ln2": jnp.ones((cfg.d_model,), jnp.bfloat16),
            "attn": blocks.attn_init(k1, cfg),
            "mlp": blocks.mlp_init(k2, cfg),
        }
        return {**_embed_init(k_emb, cfg), "macros": macros, "shared": shared}

    # ---------------------------- forward ----------------------------- #
    def _shared_block(self, sp, x, positions, kv_cache=None, pos=None):
        cfg = self.cfg
        if kv_cache is None:
            a, kv = blocks.attn_apply(sp["attn"], rms_norm(x, sp["ln1"], cfg.norm_eps),
                                      cfg, positions=positions)
        else:
            a, kv = blocks.attn_decode(sp["attn"], rms_norm(x, sp["ln1"], cfg.norm_eps),
                                       cfg, kv_cache, pos)
        x = x + shard(a, "dp", None, None)
        m = blocks.mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
        return x + shard(m, "dp", None, None), kv

    def _run(self, p, h, positions, states=None, decode_pos=None):
        cfg = self.cfg
        decode = decode_pos is not None

        def macro_fn(carry, scanned):
            x = carry
            mp = scanned["params"]
            mstates = scanned.get("states")

            def mamba_fn(cx, inner):
                lp, ln, st = inner["p"], inner["ln"], inner.get("st")
                if decode:
                    y, st_new = blocks.mamba_decode(
                        lp, rms_norm(cx, ln, cfg.norm_eps), cfg, st)
                else:
                    y, st_new = blocks.mamba_apply(
                        lp, rms_norm(cx, ln, cfg.norm_eps), cfg, state=st)
                return cx + shard(y, "dp", None, None), st_new

            inner_xs = {"p": mp["mamba"], "ln": mp["mamba_ln"]}
            if mstates is not None:
                inner_xs["st"] = mstates["mamba"]
            x, m_states = jax.lax.scan(mamba_fn, x, inner_xs)
            kv_in = None if mstates is None else mstates.get("attn_kv")
            x, kv = self._shared_block(p["shared"], x, positions,
                                       kv_cache=kv_in, pos=decode_pos)
            out_states = {"mamba": m_states}
            if decode or mstates is not None:
                out_states["attn_kv"] = {
                    "k": kv["k"] if isinstance(kv, dict) else kv[0].astype(jnp.bfloat16),
                    "v": kv["v"] if isinstance(kv, dict) else kv[1].astype(jnp.bfloat16),
                }
            return x, out_states

        fn = jax.checkpoint(macro_fn) if (cfg.remat == "full" and not decode) else macro_fn
        xs = {"params": p["macros"]}
        if states is not None:
            xs["states"] = states
        h, new_states = jax.lax.scan(fn, h, xs)
        return h, new_states

    def loss(self, params, batch):
        cfg = self.cfg
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h, _ = self._run(params, h, positions)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        loss = _xent(_logits(params, h, cfg), batch["labels"], batch.get("loss_mask"))
        return loss, {"xent": loss}

    # ---------------------------- serving ----------------------------- #
    def cache_shape(self, batch_size: int, s_max: int):
        cfg = self.cfg
        ssm = cfg.ssm
        d_in = ssm.expand * cfg.d_model
        h = d_in // ssm.head_dim
        nm, mm = self.n_macro, self.m_per_macro
        cw = ssm.conv_width
        f32 = jnp.float32
        return {
            "mamba": (
                jax.ShapeDtypeStruct((nm, mm, batch_size, cw - 1, d_in), jnp.bfloat16),
                jax.ShapeDtypeStruct((nm, mm, batch_size, cw - 1, 2 * ssm.d_state), jnp.bfloat16),
                jax.ShapeDtypeStruct((nm, mm, batch_size, h, ssm.d_state, ssm.head_dim), f32),
            ),
            "attn_kv": {
                "k": jax.ShapeDtypeStruct(
                    (nm, batch_size, s_max, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
                "v": jax.ShapeDtypeStruct(
                    (nm, batch_size, s_max, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
            },
        }

    def init_cache(self, batch_size: int, s_max: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shape(batch_size, s_max))

    def cache_logical(self):
        from repro.distribution.partition import Axes

        return {
            "mamba": (
                Axes(None, None, "dp", None, "tp"),  # conv tail x
                Axes(None, None, "dp", None, "tp"),  # conv tail bc
                Axes(None, None, "dp", "tp", None, None),  # ssm state
            ),
            "attn_kv": {
                "k": Axes(None, "dp", None, "tp", None),
                "v": Axes(None, "dp", None, "tp", None),
            },
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)
        b, s, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        states = self.init_cache(b, 0)
        # drop the kv part for prefill run; collect kv from attn outputs
        states_in = {"mamba": states["mamba"]}
        h, new_states = self._run(params, h, positions, states=states_in)
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, h[:, -1:, :], cfg), new_states

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        h = shard(params["embed"][batch["tokens"]], "dp", None, None)
        b = h.shape[0]
        pos_b = jnp.broadcast_to(jnp.asarray(batch["pos"], jnp.int32), (b,))
        positions = pos_b[:, None]
        h, new_states = self._run(params, h, positions, states=cache,
                                  decode_pos=batch["pos"])
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return _logits(params, h, cfg), new_states

"""Shared model components: configs, norms, rotary embeddings, MLPs,
memory-efficient attention.

Everything is functional: params are plain dict pytrees, layers are pure
functions.  bf16 weights / bf16 activations with fp32 softmax, norms and
accumulations.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- #
# Configs
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden size
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    slstm_every: int = 8  # one sLSTM per this many layers (7:1 mLSTM ratio)
    proj_factor: float = 2.0
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # defaults to d_model // n_heads
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10_000.0
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    xlstm: XLSTMCfg | None = None
    mrope: bool = False  # multimodal 3-axis rotary (qwen2-vl)
    enc_dec: bool = False  # whisper-style encoder-decoder
    n_enc_layers: int = 0
    frontend: str | None = None  # "vision_stub" | "audio_stub"
    frontend_dim: int = 1280  # stub patch/frame feature size
    attn_every: int = 0  # hybrid: one shared attn block per N ssm layers
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- performance knobs (hillclimbed; see EXPERIMENTS.md §Perf) ---
    q_chunk: int = 1024
    k_chunk: int = 2048
    attn_impl: str = "auto"  # auto | dense | chunked
    remat: str = "full"  # full | none
    seq_shard_activations: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def num_params(self) -> int:
        """Total parameter count N (for MODEL_FLOPS = 6·N·D accounting)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embed
        if not self.tie_embeddings:
            n += d * v  # lm_head
        per_layer = self._params_per_layer()
        n += self.n_layers * per_layer["default"]
        n += per_layer.get("extra", 0)
        if self.enc_dec:
            n += self.n_enc_layers * per_layer["encoder"]
        if self.frontend:
            n += self.frontend_dim * d  # stub projection
        return n

    def num_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.num_params()
        d, v = self.d_model, self.vocab
        n = v * d + (0 if self.tie_embeddings else d * v)
        attn = self._attn_params()
        expert = 3 * d * self.moe.d_expert
        router = d * self.moe.num_experts
        n += self.n_layers * (attn + 2 * d + router + self.moe.top_k * expert)
        return n

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.hd
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _params_per_layer(self) -> dict[str, int]:
        d = self.d_model
        attn = self._attn_params()
        if self.family == "moe":
            assert self.moe is not None
            ffn = self.moe.num_experts * 3 * d * self.moe.d_expert
            ffn += d * self.moe.num_experts  # router
            return {"default": attn + ffn + 2 * d}
        if self.family == "ssm" and self.xlstm is not None:
            # mLSTM block params (dominant): in/out proj + qkv + gates
            di = int(d * self.xlstm.proj_factor)
            m = 2 * d * di + 3 * di * di // 1 + 2 * di + di  # approx
            return {"default": m + 2 * d}
        if self.family == "hybrid" and self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            mamba = d * (2 * di + 2 * self.ssm.d_state + nh) + di * d + di
            shared_attn = attn + 3 * d * self.d_ff + 2 * d
            return {"default": mamba + 2 * d, "extra": shared_attn}
        if self.enc_dec:
            dec = attn * 2 + 2 * d * self.d_ff + 3 * d  # self+cross attn, GELU mlp
            enc = attn + 2 * d * self.d_ff + 2 * d
            return {"default": dec, "encoder": enc}
        return {"default": attn + 3 * d * self.d_ff + 2 * d}


# --------------------------------------------------------------------- #
# Shape/batch spec per assigned input-shape set
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# --------------------------------------------------------------------- #
# Primitives
# --------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, Dh); positions: broadcastable (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


def mrope(x: jax.Array, positions3: jax.Array, theta: float) -> jax.Array:
    """Qwen2-VL M-RoPE: the head dim is split into 3 sections rotated by
    temporal / height / width position ids.  positions3: (3, B, S)."""
    dh = x.shape[-1]
    sec = dh // 2 // 4  # section split 1:1:2 over (t,h,w) quarters of half-dim
    splits = [sec, sec, dh // 2 - 2 * sec]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    parts = []
    lo = 0
    for i, width in enumerate(splits):
        pos = positions3[i]  # (B, S)
        ang = pos[..., None].astype(jnp.float32) * freqs[lo : lo + width]
        parts.append(ang)
        lo += width
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


def swiglu(x, w1, w3, w2):
    """LLaMA-style gated MLP: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2


# --------------------------------------------------------------------- #
# Memory-efficient attention (online softmax over KV chunks)
# --------------------------------------------------------------------- #
def _repeat_kv(k, v, g: int):
    """Expand GQA kv heads to the full head count.  A single 64-wide head
    axis shards cleanly under 16-way TP; the grouped (hkv, g) form makes the
    SPMD partitioner replicate ('involuntary full rematerialization')."""
    if g == 1:
        return k, v
    return jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)


def _attn_dense(q, k, v, *, causal: bool, window: int | None, q_offset: int = 0):
    """Plain attention; q: (B,Sq,H,Dh), k/v: (B,Sk,Hkv,Dh).  Scores fp32."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    k, v = _repeat_kv(k, v, h // hkv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    scores *= 1.0 / math.sqrt(dh)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def _attn_chunked(q, k, v, *, causal: bool, window: int | None, q_chunk: int,
                  k_chunk: int, q_offset: int = 0):
    """FlashAttention-style two-level chunking in pure jnp: scan over KV
    chunks with running (max, sum, acc); outer map over query chunks.  Never
    materializes the (Sq, Sk) score matrix."""
    b, sq, h, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    qc = min(q_chunk, sq)
    kc = min(k_chunk, sk)
    n_q, n_k = sq // qc, sk // kc
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)
    scale = 1.0 / math.sqrt(dh)
    # repeat kv ONCE before chunking: inside the scan the unshardable
    # hkv-head block would be re-gathered per (q-chunk × kv-chunk) step
    # (measured 8e11 B on deepseek prefill); the 64-head copy shards on tp.
    k, v = _repeat_kv(k, v, h // hkv)

    kr = k.reshape(b, n_k, kc, h, dh)
    vr = v.reshape(b, n_k, kc, h, dh)

    def one_q_chunk(qi, q_blk):
        # q_blk: (B, qc, H, Dh)
        qpos = qi * qc + jnp.arange(qc) + q_offset

        def kv_step(carry, inputs):
            m, s, acc = carry
            ki, k_blk, v_blk = inputs
            kpos = ki * kc + jnp.arange(kc)
            scores = jnp.einsum(
                "bqhd,bkhd->bhqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            scores = jnp.where(mask[None, None], scores, -1e30)
            m_new = jnp.maximum(m, scores.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(scores - m_new[..., None])
            s_new = s * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32)
            )
            return (m_new, s_new, acc_new), None

        m0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
        s0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, dh), jnp.float32)
        (m, s, acc), _ = jax.lax.scan(
            kv_step,
            (m0, s0, a0),
            (jnp.arange(n_k), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)),
        )
        out = acc / jnp.maximum(s, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2).reshape(b, qc, h, dh).astype(q.dtype)

    qs = jnp.moveaxis(q.reshape(b, n_q, qc, h, dh), 1, 0)
    # flash-style backward: recompute scores/probs per chunk instead of
    # saving the (qc, kc) fp32 probability tensors of every chunk pair
    # (which would cost tens of GB per layer at 32k context).
    if causal and window is None and q_offset == 0 and sq == sk and n_q > 1:
        # causal skip: q chunk qi only attends to kv chunks covering
        # positions ≤ (qi+1)·qc — statically unrolled per q chunk so the
        # fully-masked upper-triangle chunk pairs are never computed
        # (≈2× fewer attention FLOPs at long context).
        outs = []
        for qi in range(n_q):
            n_k_i = min(n_k, -(-(qi + 1) * qc // kc))
            fn = jax.checkpoint(
                lambda q_blk, kr_i, vr_i, qi=qi: _flash_q_chunk(
                    q_blk, kr_i, vr_i, qi, qc, kc, causal, window, q_offset,
                    scale))
            outs.append(fn(qs[qi], kr[:, :n_k_i], vr[:, :n_k_i]))
        return jnp.stack(outs, 1).reshape(b, sq, h, dh)
    chunk_fn = jax.checkpoint(lambda t: one_q_chunk(t[0], t[1]))
    outs = jax.lax.map(chunk_fn, (jnp.arange(n_q), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh)


def _flash_q_chunk(q_blk, kr, vr, qi, qc, kc, causal, window, q_offset, scale):
    """One q chunk against a truncated kv-chunk range (causal skip)."""
    b, _, h, dh = q_blk.shape
    n_k = kr.shape[1]
    qpos = qi * qc + jnp.arange(qc) + q_offset

    def kv_step(carry, inputs):
        m, s, acc = carry
        ki, k_blk, v_blk = inputs
        kpos = ki * kc + jnp.arange(kc)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((qc, kc), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        s_new = s * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        return (m_new, s_new, acc_new), None

    m0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((b, h, qc), jnp.float32)
    a0 = jnp.zeros((b, h, qc, dh), jnp.float32)
    (m, s, acc), _ = jax.lax.scan(
        kv_step, (m0, s0, a0),
        (jnp.arange(n_k), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)))
    out = acc / jnp.maximum(s, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q_blk.dtype)


def attention(q, k, v, *, causal=True, window=None, impl="auto", q_chunk=1024,
              k_chunk=2048, q_offset=0):
    """Dispatch between dense and chunked attention."""
    sq, sk = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "chunked" if (sq > 2048 and sk > 2048) else "dense"
    qc, kc = min(q_chunk, sq), min(k_chunk, sk)
    if impl == "dense" or sq % qc != 0 or sk % kc != 0:
        return _attn_dense(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return _attn_chunked(q, k, v, causal=causal, window=window, q_chunk=qc,
                         k_chunk=kc, q_offset=q_offset)


# --------------------------------------------------------------------- #
# Initialization helpers
# --------------------------------------------------------------------- #
def dense_init(key, shape, dtype=jnp.bfloat16, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))

"""Propagation backend registry and device kernels.

`ops.py` is the front door: the `BackendSpec` registry behind
`run_propagation` (see docs/backends.md).  The kernel modules back the
registered backends — `propagate_pallas` (fused ELL), `bsr_spmv` (MXU
tiles), `landmark_propagate` (hot/cold approximate staging) — plus the
ingest argkmin pass and the Shiloach–Vishkin hook used for component
reordering.  The layer stays optional: every backend has an exact XLA
reference path, so TPU-less environments degrade instead of crashing.
"""

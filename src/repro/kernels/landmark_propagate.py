"""Landmark / low-rank cold-tail state for the ``landmark`` backend.

Every exact backend (``ref``, ``ell_pallas``, ``bsr``) stages the FULL
unlabeled row set on device each Δ_t, so graph size is capped by device
memory.  The ``landmark`` backend (registered in ``kernels.ops``) splits
the graph instead:

  * **hot working set** — frontier + recently-touched rows (tracked per
    batch by ``core.stream.StreamEngine``) solve EXACTLY: the
    hot-restricted snapshot (``core.snapshot.build_host_problem(hot=…)``)
    folds each cold unlabeled neighbor's committed fractional label into
    the supernode weights, which makes the restricted solve a true Jacobi
    fixpoint on the hot subgraph with the cold tail as fixed boundary —
    the barriered ``update_island`` arithmetic, every registry backend
    body, and both mesh transports are reused unchanged;
  * **cold tail** — served through the low-rank factorization held here:
    ``L`` landmark vertices (sampled evenly over the alive set), their
    committed labels ``fL`` refreshed at every commit in O(L), and a
    device-resident per-node assignment ``(N_pad, R)`` of nearest
    landmarks with cosine weights, built by reusing the
    ``kernels.argkmin`` pass against the landmark block and refreshed
    **incrementally** (only rows appended since the last commit are
    re-assigned; a full rebuild happens only on landmark resampling).

Cold estimates are ``f_v = Σ_r W[v,r] · fL[idx[v,r]]`` — one jitted
gather-reduce over ladder-bucketed shapes (``landmark_cache_size``
counts its compiles), written back at commit so cold labels keep moving
with the landmark labels at O(N·R) instead of O(edges · sweeps).

This is the repo's first accuracy-vs-speed backend: unlike the
bit-equality contract of the exact backends, ``landmark`` gates a
recorded hot-set agreement floor (``benchmarks/landmark_lp.py``,
``BENCH_landmark.json``).  See docs/backends.md.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.argkmin import argkmin_candidates

# assignment rows are processed in fixed-size chunks so an unbounded
# stream compiles one scatter shape, not one per batch size
ASSIGN_CHUNK = 1024

# assignment-table row ladder (doubling, like the embedding store's
# capacity ladder) — keeps ``_grow_assign``/``_cold_pass`` compiles
# bounded by O(log N)
ASSIGN_FLOOR = 1024


def _dim_pad(d: int) -> int:
    # mirrors ingest.embedding_store.dim_pad; duplicated (3 lines) so this
    # module never imports the ingest package (which imports kernels back)
    return max(8, -8 * (-d // 8))


def _assign_bucket(n: int, floor: int = ASSIGN_FLOOR) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


def _donate(*argnums):
    # GPU XLA can't alias these shapes and would warn per call
    return () if jax.default_backend() == "gpu" else argnums


@functools.partial(jax.jit, static_argnames=("r",),
                   donate_argnums=_donate(0, 1))
def _scatter_assign(assign_idx, assign_w, rows, val, idx, r):
    """Fold one argkmin chunk into the assignment table.

    ``val``/``idx`` are the argkmin top-k against the landmark block
    (``-inf`` marks empty slots); keep the best ``r`` per row, normalize
    the cosine weights to sum 1 (all-zero rows mean "no assignment" and
    are skipped by callers of ``_cold_pass``), and scatter at ``rows``
    (out-of-range padding rows drop).
    """
    val = val[:, :r]
    idx = idx[:, :r]
    if val.shape[1] < r:  # fewer landmarks than r: pad with empty slots
        pad = r - val.shape[1]
        val = jnp.pad(val, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        idx = jnp.pad(idx, ((0, 0), (0, pad)))
    w = jnp.where(jnp.isfinite(val), jnp.maximum(val, 0.0), 0.0)
    wsum = jnp.sum(w, axis=1, keepdims=True)
    w = jnp.where(wsum > 0, w / jnp.maximum(wsum, 1e-30), 0.0)
    assign_idx = assign_idx.at[rows].set(idx.astype(jnp.int32), mode="drop")
    assign_w = assign_w.at[rows].set(w.astype(jnp.float32), mode="drop")
    return assign_idx, assign_w


@functools.partial(jax.jit, static_argnames=("new_cap",))
def _grow_assign(assign_idx, assign_w, new_cap):
    """Pad the assignment table up the row ladder (output outgrows input,
    so no aliasing)."""
    pad = new_cap - assign_idx.shape[0]
    r = assign_idx.shape[1]
    return (jnp.concatenate([assign_idx, jnp.zeros((pad, r), jnp.int32)]),
            jnp.concatenate([assign_w, jnp.zeros((pad, r), jnp.float32)]))


@jax.jit
def _cold_pass(assign_idx, assign_w, lm_f):
    """The low-rank cold-tail pass: per-node landmark-weighted label
    estimate plus the per-node assignment weight sum (0 = no estimate)."""
    est = jnp.sum(assign_w * lm_f[assign_idx], axis=1)
    return est, jnp.sum(assign_w, axis=1)


def landmark_cache_size() -> int:
    """Live jit cache entries across the landmark update kernels
    (compile-once telemetry; the argkmin pass it reuses is counted by
    ``kernels.argkmin.argkmin_cache_size``)."""
    return int(sum(f._cache_size()
                   for f in (_scatter_assign, _grow_assign, _cold_pass)))


@dataclasses.dataclass(frozen=True)
class LandmarkConfig:
    """Knobs of the landmark cold-tail factorization.

    ``hot_ttl`` is the working-set window in batches: a vertex stays hot
    (solved exactly) for this many batches after it was last touched by a
    Δ_t, then falls to the cold tail.  ``resample_factor`` and
    ``dead_frac_max`` bound landmark staleness: the landmark set is
    resampled (and the assignment table fully rebuilt) when the alive set
    outgrows the sampled one by the factor, or when too many landmarks
    have been deleted.
    """

    num_landmarks: int = 64
    assign_k: int = 4  # landmarks per node (R)
    hot_ttl: int = 4
    resample_factor: float = 2.0
    dead_frac_max: float = 0.1

    def __post_init__(self):
        if self.num_landmarks < 1 or self.assign_k < 1 or self.hot_ttl < 0:
            raise ValueError(
                f"invalid LandmarkConfig: num_landmarks={self.num_landmarks} "
                f"assign_k={self.assign_k} hot_ttl={self.hot_ttl}")


class LandmarkState:
    """Device-resident landmark factorization, refreshed at commit
    boundaries by ``core.stream.StreamEngine``.

    Activation is lazy: until the alive set reaches twice
    ``num_landmarks`` (so the landmark block has one stable shape) the
    state reports ``ready == False`` and the engine streams unrestricted.
    After activation, ``refresh`` is incremental — only rows appended
    since the last call are assigned; a landmark resample (growth or
    deaths, see ``LandmarkConfig``) rebuilds the whole table.
    """

    def __init__(self, cfg: LandmarkConfig, emb_dim: int):
        self.cfg = cfg
        self.emb_dim = emb_dim
        self.dp = _dim_pad(emb_dim)
        self.lm_ids: np.ndarray | None = None  # (L,) global landmark ids
        self.lm_emb: jax.Array | None = None  # (L, dp) normalized rows
        self.lm_valid: jax.Array | None = None  # (L,) bool
        self.assign_idx: jax.Array | None = None  # (N_pad, R) int32
        self.assign_w: jax.Array | None = None  # (N_pad, R) f32, rows sum 1
        self.assigned_upto = 0  # rows [0, assigned_upto) hold assignments
        self.sampled_alive = 0  # alive count at the last (re)sample
        self.resamples = 0

    @property
    def ready(self) -> bool:
        """True once landmarks are sampled and assignments exist."""
        return self.lm_ids is not None

    @property
    def num_landmarks(self) -> int:
        """Landmarks in the current sample (0 before activation)."""
        return 0 if self.lm_ids is None else len(self.lm_ids)

    # ------------------------------------------------------------------ #
    def _emb_rows(self, g, store, lo: int, hi: int) -> jax.Array:
        """Normalized embedding rows [lo, hi) as a (hi-lo, dp) device
        block — from the ingest store when one is attached (already
        device-resident and dim-padded), else staged from the host
        graph's ``embn``.  A ``ShardedEmbeddingStore`` serves these and
        ``landmark_gather`` as mesh-replicated blocks, so the landmark
        assignment kernels below run unchanged over a row-sharded
        ladder."""
        if store is not None and store.count >= hi:
            return store.landmark_rows(lo, hi)
        block = np.zeros((hi - lo, self.dp), np.float32)
        embn = g.embn[lo:hi]
        block[:, : embn.shape[1]] = embn
        return jnp.asarray(block)

    def _gather_landmarks(self, g, store, ids: np.ndarray) -> jax.Array:
        if store is not None and store.count >= g.num_nodes:
            return store.landmark_gather(ids)
        block = np.zeros((len(ids), self.dp), np.float32)
        embn = g.embn[ids]
        block[:, : embn.shape[1]] = embn
        return jnp.asarray(block)

    # ------------------------------------------------------------------ #
    def _needs_resample(self, g) -> bool:
        if self.lm_ids is None:
            return True
        n_alive = int(g.alive.sum())
        if n_alive > self.cfg.resample_factor * max(1, self.sampled_alive):
            return True
        dead = int((~g.alive[self.lm_ids]).sum())
        return dead > self.cfg.dead_frac_max * len(self.lm_ids)

    def refresh(self, g, store=None) -> None:
        """Bring the factorization up to date with the graph (called at
        commit boundaries).  No-op before activation and when nothing
        changed; O(rows appended since last call) otherwise; O(N·L) only
        on a landmark resample."""
        n = g.num_nodes
        n_alive = int(g.alive.sum())
        if self.lm_ids is None and n_alive < 2 * self.cfg.num_landmarks:
            return  # not enough rows for a stable landmark block yet
        if self._needs_resample(g):
            alive_ids = np.flatnonzero(g.alive)
            pick = np.unique(np.linspace(
                0, len(alive_ids) - 1, self.cfg.num_landmarks).round()
                .astype(np.int64))
            self.lm_ids = alive_ids[pick]
            # keep the landmark-block shape stable across resamples: pad
            # by repeating row 0 with valid=False (inert in argkmin)
            ids_pad = np.zeros(self.cfg.num_landmarks, np.int64)
            ids_pad[: len(self.lm_ids)] = self.lm_ids
            self.lm_emb = self._gather_landmarks(g, store, ids_pad)
            lv = np.zeros(self.cfg.num_landmarks, bool)
            lv[: len(self.lm_ids)] = True
            self.lm_valid = jnp.asarray(lv)
            self.sampled_alive = n_alive
            self.assigned_upto = 0  # full rebuild below
            self.resamples += 1
        if self.assigned_upto >= n:
            return
        cap = _assign_bucket(n)
        if self.assign_idx is None:
            r = self.cfg.assign_k
            self.assign_idx = jnp.zeros((cap, r), jnp.int32)
            self.assign_w = jnp.zeros((cap, r), jnp.float32)
        elif cap > self.assign_idx.shape[0]:
            self.assign_idx, self.assign_w = _grow_assign(
                self.assign_idx, self.assign_w, cap)
        l_pad = int(self.lm_emb.shape[0])
        kth = jnp.full((l_pad,), -jnp.inf, jnp.float32)
        for lo in range(self.assigned_upto, n, ASSIGN_CHUNK):
            hi = min(lo + ASSIGN_CHUNK, n)
            block = self._emb_rows(g, store, lo, hi)
            m = hi - lo
            if m < ASSIGN_CHUNK:  # pad the tail chunk to the fixed shape
                block = jnp.pad(block, ((0, ASSIGN_CHUNK - m), (0, 0)))
            bvalid = jnp.asarray(np.arange(ASSIGN_CHUNK) < m)
            # base_id >= landmark rows disables the kernel's self-match
            # diagonal: nodes may legitimately BE landmarks
            val, idx, _ = argkmin_candidates(
                self.lm_emb, self.lm_valid, kth, block, bvalid,
                base_id=l_pad, slack=0.0, k=self.cfg.assign_k,
                backend="xla")
            rows = np.full(ASSIGN_CHUNK, self.assign_idx.shape[0], np.int32)
            rows[:m] = np.arange(lo, hi)  # OOB pad rows drop in the scatter
            self.assign_idx, self.assign_w = _scatter_assign(
                self.assign_idx, self.assign_w, jnp.asarray(rows), val, idx,
                r=self.cfg.assign_k)
        self.assigned_upto = n

    # ------------------------------------------------------------------ #
    def landmark_values(self, g) -> np.ndarray:
        """The (L,) committed landmark labels ``fL`` — ground-truth label
        for seeded landmarks, committed fractional label otherwise.  O(L)
        per commit; this is the whole "refresh the label matrix
        incrementally" cost."""
        ids_pad = np.zeros(self.cfg.num_landmarks, np.int64)
        ids_pad[: len(self.lm_ids)] = self.lm_ids
        f = g.f[ids_pad].astype(np.float32)
        lab = g.labels[ids_pad]
        return np.where(lab >= 0, lab.astype(np.float32), f)

    def cold_values(self, lm_f: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Low-rank label estimates for every assigned row.

        Returns host ``(est, wsum)`` over the padded node axis; rows with
        ``wsum == 0`` (never assigned, or no valid landmark) have no
        estimate and must keep their previous label.
        """
        est, wsum = _cold_pass(self.assign_idx, self.assign_w,
                               jnp.asarray(lm_f))
        return np.asarray(est), np.asarray(wsum)

    # ------------------------------------------------------------------ #
    def state_arrays(self) -> dict:
        """Device/host arrays for persistence (``core.persistence``)."""
        return {"ids": np.asarray(self.lm_ids, np.int64),
                "emb": self.lm_emb, "lm_valid": self.lm_valid,
                "assign_idx": self.assign_idx, "assign_w": self.assign_w}

    def state_meta(self) -> dict:
        """JSON-friendly scalar state for the checkpoint ``meta`` leaf."""
        return {"num_landmarks": self.cfg.num_landmarks,
                "assign_k": self.cfg.assign_k,
                "hot_ttl": self.cfg.hot_ttl,
                "resample_factor": self.cfg.resample_factor,
                "dead_frac_max": self.cfg.dead_frac_max,
                "assigned_upto": int(self.assigned_upto),
                "sampled_alive": int(self.sampled_alive),
                "resamples": int(self.resamples)}

    def load_state(self, arrays: dict, meta: dict) -> None:
        """Adopt a persisted snapshot (restore path)."""
        self.lm_ids = np.asarray(arrays["ids"], np.int64)
        self.lm_emb = jnp.asarray(np.asarray(arrays["emb"], np.float32))
        self.lm_valid = jnp.asarray(np.asarray(arrays["lm_valid"], bool))
        self.assign_idx = jnp.asarray(
            np.asarray(arrays["assign_idx"], np.int32))
        self.assign_w = jnp.asarray(
            np.asarray(arrays["assign_w"], np.float32))
        self.assigned_upto = int(meta["assigned_upto"])
        self.sampled_alive = int(meta["sampled_alive"])
        self.resamples = int(meta["resamples"])

"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_propagate_ref(nbr, wgt, wl0, wl1, frontier, f, delta=1e-4):
    """Reference for kernels.ell_propagate.ell_propagate_step."""
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    f_v = f[idx]
    nbr_term = jnp.sum(wgt * jnp.where(mask, f_v - f[:, None], 0.0), axis=1)
    wall = jnp.sum(wgt, axis=1) + wl0 + wl1
    delta_f = (0.0 - f) * wl0 + (1.0 - f) * wl1 + nbr_term
    f_new = f + jnp.where(wall > 0, delta_f / jnp.maximum(wall, 1e-30), 0.0)
    f_new = jnp.where(frontier, f_new, f)
    return f_new, jnp.abs(f_new - f) > delta


def cc_hook_ref(nbr, par):
    """Reference for kernels.cc_hook.cc_hook_step: one fused SV hook+jump.

    The jump gathers through the PREVIOUS parent vector (Jacobi-style, as
    the kernel reads its VMEM-resident input), not through the freshly
    hooked values — both iterate to the same min-label fixpoint."""
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, jnp.arange(nbr.shape[0], dtype=nbr.dtype)[:, None])
    nbr_par = jnp.where(mask, par[idx], jnp.iinfo(jnp.int32).max)
    hooked = jnp.minimum(par, jnp.min(nbr_par, axis=1))
    return par[hooked]


def bsr_spmv_ref(blocks, block_cols, x):
    """Reference for kernels.bsr_spmv.bsr_spmv.

    blocks: (R, J, BS, BS) dense tiles of a block-sparse matrix (row-padded
    BSR: each block row has J slots; unused slots have block_cols == -1 and
    zero tiles).  block_cols: (R, J) int32.  x: (R*BS,) wait — x is (C*BS,).
    Returns y = A @ x with A the (R*BS, C*BS) matrix the blocks describe.
    """
    r, j, bs, _ = blocks.shape
    y = jnp.zeros((r, bs), jnp.float32)
    for jj in range(j):
        cols = block_cols[:, jj]
        valid = cols >= 0
        xi = x.reshape(-1, bs)[jnp.where(valid, cols, 0)]  # (R, BS)
        y += jnp.where(valid[:, None],
                       jnp.einsum("rab,rb->ra", blocks[:, jj].astype(jnp.float32),
                                  xi.astype(jnp.float32)),
                       0.0)
    return y.reshape(r * bs)

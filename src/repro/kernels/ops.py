"""Kernel dispatch layer — one entry point for every propagation backend.

``run_propagation(problem, f0, frontier0, ...)`` routes a DynLP Step-3
solve to one of three interchangeable implementations:

  * ``"ref"``        — the XLA reference engine (``core.propagate``), the
                       right answer on CPU and the allclose oracle
                       everywhere else.
  * ``"ell_pallas"`` — the fused ELL Pallas kernel loop
                       (``propagate_pallas``): VPU path on TPU, interpret
                       mode off-TPU.
  * ``"bsr"``        — block-sparse MXU path: the neighbor aggregation runs
                       as ``bsr_spmv`` over a component-reordered
                       block-dense matrix.  Opt-in (never chosen by
                       ``"auto"``) because densification is O(U²) on the
                       host.

``backend="auto"`` picks by hardware + problem shape: ``ell_pallas`` on
TPU (``ref`` for tiny problems where kernel-launch overhead dominates),
``ref`` otherwise; the ``REPRO_BACKEND`` environment variable replaces
the *auto* default for fleet-wide flips without code changes (an
explicitly passed backend still wins).  ``interpret`` defaults to True
off-TPU, so Pallas backends *degrade to the interpreter instead of
crashing* in TPU-less environments (CI, laptops).

``donate=True`` routes through jit wrappers that donate the ``f0`` /
``frontier0`` buffers — the streaming engine feeds freshly staged device
arrays every Δ_t and lets XLA recycle them in place rather than allocate
per batch.  ``compile_cache_size()`` exposes the summed jit-cache entry
count of every propagation entry point: the streaming tests assert it
stays ≤ the shape-bucket ladder size (compile-once contract).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.propagate import PropagateResult, PropagationProblem, propagate
from repro.kernels.bsr_spmv import bsr_spmv, dense_to_bsr  # noqa: F401
from repro.kernels.cc_hook import cc_hook_step, connected_components_pallas  # noqa: F401
from repro.kernels.ell_propagate import ell_propagate_step

BACKENDS = ("ref", "ell_pallas", "bsr")

# BSR densifies (U, U) on the host — refuse silly sizes.
_BSR_MAX_ROWS = 8192


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# Below this row count the fused kernel's launch overhead beats the work
# saved; auto selection keeps such problems on the XLA reference path.
# Must exceed the 256-row bucket floor (core.snapshot.bucket): the count
# seen here is the padded one, so a smaller threshold would never fire.
_PALLAS_MIN_ROWS = 512


def select_backend(backend: str | None = None,
                   problem: PropagationProblem | None = None,
                   *,
                   num_rows: int | None = None,
                   sharded: bool = False) -> str:
    """Resolve ``backend`` (None/"auto" → hardware + shape, env override).

    Selection rules: an explicit backend wins; the ``REPRO_BACKEND`` env
    var replaces the "auto" default; auto gives TPU the fused ELL kernel
    (unless the problem — sized via ``problem`` or a bare ``num_rows`` —
    is too small to amortize a kernel launch) and everything else the XLA
    reference.  ``bsr`` pays an O(U²) host densification and has no
    sharded form, so the fleet-wide env hint degrades to ``ref`` whenever
    it is unusable (rows over the BSR cap, or ``sharded``); only an
    *explicitly passed* ``backend="bsr"`` reaches the caller's error
    path in those cases.
    """
    if num_rows is None and problem is not None:
        num_rows = problem.num_unlabeled
    from_env = False
    if backend in (None, "auto"):
        env = os.environ.get("REPRO_BACKEND", "auto")
        from_env = env != "auto"
        backend = env
    if backend == "auto":
        backend = "ell_pallas" if on_tpu() else "ref"
        if (backend == "ell_pallas" and num_rows is not None
                and num_rows < _PALLAS_MIN_ROWS):
            backend = "ref"
    if from_env and backend == "bsr" and (
            sharded or (num_rows is not None and num_rows > _BSR_MAX_ROWS)):
        backend = "ref"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    return backend


def _pad_rows(problem: PropagationProblem, block_rows: int):
    n = problem.num_unlabeled
    pad = (-n) % block_rows
    if pad == 0:
        return problem, n
    padded = PropagationProblem(
        nbr=jnp.pad(problem.nbr, ((0, pad), (0, 0)), constant_values=-1),
        wgt=jnp.pad(problem.wgt, ((0, pad), (0, 0))),
        wl0=jnp.pad(problem.wl0, (0, pad)),
        wl1=jnp.pad(problem.wl1, (0, pad)),
        valid=jnp.pad(problem.valid, (0, pad)),
    )
    return padded, n


@functools.partial(jax.jit, static_argnames=("max_iters", "block_rows", "interpret"))
def propagate_pallas(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> PropagateResult:
    """Frontier propagation loop driven by the fused Pallas kernel."""
    if interpret is None:
        interpret = not on_tpu()
    problem, n_orig = _pad_rows(problem, block_rows)
    n = problem.num_unlabeled
    f0 = jnp.pad(f0.astype(jnp.float32), (0, n - n_orig))
    frontier0 = jnp.pad(frontier0, (0, n - n_orig)) & problem.valid

    mask = problem.nbr >= 0
    idx = jnp.where(mask, problem.nbr, 0)

    def cond(state):
        _, frontier, it, _ = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        f, frontier, it, _ = state
        f_new, changed = ell_propagate_step(
            problem.nbr, problem.wgt, problem.wl0, problem.wl1,
            frontier, f, delta=delta, block_rows=block_rows,
            interpret=interpret,
        )
        changed &= problem.valid
        nbr_changed = jnp.any(changed[idx] & mask, axis=1)
        new_frontier = (changed | nbr_changed) & problem.valid
        resid = jnp.max(jnp.abs(f_new - f), initial=0.0)
        return f_new, new_frontier, it + 1, resid

    f, frontier, iters, resid = jax.lax.while_loop(
        cond, body, (f0, frontier0, jnp.int32(0), jnp.float32(0)))
    return PropagateResult(
        f=f[:n_orig], iterations=iters, converged=~frontier.any(),
        max_residual=resid)


# --------------------------------------------------------------------- #
# BSR / MXU path
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def _bsr_loop(blocks, block_cols, nbr, wl1, wall, valid, f0, frontier0,
              delta, max_iters, interpret):
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    delta = jnp.asarray(delta, jnp.float32)

    def cond(state):
        _, frontier, it, _ = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        f, frontier, it, _ = state
        # F'_u = (Σ_v w(u,v)·F_v + wl1_u) / Wall_u — §5's weighted average,
        # with the neighbor sum as a block-sparse matvec on the MXU.
        y = bsr_spmv(blocks, block_cols, f, interpret=interpret)[: f.shape[0]]
        f_all = jnp.where(wall > 0, (y + wl1) / jnp.maximum(wall, 1e-30), f)
        f_new = jnp.where(frontier & valid, f_all, f)
        resid = jnp.abs(f_new - f)
        changed = (resid > delta) & valid
        nbr_changed = jnp.any(changed[idx] & mask, axis=1)
        new_frontier = (changed | nbr_changed) & valid
        return f_new, new_frontier, it + 1, jnp.max(resid, initial=0.0)

    f, frontier, iters, resid = jax.lax.while_loop(
        cond, body, (f0, frontier0 & valid, jnp.int32(0), jnp.float32(0)))
    return PropagateResult(
        f=f, iterations=iters, converged=~frontier.any(), max_residual=resid)


def propagate_bsr(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_size: int = 8,
    interpret: bool | None = None,
) -> PropagateResult:
    """Frontier propagation with the aggregation as a BSR SpMV (MXU path).

    Builds the row-padded BSR form of the unlabeled↔unlabeled weight matrix
    on the host (O(U²) densification — callers reorder by connected
    component first so the tiles are dense).  Only sensible when chosen
    explicitly; see ``select_backend``.
    """
    if interpret is None:
        interpret = not on_tpu()
    n = problem.num_unlabeled
    if n > _BSR_MAX_ROWS:
        raise ValueError(
            f"bsr backend densifies (U, U): U={n} > {_BSR_MAX_ROWS}; "
            "use backend='ref' or 'ell_pallas'")
    pad = (-n) % block_size
    nbr = np.asarray(problem.nbr)
    wgt = np.asarray(problem.wgt)
    m = n + pad
    dense = np.zeros((m, m), np.float32)
    rows = np.repeat(np.arange(n), nbr.shape[1])
    cols = nbr.reshape(-1)
    keep = cols >= 0
    dense[rows[keep], cols[keep]] = wgt.reshape(-1)[keep]
    blocks, block_cols = dense_to_bsr(jnp.asarray(dense), block_size)

    zpad = lambda x, v=0: jnp.pad(x, (0, pad), constant_values=v)
    wall = problem.wall()  # wl0 only enters through the wall normalizer
    res = _bsr_loop(
        blocks, block_cols,
        jnp.pad(problem.nbr, ((0, pad), (0, 0)), constant_values=-1),
        zpad(problem.wl1), zpad(wall),
        zpad(problem.valid, False),
        zpad(f0.astype(jnp.float32)), zpad(frontier0, False),
        delta, max_iters=max_iters, interpret=interpret)
    return PropagateResult(
        f=res.f[:n], iterations=res.iterations, converged=res.converged,
        max_residual=res.max_residual)


# --------------------------------------------------------------------- #
# Donating wrappers (streaming path): the f0 buffer is consumed and its
# storage recycled by XLA across Δ_t.  (frontier0 stays undonated: its
# bool[U] shape has no matching output to alias.)
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("max_iters",),
                   donate_argnums=(1,))
def _ref_donating(problem, f0, frontier0, delta, max_iters):
    return propagate(problem, f0, frontier0, delta=delta, max_iters=max_iters)


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "block_rows", "interpret"),
                   donate_argnums=(1,))
def _pallas_donating(problem, f0, frontier0, delta, max_iters, block_rows,
                     interpret):
    return propagate_pallas(problem, f0, frontier0, delta=delta,
                            max_iters=max_iters, block_rows=block_rows,
                            interpret=interpret)


def run_propagation(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    *,
    delta: float | jax.Array = 1e-4,
    max_iters: int = 100_000,
    backend: str | None = None,
    block_rows: int = 512,
    interpret: bool | None = None,
    donate: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    shard_plan=None,
    transport: str | None = None,
    export_max: int | None = None,
) -> PropagateResult:
    """Single propagation entry point — see module docstring for routing.

    ``mesh`` adds the distributed arm: the selected backend's update body
    is wrapped in the vertex-partitioned ``shard_map`` transport of
    ``core.distributed`` (rows sharded over every mesh axis, one
    collective per sweep).  ``transport`` selects that collective:
    ``"allgather"`` (default) ships full F blocks and is layout-free;
    ``"halo"`` ships only per-shard export prefixes of length
    ``export_max`` and requires the problem's rows to already sit in a
    halo export-prefix layout (``graph.partition.build_halo_plan`` /
    ``core.snapshot.apply_halo_layout``) — labels are bit-identical
    either way.  Requires ``problem``'s row count to be a multiple of the
    mesh's device count.  Callers that stream many batches pass a
    prebuilt ``shard_plan`` (one per bucket rung; ``StreamShardPlan`` or
    ``StreamHaloPlan``, which then fixes the transport) so partition
    planning isn't redone per Δ_t; otherwise the plan is resolved (and
    memoized) from ``mesh`` + the problem shape.  ``bsr`` is single-device
    only — its host-side densification has no sharded form.
    """
    sharded = mesh is not None or shard_plan is not None
    if transport not in (None, "allgather", "halo"):
        raise ValueError(f"unknown transport {transport!r}; "
                         "want 'allgather' or 'halo'")
    if transport == "halo" and not sharded:
        raise ValueError("transport='halo' needs mesh= or a shard_plan "
                         "(single-device solves have no collective)")
    backend = select_backend(backend, problem, sharded=sharded)
    if sharded:
        from repro.core import distributed

        if backend == "bsr":
            raise ValueError(
                "bsr backend is single-device only; use 'ref' or "
                "'ell_pallas' with mesh=")
        plan = shard_plan
        if plan is None:
            if transport == "halo":
                if export_max is None:
                    raise ValueError(
                        "transport='halo' without a shard_plan needs "
                        "export_max (the per-shard export-prefix length)")
                plan = distributed.build_stream_halo_plan(
                    mesh, tuple(problem.nbr.shape), export_max,
                    backend=backend, delta=float(delta),
                    max_iters=max_iters, block_rows=block_rows,
                    interpret=interpret, donate=donate)
            else:
                plan = distributed.build_stream_plan(
                    mesh, tuple(problem.nbr.shape), backend=backend,
                    delta=float(delta), max_iters=max_iters,
                    block_rows=block_rows, interpret=interpret,
                    donate=donate)
        else:
            # the plan's baked-in hyperparameters drive the solve — refuse
            # kwargs that silently disagree with them
            want = (backend, float(delta), max_iters, block_rows, interpret,
                    transport if transport is not None else plan.transport)
            have = (plan.backend, plan.delta, plan.max_iters,
                    plan.block_rows, plan.interpret, plan.transport)
            if want != have:
                raise ValueError(
                    f"shard_plan mismatch: called with (backend, delta, "
                    f"max_iters, block_rows, interpret, transport)={want} "
                    f"but plan was built with {have}")
        return plan(problem, f0, frontier0)
    if backend == "ref":
        if donate:
            return _ref_donating(problem, f0, frontier0, delta, max_iters)
        return propagate(problem, f0, frontier0, delta=delta,
                         max_iters=max_iters)
    if backend == "ell_pallas":
        if interpret is None:
            interpret = not on_tpu()
        block_rows = min(block_rows, problem.num_unlabeled)
        if donate:
            return _pallas_donating(problem, f0, frontier0, delta, max_iters,
                                    block_rows, interpret)
        return propagate_pallas(problem, f0, frontier0, delta=delta,
                                max_iters=max_iters, block_rows=block_rows,
                                interpret=interpret)
    return propagate_bsr(problem, f0, frontier0, delta=delta,
                         max_iters=max_iters, interpret=interpret)


_CACHED_ENTRY_POINTS = (
    lambda: propagate,
    lambda: propagate_pallas,
    lambda: _ref_donating,
    lambda: _pallas_donating,
    lambda: _bsr_loop,
)


def compile_cache_size() -> int:
    """Total jit-cache entries across every propagation entry point.

    Each entry is one (shapes, statics) specialization, i.e. one compile.
    Sampled before/after a stream, the delta is the stream's recompile
    count — the number the bucket ladder is designed to bound.
    """
    total = 0
    for get in _CACHED_ENTRY_POINTS:
        fn = get()
        try:
            total += fn._cache_size()
        except AttributeError:  # pragma: no cover — future jax rename
            pass
    from repro.core import distributed

    return total + distributed.sharded_cache_size()

"""Kernel dispatch layer — a capability-declaring backend registry.

Every propagation backend registers a ``BackendSpec`` describing what it
can do; ``run_propagation``, ``select_backend`` and
``compile_cache_size`` iterate the registry instead of hard-coding
backend names, so adding a backend is one ``register_backend`` call:

  * ``sharded`` / ``transports`` — whether the backend has a mesh form
    (``core.distributed`` wraps its per-shard update body) and which
    per-sweep collectives that form supports;
  * ``auto_eligible(info, hw)`` — when ``backend="auto"`` may pick it,
    from the problem shape and the measured properties in
    ``ProblemInfo`` (the streaming engine measures the post-reorder BSR
    block fill factor at rung entry and feeds it back in here);
  * ``run`` / ``cache_entry_points`` — the (donate-capable) single-device
    entry point and the jitted functions whose cache sizes make up the
    compile-once accounting.

Registered backends:

  * ``"ref"``        — the XLA reference engine (``core.propagate``), the
                       right answer on CPU and the allclose oracle
                       everywhere else.
  * ``"ell_pallas"`` — the fused ELL Pallas kernel loop
                       (``propagate_pallas``): VPU path on TPU, interpret
                       mode off-TPU.
  * ``"bsr"``        — block-sparse MXU path: the neighbor aggregation
                       runs as ``bsr_spmv`` over component-reordered
                       block-dense tiles built DIRECTLY from the ELL
                       tensor (``kernels.bsr_spmv.ell_bsr_layout`` +
                       device-side ``fill_bsr_blocks`` — O(nnz), no
                       dense (U, U) intermediate).  Sharded under both
                       transports; auto-eligible on TPU when the
                       post-reorder block fill factor clears
                       ``bsr_auto_fill_min`` (a
                       per-hardware registry property, like the tile
                       edge ``bsr_block_size``).
  * ``"landmark"``   — the APPROXIMATE hot/cold split for beyond-HBM
                       graphs (``kernels.landmark_propagate``): exact
                       barriered Jacobi on the hot working set, a
                       low-rank landmark pass for the cold tail.  The
                       hot/cold machinery lives in the streaming engine
                       (working-set tracking, cold-label folding, commit
                       refresh); standalone ``run_propagation`` calls
                       degrade to the exact ``ref`` body.  Unlike every
                       other backend its contract is a recorded hot-set
                       agreement floor, NOT bit-equality — see
                       docs/backends.md.  Auto-eligible only when the
                       caller declares ``ProblemInfo.landmark_ready``
                       (the engine does, once landmark state is
                       configured and sampled) and the row count clears
                       ``LANDMARK_AUTO_MIN_ROWS``.

``backend="auto"`` scans the registry by priority and takes the first
backend whose ``auto_eligible`` accepts the problem; the
``REPRO_BACKEND`` environment variable replaces the *auto* default for
fleet-wide flips (an explicitly passed backend still wins, and an env
hint that names a backend unusable in the current mode degrades back to
the auto scan instead of failing).  ``interpret`` defaults to True
off-TPU, so Pallas backends *degrade to the interpreter instead of
crashing* in TPU-less environments (CI, laptops).

``donate=True`` routes through jit wrappers that donate the ``f0``
buffer — the streaming engine feeds freshly staged device arrays every
Δ_t and lets XLA recycle them in place rather than allocate per batch.
``compile_cache_size()`` sums the jit-cache entry count of every
registered backend's entry points (plus the sharded runners): the
streaming tests assert it stays ≤ the shape-bucket ladder size.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.propagate import (PropagateResult, PropagationProblem,
                                  bsr_update_island, propagate)
from repro.kernels.bsr_spmv import (bsr_spmv, dense_to_bsr,  # noqa: F401
                                    ell_bsr_layout, fill_bsr_blocks)
from repro.kernels.cc_hook import cc_hook_step, connected_components_pallas  # noqa: F401
from repro.kernels.ell_propagate import ell_propagate_step


def on_tpu() -> bool:
    """True when jax dispatches to a real TPU (not interpret mode)."""
    return jax.default_backend() == "tpu"


# Below this row count the fused kernels' launch overhead beats the work
# saved; auto selection keeps such problems on the XLA reference path.
# Must exceed the 256-row bucket floor (core.snapshot.bucket): the count
# seen here is the padded one, so a smaller threshold would never fire.
_PALLAS_MIN_ROWS = 512

# The BSR tile edge and auto fill threshold are per-hardware registry
# properties now — see ``bsr_block_size`` / ``bsr_auto_fill_min`` below
# (8 interpret-friendly on CPU, the MXU's native 128 on real TPU).

# auto may pick the approximate landmark backend only at row counts
# where exact staging pressure is real — below this the whole problem
# fits a single exact rung comfortably and approximation buys nothing.
LANDMARK_AUTO_MIN_ROWS = 4096


# --------------------------------------------------------------------- #
# Backend registry
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ProblemInfo:
    """What auto-selection may know about a solve.

    ``block_fill`` is the post-component-reorder BSR fill factor — only
    the streaming engine measures it (at rung entry); plain callers leave
    it ``None``, which keeps ``bsr`` out of their auto scan.
    ``landmark_ready`` declares that the caller runs the hot/cold
    landmark machinery (sampled landmarks + assignment table); plain
    callers leave it False, which keeps the approximate ``landmark``
    backend out of their auto scan the same way.
    """

    num_rows: int | None = None
    block_fill: float | None = None
    sharded: bool = False
    landmark_ready: bool = False


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One propagation backend's declared capabilities."""

    name: str
    sharded: bool  # has a core.distributed per-shard update body
    transports: tuple[str, ...]  # collectives the sharded form supports
    auto_priority: int  # auto scans high → low
    auto_eligible: Callable[[ProblemInfo, str], bool]  # (info, hw) -> bool
    run: Callable  # single-device entry point
    cache_entry_points: tuple[Callable[[], object], ...]
    # per-hardware tile edge for backends that tile their aggregation
    # (hw string -> edge length); None for untiled backends
    block_size: Callable[[str], int] | None = None


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(spec: BackendSpec) -> BackendSpec:
    """Add a backend to the dispatch registry (last registration wins)."""
    _REGISTRY[spec.name] = spec
    return spec


def backend_names() -> tuple[str, ...]:
    """Registered backend names, registration order."""
    return tuple(_REGISTRY)


def backend_spec(name: str) -> BackendSpec:
    """The registered ``BackendSpec`` for ``name`` (raises on unknown)."""
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown backend {name!r}; want one of {backend_names()}")
    return spec


def bsr_block_size(hw: str | None = None) -> int:
    """The bsr backend's tile edge on ``hw`` (default: this process's
    backend) — a registry property, not a module constant: 8 keeps
    interpret-mode CI cheap while still mapping onto the MXU's (8, 128)
    lane tiling; on real TPU the (128, 128) MXU systolic array wants the
    full native edge."""
    return backend_spec("bsr").block_size(hw or jax.default_backend())


def bsr_auto_fill_min(hw: str | None = None) -> float:
    """Minimum touched-tile fill fraction for auto to pick bsr on ``hw``,
    re-derived from the tile edge: one (B, B) tile pays a fixed MXU pass
    regardless of how many of its entries carry a real edge, while the
    VPU ELL kernel pays per edge lane — so the break-even density scales
    as ~2/B (0.25 at the interpret-friendly edge of 8, ~0.016 at the MXU's
    128, where even sparse tiles amortize the systolic pass)."""
    return 2.0 / bsr_block_size(hw)


def _auto_select(info: ProblemInfo, hw: str) -> str:
    for spec in sorted(_REGISTRY.values(), key=lambda s: -s.auto_priority):
        if info.sharded and not spec.sharded:
            continue
        if spec.auto_eligible(info, hw):
            return spec.name
    raise RuntimeError("no auto-eligible backend registered")  # pragma: no cover


def select_backend(backend: str | None = None,
                   problem: PropagationProblem | None = None,
                   *,
                   num_rows: int | None = None,
                   sharded: bool = False,
                   block_fill: float | None = None,
                   landmark_ready: bool = False,
                   use_env: bool = True) -> str:
    """Resolve ``backend`` (None/"auto" → registry scan, env override).

    An explicit backend wins; the ``REPRO_BACKEND`` env var replaces the
    "auto" default; auto walks the registry by priority and takes the
    first backend whose ``auto_eligible`` accepts a ``ProblemInfo`` built
    from ``problem``/``num_rows``/``block_fill``.  An env *hint* naming a
    backend with no sharded form degrades to the auto scan when
    ``sharded`` (fleet-wide hints must not kill a stream); an explicitly
    passed backend reaches the caller's error path instead.

    ``use_env=False`` skips the env read — the streaming engine pins the
    hint once at construction (its row padding and candidate set depend
    on it), so a mid-stream env flip must not change later rungs.
    """
    if num_rows is None and problem is not None:
        num_rows = problem.num_unlabeled
    from_env = False
    if backend in (None, "auto"):
        env = (os.environ.get("REPRO_BACKEND", "auto") if use_env
               else "auto")
        from_env = env != "auto"
        backend = env
    info = ProblemInfo(num_rows=num_rows, block_fill=block_fill,
                       sharded=sharded, landmark_ready=landmark_ready)
    hw = jax.default_backend()
    if backend == "auto":
        return _auto_select(info, hw)
    spec = backend_spec(backend)
    if from_env and sharded and not spec.sharded:
        return _auto_select(info, hw)
    return backend


def backend_candidates(backend: str | None = None, *,
                       sharded: bool = False) -> tuple[str, ...]:
    """Every backend the given knob could resolve to, env included.

    The streaming engine asks this once at construction to decide
    whether BSR could ever be selected — and only then pays the
    block-size row padding and per-rung fill measurement.
    """
    if backend not in (None, "auto"):
        return (backend_spec(backend).name,)
    env = os.environ.get("REPRO_BACKEND", "auto")
    if env != "auto":
        spec = backend_spec(env)
        if not (sharded and not spec.sharded):
            return (env,)
    hw = jax.default_backend()
    optimistic = ProblemInfo(num_rows=None, block_fill=1.0, sharded=sharded,
                             landmark_ready=True)
    return tuple(
        s.name for s in sorted(_REGISTRY.values(),
                               key=lambda s: -s.auto_priority)
        if (not sharded or s.sharded) and s.auto_eligible(optimistic, hw))


# --------------------------------------------------------------------- #
# ell_pallas backend
# --------------------------------------------------------------------- #
def _pad_rows(problem: PropagationProblem, block_rows: int):
    n = problem.num_unlabeled
    pad = (-n) % block_rows
    if pad == 0:
        return problem, n
    padded = PropagationProblem(
        nbr=jnp.pad(problem.nbr, ((0, pad), (0, 0)), constant_values=-1),
        wgt=jnp.pad(problem.wgt, ((0, pad), (0, 0))),
        wl0=jnp.pad(problem.wl0, (0, pad)),
        wl1=jnp.pad(problem.wl1, (0, pad)),
        valid=jnp.pad(problem.valid, (0, pad)),
    )
    return padded, n


@functools.partial(jax.jit, static_argnames=("max_iters", "block_rows", "interpret"))
def propagate_pallas(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> PropagateResult:
    """Frontier propagation loop driven by the fused Pallas kernel."""
    if interpret is None:
        interpret = not on_tpu()
    problem, n_orig = _pad_rows(problem, block_rows)
    n = problem.num_unlabeled
    f0 = jnp.pad(f0.astype(jnp.float32), (0, n - n_orig))
    frontier0 = jnp.pad(frontier0, (0, n - n_orig)) & problem.valid

    mask = problem.nbr >= 0
    idx = jnp.where(mask, problem.nbr, 0)

    def cond(state):
        """Sweep while the frontier is non-empty and iterations remain."""
        _, frontier, it, _ = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        """One frontier-masked Jacobi sweep; returns the next state."""
        f, frontier, it, _ = state
        f_new, changed = ell_propagate_step(
            problem.nbr, problem.wgt, problem.wl0, problem.wl1,
            frontier, f, delta=delta, block_rows=block_rows,
            interpret=interpret,
        )
        changed &= problem.valid
        nbr_changed = jnp.any(changed[idx] & mask, axis=1)
        new_frontier = (changed | nbr_changed) & problem.valid
        resid = jnp.max(jnp.abs(f_new - f), initial=0.0)
        return f_new, new_frontier, it + 1, resid

    f, frontier, iters, resid = jax.lax.while_loop(
        cond, body, (f0, frontier0, jnp.int32(0), jnp.float32(0)))
    return PropagateResult(
        f=f[:n_orig], iterations=iters, converged=~frontier.any(),
        max_residual=resid)


# --------------------------------------------------------------------- #
# BSR / MXU backend — tiles built directly from the ELL tensor
# --------------------------------------------------------------------- #
def _bsr_fixpoint(problem, slot, f0, frontier0, delta, max_iters, interpret,
                  block_size, num_slots):
    """Frontier fixpoint with the aggregation as a BSR SpMV.  The tile
    tensor is scatter-built from the staged ELL arrays *inside* the jit
    (``fill_bsr_blocks``), so it never exists on the host."""
    nbr = problem.nbr
    blocks, bcols = fill_bsr_blocks(nbr, problem.wgt, slot,
                                    block_size=block_size,
                                    num_slots=num_slots)
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    delta_ = jnp.asarray(delta, jnp.float32)
    wall = problem.wall()
    valid = problem.valid
    n = nbr.shape[0]

    def cond(state):
        """Sweep while the frontier is non-empty and iterations remain."""
        _, frontier, it, _ = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        """One frontier-masked Jacobi sweep; returns the next state."""
        f, frontier, it, _ = state
        # F'_u = (Σ_v w(u,v)·F_v + wl1_u) / Wall_u — §5's weighted average,
        # with the neighbor sum as a block-sparse matvec on the MXU.
        y = bsr_spmv(blocks, bcols, f, interpret=interpret)[:n]
        f_all = bsr_update_island(y, problem.wl1, wall, f)
        f_new = jnp.where(frontier & valid, f_all, f)
        resid = jnp.abs(f_new - f)
        changed = (resid > delta_) & valid
        nbr_changed = jnp.any(changed[idx] & mask, axis=1)
        new_frontier = (changed | nbr_changed) & valid
        return f_new, new_frontier, it + 1, jnp.max(resid, initial=0.0)

    f, frontier, iters, resid = jax.lax.while_loop(
        cond, body, (f0.astype(jnp.float32), frontier0 & valid,
                     jnp.int32(0), jnp.float32(0)))
    return PropagateResult(
        f=f, iterations=iters, converged=~frontier.any(), max_residual=resid)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret",
                                             "block_size", "num_slots"))
def _bsr_solve(problem, slot, f0, frontier0, delta, max_iters, interpret,
               block_size, num_slots):
    return _bsr_fixpoint(problem, slot, f0, frontier0, delta, max_iters,
                         interpret, block_size, num_slots)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret",
                                             "block_size", "num_slots"),
                   donate_argnums=(2,))
def _bsr_donating(problem, slot, f0, frontier0, delta, max_iters, interpret,
                  block_size, num_slots):
    return _bsr_fixpoint(problem, slot, f0, frontier0, delta, max_iters,
                         interpret, block_size, num_slots)


def propagate_bsr(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_size: int | None = None,
    interpret: bool | None = None,
    slot=None,
    num_slots: int | None = None,
    donate: bool = False,
) -> PropagateResult:
    """Frontier propagation with the aggregation as a BSR SpMV (MXU path).

    Streaming callers (``core.stream.StreamEngine``) pass a pre-ordered
    problem plus the per-edge ``slot`` map and the rung's compiled
    ``num_slots`` budget (``kernels.bsr_spmv.ell_bsr_layout``).  One-shot
    callers pass neither: this entry point then component-reorders the
    rows on the host (the paper's Step-1 clustering order), derives the
    layout in O(nnz), solves in the reordered space, and folds the labels
    back — no dense (U, U) intermediate at any size.
    """
    if interpret is None:
        interpret = not on_tpu()
    if block_size is None:
        block_size = bsr_block_size()
    if slot is not None:
        if num_slots is None:
            raise ValueError("propagate_bsr with slot= needs num_slots= "
                             "(the compiled tile-slot budget)")
        if isinstance(slot, np.ndarray) and slot.size \
                and int(slot.max()) >= num_slots:
            # a slot beyond the budget would scatter into a neighboring
            # block row's tile — refuse loudly instead (device-array
            # callers rely on fill_bsr_blocks dropping such lanes; the
            # streaming engine checks its budget before dispatch)
            raise ValueError(
                f"slot map needs {int(slot.max()) + 1} tile slots but "
                f"num_slots={num_slots}; pass the layout's num_slots "
                "(padded up is fine)")
        fn = _bsr_donating if donate else _bsr_solve
        return fn(problem, jnp.asarray(slot), f0, frontier0, delta,
                  max_iters=max_iters, interpret=interpret,
                  block_size=block_size, num_slots=num_slots)

    # one-shot path: reorder + layout on the host, O(nnz).  Deferred
    # imports: repro.core's package init reaches back into this module
    # (dynlp), so core submodules beyond `propagate` can't load at import
    # time here.
    from repro.core.components import component_order, permute_ell_rows
    from repro.core.snapshot import bucket_k

    n = problem.num_unlabeled
    pad = (-n) % block_size
    nbr_h = np.asarray(problem.nbr)
    if pad:
        nbr_h = np.concatenate(
            [nbr_h, np.full((pad, nbr_h.shape[1]), -1, np.int32)])
    order = component_order(nbr_h)
    nbr_p, inv = permute_ell_rows(nbr_h, order)
    layout = ell_bsr_layout(nbr_p, block_size)

    def rpad(x, fill=0):
        """Pad per-row arrays to the block multiple, then permute."""
        x = np.asarray(x)
        if not pad:
            return x[order]
        widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
        return np.pad(x, widths, constant_values=fill)[order]

    pp = PropagationProblem(
        nbr=jnp.asarray(nbr_p), wgt=jnp.asarray(rpad(problem.wgt)),
        wl0=jnp.asarray(rpad(problem.wl0)), wl1=jnp.asarray(rpad(problem.wl1)),
        valid=jnp.asarray(rpad(problem.valid, False)))
    res = _bsr_solve(
        pp, jnp.asarray(layout.slot),
        jnp.asarray(rpad(np.asarray(f0, np.float32))),
        jnp.asarray(rpad(np.asarray(frontier0), False)),
        delta, max_iters=max_iters, interpret=interpret,
        block_size=block_size, num_slots=bucket_k(layout.num_slots))
    return PropagateResult(
        f=res.f[jnp.asarray(inv[:n])], iterations=res.iterations,
        converged=res.converged, max_residual=res.max_residual)


# --------------------------------------------------------------------- #
# Donating wrappers (streaming path): the f0 buffer is consumed and its
# storage recycled by XLA across Δ_t.  (frontier0 stays undonated: its
# bool[U] shape has no matching output to alias.)
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("max_iters",),
                   donate_argnums=(1,))
def _ref_donating(problem, f0, frontier0, delta, max_iters):
    return propagate(problem, f0, frontier0, delta=delta, max_iters=max_iters)


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "block_rows", "interpret"),
                   donate_argnums=(1,))
def _pallas_donating(problem, f0, frontier0, delta, max_iters, block_rows,
                     interpret):
    return propagate_pallas(problem, f0, frontier0, delta=delta,
                            max_iters=max_iters, block_rows=block_rows,
                            interpret=interpret)


# --------------------------------------------------------------------- #
# Registry entries (scan order for auto = priority, high first)
# --------------------------------------------------------------------- #
def _run_ref(problem, f0, frontier0, *, delta, max_iters, donate, **_):
    if donate:
        return _ref_donating(problem, f0, frontier0, delta, max_iters)
    return propagate(problem, f0, frontier0, delta=delta,
                     max_iters=max_iters)


def _run_ell_pallas(problem, f0, frontier0, *, delta, max_iters, block_rows,
                    interpret, donate, **_):
    if interpret is None:
        interpret = not on_tpu()
    block_rows = min(block_rows, problem.num_unlabeled)
    if donate:
        return _pallas_donating(problem, f0, frontier0, delta, max_iters,
                                block_rows, interpret)
    return propagate_pallas(problem, f0, frontier0, delta=delta,
                            max_iters=max_iters, block_rows=block_rows,
                            interpret=interpret)


def _run_bsr(problem, f0, frontier0, *, delta, max_iters, interpret, donate,
             slot=None, num_slots=None, block_size=None, **_):
    return propagate_bsr(problem, f0, frontier0, delta=delta,
                         max_iters=max_iters, block_size=block_size,
                         interpret=interpret, slot=slot, num_slots=num_slots,
                         donate=donate)


def _run_landmark(problem, f0, frontier0, *, delta, max_iters, donate, **_):
    """The landmark backend's solve body — the exact reference update.

    The approximation lives entirely in how the streaming engine STAGES
    for this backend (hot-restricted snapshot with cold labels folded as
    boundary weights, plus the commit-time low-rank cold pass in
    ``kernels.landmark_propagate``).  The staged problem itself is solved
    exactly, so standalone callers selecting ``landmark`` just get the
    reference answer.
    """
    return _run_ref(problem, f0, frontier0, delta=delta,
                    max_iters=max_iters, donate=donate)


def _landmark_cold_entry():
    # deferred: landmark_propagate imports argkmin, which this module's
    # importers don't all need at import time
    from repro.kernels.landmark_propagate import _cold_pass
    return _cold_pass


register_backend(BackendSpec(
    name="ref",
    sharded=True,
    transports=("allgather", "halo"),
    auto_priority=10,  # the always-eligible floor of the scan
    auto_eligible=lambda info, hw: True,
    run=_run_ref,
    cache_entry_points=(lambda: propagate, lambda: _ref_donating),
))

register_backend(BackendSpec(
    name="ell_pallas",
    sharded=True,
    transports=("allgather", "halo"),
    auto_priority=20,
    auto_eligible=lambda info, hw: hw == "tpu" and (
        info.num_rows is None or info.num_rows >= _PALLAS_MIN_ROWS),
    run=_run_ell_pallas,
    cache_entry_points=(lambda: propagate_pallas, lambda: _pallas_donating),
))

register_backend(BackendSpec(
    name="bsr",
    sharded=True,
    transports=("allgather", "halo"),
    auto_priority=30,  # MXU path outranks the VPU kernel when eligible
    auto_eligible=lambda info, hw: hw == "tpu"
    and info.block_fill is not None
    and info.block_fill >= bsr_auto_fill_min(hw)
    and (info.num_rows is None or info.num_rows >= _PALLAS_MIN_ROWS),
    run=_run_bsr,
    cache_entry_points=(lambda: _bsr_solve, lambda: _bsr_donating),
    block_size=lambda hw: 128 if hw == "tpu" else 8,
))

register_backend(BackendSpec(
    name="landmark",
    sharded=True,  # the hot solve reuses the ref mesh body + transports
    transports=("allgather", "halo"),
    auto_priority=40,  # when the caller runs hot/cold, scale wins
    auto_eligible=lambda info, hw: info.landmark_ready and (
        info.num_rows is None or info.num_rows >= LANDMARK_AUTO_MIN_ROWS),
    run=_run_landmark,
    cache_entry_points=(lambda: propagate, lambda: _ref_donating,
                        _landmark_cold_entry),
))

BACKENDS = backend_names()


def run_propagation(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    *,
    delta: float | jax.Array = 1e-4,
    max_iters: int = 100_000,
    backend: str | None = None,
    block_rows: int = 512,
    interpret: bool | None = None,
    donate: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    shard_plan=None,
    transport: str | None = None,
    export_max: int | None = None,
    slot=None,
    num_slots: int | None = None,
    block_size: int | None = None,
) -> PropagateResult:
    """Single propagation entry point — see module docstring for routing.

    ``mesh`` adds the distributed arm: the selected backend's update body
    is wrapped in the vertex-partitioned ``shard_map`` transport of
    ``core.distributed`` (rows sharded over every mesh axis, one
    collective per sweep).  ``transport`` selects that collective:
    ``"allgather"`` (default) ships full F blocks and is layout-free;
    ``"halo"`` ships only per-shard export prefixes of length
    ``export_max`` and requires the problem's rows to already sit in a
    halo export-prefix layout (``graph.partition.build_halo_plan`` /
    ``core.snapshot.apply_halo_layout``) — labels are bit-identical
    either way.  Requires ``problem``'s row count to be a multiple of the
    mesh's device count.  Callers that stream many batches pass a
    prebuilt ``shard_plan`` (one per bucket rung; ``StreamShardPlan`` or
    ``StreamHaloPlan``, which then fixes the transport) so partition
    planning isn't redone per Δ_t; otherwise the plan is resolved (and
    memoized) from ``mesh`` + the problem shape.  The ``bsr`` backend
    additionally needs the per-edge ``slot`` map and (sharded) the
    compiled ``num_slots`` budget — ``StreamEngine`` derives both per
    Δ_t from ``kernels.bsr_spmv.ell_bsr_layout``.
    """
    sharded = mesh is not None or shard_plan is not None
    if transport not in (None, "allgather", "halo"):
        raise ValueError(f"unknown transport {transport!r}; "
                         "want 'allgather' or 'halo'")
    if transport == "halo" and not sharded:
        raise ValueError("transport='halo' needs mesh= or a shard_plan "
                         "(single-device solves have no collective)")
    backend = select_backend(backend, problem, sharded=sharded)
    spec = backend_spec(backend)
    if sharded:
        from repro.core import distributed

        if not spec.sharded:
            raise ValueError(
                f"backend {backend!r} is single-device only; registry "
                f"sharded backends: "
                f"{tuple(s.name for s in _REGISTRY.values() if s.sharded)}")
        if transport is not None and transport not in spec.transports:
            raise ValueError(
                f"backend {backend!r} does not support transport "
                f"{transport!r}; declared transports: {spec.transports}")
        plan = shard_plan
        if plan is None:
            bsr_kw = {}
            if backend == "bsr":
                if slot is None or num_slots is None:
                    raise ValueError(
                        "sharded backend='bsr' needs slot= and num_slots= "
                        "(the per-edge BSR slot map + compiled tile budget "
                        "from kernels.bsr_spmv.ell_bsr_layout)")
                bsr_kw = dict(
                    block_size=(block_size if block_size is not None
                                else bsr_block_size()),
                    num_slots=num_slots)
            if transport == "halo":
                if export_max is None:
                    raise ValueError(
                        "transport='halo' without a shard_plan needs "
                        "export_max (the per-shard export-prefix length)")
                plan = distributed.build_stream_halo_plan(
                    mesh, tuple(problem.nbr.shape), export_max,
                    backend=backend, delta=float(delta),
                    max_iters=max_iters, block_rows=block_rows,
                    interpret=interpret, donate=donate, **bsr_kw)
            else:
                plan = distributed.build_stream_plan(
                    mesh, tuple(problem.nbr.shape), backend=backend,
                    delta=float(delta), max_iters=max_iters,
                    block_rows=block_rows, interpret=interpret,
                    donate=donate, **bsr_kw)
        else:
            # the plan's baked-in hyperparameters drive the solve — refuse
            # kwargs that silently disagree with them
            want = (backend, float(delta), max_iters, block_rows, interpret,
                    transport if transport is not None else plan.transport)
            have = (plan.backend, plan.delta, plan.max_iters,
                    plan.block_rows, plan.interpret, plan.transport)
            if want != have:
                raise ValueError(
                    f"shard_plan mismatch: called with (backend, delta, "
                    f"max_iters, block_rows, interpret, transport)={want} "
                    f"but plan was built with {have}")
            if backend == "bsr" and num_slots is not None \
                    and num_slots != plan.num_slots:
                raise ValueError(
                    f"shard_plan mismatch: num_slots={num_slots} but plan "
                    f"compiled {plan.num_slots}")
        if plan.backend == "bsr":
            if slot is None:
                raise ValueError("a bsr shard plan needs the per-edge "
                                 "slot map (slot=)")
            if isinstance(slot, np.ndarray) and slot.size \
                    and int(slot.max()) >= plan.num_slots:
                raise ValueError(
                    f"slot map needs {int(slot.max()) + 1} tile slots "
                    f"but the plan compiled num_slots={plan.num_slots}")
            return plan(problem, f0, frontier0, slot=jnp.asarray(slot))
        return plan(problem, f0, frontier0)
    return spec.run(problem, f0, frontier0, delta=delta, max_iters=max_iters,
                    block_rows=block_rows, interpret=interpret, donate=donate,
                    slot=slot, num_slots=num_slots, block_size=block_size)


def compile_cache_size() -> int:
    """Total jit-cache entries across every registered backend's entry
    points (plus the sharded shard_map runners).

    Each entry is one (shapes, statics) specialization, i.e. one compile.
    Sampled before/after a stream, the delta is the stream's recompile
    count — the number the bucket ladder is designed to bound.
    """
    total = 0
    seen: set[int] = set()
    for spec in _REGISTRY.values():
        for get in spec.cache_entry_points:
            fn = get()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            try:
                total += fn._cache_size()
            except AttributeError:  # pragma: no cover — future jax rename
                pass
    from repro.core import distributed

    return total + distributed.sharded_cache_size()

"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container validates kernel
bodies on CPU); on a real TPU backend pass ``interpret=False`` to compile
through Mosaic.  ``propagate_pallas`` is a drop-in replacement for
``core.propagate.propagate`` built on the fused kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.propagate import PropagateResult, PropagationProblem
from repro.kernels.bsr_spmv import bsr_spmv, dense_to_bsr  # noqa: F401
from repro.kernels.cc_hook import cc_hook_step, connected_components_pallas  # noqa: F401
from repro.kernels.ell_propagate import ell_propagate_step


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_rows(problem: PropagationProblem, block_rows: int):
    n = problem.num_unlabeled
    pad = (-n) % block_rows
    if pad == 0:
        return problem, n
    padded = PropagationProblem(
        nbr=jnp.pad(problem.nbr, ((0, pad), (0, 0)), constant_values=-1),
        wgt=jnp.pad(problem.wgt, ((0, pad), (0, 0))),
        wl0=jnp.pad(problem.wl0, (0, pad)),
        wl1=jnp.pad(problem.wl1, (0, pad)),
        valid=jnp.pad(problem.valid, (0, pad)),
    )
    return padded, n


@functools.partial(jax.jit, static_argnames=("max_iters", "block_rows", "interpret"))
def propagate_pallas(
    problem: PropagationProblem,
    f0: jax.Array,
    frontier0: jax.Array,
    delta: float = 1e-4,
    max_iters: int = 100_000,
    block_rows: int = 512,
    interpret: bool | None = None,
) -> PropagateResult:
    """Frontier propagation loop driven by the fused Pallas kernel."""
    if interpret is None:
        interpret = not on_tpu()
    problem, n_orig = _pad_rows(problem, block_rows)
    n = problem.num_unlabeled
    f0 = jnp.pad(f0.astype(jnp.float32), (0, n - n_orig))
    frontier0 = jnp.pad(frontier0, (0, n - n_orig)) & problem.valid

    mask = problem.nbr >= 0
    idx = jnp.where(mask, problem.nbr, 0)

    def cond(state):
        _, frontier, it, _ = state
        return jnp.logical_and(frontier.any(), it < max_iters)

    def body(state):
        f, frontier, it, _ = state
        f_new, changed = ell_propagate_step(
            problem.nbr, problem.wgt, problem.wl0, problem.wl1,
            frontier, f, delta=delta, block_rows=block_rows,
            interpret=interpret,
        )
        changed &= problem.valid
        nbr_changed = jnp.any(changed[idx] & mask, axis=1)
        new_frontier = (changed | nbr_changed) & problem.valid
        resid = jnp.max(jnp.abs(f_new - f), initial=0.0)
        return f_new, new_frontier, it + 1, resid

    f, frontier, iters, resid = jax.lax.while_loop(
        cond, body, (f0, frontier0, jnp.int32(0), jnp.float32(0)))
    return PropagateResult(
        f=f[:n_orig], iterations=iters, converged=~frontier.any(),
        max_residual=resid)

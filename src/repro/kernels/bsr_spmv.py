"""Pallas TPU kernel: block-sparse SpMV with scalar-prefetched block indices.

This is the MXU path for DynLP's aggregation on *reordered* graphs: after
clustering vertices by connected component (Step 1 produces exactly this
ordering), the adjacency matrix densifies into blocks; storing it as
row-padded BSR (each block row has J tile slots, empty slots flagged -1)
turns the irregular SpMV of the paper into a sequence of dense
(BS × BS) @ (BS,) MXU ops.

The block-column ids are SCALAR-PREFETCHED: the x BlockSpec's index_map
reads them to decide which x tile to stage into VMEM before each grid step
— the canonical Pallas TPU sparse pattern (no dynamic gathers in the body).

The BSR form is built **directly from the ELL tensor** — never through a
dense (U, U) intermediate:

  * ``ell_bsr_layout`` (host, O(nnz log nnz)) assigns every ELL edge a
    slot inside its block row and reports the layout's slot requirement
    and block fill factor;
  * ``fill_bsr_blocks`` (device, O(nnz) scatter, runs inside the jitted
    solve) turns the staged ELL ``(nbr, wgt)`` plus the slot map into the
    ``(R, J, BS, BS)`` tile tensor and ``(R, J)`` block-column ids.

The slot map is the only extra array shipped per Δ_t (same shape as
``nbr``); the tiles themselves only ever exist on the device.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cols_ref, blocks_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(cols_ref[i, j] >= 0)
    def _acc():
        a = blocks_ref[0, 0]  # (BS, BS)
        x = x_ref[...]  # (BS,)
        y_ref[...] += jnp.dot(
            a.astype(jnp.float32), x.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmv(
    blocks: jax.Array,  # (R, J, BS, BS) float — row-padded BSR tiles
    block_cols: jax.Array,  # (R, J) int32 — tile column ids, -1 = empty
    x: jax.Array,  # (C * BS,) float
    interpret: bool = True,
) -> jax.Array:
    """Block-sparse y = A @ x over `(R, J, BS, BS)` BSR tiles on the MXU.

    Empty tile slots carry `block_cols == -1` and are steered to a
    zero-weight read of column block 0, so padding never contributes.
    """
    r, j, bs, _ = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, j),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda i, jj, cols: (i, jj, 0, 0)),
            pl.BlockSpec((bs,), lambda i, jj, cols: (jnp.maximum(cols[i, jj], 0),)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i, jj, cols: (i,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * bs,), jnp.float32),
        interpret=interpret,
    )(block_cols, blocks, x)


# --------------------------------------------------------------------- #
# Direct ELL -> BSR build (no dense intermediate)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BsrLayout:
    """Host-side slot assignment for one ELL snapshot.

    ``slot[u, k]`` is the tile slot (within block row ``u // block_size``)
    that edge ``(u, nbr[u, k])`` scatters into, or -1 for empty ELL lanes.
    ``num_slots`` is the layout's exact requirement (max distinct block
    columns touched by any block row); callers compile for a padded budget
    ≥ it and fall back when a later snapshot exceeds the budget.
    """

    slot: np.ndarray  # (U_pad, K) int32, -1 on empty lanes
    num_slots: int  # max distinct block cols in any block row (≥ 1)
    n_blocks: int  # distinct (block row, block col) pairs with an edge
    nnz: int  # real ELL edges
    block_size: int

    @property
    def fill(self) -> float:
        """Fraction of the touched tiles' entries that carry an edge —
        the density the MXU actually computes on.  1.0 means every
        touched (BS, BS) tile is completely dense."""
        cap = self.n_blocks * self.block_size * self.block_size
        return self.nnz / cap if cap else 0.0


def ell_bsr_layout(nbr: np.ndarray, block_size: int) -> BsrLayout:
    """Assign every ELL edge a BSR tile slot — host, O(nnz log nnz).

    Rows are expected pre-ordered (component order or halo layout); the
    layout never reorders.  ``len(nbr)`` must be a multiple of
    ``block_size`` (callers pad rows first).
    """
    m, _ = nbr.shape
    if m % block_size:
        raise ValueError(f"rows {m} not a multiple of block_size {block_size}")
    valid = nbr >= 0
    nnz = int(valid.sum())
    r = m // block_size
    if nnz == 0:
        return BsrLayout(slot=np.full(nbr.shape, -1, np.int32), num_slots=1,
                         n_blocks=0, nnz=0, block_size=block_size)
    br = np.repeat(np.arange(r, dtype=np.int64), block_size)[:, None]
    n_cols = int(nbr.max()) // block_size + 1
    # one key per (block row, block col) pair; rank each row's distinct
    # pairs by searchsorted into the global sorted-unique key list
    key = np.where(valid, br * n_cols + nbr // block_size, -1)
    uniq = np.unique(key[valid])
    pos = np.searchsorted(uniq, key)
    seg = np.searchsorted(uniq // n_cols, np.arange(r, dtype=np.int64))
    slot = np.where(valid, pos - seg[br], -1).astype(np.int32)
    counts = np.diff(np.append(seg, len(uniq)))
    return BsrLayout(slot=slot, num_slots=int(max(1, counts.max())),
                     n_blocks=len(uniq), nnz=nnz, block_size=block_size)


def fill_bsr_blocks(nbr: jax.Array, wgt: jax.Array, slot: jax.Array,
                    *, block_size: int, num_slots: int):
    """Device-side O(nnz) scatter: staged ELL rows -> row-padded BSR.

    Traced inside the jitted solves (single-device ``_bsr_solve`` and the
    sharded update bodies), so the (R, J, BS, BS) tile tensor never
    exists on the host.  ``nbr`` may hold *global* column ids (sharded
    path) — block columns index whatever vector the SpMV later consumes.
    Lanes whose slot falls outside ``[0, num_slots)`` are DROPPED, never
    scattered (an out-of-budget slot would otherwise land in a
    neighboring block row's tile); callers guarantee the budget covers
    the layout (``propagate_bsr`` validates host-side slot maps, the
    streaming engine checks its per-rung budget before dispatch).
    Returns ``(blocks, block_cols)`` for ``bsr_spmv``.
    """
    m, _ = nbr.shape
    r = m // block_size
    rows = jax.lax.broadcasted_iota(jnp.int32, nbr.shape, 0)
    br = rows // block_size
    ur = rows % block_size
    valid = (nbr >= 0) & (slot >= 0) & (slot < num_slots)
    s = jnp.where(valid, slot, 0)
    vc = jnp.where(valid, nbr % block_size, 0)
    flat = ((br * num_slots + s) * block_size + ur) * block_size + vc
    # every real ELL edge owns a distinct target (rows list each neighbor
    # once); invalid lanes alias slot 0 but contribute an exact 0.0
    blocks = jnp.zeros((r * num_slots * block_size * block_size,), jnp.float32)
    blocks = blocks.at[flat.reshape(-1)].add(
        jnp.where(valid, wgt, 0.0).astype(jnp.float32).reshape(-1))
    bc = jnp.where(valid, nbr // block_size, -1)
    cols = jnp.full((r, num_slots), -1, jnp.int32)
    cols = cols.at[br.reshape(-1), s.reshape(-1)].max(bc.reshape(-1))
    return blocks.reshape(r, num_slots, block_size, block_size), cols


def dense_to_bsr(a: jax.Array, bs: int):
    """Dense (N, M) -> row-padded BSR (blocks, block_cols).

    .. deprecated:: kept as the *test oracle* for ``ell_bsr_layout`` /
       ``fill_bsr_blocks`` only.  Production paths build BSR directly
       from the ELL tensor (O(nnz), no dense intermediate) — do not use
       this on any hot path.
    """
    a = np.asarray(a)
    n, m = a.shape
    assert n % bs == 0 and m % bs == 0
    rb, cb = n // bs, m // bs
    tiles = a.reshape(rb, bs, cb, bs).transpose(0, 2, 1, 3)  # (rb, cb, bs, bs)
    nz = np.array([[tiles[i, j].any() for j in range(cb)] for i in range(rb)])
    jmax = max(1, int(nz.sum(1).max()))
    blocks = np.zeros((rb, jmax, bs, bs), a.dtype)
    cols = np.full((rb, jmax), -1, np.int32)
    for i in range(rb):
        slot = 0
        for j in range(cb):
            if nz[i, j]:
                blocks[i, slot] = tiles[i, j]
                cols[i, slot] = j
                slot += 1
    return jnp.asarray(blocks), jnp.asarray(cols)

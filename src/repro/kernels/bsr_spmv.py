"""Pallas TPU kernel: block-sparse SpMV with scalar-prefetched block indices.

This is the MXU path for DynLP's aggregation on *reordered* graphs: after
clustering vertices by connected component (Step 1 produces exactly this
ordering), the adjacency matrix densifies into blocks; storing it as
row-padded BSR (each block row has J tile slots, empty slots flagged -1)
turns the irregular SpMV of the paper into a sequence of dense
(BS × BS) @ (BS,) MXU ops.

The block-column ids are SCALAR-PREFETCHED: the x BlockSpec's index_map
reads them to decide which x tile to stage into VMEM before each grid step
— the canonical Pallas TPU sparse pattern (no dynamic gathers in the body).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cols_ref, blocks_ref, x_ref, y_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(cols_ref[i, j] >= 0)
    def _acc():
        a = blocks_ref[0, 0]  # (BS, BS)
        x = x_ref[...]  # (BS,)
        y_ref[...] += jnp.dot(
            a.astype(jnp.float32), x.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmv(
    blocks: jax.Array,  # (R, J, BS, BS) float — row-padded BSR tiles
    block_cols: jax.Array,  # (R, J) int32 — tile column ids, -1 = empty
    x: jax.Array,  # (C * BS,) float
    interpret: bool = True,
) -> jax.Array:
    r, j, bs, _ = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r, j),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda i, jj, cols: (i, jj, 0, 0)),
            pl.BlockSpec((bs,), lambda i, jj, cols: (jnp.maximum(cols[i, jj], 0),)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i, jj, cols: (i,)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r * bs,), jnp.float32),
        interpret=interpret,
    )(block_cols, blocks, x)


def dense_to_bsr(a: jax.Array, bs: int):
    """Host helper: dense (N, M) -> row-padded BSR (blocks, block_cols)."""
    import numpy as np

    a = np.asarray(a)
    n, m = a.shape
    assert n % bs == 0 and m % bs == 0
    rb, cb = n // bs, m // bs
    tiles = a.reshape(rb, bs, cb, bs).transpose(0, 2, 1, 3)  # (rb, cb, bs, bs)
    nz = np.array([[tiles[i, j].any() for j in range(cb)] for i in range(rb)])
    jmax = max(1, int(nz.sum(1).max()))
    blocks = np.zeros((rb, jmax, bs, bs), a.dtype)
    cols = np.full((rb, jmax), -1, np.int32)
    for i in range(rb):
        slot = 0
        for j in range(cb):
            if nz[i, j]:
                blocks[i, slot] = tiles[i, j]
                cols[i, slot] = j
                slot += 1
    return jnp.asarray(blocks), jnp.asarray(cols)

"""Pallas TPU kernel: fused DynLP frontier propagation step (Alg. 2 L23-32).

The paper's CUDA version assigns a thread block per CSR row and reduces
partial edge sums in shared memory (Fig. 3).  The TPU formulation processes
ELL row *tiles*: a (R, K) block of neighbor ids/weights per grid step, the
full label vector F resident in VMEM (per-shard N ≤ ~4M floats ≪ 16 MiB),
and the whole update — gather, weighted average, δ-threshold, frontier
decision — fused into one VPU pass so F is read from HBM once per sweep.

Grid: (N // R,).  BlockSpecs tile nbr/wgt/wl0/wl1/frontier by rows; F and
the output F' use a constant index_map (whole-vector VMEM residency).

out[0] = F'        (N,)  updated labels (only frontier rows move)
out[1] = changed   (N,)  |ΔF| > δ flags (drives the next frontier)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nbr_ref, wgt_ref, wl0_ref, wl1_ref, frontier_ref, f_ref,
            delta_ref, offset_ref, fout_ref, changed_ref):
    nbr = nbr_ref[...]  # (R, K) int32
    wgt = wgt_ref[...]  # (R, K) f32
    f_all = f_ref[...]  # (N,) f32 — VMEM resident
    # offset maps this invocation's row tile into F: 0 single-device, the
    # shard's global row base under shard_map (core.distributed).
    row0 = pl.program_id(0) * nbr.shape[0] + offset_ref[0]
    rows = row0 + jax.lax.iota(jnp.int32, nbr.shape[0])
    # clamp: a shard whose row block is padded past a multiple of R may
    # point its pad rows beyond F — their outputs are discarded anyway
    rows = jnp.minimum(rows, f_all.shape[0] - 1)
    f_u = f_all[rows]  # (R,)

    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    f_v = jnp.take(f_all, idx.reshape(-1), axis=0).reshape(idx.shape)
    nbr_term = jnp.sum(wgt * jnp.where(mask, f_v - f_u[:, None], 0.0), axis=1)

    wl0 = wl0_ref[...]
    wl1 = wl1_ref[...]
    wall = jnp.sum(wgt, axis=1) + wl0 + wl1
    delta_f = (0.0 - f_u) * wl0 + (1.0 - f_u) * wl1 + nbr_term
    f_new = f_u + jnp.where(wall > 0, delta_f / jnp.maximum(wall, 1e-30), 0.0)

    frontier = frontier_ref[...]
    f_new = jnp.where(frontier, f_new, f_u)
    fout_ref[...] = f_new
    changed_ref[...] = jnp.abs(f_new - f_u) > delta_ref[0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ell_propagate_step(
    nbr: jax.Array,  # (N, K) int32, PAD == -1
    wgt: jax.Array,  # (N, K) float32
    wl0: jax.Array,  # (N,)
    wl1: jax.Array,  # (N,)
    frontier: jax.Array,  # (N,) bool
    f: jax.Array,  # (Nf,) float32 — Nf ≥ N; the gathered GLOBAL labels
    delta: float = 1e-4,
    block_rows: int = 512,
    interpret: bool = True,
    row_offset: jax.Array | int = 0,
) -> tuple[jax.Array, jax.Array]:
    """One fused frontier sweep over ``nbr``'s rows.

    Single-device callers pass ``f`` of the same length as ``nbr`` and
    ``row_offset=0``.  Under ``shard_map`` (core.distributed) ``nbr`` is
    the shard's row block, ``f`` the all-gathered global vector, and
    ``row_offset`` the shard's global row base — outputs stay per-shard.
    """
    n, k = nbr.shape
    n_f = f.shape[0]
    r = min(block_rows, n)
    assert n % r == 0, (n, r)
    grid = (n // r,)
    delta_arr = jnp.full((1,), delta, jnp.float32)
    offset_arr = jnp.full((1,), row_offset, jnp.int32)
    row_spec = lambda width=None: pl.BlockSpec(
        (r,) if width is None else (r, width), lambda i: (i,) if width is None else (i, 0)
    )
    full_spec = pl.BlockSpec((n_f,), lambda i: (0,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    fout, changed = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            row_spec(k),  # nbr
            row_spec(k),  # wgt
            row_spec(),  # wl0
            row_spec(),  # wl1
            row_spec(),  # frontier
            full_spec,  # f (whole vector in VMEM)
            scalar_spec,  # delta
            scalar_spec,  # row offset
        ],
        out_specs=[row_spec(), row_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.bool_),
        ],
        interpret=interpret,
    )(nbr, wgt, wl0.astype(jnp.float32), wl1.astype(jnp.float32),
      frontier, f.astype(jnp.float32), delta_arr, offset_arr)
    return fout, changed

"""Pallas TPU kernel: tiled cosine argkmin over the device embedding store.

One pass over the store answers both questions an arriving batch poses
(the DynLP "necessary updates only" discipline applied to construction):

  1. **New-row candidates** — for every batch row, the top-(k + margin)
     store rows by fast similarity.  These are *candidate supersets*: the
     final top-k is re-selected canonically on the host (``graph.knn``
     module docstring), so the kernel's matmul rounding can never leak
     into edge weights.
  2. **Displaced-row pruning** — the mask of existing store rows whose
     current k-th weight at least one batch point beats (within
     ``selection_slack``).  Only these rows pay a list merge on the host;
     everything else is untouched.

Layout: the store is row-indexed by *global vertex id* (it never
compacts; dead rows are masked out of ``valid``), and the batch is
appended to the store **before** the call, so batch rows are ordinary
columns for each other — within-batch neighbors fall out for free and
self-matches are excluded by the ``store_row == base_id + query_row``
diagonal.

Grid: (C // R,) over store row tiles.  The batch block and the running
(M, TK) best-candidate accumulator use constant index maps (VMEM
resident across grid steps, ``@pl.when`` init at step 0 — the standard
cross-step accumulation pattern); the displacement mask is written per
tile.  Ties select the lowest store row, matching both ``lax.top_k``
and the host oracle's canonical order, so mass-duplicate inputs keep
identical candidate coverage on every path.

The ``xla`` twin (one fused jit: matmul + ``lax.top_k`` + mask) serves
non-TPU hardware; ``backend="auto"`` picks Pallas on TPU, XLA elsewhere.
Interpret-mode Pallas is only used to *verify* agreement in tests and
``benchmarks/ingest_lp.py --check``.

**Sharded sweep (move-the-batch orientation).**  When the store is
row-sharded over a mesh (``ingest.ShardedEmbeddingStore``), each device
runs the same pass against only its resident rows with ``row0`` set to
its shard's global row offset — candidate ids and the ``base_id``
comparisons are global, so per-shard outputs compose without any host
renumbering — then ``shard_sweep_body`` all-gathers the per-shard
top-(k+margin) lists and ``merge_topk`` reduces them to the global
top-(k+margin).  Shard row blocks are contiguous-ascending and each
per-shard list orders tied values by ascending id, so the merge's
ties→lowest-position rule IS ties→lowest-global-id: the merged list is
bit-identical to the single-device pass, and the displacement masks
concatenate to the single-device mask because each row's dot product is
the same reduction wherever it lives.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph.knn import SELECT_MARGIN

_INT_MAX = 2**31 - 1  # python literal: a jnp scalar here would be a captured tracer in the kernel


def _on_tpu() -> bool:
    # mirrors kernels.ops.on_tpu; inlined because ops pulls in
    # core.propagate, which imports this package — circular either way
    return jax.default_backend() == "tpu"


def _kernel(store_ref, valid_ref, kth_ref, batch_ref, bvalid_ref,
            base_ref, slack_ref, row0_ref, val_ref, idx_ref, disp_ref, *,
            topk):
    i = pl.program_id(0)
    tile = store_ref[...]  # (R, D)
    batch = batch_ref[...]  # (M, D) — VMEM resident across tiles
    r = tile.shape[0]
    m = batch.shape[0]
    base_id = base_ref[0]
    # row0 is this store block's global row offset (0 single-device; the
    # shard's offset under the sharded sweep) — all row ids downstream of
    # rows_g are global, so per-shard outputs merge without renumbering
    rows_g = row0_ref[0] + i * r + jax.lax.iota(jnp.int32, r)

    s = jnp.dot(batch, tile.T, preferred_element_type=jnp.float32)  # (M, R)
    w = (s + 1.0) * 0.5
    self_mask = rows_g[None, :] == (base_id + jax.lax.iota(jnp.int32, m)[:, None])
    col_ok = valid_ref[...][None, :] & ~self_mask
    wm = jnp.where(col_ok, w, -jnp.inf)

    # displacement pruning: old valid rows some batch point beats
    old = valid_ref[...] & (rows_g < base_id)
    wq = jnp.where(bvalid_ref[...][:, None], w, -jnp.inf)
    colmax = jnp.max(wq, axis=0)  # (R,)
    disp_ref[...] = old & (colmax > kth_ref[...] - slack_ref[0])

    # fold this tile into the running top-TK (ties -> lowest store row)
    @pl.when(i == 0)
    def _init():
        val_ref[...] = jnp.full(val_ref.shape, -jnp.inf, jnp.float32)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    cand_val = jnp.concatenate([val_ref[...], wm], axis=1)  # (M, TK+R)
    cand_idx = jnp.concatenate(
        [idx_ref[...], jnp.broadcast_to(rows_g[None, :], (m, r))], axis=1)
    vals, idxs = [], []
    for _ in range(topk):
        mx = jnp.max(cand_val, axis=1)
        tie = cand_val == mx[:, None]
        sel = jnp.min(jnp.where(tie, cand_idx, _INT_MAX), axis=1)
        vals.append(mx)
        idxs.append(sel)
        cand_val = jnp.where(tie & (cand_idx == sel[:, None]), -jnp.inf, cand_val)
    val_ref[...] = jnp.stack(vals, axis=1)
    idx_ref[...] = jnp.stack(idxs, axis=1)


def _argkmin_pallas_impl(store, valid, kth, batch, batch_valid, base_id,
                         slack, row0, topk, block_rows, interpret):
    """Unjitted Pallas pass over one (shard-local or whole) store block;
    ``row0`` is the block's global row offset."""
    c, d = store.shape
    m = batch.shape[0]
    r = min(block_rows, c)
    assert c % r == 0, (c, r)
    row_spec = lambda width=None: pl.BlockSpec(
        (r,) if width is None else (r, width),
        (lambda i: (i,)) if width is None else (lambda i: (i, 0)))
    const_spec = lambda *shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    val, idx, disp = pl.pallas_call(
        functools.partial(_kernel, topk=topk),
        grid=(c // r,),
        in_specs=[
            row_spec(d),          # store tile
            row_spec(),           # valid
            row_spec(),           # kth
            const_spec(m, d),     # batch
            const_spec(m),        # batch_valid
            const_spec(1),        # base_id
            const_spec(1),        # slack
            const_spec(1),        # row0 (global offset of this block)
        ],
        out_specs=[const_spec(m, topk), const_spec(m, topk), row_spec()],
        out_shape=[
            jax.ShapeDtypeStruct((m, topk), jnp.float32),
            jax.ShapeDtypeStruct((m, topk), jnp.int32),
            jax.ShapeDtypeStruct((c,), jnp.bool_),
        ],
        interpret=interpret,
    )(store, valid, kth.astype(jnp.float32), batch, batch_valid,
      jnp.full((1,), base_id, jnp.int32), jnp.full((1,), slack, jnp.float32),
      jnp.full((1,), row0, jnp.int32))
    return val, idx, disp


_argkmin_pallas = jax.jit(
    _argkmin_pallas_impl,
    static_argnames=("topk", "block_rows", "interpret"))


def _argkmin_xla_impl(store, valid, kth, batch, batch_valid, base_id, slack,
                      row0, topk):
    """Unjitted XLA pass over one (shard-local or whole) store block;
    ``row0`` is the block's global row offset — the shared arithmetic of
    the single-device jit and the per-shard body, so displacement bits
    and candidate values agree across both by construction."""
    c = store.shape[0]
    m = batch.shape[0]
    rows_g = row0 + jnp.arange(c, dtype=jnp.int32)
    # store-major orientation: on CPU XLA, (C, D) @ (D, M) with the big
    # operand on the left runs ~4x faster than batch @ store.T, and the
    # barrier stops XLA from folding the later transpose back into the
    # dot (which would silently restore the slow orientation)
    s = jax.lax.optimization_barrier(
        jnp.dot(store, batch.T, preferred_element_type=jnp.float32))  # (C, M)
    w = (s + 1.0) * 0.5
    old = valid & (rows_g < base_id)
    colmax = jnp.max(jnp.where(batch_valid[None, :], w, -jnp.inf), axis=1)
    disp = old & (colmax > kth - slack)
    self_mask = rows_g[None, :] == base_id + jnp.arange(m, dtype=jnp.int32)[:, None]
    wm = jnp.where(valid[None, :] & ~self_mask, w.T, -jnp.inf)
    val, idx = jax.lax.top_k(wm, topk)  # ties keep the lower index
    return val, (row0 + idx).astype(jnp.int32), disp


_argkmin_xla = jax.jit(_argkmin_xla_impl, static_argnames=("topk",))


def merge_topk(val_g, idx_g, topk: int):
    """Top-``topk`` merge of concatenated per-shard candidate lists.

    ``val_g``/``idx_g`` are ``(M, D·tk_loc)`` — shard s's list occupies
    columns ``[s·tk_loc, (s+1)·tk_loc)``.  ``lax.top_k`` breaks ties by
    lowest *position*; shard row blocks are contiguous-ascending and each
    shard list orders tied values by ascending global id, so lowest
    position ⇔ lowest global id — the canonical tie order of the
    single-device pass and the host oracle.
    """
    mval, pos = jax.lax.top_k(val_g, topk)
    midx = jnp.take_along_axis(idx_g, pos, axis=1)
    return mval, midx


def shard_sweep_body(emb_l, valid_l, kth_l, batch, bvalid, base_id, slack,
                     *, axes, topk, backend, block_rows, interpret):
    """Per-device body of the sharded store sweep (runs under shard_map).

    The shard's resident rows are the matmul operand; the replicated
    batch moved to it.  Runs the selected per-block pass with this
    shard's global ``row0``, then all-gathers the per-shard
    top-``tk_loc`` lists and merges to the global top-``topk``
    (``merge_topk``).  One collective moves everything: the f32 values
    are bitcast to int32 (exact) and packed beside the ids so the
    gather ships a single ``(M, 2·tk_loc)`` block per shard, and the
    displacement mask rides back replicated (a ``(C,)`` bool gather) so
    the host pull is one local copy instead of D shard reads.
    """
    c_loc = emb_l.shape[0]
    row0 = (jax.lax.axis_index(axes) * c_loc).astype(jnp.int32)
    tk_loc = min(topk, c_loc)  # D·tk_loc ≥ topk either way: coverage holds
    if backend == "pallas":
        val, idx, disp = _argkmin_pallas_impl(
            emb_l, valid_l, kth_l, batch, bvalid, base_id, slack, row0,
            tk_loc, block_rows, interpret)
    else:
        val, idx, disp = _argkmin_xla_impl(
            emb_l, valid_l, kth_l, batch, bvalid, base_id, slack, row0,
            tk_loc)
    packed = jnp.concatenate(
        [jax.lax.bitcast_convert_type(val, jnp.int32), idx], axis=1)
    packed_g = jax.lax.all_gather(packed, axes, axis=1, tiled=True)
    n_sh = packed_g.shape[1] // (2 * tk_loc)
    packed_g = packed_g.reshape(packed.shape[0], n_sh, 2, tk_loc)
    val_g = jax.lax.bitcast_convert_type(
        packed_g[:, :, 0, :], jnp.float32).reshape(packed.shape[0], -1)
    idx_g = packed_g[:, :, 1, :].reshape(packed.shape[0], -1)
    mval, midx = merge_topk(val_g, idx_g, topk)
    disp_g = jax.lax.all_gather(disp, axes, axis=0, tiled=True)
    return mval, midx, disp_g


def argkmin_candidates(
    store: jax.Array,        # (C, D) f32 normalized embeddings, row == global id
    valid: jax.Array,        # (C,) bool — initialized & alive (incl. the batch)
    kth: jax.Array,          # (C,) f32 — current k-th weight, -inf under-full
    batch: jax.Array,        # (M, D) f32 normalized new rows (already in store)
    batch_valid: jax.Array,  # (M,) bool — first m rows real, rest padding
    base_id: int,            # global id of batch row 0
    slack: float,            # selection_slack(D): pruning tolerance
    *,
    k: int,
    backend: str = "auto",
    block_rows: int = 256,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fast-path candidates + displacement mask for one embedding batch.

    Returns ``(val (M, k+SELECT_MARGIN) f32, idx (M, k+SELECT_MARGIN)
    int32, disp (C,) bool)``; ``val == -inf`` marks empty candidate slots
    (callers must drop them before canonical re-selection).
    """
    topk = min(k + SELECT_MARGIN, store.shape[0])
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "xla"
    if backend == "pallas":
        if interpret is None:
            interpret = not _on_tpu()
        return _argkmin_pallas(store, valid, kth, batch, batch_valid,
                               base_id, slack, 0, topk, block_rows, interpret)
    if backend == "xla":
        return _argkmin_xla(store, valid, kth, batch, batch_valid,
                            jnp.int32(base_id), jnp.float32(slack),
                            jnp.int32(0), topk)
    raise ValueError(f"unknown argkmin backend {backend!r}")


def argkmin_cache_size() -> int:
    """Live jit cache entries across both argkmin backends (compile-once
    telemetry for the ingest ladder gate)."""
    return int(_argkmin_pallas._cache_size() + _argkmin_xla._cache_size())

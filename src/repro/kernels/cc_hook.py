"""Pallas TPU kernel: Shiloach–Vishkin hook + jump step (paper Fig. 2).

The CUDA version hooks each vertex to the min parent among its neighbors
and then pointer-jumps ``par[i] = par[par[i]]``.  The TPU version fuses both
into one pass over ELL row tiles with the parent vector VMEM-resident:
hook is a masked row min-reduce (VPU), jump is a second gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(nbr_ref, par_ref, out_ref):
    nbr = nbr_ref[...]  # (R, K)
    par = par_ref[...]  # (N,)
    row0 = pl.program_id(0) * nbr.shape[0]
    rows = row0 + jax.lax.iota(jnp.int32, nbr.shape[0])
    own = par[rows]
    mask = nbr >= 0
    idx = jnp.where(mask, nbr, 0)
    nbr_par = jnp.take(par, idx.reshape(-1), axis=0).reshape(idx.shape)
    nbr_par = jnp.where(mask, nbr_par, jnp.iinfo(jnp.int32).max)
    hooked = jnp.minimum(own, jnp.min(nbr_par, axis=1))
    # jump (path halving): par[par[u]] — a second VMEM gather
    out_ref[...] = jnp.take(par, hooked, axis=0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cc_hook_step(
    nbr: jax.Array,  # (N, K) int32, PAD == -1
    par: jax.Array,  # (N,) int32
    block_rows: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """One fused Shiloach–Vishkin hook + path-halving jump over the ELL
    adjacency: per row, min over the neighbors' parents, then one jump
    through the (previous iteration's) parent vector."""
    n, k = nbr.shape
    r = min(block_rows, n)
    assert n % r == 0
    out = pl.pallas_call(
        _kernel,
        grid=(n // r,),
        in_specs=[
            pl.BlockSpec((r, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(nbr, par)
    return out


def connected_components_pallas(nbr, max_iters: int = 10_000, interpret=True,
                                block_rows: int = 512):
    """Full SV loop built on the kernel (hook+jump until fixpoint).

    Note: the jump inside the fused kernel reads the PREVIOUS iteration's
    parent vector (Jacobi-style), which still converges to the same fixpoint
    as the sequential hook-then-jump (both are monotone min-contractions
    bounded by the true component min)."""

    n = nbr.shape[0]

    def cond(state):
        """Loop while any parent changed and iterations remain."""
        par, changed, it = state
        return jnp.logical_and(changed, it < max_iters)

    def body(state):
        """One hook+jump step; flags whether any parent moved."""
        par, _, it = state
        new = cc_hook_step(nbr, par, block_rows=block_rows, interpret=interpret)
        return new, jnp.any(new != par), it + 1

    par0 = jnp.arange(n, dtype=jnp.int32)
    par, _, iters = jax.lax.while_loop(cond, body, (par0, jnp.bool_(True), jnp.int32(0)))
    return par, iters

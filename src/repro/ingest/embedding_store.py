"""Bucket-ladder device-resident embedding store (ingest tentpole).

Holds every vertex's row-normalized embedding on device, row-indexed by
*global vertex id* — the store never compacts, deletions just clear the
``valid`` flag — plus the per-row current k-th neighbor weight the
argkmin kernel prunes displacement candidates against.

Compile-once contract: capacity grows on a doubling ladder from a floor
that is a multiple of the argkmin row tile (so the kernel grid always
divides evenly), batches pad on their own doubling ladder, and every
mutation (append / kill / set_kth / grow) is a jitted donated update —
so the jit cache is bounded by the ladder cross-product, not the stream
length, and steady-state batches re-use buffers in place on TPU.
``store_cache_size``/``ingest_ladder_bound`` (``ingest.incremental_knn``
re-exports) make the bound checkable by the bench ``--check`` gate.

``ShardedEmbeddingStore`` is the mesh twin: the same ladder, the same
donated updates, but every (capacity, ·) array is row-sharded over the
stream mesh via ``NamedSharding`` — each device holds ``cap / D`` rows
resident, spilling the store past single-device HBM, and the argkmin
orientation flips to move-the-batch (``kernels.argkmin.shard_sweep_body``
via ``core.distributed.StoreShardPlan``).  The update jits are memoized
per sharding with explicit ``out_shardings`` so appends/kills stay
shard-local donated writes and the ladder never silently decays to a
replicated layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

CAP_FLOOR = 1024  # multiple of the argkmin kernel's 256-row tile
BATCH_FLOOR = 8


def cap_bucket(n: int, floor: int = CAP_FLOOR) -> int:
    """Store capacity ladder: doubling, floor a multiple of the row tile."""
    b = floor
    while b < n:
        b *= 2
    return b


def batch_bucket(m: int, floor: int = BATCH_FLOOR) -> int:
    """Batch/scatter row-count ladder (doubling)."""
    b = floor
    while b < m:
        b *= 2
    return b


def dim_pad(d: int) -> int:
    """Pad the feature axis to a lane-friendly multiple of 8 (zeros are
    inert under dot products)."""
    return max(8, -8 * (-d // 8))


def _donate(*argnums):
    """Donation works on TPU and CPU (in-place aliasing keeps appends
    O(batch) instead of O(capacity)); GPU XLA can't alias these shapes
    and would warn on every call."""
    return () if jax.default_backend() == "gpu" else argnums


def _append_impl(emb, valid, kth, block, bvalid, offset):
    emb = jax.lax.dynamic_update_slice(emb, block, (offset, 0))
    valid = jax.lax.dynamic_update_slice(valid, bvalid, (offset,))
    kth = jax.lax.dynamic_update_slice(
        kth, jnp.full(bvalid.shape, -jnp.inf, jnp.float32), (offset,))
    return emb, valid, kth


def _grow_impl(emb, valid, kth, new_cap):  # output outgrows input: can't alias
    pad = new_cap - emb.shape[0]
    emb = jnp.concatenate([emb, jnp.zeros((pad, emb.shape[1]), jnp.float32)])
    valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
    kth = jnp.concatenate([kth, jnp.full((pad,), -jnp.inf, jnp.float32)])
    return emb, valid, kth


def _kill_impl(valid, ids):
    # ids are padded with an out-of-range value; mode="drop" discards them
    return valid.at[ids].set(False, mode="drop")


def _set_kth_impl(kth, rows, vals):
    return kth.at[rows].set(vals, mode="drop")


_append = jax.jit(_append_impl, donate_argnums=_donate(0, 1, 2))
_grow = jax.jit(_grow_impl, static_argnames=("new_cap",))
_kill = jax.jit(_kill_impl, donate_argnums=_donate(0))
_set_kth = jax.jit(_set_kth_impl, donate_argnums=_donate(0))

# Sharded twins of the update jits, memoized per (row, row2) sharding pair
# — one dict per mesh layout, process lifetime like the module jits.  The
# explicit ``out_shardings`` pin every result to the store's row sharding:
# appends/kills become shard-local donated writes (GSPMD routes the update
# slice to the owning shards) and a ladder grow re-lands the doubled
# capacity evenly instead of letting sharding propagation decide.
_SHARDED_FNS: dict = {}


def _sharded_update_fns(s1, s2) -> dict:
    """Update jits whose outputs are pinned to row shardings ``s1`` (per
    row) / ``s2`` (row-major 2-D)."""
    fns = _SHARDED_FNS.get((s1, s2))
    if fns is None:
        fns = {
            "append": jax.jit(_append_impl, donate_argnums=_donate(0, 1, 2),
                              out_shardings=(s2, s1, s1)),
            "grow": jax.jit(_grow_impl, static_argnames=("new_cap",),
                            out_shardings=(s2, s1, s1)),
            "kill": jax.jit(_kill_impl, donate_argnums=_donate(0),
                            out_shardings=s1),
            "set_kth": jax.jit(_set_kth_impl, donate_argnums=_donate(0),
                               out_shardings=s1),
        }
        _SHARDED_FNS[(s1, s2)] = fns
    return fns


def store_cache_size() -> int:
    """Live jit cache entries across the store's update kernels (both the
    single-device jits and every sharded twin)."""
    total = sum(f._cache_size() for f in (_append, _grow, _kill, _set_kth))
    for fns in _SHARDED_FNS.values():
        total += sum(f._cache_size() for f in fns.values())
    return int(total)


class EmbeddingStore:
    """Device-resident (capacity, dim_pad) normalized embedding array."""

    def __init__(self, emb_dim: int, capacity_floor: int = CAP_FLOOR):
        self.emb_dim = emb_dim
        self.dp = dim_pad(emb_dim)
        self.count = 0  # rows ever assigned (== graph num_nodes when synced)
        self.grows = 0
        self.appends = 0
        cap = cap_bucket(max(1, capacity_floor))
        self.emb = jnp.zeros((cap, self.dp), jnp.float32)
        self.valid = jnp.zeros((cap,), bool)
        self.kth = jnp.full((cap,), -jnp.inf, jnp.float32)

    @property
    def capacity(self) -> int:
        return self.emb.shape[0]

    @property
    def n_shards(self) -> int:
        """Device count the store's rows are spread over (1 here)."""
        return 1

    def device_bytes(self) -> int:
        """Max over devices of this store's resident bytes — the
        per-device memory bound the sharded bench gate checks (equals
        the total on a single-device store)."""
        per: dict = {}
        for arr in (self.emb, self.valid, self.kth):
            for sh in arr.addressable_shards:
                per[sh.device] = per.get(sh.device, 0) + sh.data.nbytes
        return int(max(per.values()))

    # -- layout hooks the sharded subclass overrides -------------------- #
    def _update_fns(self) -> dict:
        return {"append": _append, "grow": _grow, "kill": _kill,
                "set_kth": _set_kth}

    def _put_batch(self, x: np.ndarray) -> jax.Array:
        """Stage a host batch block on device (replicated when sharded)."""
        return jnp.asarray(x)

    def _put_state(self, emb_h, valid_h, kth_h) -> None:
        """Adopt host-built full-capacity arrays as the store state."""
        self.emb = jnp.asarray(emb_h)
        self.valid = jnp.asarray(valid_h)
        self.kth = jnp.asarray(kth_h)

    # ------------------------------------------------------------------ #
    def ensure(self, rows: int) -> None:
        """Grow the ladder until ``rows`` fit (donated device-side pad)."""
        if rows > self.capacity:
            new_cap = cap_bucket(rows)
            self.emb, self.valid, self.kth = self._update_fns()["grow"](
                self.emb, self.valid, self.kth, new_cap)
            self.grows += 1

    def backfill(self, embn: np.ndarray, alive: np.ndarray,
                 kth: np.ndarray) -> None:
        """One-shot adoption of an existing graph's rows (host → device);
        used when an ingestor attaches to a non-empty graph."""
        n = len(embn)
        cap = max(self.capacity, cap_bucket(max(n, 1)))
        emb_h = np.zeros((cap, self.dp), np.float32)
        emb_h[:n, : self.emb_dim] = embn
        valid_h = np.zeros(cap, bool)
        valid_h[:n] = alive
        kth_h = np.full(cap, -np.inf, np.float32)
        kth_h[:n] = kth
        self._put_state(emb_h, valid_h, kth_h)
        self.count = n

    def state_arrays(self) -> dict[str, jax.Array]:
        """The store's full device state for persistence.  jax arrays are
        immutable — mutations REPLACE ``self.emb`` etc. — so these handles
        stay torn-write-safe even under an async checkpoint writer."""
        return {"emb": self.emb, "valid": self.valid, "kth": self.kth}

    def load_state_arrays(self, arrays, count: int) -> None:
        """Adopt a ``state_arrays`` snapshot (restore path).  The saved
        capacity is already a ladder bucket, so the jit-cache economics of
        the restored store match the original's."""
        emb = np.asarray(arrays["emb"], np.float32)
        if emb.shape[1] != self.dp:
            raise ValueError(
                f"store snapshot dim {emb.shape[1]} != padded dim {self.dp} "
                f"(emb_dim {self.emb_dim})")
        self._put_state(emb, np.asarray(arrays["valid"], bool),
                        np.asarray(arrays["kth"], np.float32))
        self.count = int(count)

    def append(self, embn: np.ndarray) -> tuple[jax.Array, jax.Array, int]:
        """Append a normalized batch at the next free rows.

        Returns ``(batch (Mp, dp) device, batch_valid (Mp,) device,
        base_id)`` ready for ``kernels.argkmin`` — padding rows are
        zeroed and flagged invalid; the next append overwrites them.
        """
        m = len(embn)
        mp = batch_bucket(max(m, 1))
        base_id = self.count
        self.ensure(base_id + mp)
        block = np.zeros((mp, self.dp), np.float32)
        block[:m, : self.emb_dim] = embn
        bvalid = np.arange(mp) < m
        batch_dev = self._put_batch(block)
        bvalid_dev = self._put_batch(bvalid)
        self.emb, self.valid, self.kth = self._update_fns()["append"](
            self.emb, self.valid, self.kth, batch_dev, bvalid_dev,
            np.int32(base_id))
        self.count += m
        self.appends += 1
        return batch_dev, bvalid_dev, base_id

    def landmark_rows(self, lo: int, hi: int) -> jax.Array:
        """Device slice of rows ``[lo, hi)`` — the landmark backend's
        assignment-refresh hook (``kernels.landmark_propagate``): query
        blocks come straight off the resident array, no host staging."""
        return self.emb[lo:hi]

    def landmark_gather(self, ids: np.ndarray) -> jax.Array:
        """Device gather of the sampled landmark rows by global id — the
        landmark backend's sampling hook (one small gather per resample,
        never a full-store copy)."""
        return self.emb[jnp.asarray(np.asarray(ids, np.int32))]

    def kill(self, ids: np.ndarray) -> None:
        """Mark rows dead (deletions) — they stop matching immediately."""
        if not len(ids):
            return
        rp = batch_bucket(len(ids))
        padded = np.full(rp, self.capacity, np.int32)  # OOB pad → dropped
        padded[: len(ids)] = ids
        self.valid = self._update_fns()["kill"](
            self.valid, jnp.asarray(padded))

    def set_kth(self, rows: np.ndarray, vals: np.ndarray) -> None:
        """Refresh the pruning thresholds of rows whose lists changed."""
        if not len(rows):
            return
        rp = batch_bucket(len(rows))
        rows_p = np.full(rp, self.capacity, np.int32)
        rows_p[: len(rows)] = rows
        vals_p = np.zeros(rp, np.float32)
        vals_p[: len(rows)] = vals
        self.kth = self._update_fns()["set_kth"](
            self.kth, jnp.asarray(rows_p), jnp.asarray(vals_p))


class ShardedEmbeddingStore(EmbeddingStore):
    """Row-sharded twin of ``EmbeddingStore`` over a stream mesh.

    Every (capacity, ·) ladder array carries
    ``NamedSharding(mesh, P(axes))`` — each device holds ``cap / D``
    contiguous rows resident (global row id ``shard · cap/D + local``),
    so the store's HBM footprint per device is ``1/D`` of the unsharded
    ladder and capacity scales with the mesh instead of one device.

    The update jits are the sharded twins from ``_sharded_update_fns``
    (same arithmetic, outputs pinned to the row sharding, donation
    intact), batches stage replicated (the move-the-batch broadcast), and
    the landmark hooks re-replicate their small result blocks so the
    landmark backend's downstream jits never specialize on exotic
    shardings.  Candidate search goes through
    ``core.distributed.StoreShardPlan`` instead of the single-device
    ``argkmin_candidates`` — ``DeviceIngestor`` routes automatically.
    """

    def __init__(self, emb_dim: int, mesh, capacity_floor: int = CAP_FLOOR):
        n_dev = int(mesh.devices.size)
        floor_cap = cap_bucket(max(1, capacity_floor))
        if floor_cap % n_dev:
            raise ValueError(
                f"store capacity floor {floor_cap} not divisible by mesh "
                f"device count {n_dev}; the doubling ladder keeps rows "
                "divisible only for power-of-two meshes up to the floor")
        self.mesh = mesh
        axes = mesh.axis_names
        self._s1 = NamedSharding(mesh, P(axes))
        self._s2 = NamedSharding(mesh, P(axes, None))
        self._srep = NamedSharding(mesh, P())
        super().__init__(emb_dim, capacity_floor=capacity_floor)
        # the ladder floor was built unsharded by the parent ctor
        self.emb = jax.device_put(self.emb, self._s2)
        self.valid = jax.device_put(self.valid, self._s1)
        self.kth = jax.device_put(self.kth, self._s1)

    @property
    def n_shards(self) -> int:
        return int(self.mesh.devices.size)

    def _update_fns(self) -> dict:
        return _sharded_update_fns(self._s1, self._s2)

    def _put_batch(self, x: np.ndarray) -> jax.Array:
        # the orientation flip: the small batch broadcasts to every shard
        return jax.device_put(np.asarray(x), self._srep)

    def _put_state(self, emb_h, valid_h, kth_h) -> None:
        # backfill/restore land directly in the row sharding — elastic
        # across mesh shapes because snapshots are plain host arrays
        self.emb = jax.device_put(np.asarray(emb_h, np.float32), self._s2)
        self.valid = jax.device_put(np.asarray(valid_h, bool), self._s1)
        self.kth = jax.device_put(np.asarray(kth_h, np.float32), self._s1)

    def landmark_rows(self, lo: int, hi: int) -> jax.Array:
        """Cold-tail assignment block, re-replicated: the slice spans
        shards, and the landmark jits expect one placement."""
        return jax.device_put(self.emb[lo:hi], self._srep)

    def landmark_gather(self, ids: np.ndarray) -> jax.Array:
        """Landmark sample gather, re-replicated (small: one row per
        landmark)."""
        return jax.device_put(super().landmark_gather(ids), self._srep)

"""Device ingest selector: embedding batches → ``apply_batch`` candidates.

``DeviceIngestor`` implements the selector protocol of
``graph.dynamic.apply_batch`` (``on_delete`` / ``select`` / ``finalize``)
on top of the device-resident ``EmbeddingStore`` and the
``kernels.argkmin`` pass:

  * ``on_delete`` masks the rows out of the store (they stop matching
    immediately);
  * ``select`` appends the batch to the store and runs one fused
    argkmin over it, returning the new rows' candidate supersets plus
    the displaced-row ``flagged`` set pruned against each row's current
    k-th weight — only a (M, k+margin) value/index block and a (C,)
    mask cross back to the host;
  * ``finalize`` pushes the refreshed k-th weights of every row whose
    list changed back to the store, keeping the next batch's
    displacement pruning exact.

Canonical re-selection and list merges stay in ``DynamicGraph`` — the
ingestor only nominates supersets, which is why its streams are
bit-identical to the ``HostKNNSelector`` staging path (see the
``graph.knn`` module docstring for the contract).

With a mesh (``DeviceIngestor(..., mesh=...)``) the ingestor builds the
row-sharded store and flips the argkmin orientation to move-the-batch:
candidate search runs through ``core.distributed.StoreShardPlan`` (one
memoized plan per capacity rung), and the merged candidate lists and
the gathered displacement mask come back replicated, so the D2H pull
stays one local copy per array.  Everything downstream (canonical
re-selection, ``finalize``) is unchanged, so sharded streams stay
bit-identical to single-device ones.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.graph.dynamic import Selection
from repro.graph.knn import SELECT_MARGIN, selection_slack
from repro.kernels.argkmin import argkmin_cache_size, argkmin_candidates

from .embedding_store import (
    BATCH_FLOOR,
    CAP_FLOOR,
    EmbeddingStore,
    ShardedEmbeddingStore,
    batch_bucket,
    cap_bucket,
    store_cache_size,
)


def ingest_cache_size() -> int:
    """Total live jit entries on the ingest path (store updates + both
    argkmin backends + the sharded sweep runners) — the quantity the
    recompile gate bounds."""
    from repro.core.distributed import store_sweep_cache_size
    return store_cache_size() + argkmin_cache_size() + store_sweep_cache_size()


def _rungs(floor: int, hi: int) -> int:
    n, b = 1, floor
    while b < hi:
        b *= 2
        n += 1
    return n


def ingest_ladder_bound(max_rows: int, max_batch: int, *,
                        sharded: bool = False) -> int:
    """A-priori bound on ``ingest_cache_size()`` for a stream that never
    exceeds ``max_rows`` total rows or ``max_batch`` rows per batch.

    Every jitted entry point is keyed by bucketed shapes only, so the
    cache is bounded by the ladder cross-product — independent of stream
    length.  Scatter updates (kill / set_kth) can touch up to the whole
    store, hence the ``max_rows`` rung count for those terms.

    ``sharded=True`` adds the sharded sweep runner's rung cross-product
    (``core.distributed.store_sweep_cache_size``): the sweep inlines the
    per-shard pass unjitted, so it contributes exactly one extra entry
    per (capacity rung, batch bucket) and nothing else — the sharded
    store's update jits are distinct cache entries from the single-device
    ones but identical in count, already covered by the terms below.
    """
    n_cap = _rungs(CAP_FLOOR, cap_bucket(max_rows))
    n_b = _rungs(BATCH_FLOOR, batch_bucket(max(max_batch, 1)))
    n_s = _rungs(BATCH_FLOOR, batch_bucket(max_rows))
    return (
        n_cap * n_b      # _append
        + n_cap * n_b    # argkmin (one entry per (C, Mp) pair)
        + (n_cap - 1)    # _grow
        + n_cap * n_s    # _kill
        + n_cap * n_s    # _set_kth
        + (n_cap * n_b if sharded else 0)  # sharded sweep runner
    )


class DeviceIngestor:
    """Selector running candidate search on the device embedding store.

    Construct once per graph/engine and pass as ``apply_batch(...,
    selector=ingestor)`` (``StreamEngine(ingest="device")`` does this for
    you).  ``attach`` adopts a non-empty graph's rows; afterwards the
    store tracks the graph batch-for-batch.
    """

    def __init__(
        self,
        emb_dim: int,
        *,
        backend: str = "auto",
        block_rows: int = 256,
        interpret: bool | None = None,
        capacity_floor: int = CAP_FLOOR,
        mesh=None,
    ):
        self.mesh = None
        if mesh is not None:
            if cap_bucket(max(1, capacity_floor)) % int(mesh.devices.size):
                warnings.warn(
                    f"mesh device count {int(mesh.devices.size)} does not "
                    f"divide the store capacity ladder; falling back to the "
                    "single-device embedding store", stacklevel=2)
            else:
                self.mesh = mesh
        if self.mesh is not None:
            self.store: EmbeddingStore = ShardedEmbeddingStore(
                emb_dim, self.mesh, capacity_floor=capacity_floor)
        else:
            self.store = EmbeddingStore(emb_dim, capacity_floor=capacity_floor)
        self.backend = backend
        self.block_rows = block_rows
        self.interpret = interpret
        self.selects = 0

    def attach(self, g) -> None:
        """Adopt an existing graph's rows (host → device backfill)."""
        n = g.num_nodes
        rows = np.arange(n, dtype=np.int64)
        self.store.backfill(g.embn, g.alive, g.kth_weights(rows))

    # ----- selector protocol ------------------------------------------- #
    def on_delete(self, g, del_ids: np.ndarray) -> None:
        self.store.kill(np.asarray(del_ids, np.int64))

    def select(self, g, new_ids: np.ndarray, embn_new: np.ndarray) -> Selection:
        base_id = int(new_ids[0])
        if self.store.count != base_id:
            if self.store.count == 0 and base_id > 0:
                # lazy attach: adopt the pre-batch rows (they live at
                # g[:base_id]; apply_batch appended the batch already)
                self.store.backfill(
                    g.embn[:base_id], g.alive[:base_id],
                    g.kth_weights(np.arange(base_id, dtype=np.int64)))
            else:
                raise RuntimeError(
                    f"DeviceIngestor out of sync with graph: store has "
                    f"{self.store.count} rows, batch starts at {base_id}. "
                    "Use one ingestor per graph and route every batch "
                    "through it.")
        batch_dev, bvalid_dev, bid = self.store.append(
            np.ascontiguousarray(embn_new, np.float32))
        assert bid == base_id
        if self.mesh is not None:
            from repro.core.distributed import build_store_shard_plan
            plan = build_store_shard_plan(
                self.mesh, (self.store.capacity, self.store.dp),
                backend=self.backend, block_rows=self.block_rows,
                interpret=self.interpret)
            val, idx, disp = plan.sweep(
                self.store.emb, self.store.valid, self.store.kth,
                batch_dev, bvalid_dev, base_id, selection_slack(g.emb_dim),
                topk=min(g.k + SELECT_MARGIN, self.store.capacity))
        else:
            val, idx, disp = argkmin_candidates(
                self.store.emb, self.store.valid, self.store.kth,
                batch_dev, bvalid_dev, base_id, selection_slack(g.emb_dim),
                k=g.k, backend=self.backend, block_rows=self.block_rows,
                interpret=self.interpret)
        m = len(new_ids)
        # D2H the padded blocks whole, slice on the host: jnp slicing
        # would dispatch one device gather per distinct m (under a mesh
        # all three outputs come back replicated — the sweep gathers the
        # displacement shards on device — so every pull is a local copy)
        val = np.asarray(val)[:m]
        cand = np.where(np.isfinite(val), np.asarray(idx).astype(np.int64)[:m], -1)
        flagged = np.flatnonzero(np.asarray(disp)).astype(np.int64)
        self.selects += 1
        return Selection(cand_idx=cand, flagged=flagged)

    def finalize(self, g, rows: np.ndarray, kth: np.ndarray) -> None:
        self.store.set_kth(
            np.asarray(rows, np.int64), np.asarray(kth, np.float32))

"""Device-resident streaming kNN ingestion (see ``docs/ingestion.md``).

Turns raw embedding batches into incremental graph updates on device:
``EmbeddingStore`` keeps every vertex's normalized embedding resident in
a bucket-ladder array (``ShardedEmbeddingStore`` row-shards the ladder
over a stream mesh, spilling past single-device HBM), and
``DeviceIngestor`` plugs into ``graph.dynamic.apply_batch`` as the
candidate selector, running the ``kernels.argkmin`` distance+top-k pass
— move-the-batch over the shards when a mesh is attached — instead of
host-staged BLAS.
"""

from .embedding_store import EmbeddingStore, ShardedEmbeddingStore
from .incremental_knn import DeviceIngestor, ingest_cache_size, ingest_ladder_bound

__all__ = [
    "EmbeddingStore",
    "ShardedEmbeddingStore",
    "DeviceIngestor",
    "ingest_cache_size",
    "ingest_ladder_bound",
]

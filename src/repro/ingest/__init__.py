"""Device-resident streaming kNN ingestion (see ``docs/ingestion.md``).

Turns raw embedding batches into incremental graph updates on device:
``EmbeddingStore`` keeps every vertex's normalized embedding resident in
a bucket-ladder array, and ``DeviceIngestor`` plugs into
``graph.dynamic.apply_batch`` as the candidate selector, running the
``kernels.argkmin`` distance+top-k pass instead of host-staged BLAS.
"""

from .embedding_store import EmbeddingStore
from .incremental_knn import DeviceIngestor, ingest_cache_size, ingest_ladder_bound

__all__ = [
    "EmbeddingStore",
    "DeviceIngestor",
    "ingest_cache_size",
    "ingest_ladder_bound",
]

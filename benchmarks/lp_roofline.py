"""LP roofline dry-run — the paper-representative §Perf cell.

Lowers the distributed DynLP iteration at production scale (50M vertices,
avg degree 8, 256 chips) and derives per-iteration roofline terms for two
transports:

  baseline : full label-vector all-gather per iteration (DESIGN.md §4)
  halo     : export-prefix all-gather (graph.partition.build_halo_plan) —
             valid because DynLP's own Step-1 connected-component clustering
             yields exactly the locality the plan exploits.

The synthetic production graph is banded (neighbors within ±W rows — the
post-clustering layout), so the export prefix is ≈2W rows per shard.
Correctness of both transports vs the single-device engine is covered by
tests/test_distributed_lp.py and tests/test_halo_lp.py.

    PYTHONPATH=src python -m benchmarks.lp_roofline
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import make_propagate_fn, make_propagate_halo_fn
from repro.launch import hlo_analysis
from repro.launch import mesh as meshlib

N = 50_331_648  # ~50M vertices (paper's max), divisible by 256
K = 8
ITERS = 1000  # analyzer reads the trip count from the while condition
EXPORT = 8192  # banded graph, band W=4096 → ≈2W exported rows per shard
OUT = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_variant(halo: bool):
    mesh = meshlib.make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size
    args = (
        _sds((N, K), jnp.int32),  # nbr
        _sds((N, K), jnp.float32),  # wgt
        _sds((N,), jnp.float32),  # wl0
        _sds((N,), jnp.float32),  # wl1
        _sds((N,), jnp.bool_),  # valid
        _sds((N,), jnp.float32),  # f
        _sds((N,), jnp.bool_),  # frontier
    )
    if halo:
        fn = make_propagate_halo_fn(mesh, N // n_dev, EXPORT, max_iters=ITERS)
    else:
        fn = make_propagate_fn(mesh, max_iters=ITERS)
    with mesh:
        compiled = fn.lower(*args).compile()
    deep = hlo_analysis.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    # the analyzer multiplies loop bodies by detected trip counts; divide
    # back out whichever applied so the record is strictly per-iteration
    trips = max([v for v in deep["while_trip_counts"].values()
                 if v >= ITERS] or [1])
    # elementwise VPU work is invisible to the dot-based flop counter;
    # analytic: ~6 ops per edge slot (gather-sub-mul-add-div-cmp)
    flops_iter = 6.0 * N * K / n_dev
    return {
        "variant": "halo" if halo else "allgather",
        "n_vertices": N,
        "degree": K,
        "chips": int(n_dev),
        "per_iter": {
            "collective_bytes": deep["collective_total"] / trips,
            "flops": flops_iter,
        },
        "collective_breakdown": {k: v / trips for k, v in
                                 deep["collective_bytes"].items()},
        "memory_gib": (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
        / 2**30,
    }


def main():
    os.makedirs(OUT, exist_ok=True)
    rows = []
    for halo in (False, True):
        r = lower_variant(halo)
        rows.append(r)
        path = os.path.join(OUT, f"lp_dynlp__{r['variant']}__16x16.json")
        json.dump(r, open(path, "w"), indent=2)
        # roofline terms per iteration (per device)
        t_coll = r["per_iter"]["collective_bytes"] / meshlib.ICI_BW
        t_comp = r["per_iter"]["flops"] / meshlib.PEAK_FLOPS_BF16
        edge_bytes = (N * K * 8) / r["chips"]  # nbr+wgt read per iteration
        t_mem = edge_bytes / meshlib.HBM_BW
        print(f"{r['variant']:10s} coll/iter={r['per_iter']['collective_bytes']:.3e}B "
              f"({t_coll*1e6:.1f}us) mem/iter={edge_bytes:.2e}B ({t_mem*1e6:.1f}us) "
              f"flops/iter={r['per_iter']['flops']:.3e} ({t_comp*1e6:.2f}us) "
              f"dominant={'collective' if t_coll > max(t_mem, t_comp) else 'memory'}")
    speedup = (rows[0]["per_iter"]["collective_bytes"]
               / max(rows[1]["per_iter"]["collective_bytes"], 1))
    print(f"halo exchange cuts per-iteration collective bytes {speedup:.1f}x")
    return rows


if __name__ == "__main__":
    main()

"""LP serving benchmark: sustained query throughput while mutations stream.

Drives ``serving.lp_service.LPService`` (queries answered from the last
committed ``LabelView``, mutations coalesced per admission window and
pipelined through ``StreamEngine.submit``/``poll``) with a mixed
query/mutation workload: every stream batch is fed as several mutations,
and while its solve is in flight the driver issues query bursts — the
read path never blocks on the device, so queries overlap propagation.

Arms:

  * ``serve``          — single-device StreamEngine under the service;
  * ``serve_sharded``  — the same workload with the engine's buckets
                         row-sharded over every visible device (set
                         ``REPRO_FORCE_HOST_DEVICES=8`` to force an
                         8-virtual-device CPU mesh, decided before jax
                         initializes; the CI bench-smoke job does this).

Per arm it records sustained query calls/sec and node-lookups/sec,
query latency percentiles, mutation enqueue→commit latency percentiles,
and the engine's recompile count, into ``BENCH_serve.json``.
``--check`` hard-asserts the serving contract: queries were served while
a batch was in flight (overlap), every admitted batch committed, and
recompiles stayed ≤ the bucket-ladder bound.  ``--tiny`` shrinks the
stream for CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time

# Must run before jax initializes: virtual CPU devices for the sharded arm.
_force = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _force:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_force}"
    ).strip()

import jax
import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.core.snapshot import ladder_size
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import DynamicGraph
from repro.kernels import ops
from repro.launch.mesh import make_stream_mesh
from repro.serving.lp_service import LPService

OUT = "BENCH_serve.json"
DELTA = 1e-3  # match stream_throughput: measure machinery, not solve depth

SPEC = dict(total_vertices=3000, batch_size=60, seed=0,
            class_sep=6.0, noise=0.9, frac_deleted=0.09)
TINY = dict(total_vertices=600, batch_size=60, seed=0,
            class_sep=6.0, noise=0.9, frac_deleted=0.09)

QUERY_BURST = 64  # node ids per query call
MIN_BURSTS_PER_BATCH = 25
MUTATIONS_PER_BATCH = 4  # each stream batch arrives as this many mutations

# Recorded floors for --check (generous: queries are pure numpy reads
# from the committed view, typically well under a millisecond even on a
# loaded CI runner — tripping these means the read path regressed into
# blocking on the device or copying the world).
QUERY_P95_MS_FLOOR = 50.0
COMMIT_P95_MS_FLOOR = 30_000.0


def _pct(xs: list[float]) -> dict:
    arr = np.asarray(xs)
    return {"p50": round(float(np.percentile(arr, 50)), 4),
            "p95": round(float(np.percentile(arr, 95)), 4),
            "p99": round(float(np.percentile(arr, 99)), 4),
            "max": round(float(arr.max()), 4)}


def _run_serve(spec: StreamSpec, mesh=None) -> dict:
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=DELTA, mesh=mesh)
    # window bound sits above one batch's ops so admission happens at the
    # driver's flush() — the solve is then guaranteed in flight when the
    # query bursts start (in_flight clears only at commit, via pump()).
    svc = LPService(eng, window_ops=spec.batch_size * 2, window_ms=1e9,
                    max_pending_ops=spec.batch_size * 8)
    rng = np.random.default_rng(7)
    q_ms: list[float] = []
    t0 = time.perf_counter()
    for batch, _ in gaussian_mixture_stream(spec):
        n = len(batch.ins_emb)
        cuts = [(i * n) // MUTATIONS_PER_BATCH
                for i in range(MUTATIONS_PER_BATCH + 1)]
        svc.mutate(ins_emb=batch.ins_emb[:cuts[1]],
                   ins_labels=batch.ins_labels[:cuts[1]],
                   del_ids=batch.del_ids)
        for a, b in zip(cuts[1:], cuts[2:]):
            svc.mutate(ins_emb=batch.ins_emb[a:b],
                       ins_labels=batch.ins_labels[a:b])
        svc.flush()  # close the window; solve now in flight
        # serve reads while the batch propagates; pump() commits the
        # moment the device is done — reads never wait on it
        bursts = 0
        while eng.in_flight or bursts < MIN_BURSTS_PER_BATCH:
            hi = max(1, svc.committed_view().num_nodes)
            ids = rng.integers(0, hi, QUERY_BURST)
            tq = time.perf_counter()
            svc.query(ids)
            q_ms.append((time.perf_counter() - tq) * 1e3)
            bursts += 1
            svc.pump()
    svc.sync()
    elapsed = time.perf_counter() - t0
    st = svc.stats()
    max_k = max(k for _, k in eng.bucket_keys)
    out = {
        "batches": eng.batches,
        "mutations": st.mutations,
        "ops_accepted": st.ops_accepted,
        "batches_admitted": st.batches_admitted,
        "batches_committed": st.batches_committed,
        "queries": st.queries,
        "query_nodes": st.query_nodes,
        "queries_while_inflight": st.queries_while_inflight,
        "elapsed_s": round(elapsed, 3),
        "query_calls_per_sec": round(st.queries / elapsed, 1),
        "node_lookups_per_sec": round(st.query_nodes / elapsed, 1),
        "mutation_ops_per_sec": round(st.ops_accepted / elapsed, 1),
        "query_latency_ms": _pct(q_ms),
        "median_query_ms": round(statistics.median(q_ms), 4),
        "mutation_commit_latency_ms": st.commit_latency_ms,
        "recompiles": st.recompiles,
        "bucket_rungs": st.bucket_rungs,
        "ladder_bound": ladder_size(spec.total_vertices + 256, max_k),
    }
    if mesh is not None:
        out["mesh_devices"] = int(mesh.devices.size)
        out["plan_builds"] = eng.plan_builds
        out["transport"] = st.transport  # per-rung modes + halo traffic
    return out


def main(out: str = OUT, tiny: bool = False, check: bool = False) -> dict:
    n_dev = len(jax.devices())
    mesh = make_stream_mesh() if n_dev > 1 else None
    spec = StreamSpec(**(TINY if tiny else SPEC))
    results = {
        "backend_auto_resolves_to": ops.select_backend("auto"),
        "devices": n_dev,
        "sharded_arm": mesh is not None,
        "query_burst": QUERY_BURST,
        "floors": {"query_p95_ms": QUERY_P95_MS_FLOOR,
                   "commit_p95_ms": COMMIT_P95_MS_FLOOR},
        "serve": _run_serve(spec),
    }
    arms = {"serve": results["serve"]}
    if mesh is not None:
        results["serve_sharded"] = _run_serve(spec, mesh=mesh)
        arms["serve_sharded"] = results["serve_sharded"]
    for name, r in arms.items():
        print(f"{name}: {r['query_calls_per_sec']:.0f} queries/s "
              f"({r['node_lookups_per_sec']:.0f} node lookups/s, "
              f"p95 {r['query_latency_ms']['p95']:.3f} ms) while "
              f"{r['mutation_ops_per_sec']:.0f} mutation ops/s streamed | "
              f"{r['queries_while_inflight']}/{r['queries']} queries served "
              f"mid-flight | mutation commit p50/p95 "
              f"{r['mutation_commit_latency_ms'].get('p50')}/"
              f"{r['mutation_commit_latency_ms'].get('p95')} ms | "
              f"{r['recompiles']} recompiles ≤ ladder {r['ladder_bound']}")
        if check:  # the serving contract + recorded latency floors
            _gate(f"{name}/overlap", r["queries_while_inflight"] > 0,
                  "no query was served while a solve was in flight")
            _gate(f"{name}/commits",
                  r["batches_admitted"] == r["batches_committed"],
                  f"{r['batches_admitted']} admitted != "
                  f"{r['batches_committed']} committed")
            _gate(f"{name}/recompiles", r["recompiles"] <= r["ladder_bound"],
                  f"{r['recompiles']} recompiles > ladder "
                  f"{r['ladder_bound']}")
            _gate(f"{name}/query_p95",
                  r["query_latency_ms"]["p95"] <= QUERY_P95_MS_FLOOR,
                  f"query p95 {r['query_latency_ms']['p95']} ms > floor "
                  f"{QUERY_P95_MS_FLOOR} ms")
            _gate(f"{name}/commit_p95",
                  r["mutation_commit_latency_ms"].get("p95", 0)
                  <= COMMIT_P95_MS_FLOOR,
                  f"commit p95 {r['mutation_commit_latency_ms'].get('p95')} "
                  f"ms > floor {COMMIT_P95_MS_FLOOR} ms")
            if "plan_builds" in r:
                # halo export-budget overflows build the rung's
                # all-gather twin too — allow one extra plan per overflow
                bound = r["bucket_rungs"] + r["transport"]["overflows"]
                _gate(f"{name}/plan_builds", r["plan_builds"] <= bound,
                      f"{r['plan_builds']} plans > {r['bucket_rungs']} "
                      f"rungs + {r['transport']['overflows']} overflows")
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 600-vertex stream")
    ap.add_argument("--check", action="store_true",
                    help="assert overlap + commit + compile-once contract")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(out=args.out, tiny=args.tiny, check=args.check)

"""LP serving benchmark: open-loop read load against the async service.

Drives ``serving.lp_service.LPService`` with its background driver
running (queries fused into jitted device gathers against the committed
``DeviceLabelView``; mutations coalesced per admission window and
pipelined through ``StreamEngine.submit``/``poll`` by the driver's
clock) under two phases per arm:

  * **open-loop** — reads arrive on a FIXED schedule (``OFFERED_QPS``)
    while a writer thread replays the full mutation stream; each
    latency is measured from the read's *scheduled arrival* to its
    fulfilment, so queueing delay behind slow windows is charged to the
    service instead of silently self-throttling the load generator (the
    closed-loop caller of the pre-async benchmark had exactly that
    coordinated-omission bug).  Gated by per-arm p99 SLO floors.
  * **saturation** — after the writer drains, reads are issued
    back-to-back with a bounded number of outstanding tickets against
    the QUIESCENT service; sustained ``node_lookups_per_sec`` is the
    headline (floor: 100x the host-indexing read path this replaced,
    ``LOOKUPS_FLOOR``).  Quiescence matters for the sharded/single
    comparison: a concurrent writer would charge the sharded arm its
    (much larger, virtual-device-multiplied) commit HOST cost against
    read throughput, measuring writer CPU rather than read capacity.

Arms:

  * ``serve``          — single-device StreamEngine under the service;
  * ``serve_sharded``  — engine row-sharded over the visible devices
                         (``REPRO_FORCE_HOST_DEVICES=8`` forces an
                         8-virtual-device CPU mesh, decided before jax
                         initializes; the CI bench-smoke job does this)
                         with reads served from the mesh's spare device
                         (``core.distributed.read_replica_device``) so
                         gathers never queue behind solve programs.

Arms run as interleaved best-of-``ROUNDS`` (the stream_throughput
precedent: kills one-sided CI drift).  ``--check`` hard-asserts the
serving contract — overlap, commits, compile bounds, the lookup floor,
the open-loop p99 floor, and sharded-vs-single: strictly faster at full
scale, where replica isolation outweighs mesh staging overhead; bounded
below by ``SHARDED_RATIO_FLOOR`` under ``--tiny``, whose ~5 ms solves
leave the mechanism inside measurement noise (docs/benchmarks.md).
``--tiny`` shrinks the stream for CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os
import threading
import time

# Must run before jax initializes: virtual CPU devices for the sharded arm.
_force = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _force:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_force}"
    ).strip()

import jax
import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.core.snapshot import ladder_size
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, gaussian_mixture_stream
from repro.graph.dynamic import DynamicGraph
from repro.kernels import ops
from repro.launch.mesh import make_stream_mesh
from repro.serving.lp_service import LPService

OUT = "BENCH_serve.json"
DELTA = 1e-3  # match stream_throughput: measure machinery, not solve depth

SPEC = dict(total_vertices=3000, batch_size=60, seed=0,
            class_sep=6.0, noise=0.9, frac_deleted=0.09)
TINY = dict(total_vertices=600, batch_size=60, seed=0,
            class_sep=6.0, noise=0.9, frac_deleted=0.09)

QUERY_BURST = 64  # node ids per open-loop query
OFFERED_QPS = 300.0  # open-loop arrival rate (fixed schedule)
SAT_BURST = 4096  # node ids per saturation ticket
SAT_OUTSTANDING = 32  # max unfulfilled saturation tickets
SAT_SECONDS = {True: 4.0, False: 6.0}  # keyed by tiny
ROUNDS = {True: 3, False: 3}
MUTATIONS_PER_BATCH = 4  # each stream batch arrives as this many mutations
WRITER_PAUSE_S = 0.015  # gap between stream batches: longer than
# window_ms, so the partial window left at a batch boundary is admitted
# by the DRIVER's deadline clock, not by the next mutation's size check

# Recorded floors for --check.  The lookup floor is 100x the PR-5
# committed number for the host-indexing read path this PR replaced
# (5816.1 node lookups/sec): fused jitted gathers clear it by orders of
# magnitude, so tripping it means the read path regressed back into
# per-call host work.  The p99 floors bound OPEN-LOOP latency
# (scheduled arrival -> fulfilment, queueing included) PER ARM: the
# single arm's tail is the gather ladder's compile stalls (the graph
# grows through node buckets DURING the open-loop phase, and a read
# scheduled behind a fresh rung's jit compile is charged its wait); the
# sharded arm's tail additionally queues behind commit stalls that a
# forced 8-virtual-device mesh multiplies on shared host cores.  The
# floors bound those tails, they do not pretend them away.  The sharded
# ratio floor guards the PR-5 regression ("sharded 2x slower"); at full
# scale the check is strict (> 1).
PR5_NODE_LOOKUPS_PER_SEC = 5816.1
LOOKUPS_FLOOR = 100.0 * PR5_NODE_LOOKUPS_PER_SEC
OPEN_LOOP_P99_MS_FLOOR = {"serve": 350.0, "serve_sharded": 2500.0}
COMMIT_P95_MS_FLOOR = 30_000.0
# the per-arm LOOKUPS_FLOOR catches a read path regressing to host
# work outright; the tiny ratio floor specifically guards the sharded
# arm being left behind (PR-5 measured 0.47x).  It is deliberately
# loose: saturated gather rates on shared CI cores swing ~±20%
# between best-of-3 rounds, and a floor inside that band would flake.
SHARDED_RATIO_FLOOR = 0.75


def _pct(xs) -> dict:
    """Latency percentiles; {} on empty samples (a zero-query phase must
    not crash the report)."""
    if xs is None or not len(xs):
        return {}
    arr = np.asarray(xs)
    return {"p50": round(float(np.percentile(arr, 50)), 4),
            "p95": round(float(np.percentile(arr, 95)), 4),
            "p99": round(float(np.percentile(arr, 99)), 4),
            "max": round(float(arr.max()), 4)}


class _Writer(threading.Thread):
    """Replays stream batches through ``mutate`` as fast as the service
    admits them (the driver's clock handles windows and commits)."""

    def __init__(self, svc: LPService, batches: list):
        super().__init__(daemon=True)
        self.svc = svc
        self.batches = batches
        self.done = threading.Event()

    def run(self):
        for batch in self.batches:
            n = len(batch.ins_emb)
            cuts = [(i * n) // MUTATIONS_PER_BATCH
                    for i in range(MUTATIONS_PER_BATCH + 1)]
            self.svc.mutate(ins_emb=batch.ins_emb[:cuts[1]],
                            ins_labels=batch.ins_labels[:cuts[1]],
                            del_ids=batch.del_ids)
            for a, b in zip(cuts[1:], cuts[2:]):
                if b > a:
                    self.svc.mutate(ins_emb=batch.ins_emb[a:b],
                                    ins_labels=batch.ins_labels[a:b])
            time.sleep(WRITER_PAUSE_S)
        self.done.set()


def _open_loop(svc: LPService, rng, writer: _Writer) -> dict:
    """Fixed-schedule read load while the writer streams; latency from
    each read's SCHEDULED arrival (coordinated-omission-free)."""
    period = 1.0 / OFFERED_QPS
    pending: list[tuple[object, float]] = []
    t0 = time.perf_counter()
    i = 0
    while not writer.done.is_set():
        sched = t0 + i * period
        now = time.perf_counter()
        if now < sched:
            time.sleep(sched - now)
        hi = max(1, svc.committed_view().num_nodes)
        t = svc.query_async(rng.integers(0, hi, QUERY_BURST))
        pending.append((t, sched))
        i += 1
    elapsed = time.perf_counter() - t0
    lat = []
    for t, sched in pending:
        t.wait(60.0)
        lat.append((t.completed_at - sched) * 1e3)
    return {
        "offered_qps": OFFERED_QPS,
        "queries": len(pending),
        "elapsed_s": round(elapsed, 3),
        "achieved_qps": round(len(pending) / elapsed, 1),
        "latency_ms": _pct(lat),
    }


def _saturate(svc: LPService, rng, seconds: float) -> dict:
    """Back-to-back reads with bounded outstanding tickets against the
    drained service; sustained node lookups/sec is the headline."""
    lookups = 0
    outstanding: list = []
    t0 = time.perf_counter()
    deadline = t0 + seconds
    while time.perf_counter() < deadline:
        hi = max(1, svc.committed_view().num_nodes)
        outstanding.append(svc.query_async(rng.integers(0, hi, SAT_BURST)))
        if len(outstanding) >= SAT_OUTSTANDING:
            head = outstanding.pop(0)
            head.wait(60.0)
            lookups += len(head.ids)
    for t in outstanding:
        t.wait(60.0)
        lookups += len(t.ids)
    elapsed = time.perf_counter() - t0
    return {
        "burst": SAT_BURST,
        "lookups": lookups,
        "elapsed_s": round(elapsed, 3),
        "node_lookups_per_sec": round(lookups / elapsed, 1),
    }


def _run_serve(spec: StreamSpec, mesh=None, tiny: bool = False) -> dict:
    g = DynamicGraph(emb_dim=spec.emb_dim, k=5)
    eng = StreamEngine(g, delta=DELTA, mesh=mesh)
    # window_ops does not divide a batch's op count, so batch boundaries
    # leave a partial window open for WRITER_PAUSE_S > window_ms — those
    # admissions MUST come from the driver's deadline clock
    svc = LPService(eng, window_ops=spec.batch_size * 3 // 4, window_ms=10.0,
                    max_pending_ops=spec.batch_size * 8)
    rng = np.random.default_rng(7)
    batches = [b for b, _ in gaussian_mixture_stream(spec)]
    t0 = time.perf_counter()
    with svc:
        # phase 1: open-loop latency while the whole stream lands
        writer = _Writer(svc, batches)
        writer.start()
        open_loop = _open_loop(svc, rng, writer)
        writer.join()
        svc.sync()
        # phase 2: saturation throughput against the quiescent service
        saturation = _saturate(svc, rng, SAT_SECONDS[tiny])
        elapsed = time.perf_counter() - t0
        st = svc.stats()
    max_k = max(k for _, k in eng.bucket_keys)
    out = {
        "batches": eng.batches,
        "mutations": st.mutations,
        "ops_accepted": st.ops_accepted,
        "batches_admitted": st.batches_admitted,
        "batches_committed": st.batches_committed,
        "deadline_admissions": st.deadline_admissions,
        "queries": st.queries,
        "query_nodes": st.query_nodes,
        "queries_while_inflight": st.queries_while_inflight,
        "read_batches": st.read_batches,
        "read_tickets": st.read_tickets,
        "elapsed_s": round(elapsed, 3),
        "mutation_ops_per_sec": round(st.ops_accepted / elapsed, 1),
        "open_loop": open_loop,
        "saturation": saturation,
        "node_lookups_per_sec": saturation["node_lookups_per_sec"],
        "mutation_commit_latency_ms": st.commit_latency_ms,
        "recompiles": st.recompiles,
        "bucket_rungs": st.bucket_rungs,
        "ladder_bound": ladder_size(spec.total_vertices + 256, max_k),
    }
    if mesh is not None:
        out["mesh_devices"] = int(mesh.devices.size)
        out["plan_builds"] = eng.plan_builds
        out["transport"] = st.transport  # per-rung modes + halo traffic
    return out


def _check_arm(name: str, r: dict):
    """The serving contract + recorded floors for one arm."""
    _gate(f"{name}/overlap", r["queries_while_inflight"] > 0,
          "no query was served while a solve was in flight")
    _gate(f"{name}/deadline", r["deadline_admissions"] > 0,
          "the driver's deadline clock never admitted a window — "
          "admission depended on caller traffic")
    _gate(f"{name}/commits",
          r["batches_admitted"] == r["batches_committed"],
          f"{r['batches_admitted']} admitted != "
          f"{r['batches_committed']} committed")
    _gate(f"{name}/recompiles", r["recompiles"] <= r["ladder_bound"],
          f"{r['recompiles']} recompiles > ladder {r['ladder_bound']}")
    _gate(f"{name}/lookups",
          r["node_lookups_per_sec"] >= LOOKUPS_FLOOR,
          f"{r['node_lookups_per_sec']} node lookups/s < floor "
          f"{LOOKUPS_FLOOR} (100x the host read path)")
    p99 = r["open_loop"]["latency_ms"].get("p99", 0.0)
    floor = OPEN_LOOP_P99_MS_FLOOR[name]
    _gate(f"{name}/open_loop_p99", p99 <= floor,
          f"open-loop p99 {p99} ms > floor {floor} ms")
    _gate(f"{name}/commit_p95",
          r["mutation_commit_latency_ms"].get("p95", 0)
          <= COMMIT_P95_MS_FLOOR,
          f"commit p95 {r['mutation_commit_latency_ms'].get('p95')} "
          f"ms > floor {COMMIT_P95_MS_FLOOR} ms")
    if "plan_builds" in r:
        # halo export-budget overflows build the rung's all-gather twin
        # too — allow one extra plan per overflow
        bound = r["bucket_rungs"] + r["transport"]["overflows"]
        _gate(f"{name}/plan_builds", r["plan_builds"] <= bound,
              f"{r['plan_builds']} plans > {r['bucket_rungs']} "
              f"rungs + {r['transport']['overflows']} overflows")


def main(out: str = OUT, tiny: bool = False, check: bool = False) -> dict:
    n_dev = len(jax.devices())
    # serving mesh: one device stays OUT of the solver mesh as the read
    # replica (core.distributed.read_replica_device) — query gathers then
    # never share an execution stream with solves or snapshot staging.
    # A full-width mesh would instead publish views row-sharded, paying a
    # per-gather collective (docs/serving.md §Sharded serving).
    mesh = make_stream_mesh(max(n_dev - 1, 1)) if n_dev > 1 else None
    spec = StreamSpec(**(TINY if tiny else SPEC))
    arm_specs = {"serve": None}
    if mesh is not None:
        arm_specs["serve_sharded"] = mesh
    # interleaved best-of-rounds: scheduler/CI drift hits both arms
    # alike instead of whichever ran second.  The two phases are
    # INDEPENDENT measurements and jitter hits them independently, so
    # each phase's best round is recorded on its own — saturation by
    # lookups/s, open-loop by p99 (a round that saturates best can
    # still carry a one-off stall in its open-loop tail).
    rounds = ROUNDS[tiny]
    best: dict[str, dict] = {}
    best_ol: dict[str, dict] = {}
    history: dict[str, list] = {k: [] for k in arm_specs}
    history_ol: dict[str, list] = {k: [] for k in arm_specs}
    for _ in range(rounds):
        for name, m in arm_specs.items():
            r = _run_serve(spec, mesh=m, tiny=tiny)
            history[name].append(r["node_lookups_per_sec"])
            p99 = r["open_loop"]["latency_ms"].get("p99", float("inf"))
            history_ol[name].append(p99)
            if (name not in best
                    or r["node_lookups_per_sec"]
                    > best[name]["node_lookups_per_sec"]):
                best[name] = r
            if (name not in best_ol
                    or p99 < best_ol[name]["latency_ms"].get(
                        "p99", float("inf"))):
                best_ol[name] = r["open_loop"]
    for name in best:
        best[name]["open_loop"] = best_ol[name]
    results = {
        "backend_auto_resolves_to": ops.select_backend("auto"),
        "devices": n_dev,
        "sharded_arm": mesh is not None,
        "rounds": rounds,
        "query_burst": QUERY_BURST,
        "offered_qps": OFFERED_QPS,
        "floors": {"node_lookups_per_sec": LOOKUPS_FLOOR,
                   "open_loop_p99_ms": dict(OPEN_LOOP_P99_MS_FLOOR),
                   "commit_p95_ms": COMMIT_P95_MS_FLOOR,
                   "sharded_ratio_tiny": SHARDED_RATIO_FLOOR},
        "lookups_per_round": history,
        "open_loop_p99_per_round": history_ol,
    }
    results.update(best)
    for name, r in best.items():
        ol = r["open_loop"]
        print(f"{name}: {r['node_lookups_per_sec']:.0f} node lookups/s "
              f"saturated | open-loop {ol['achieved_qps']:.0f}/"
              f"{ol['offered_qps']:.0f} q/s, p50/p99 "
              f"{ol['latency_ms'].get('p50')}/{ol['latency_ms'].get('p99')} "
              f"ms | {r['mutation_ops_per_sec']:.0f} mutation ops/s | "
              f"{r['queries_while_inflight']}/{r['queries']} reads "
              f"mid-flight | {r['deadline_admissions']} deadline admissions "
              f"| commit p50/p95 {r['mutation_commit_latency_ms'].get('p50')}"
              f"/{r['mutation_commit_latency_ms'].get('p95')} ms | "
              f"{r['recompiles']} recompiles ≤ ladder {r['ladder_bound']}")
        if check:
            _check_arm(name, r)
    if mesh is not None and check:
        ratio = (best["serve_sharded"]["node_lookups_per_sec"]
                 / max(best["serve"]["node_lookups_per_sec"], 1e-9))
        results["sharded_over_single"] = round(ratio, 3)
        if tiny:
            # ~5 ms tiny solves put replica isolation inside the noise:
            # gate only the PR-5 "2x slower" regression here; the strict
            # comparison is a full-scale property (docs/benchmarks.md)
            _gate("sharded/ratio", ratio >= SHARDED_RATIO_FLOOR,
                  f"sharded/single lookup ratio {ratio:.3f} < "
                  f"{SHARDED_RATIO_FLOOR}")
        else:
            _gate("sharded/strictly_faster", ratio > 1.0,
                  f"sharded/single lookup ratio {ratio:.3f} — replica "
                  "reads should beat single-device at full scale")
    elif mesh is not None:
        results["sharded_over_single"] = round(
            best["serve_sharded"]["node_lookups_per_sec"]
            / max(best["serve"]["node_lookups_per_sec"], 1e-9), 3)
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 600-vertex stream")
    ap.add_argument("--check", action="store_true",
                    help="assert overlap + floors + compile-once contract")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(out=args.out, tiny=args.tiny, check=args.check)

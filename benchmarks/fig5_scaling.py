"""Paper Fig. 5: DynLP iterations and execution time vs dataset size.

Protocol (§7.2): 1% of vertices carry ground truth, all unlabeled vertices
arrive as ONE batch, average degree 5 (kNN k=5).  The paper's absolute sizes
(50K..50M on an H100) scale down to CPU; the CLAIM under test is the trend:
iterations and time grow with vertex count.
"""

from __future__ import annotations

from benchmarks.common import run_stream, spec_for
from repro.core.dynlp import DynLP


def run(sizes=(2_000, 5_000, 12_000, 30_000), delta=1e-4):
    rows = []
    for n in sizes:
        out = run_stream(DynLP, spec_for(n, seed=5), delta=delta)
        rows.append({
            "n": n,
            "iterations": out["total_iters"],
            "ms": out["total_ms"],
            "acc": out["acc_vs_truth"],
        })
    return rows


def main(full: bool = False):
    sizes = (2_000, 5_000, 12_000, 30_000, 80_000) if full else (
        2_000, 5_000, 12_000)
    rows = run(sizes)
    print("fig5: n,iterations,ms,acc_vs_truth")
    for r in rows:
        print(f"fig5,{r['n']},{r['iterations']},{r['ms']:.0f},{r['acc']:.4f}")
    # claim: monotone growth of iterations & time with n
    iters = [r["iterations"] for r in rows]
    assert iters == sorted(iters) or iters[-1] > iters[0], iters
    return rows


if __name__ == "__main__":
    main()

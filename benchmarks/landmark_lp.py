"""Landmark backend benchmark: hot-set agreement + beyond-ladder scale.

The landmark backend is the repo's first accuracy-vs-speed backend
(docs/backends.md): exact Jacobi on the hot working set, a low-rank
landmark pass for the cold tail.  Its contract is therefore measured,
not bit-checked, in two arms:

  * ``agreement`` — the acceptance workload (50 mixed insert/delete
    batches) through the exact engine and the landmark engine side by
    side.  The headline is binary-label agreement on the HOT SET (rows
    the landmark engine solved exactly; the cold tail's low-rank labels
    are reported but not gated — they are the approximation), the
    ``max_k_accuracy`` precedent: a recorded floor, gated by --check.
  * ``scale`` — an insert-heavy stream pushed past the point where the
    exact backends' staged problem stops being "incremental": every
    exact backend stages the FULL unlabeled row set per Δ_t (the bucket
    ladder rung ``bucket(n_unl)``), while the landmark engine stages
    only the hot working set.  The gate records that the landmark
    engine's largest hot rung stayed under half the exact requirement
    at the final node count — the beyond-HBM headroom, measured — plus
    a steady-state throughput floor at that scale.

``--check`` gates the recorded floors (agreement, staged-rows fraction,
throughput, and that the hot/cold machinery actually engaged); the
bench-smoke CI job runs ``--tiny --check``.  Schema: see
docs/benchmarks.md §BENCH_landmark.json.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.core.snapshot import bucket
from repro.core.stream import StreamEngine
from repro.data.synth import StreamSpec, accuracy, gaussian_mixture_stream
from repro.graph.dynamic import UNLABELED, DynamicGraph
from repro.kernels.landmark_propagate import landmark_cache_size

OUT = "BENCH_landmark.json"
DELTA = 1e-4
K = 5

# Recorded floor: binary agreement on the hot set vs the exact engine.
# The hot solve is exact ON ITS SUBGRAPH; disagreement can only enter
# through the cold boundary labels, so clean synthetics sit at ~1.0.
AGREEMENT_FLOOR = 0.98

# Recorded ceiling: the landmark engine's largest staged hot rung, as a
# fraction of the bucket the exact backends would stage at the final
# unlabeled count.  This is the "beyond the exact ladder" claim in one
# number — the gate fails if hot tracking degenerates to full staging.
SCALE_STAGE_MAX_FRACTION = 0.5

# agreement arm reuses the acceptance-test stream protocol with a roomy
# hot_ttl (agreement is measured over the hot set, so keep it large);
# the scale arm streams insert-heavy with a tight hot_ttl so the
# working set stays batch-local while the graph grows past the rung the
# exact engines would need.  frac_labeled is explicit (5%) — the stream
# generator derives nothing from frac_unlabeled — so label propagation
# is actually exercised (acc vs truth ~0.99, not chance).
FULL = dict(agree_nodes=1500, agree_batch=30, agree_ttl=3,
            scale_nodes=24_000, scale_batch=400, scale_ttl=1, meas_tail=20,
            landmarks=64, assign_k=4,
            scale_ops_floor=1000.0)
TINY = dict(agree_nodes=1500, agree_batch=30, agree_ttl=3,
            scale_nodes=9_000, scale_batch=200, scale_ttl=1, meas_tail=10,
            landmarks=64, assign_k=4,
            scale_ops_floor=700.0)


def _lm_cfg(cfg: dict, ttl: int) -> dict:
    return dict(num_landmarks=cfg["landmarks"], assign_k=cfg["assign_k"],
                hot_ttl=ttl)


def _agreement_arm(cfg: dict) -> dict:
    spec = StreamSpec(total_vertices=cfg["agree_nodes"],
                      batch_size=cfg["agree_batch"], seed=11,
                      class_sep=6.0, noise=0.9, frac_deleted=0.2,
                      frac_labeled=0.05)
    g_ref = DynamicGraph(emb_dim=spec.emb_dim, k=K)
    g_lm = DynamicGraph(emb_dim=spec.emb_dim, k=K)
    ref = StreamEngine(g_ref, delta=DELTA)
    lm = StreamEngine(g_lm, delta=DELTA, backend="landmark",
                      landmark=_lm_cfg(cfg, cfg["agree_ttl"]))
    truth = {}
    for batch, cls in gaussian_mixture_stream(spec):
        base = g_ref.num_nodes
        ref.step(batch)
        lm.step(batch)
        truth.update((base + i, c) for i, c in enumerate(cls))
    ids = np.flatnonzero(g_ref.alive & (g_ref.labels == UNLABELED))
    hot = (lm._touched_at[ids] >= 0) & (
        lm.batches - lm._touched_at[ids] <= cfg["agree_ttl"])
    pr = (g_ref.f[ids] >= 0.5).astype(np.int8)
    pl = (g_lm.f[ids] >= 0.5).astype(np.int8)
    tr = np.array([truth[i] for i in ids], np.int8)
    summary = lm.transport_summary()["landmark"]
    return {
        "batches": lm.batches,
        "unlabeled": len(ids),
        "hot_rows": int(hot.sum()),
        "hot_agreement": round(float((pr[hot] == pl[hot]).mean()), 4),
        "overall_agreement": round(float((pr == pl).mean()), 4),
        "acc_exact_vs_truth": accuracy(pr, tr),
        "acc_landmark_vs_truth": accuracy(pl, tr),
        "landmark": summary,
    }


def _scale_arm(cfg: dict) -> dict:
    spec = StreamSpec(total_vertices=cfg["scale_nodes"],
                      batch_size=cfg["scale_batch"], seed=7,
                      class_sep=6.0, noise=0.9, frac_labeled=0.05,
                      frac_deleted=0.0)
    g = DynamicGraph(emb_dim=spec.emb_dim, k=K)
    eng = StreamEngine(g, delta=DELTA, backend="landmark",
                       landmark=_lm_cfg(cfg, cfg["scale_ttl"]))
    stats, walls = [], []
    for batch, _ in gaussian_mixture_stream(spec):
        t0 = time.perf_counter()
        stats.append(eng.step(batch))
        walls.append(time.perf_counter() - t0)
    tail = cfg["meas_tail"]
    steady_s = sum(walls[-tail:])
    steady_rows = tail * cfg["scale_batch"]
    hot_rungs = [s.bucket[0] for s in stats
                 if s.backend == "landmark" and s.bucket[0]]
    n_unl = int((g.alive & (g.labels == UNLABELED)).sum())
    exact_rows = bucket(n_unl)  # what ANY exact backend must stage per Δ_t
    max_hot = max(hot_rungs) if hot_rungs else 0
    return {
        "total_nodes": g.num_nodes,
        "unlabeled": n_unl,
        "batches": eng.batches,
        "ops_per_sec": round(steady_rows / steady_s, 1),
        "steady_rows": steady_rows,
        "steady_s": round(steady_s, 4),
        "exact_bucket_rows": exact_rows,
        "max_hot_bucket_rows": max_hot,
        "staged_fraction": round(max_hot / exact_rows, 4),
        "recompiles": eng.recompile_count,
        "landmark_cache_entries": landmark_cache_size(),
        "landmark": eng.transport_summary()["landmark"],
    }


def main(out: str = OUT, tiny: bool = False, check: bool = False) -> dict:
    cfg = TINY if tiny else FULL
    agree = _agreement_arm(cfg)
    scale = _scale_arm(cfg)
    results = {
        "config": dict(cfg),
        "floors": {
            "hot_agreement": AGREEMENT_FLOOR,
            "scale_stage_max_fraction": SCALE_STAGE_MAX_FRACTION,
            "scale_ops_per_sec": cfg["scale_ops_floor"],
        },
        "agreement": agree,
        "scale": scale,
    }
    print(f"agreement: hot {agree['hot_agreement']} "
          f"({agree['hot_rows']} rows), overall "
          f"{agree['overall_agreement']} over {agree['unlabeled']} "
          f"unlabeled | acc exact {agree['acc_exact_vs_truth']:.3f} vs "
          f"landmark {agree['acc_landmark_vs_truth']:.3f}")
    print(f"scale: {scale['total_nodes']} nodes, "
          f"{scale['ops_per_sec']:.0f} rows/s steady | staged "
          f"{scale['max_hot_bucket_rows']} of exact "
          f"{scale['exact_bucket_rows']} rows "
          f"({scale['staged_fraction']:.2f})")
    if check:
        _gate("landmark/hot_agreement",
              agree["hot_agreement"] >= AGREEMENT_FLOOR,
              f"hot-set agreement {agree['hot_agreement']} < floor "
              f"{AGREEMENT_FLOOR}")
        _gate("landmark/engaged",
              agree["landmark"]["streaming"]
              and agree["landmark"]["cold_rows"] > 0,
              "the hot/cold machinery never engaged on the agreement arm")
        _gate("landmark/scale_staging",
              scale["staged_fraction"] <= SCALE_STAGE_MAX_FRACTION,
              f"max hot rung {scale['max_hot_bucket_rows']} rows is "
              f"{scale['staged_fraction']}x of the exact requirement "
              f"{scale['exact_bucket_rows']} (> "
              f"{SCALE_STAGE_MAX_FRACTION})")
        _gate("landmark/scale_throughput",
              scale["ops_per_sec"] >= cfg["scale_ops_floor"],
              f"{scale['ops_per_sec']} rows/s < floor "
              f"{cfg['scale_ops_floor']}")
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized config (bench smoke)")
    ap.add_argument("--check", action="store_true",
                    help="gate the recorded floors (nonzero exit on fail)")
    a = ap.parse_args()
    main(out=a.out, tiny=a.tiny, check=a.check)

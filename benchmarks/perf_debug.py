"""Perf-loop profiler: lower one cell and print the TOP collective sites
(op, result shape, enclosing computation, trip multiplier, total bytes) and
top dot sites — the dry-run 'profile' that drives §Perf iterations.

    PYTHONPATH=src python -m benchmarks.perf_debug --arch deepseek-67b \
        --shape train_4k [--layout tp] [--fsdp off] [--microbatches 16]
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

from repro.launch import hlo_analysis as H


def site_breakdown(text: str):
    comps = H.split_computations(text)
    # first pass: multipliers via call graph from ENTRY
    stats = {}
    for name, lines in comps.items():
        calls = []
        trip_map = {}
        for line in lines:
            if " while(" in line:
                body = H._CALL_RE.search(line)
                cond = H._COND_RE.search(line)
                trips = 1
                if cond and cond.group(1) in comps:
                    consts = []
                    for cl in comps[cond.group(1)]:
                        consts += [int(c) for c in H._CONST_CMP_RE.findall(cl)]
                    if consts:
                        trips = max(consts)
                if body:
                    calls.append((body.group(1), trips))
            elif " fusion(" in line or " call(" in line or "custom-call" in line:
                m = H._CALL_RE.search(line)
                if m:
                    calls.append((m.group(1), 1))
        stats[name] = calls

    mult = defaultdict(float)

    def walk(name, m, seen=()):
        if name in seen or name not in stats:
            return
        mult[name] += m
        for callee, trips in stats[name]:
            walk(callee, m * trips, seen + (name,))

    entry = "ENTRY" if "ENTRY" in comps else next(iter(comps))
    walk(entry, 1.0)

    sites = []
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        for line in lines:
            coll = next((c for c in H.COLLECTIVES if f" {c}(" in line
                         or f" {c}-start(" in line), None)
            if coll:
                ty = line.split("=", 1)[1].split(coll)[0] if "=" in line else line
                nbytes = H._type_bytes(ty)
                sites.append((nbytes * m, coll, ty.strip()[:60], name[:40], m))
    return sorted(sites, reverse=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layout", default="tp")
    ap.add_argument("--fsdp", default="auto")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell  # noqa: E402 (env flag set above)

    # re-lower with text capture
    import repro.launch.dryrun as DR

    captured = {}
    orig_analyze = DR.hlo_analysis.analyze

    def capture(text, *a, **k):
        captured["text"] = text
        return orig_analyze(text, *a, **k)

    DR.hlo_analysis.analyze = capture
    fsdp = {"auto": None, "on": True, "off": False}[args.fsdp]
    rec = lower_cell(args.arch, args.shape, args.multi_pod,
                     microbatches=args.microbatches, fsdp=fsdp,
                     layout=args.layout)
    DR.hlo_analysis.analyze = orig_analyze
    print(f"total collective bytes/device: {rec['hlo']['collective_total']:.3e}")
    print(f"flops/device: {rec['hlo']['flops']:.3e}   "
          f"peak mem: {rec['memory']['peak_estimate_bytes']/2**30:.2f} GiB")
    print(f"\ntop {args.top} collective sites (bytes×trips, op, result, "
          f"computation, mult):")
    for nbytes, op, ty, comp, m in site_breakdown(captured["text"])[: args.top]:
        print(f"  {nbytes:.3e}  {op:18s} {ty:60s} {comp:40s} x{m:.0f}")


if __name__ == "__main__":
    main()

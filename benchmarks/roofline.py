"""Roofline derivation from the dry-run records (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs/device / 197 TFLOP/s
    memory term     = HBM_bytes/device / 819 GB/s
    collective term = collective_bytes/device / 50 GB/s/link

HLO_FLOPs comes from the loop-aware static analyzer (launch.hlo_analysis) —
``compiled.cost_analysis()`` counts while bodies once and is kept only as a
cross-check.  HBM bytes are estimated as
``cost_bytes × (hlo_flops / cost_flops)``: the flops undercount ratio equals
the loop-trip multiplicity of the dominant (layer-scan) loops, and the bytes
live in the same loops.  MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill),
2·N·B (decode: one token per sequence), N = active params for MoE.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--json]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,  # one new token per sequence
    "long_500k": 1,
}


def model_flops(rec) -> float:
    shape = rec["shape"]
    n = rec["num_active_params"]
    tokens = SHAPE_TOKENS[shape]
    if shape == "train_4k":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def _mesh_dims(rec):
    dims = [int(x) for x in rec["mesh"].split("x")]
    tp = dims[-1]
    dp = 1
    for d in dims[:-1]:
        dp *= d
    return dp, tp


def analytic_bytes(rec) -> float:
    """Per-device HBM traffic model (documented in EXPERIMENTS.md §Roofline).

    weights: bf16 reads — train: fwd + remat-fwd + bwd per microbatch (+ the
    gathered copies under FSDP); prefill/decode: one read.
    optimizer: master/m/v read+write + grads + param write ≈ 34 B/param,
    sharded tp×dp (ZeRO).
    activations: c·D_model·L·tokens_per_device·2 bytes with c≈120 (train:
    fwd+bwd+remat reads/writes of block intermediates), c≈40 (prefill).
    KV cache: full read per decoded token; write during prefill.
    """
    from repro.configs.registry import get_config

    cfg = get_config(rec["arch"])
    dp, tp = _mesh_dims(rec)
    chips = rec["n_chips"]
    p = rec["num_params"]
    mb = rec.get("microbatches", 1)
    shape = rec["shape"]
    d, l_eff = cfg.d_model, cfg.n_layers + cfg.n_enc_layers

    if shape == "train_4k":
        tokens_dev = SHAPE_TOKENS[shape] / dp
        w = 3 * (2 * p / tp) * mb * (2 if rec.get("fsdp") else 1)
        opt = 34 * p / (tp * dp)
        act = 120 * d * l_eff * tokens_dev * 2
        return w + opt + act
    if shape == "prefill_32k":
        tokens_dev = SHAPE_TOKENS[shape] / dp
        w = 2 * p / tp
        act = 40 * d * l_eff * tokens_dev * 2
        cache = _cache_bytes(cfg, 32, 32768) / chips
        return w + act + cache
    # decode: read all (active) weights + the full cache once per token
    b = 128 if shape == "decode_32k" else 1
    s = 32768 if shape == "decode_32k" else 524_288
    w = 2 * rec["num_active_params"] / tp
    cache = _cache_bytes(cfg, b, s) / chips
    act = 20 * d * l_eff * b / dp * 2
    return w + cache + act


def _cache_bytes(cfg, batch: int, s: int) -> float:
    """Global KV/state cache bytes for this architecture."""
    if cfg.xlstm is not None:  # recurrent: matrix memories, no KV growth
        d_in = int(cfg.d_model * cfg.xlstm.proj_factor)
        per_layer = batch * (d_in // cfg.n_heads) * d_in * 4
        return cfg.n_layers * per_layer
    kv = 2 * batch * s * cfg.n_kv_heads * cfg.hd * 2
    if cfg.sliding_window:
        kv = 2 * batch * min(s, cfg.sliding_window) * cfg.n_kv_heads * cfg.hd * 2
    if cfg.attn_every:  # zamba: shared attn blocks + mamba states
        n_macro = max(1, round(cfg.n_layers / (cfg.attn_every + 1)))
        d_in = cfg.ssm.expand * cfg.d_model
        states = cfg.n_layers * batch * (d_in // cfg.ssm.head_dim) * \
            cfg.ssm.d_state * cfg.ssm.head_dim * 4
        return n_macro * kv + states
    if cfg.enc_dec:
        return cfg.n_layers * kv * 2  # self (bounded) + cross approximated
    return cfg.n_layers * kv


def derive(rec) -> dict:
    chips = rec["n_chips"]
    flops_dev = rec["hlo"]["flops"]
    cost_flops = max(rec["cost"]["flops"], 1.0)
    cost_bytes = rec["cost"]["bytes_accessed"]
    loop_ratio = max(1.0, flops_dev / cost_flops)
    bytes_dev = analytic_bytes(rec)
    bytes_dev_alt = cost_bytes * loop_ratio  # cost-scaled cross-check
    coll_dev = rec["hlo"]["collective_total"]

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(rec)
    useful_ratio = mf / max(flops_dev * chips, 1.0)
    # achievable fraction of compute roofline at the modeled step time
    mfu = (mf / chips / max(step_time, 1e-12)) / PEAK_FLOPS
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "flops_dev": flops_dev,
        "bytes_dev": bytes_dev,
        "bytes_dev_cost_scaled": bytes_dev_alt,
        "coll_dev": coll_dev,
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": mfu,
        "peak_mem_gib": rec["memory"]["peak_estimate_bytes"] / 2**30,
        "fits_hbm": rec.get("fits_hbm", True),
        "microbatches": rec.get("microbatches", 1),
        "fsdp": rec.get("fsdp", False),
        "suggestion": suggest(dominant, rec),
    }


def suggest(dominant: str, rec) -> str:
    kind = rec["shape"]
    if dominant == "collective":
        if kind == "train_4k":
            return ("shrink per-layer resharding: drop sequence-parallel "
                    "all-gathers or widen DP vs TP for this model size")
        return ("shard KV/state on a dimension that avoids per-layer score "
                "all-reduce (flash-decode style seq sharding)")
    if dominant == "memory":
        if "decode" in kind or kind == "long_500k":
            return ("decode is weight/cache-bandwidth bound by nature; raise "
                    "batch per chip or quantize KV cache to int8")
        return "increase arithmetic intensity: larger microbatches or fusion"
    return ("compute-bound: skip fully-masked causal KV blocks in chunked "
            "attention and cut remat recompute on cheap ops")


def load_records(mesh_filter=None, tag="", directory=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(directory or DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split("__")
        if len(parts) != 3:
            continue
        mesh_part = parts[2]
        file_tag = ""
        for mesh_base in ("2x16x16", "16x16"):
            if mesh_part.startswith(mesh_base):
                file_tag = mesh_part[len(mesh_base):].lstrip("_")
                break
        if file_tag != tag:
            continue
        r = json.load(open(path))
        if "arch" not in r:  # lp_dynlp records have their own format
            continue
        if r.get("status") != "ok":
            recs.append(r)
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        recs.append(r)
    return recs


def fmt_s(x):
    return f"{x*1e3:8.2f}ms" if x < 10 else f"{x:8.2f}s "


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--dir", default=None,
                    help="records dir (e.g. experiments/dryrun_baseline)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    rows, skipped, failed = [], [], []
    for rec in load_records(args.mesh, args.tag, directory=args.dir):
        if rec.get("status") == "skipped":
            if not rec.get("multi_pod"):
                skipped.append(rec)
            continue
        if rec.get("status") == "failed":
            failed.append(rec)
            continue
        rows.append(derive(rec))

    if args.json:
        print(json.dumps(rows, indent=1))
        return

    hdr = (f"{'arch':22s} {'shape':12s} {'comp':>10s} {'mem':>10s} "
           f"{'coll':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'mem GiB':>8s} {'mb':>3s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch']:22s} {r['shape']:12s} {fmt_s(r['t_compute'])} "
              f"{fmt_s(r['t_memory'])} {fmt_s(r['t_collective'])} "
              f"{r['dominant']:>10s} {r['useful_flops_ratio']*100:6.1f}% "
              f"{r['roofline_fraction']*100:6.2f}% "
              f"{r['peak_mem_gib']:8.2f} {r['microbatches']:3d}")
    for rec in skipped:
        print(f"{rec['arch']:22s} {rec['shape']:12s}  SKIPPED: {rec['reason'][:70]}")
    for rec in failed:
        print(f"{rec['arch']:22s} {rec['shape']:12s}  FAILED: {rec['error'][:70]}")


if __name__ == "__main__":
    main()

"""Shim: the analyzer lives in repro.launch.hlo_analysis (src tree)."""
from repro.launch.hlo_analysis import *  # noqa: F401,F403

"""Checkpoint benchmark: durability overhead + restore-and-replay gates.

Two arms replay ONE pre-generated mutation stream through ``LPService``
(mutate → flush → sync per batch, so every commit is a quiescent
checkpoint boundary):

  * ``plain``      — no durability: the baseline steady-state
                     "embeddings in → labels committed" throughput.
  * ``checkpoint`` — ``checkpoint_every=1``: the service snapshots the
                     FULL engine state (``core.persistence``) through
                     ``CheckpointManager.save_async`` at every commit —
                     the worst-case cadence, so the measured ratio
                     bounds every real deployment from below.

Arms run interleaved best-of-``ROUNDS`` (stream_throughput precedent:
scheduler drift hits both alike).  After the checkpointed arm, the
retained rolling checkpoints double as sampled KILL POINTS: from EVERY
retained step the benchmark restores a fresh engine, replays the rest
of the stream, and compares the final graph byte-for-byte against the
plain arm's — the crash-recovery contract measured end to end.  The
newest checkpoint also times ``StreamEngine.restore`` through its first
replayed commit (the restart-latency headline).

``--check`` gates the recorded floors:

  * checkpointed throughput ≥ ``CHECKPOINT_OVERHEAD_FLOOR`` x the plain
    arm (per-commit async snapshots cost at most 20%);
  * restore + replay from EVERY retained checkpoint step reproduces the
    uninterrupted final state bit-identically (labels, fractional
    labels, adjacency);
  * the checkpointed arm's own final graph is byte-identical to the
    plain arm's (durability must never perturb the solve);
  * at least ``cfg["keep"]`` kill points were actually sampled.

Single-device by design (the 8-virtual-device crash/restore and elastic
mesh arms are proven by tests/test_checkpoint_restore.py); this
benchmark measures durability cost without mesh staging noise.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.checkpoint import manager as ckpt_mgr
from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.serving.lp_service import LPService

OUT = "BENCH_checkpoint.json"
DELTA = 1e-5  # realistic solve depth: the ratio measures durability
# overhead against commits that carry real propagation work
K = 5

# seed phase: mixed mostly-labeled stream growing the graph through
# bucket rungs (rung compiles paid up front); measured phase: all-labeled
# steady-state insert batches, one commit (and one snapshot) per batch
FULL = dict(dim=64, seed_rows=4000, seed_batch=200,
            meas_batches=30, meas_batch=128, keep=4)
TINY = dict(dim=32, seed_rows=1200, seed_batch=120,
            meas_batches=10, meas_batch=128, keep=4)
SEED_LABELED_FRAC = 0.9
SEED_DELETE_FRAC = 0.05
WARM_STEPS = 2
ROUNDS = 3

# Recorded floor: per-commit async checkpointing keeps >= 80% of the
# plain arm's steady-state throughput.  The snapshot is a host copy of
# the graph arrays plus a worker-thread .npy write; the solve itself
# dominates, and any cheaper cadence only does better.
CHECKPOINT_OVERHEAD_FLOOR = 0.8


def _make_stream(cfg: dict, seed: int = 0):
    """One deterministic stream, replayed verbatim by both arms and by
    every restore (deletes pick from rows alive at generation time, so
    the same ids are valid in every replay)."""
    rng = np.random.default_rng(seed)
    dim = cfg["dim"]

    def insert_batch(m: int, labeled_frac: float) -> BatchUpdate:
        emb = rng.normal(0, 1, (m, dim)).astype(np.float32)
        lab = np.where(rng.random(m) < labeled_frac,
                       rng.integers(0, 2, m), UNLABELED).astype(np.int8)
        return BatchUpdate(emb, lab, np.zeros(0, np.int64))

    next_id = 0
    alive: list[int] = []
    seed_batches = []
    n_del = int(cfg["seed_batch"] * SEED_DELETE_FRAC)
    for _ in range(cfg["seed_rows"] // cfg["seed_batch"]):
        b = insert_batch(cfg["seed_batch"], SEED_LABELED_FRAC)
        dels = np.zeros(0, np.int64)
        if len(alive) > 4 * n_del > 0:
            dels = rng.choice(np.asarray(alive, np.int64), n_del,
                              replace=False)
            gone = set(dels.tolist())
            alive = [i for i in alive if i not in gone]
        seed_batches.append(BatchUpdate(b.ins_emb, b.ins_labels,
                                        np.sort(dels)))
        alive += range(next_id, next_id + cfg["seed_batch"])
        next_id += cfg["seed_batch"]
    warm = [insert_batch(cfg["meas_batch"], 1.0) for _ in range(WARM_STEPS)]
    meas = [insert_batch(cfg["meas_batch"], 1.0)
            for _ in range(cfg["meas_batches"])]
    return seed_batches, warm, meas


def _fingerprint(g: DynamicGraph) -> dict[str, bytes]:
    """Byte images of everything restore-and-replay promises to keep
    identical to the uninterrupted run."""
    return {name: np.ascontiguousarray(arr).tobytes()
            for name, arr in (("f", g.f), ("labels", g.labels),
                              ("alive", g.alive), ("knn_idx", g.knn_idx),
                              ("knn_wgt", g.knn_wgt))}


def _feed(svc: LPService, batch: BatchUpdate):
    svc.mutate(ins_emb=batch.ins_emb, ins_labels=batch.ins_labels,
               del_ids=batch.del_ids)
    svc.flush()
    svc.sync()


def _run_arm(ckpt_dir: str | None, cfg: dict, stream) -> dict:
    seed_batches, warm, meas = stream
    g = DynamicGraph(emb_dim=cfg["dim"], k=K)
    eng = StreamEngine(g, delta=DELTA)
    kw = {}
    if ckpt_dir is not None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
        kw = dict(checkpoint_every=1, checkpoint_dir=ckpt_dir,
                  checkpoint_keep=cfg["keep"])
    svc = LPService(eng, window_ops=10_000, window_ms=1e9,
                    max_pending_ops=100_000, **kw)
    for b in seed_batches:
        _feed(svc, b)
    for b in warm:
        _feed(svc, b)
    rows = sum(len(b.ins_emb) for b in meas)
    t0 = time.perf_counter()
    for b in meas:
        _feed(svc, b)
    dt = time.perf_counter() - t0
    if svc._ckpt_mgr is not None:
        svc._ckpt_mgr.wait()  # settle the last async write (off the clock)
    return {
        "ops_per_sec": round(rows / dt, 1),
        "measured_rows": rows,
        "measured_s": round(dt, 4),
        "total_rows": g.num_nodes,
        "commits": eng.commits,
        "checkpoints_written": svc.checkpoints_written,
        "fingerprint": _fingerprint(g),
    }


def _retained_steps(directory: str) -> list[int]:
    return sorted(
        s for n in os.listdir(directory)
        if (s := ckpt_mgr._step_of(n)) is not None
        and os.path.exists(os.path.join(directory, n, ".complete")))


def _restore_and_replay(ckpt_dir: str, step: int, all_batches,
                        oracle_fp) -> bool:
    """Restore from ``step``, replay the remaining stream, compare."""
    r = StreamEngine.restore(ckpt_dir, step=step)
    for b in all_batches[r.batches:]:
        r.step(b)
    fp = _fingerprint(r.graph)
    return all(fp[k] == oracle_fp[k] for k in oracle_fp)


def main(out: str = OUT, tiny: bool = False, check: bool = False) -> dict:
    cfg = TINY if tiny else FULL
    stream = _make_stream(cfg)
    seed_batches, warm, meas = stream
    all_batches = seed_batches + warm + meas
    ckpt_dir = os.path.join(tempfile.mkdtemp(prefix="bench_ckpt_"), "ck")

    arms = ("plain", "checkpoint")
    best: dict[str, dict] = {}
    history: dict[str, list] = {a: [] for a in arms}
    for _ in range(ROUNDS):  # interleaved best-of: drift hits both arms
        for arm in arms:
            r = _run_arm(ckpt_dir if arm == "checkpoint" else None,
                         cfg, stream)
            history[arm].append(r["ops_per_sec"])
            if arm not in best or r["ops_per_sec"] > best[arm]["ops_per_sec"]:
                best[arm] = r

    fp_plain = best["plain"].pop("fingerprint")
    fp_ckpt = best["checkpoint"].pop("fingerprint")
    arms_identical = all(fp_plain[k] == fp_ckpt[k] for k in fp_plain)

    # every retained rolling checkpoint is a sampled kill point: restore
    # and replay must reproduce the uninterrupted final state exactly
    steps = _retained_steps(ckpt_dir)
    replay_ok = {s: _restore_and_replay(ckpt_dir, s, all_batches, fp_plain)
                 for s in steps}

    # restart latency: newest checkpoint -> engine answering after its
    # first replayed commit (fresh restore, after the replay gates)
    newest = steps[-1] if steps else None
    restore_ms = first_commit_ms = None
    if newest is not None:
        t0 = time.perf_counter()
        r = StreamEngine.restore(ckpt_dir, step=newest)
        restore_ms = (time.perf_counter() - t0) * 1e3
        nxt = (all_batches[r.batches] if r.batches < len(all_batches)
               else meas[-1])  # fully-caught-up: time a fresh steady batch
        r.step(nxt)
        first_commit_ms = (time.perf_counter() - t0) * 1e3

    # PAIRED per-round ratios: each round's checkpoint arm divides by the
    # plain arm it was interleaved with, so machine-wide drift cancels
    # within the pair instead of letting one lucky plain round sink the
    # ratio; the best round carries the floor (both arms fully warm).
    round_ratios = [round(c / max(p, 1e-9), 3)
                    for p, c in zip(history["plain"],
                                    history["checkpoint"])]
    ratio = max(round_ratios)
    results = {
        "config": {k: v for k, v in cfg.items()},
        "rounds": ROUNDS,
        "ops_per_sec_per_round": history,
        "floors": {"checkpoint_overhead_ratio": CHECKPOINT_OVERHEAD_FLOOR},
        "checkpoint_overhead_ratio": ratio,
        "overhead_ratio_per_round": round_ratios,
        "arms_identical": arms_identical,
        "restore_points": steps,
        "restore_replay_identical": replay_ok,
        "restore_ms": None if restore_ms is None else round(restore_ms, 2),
        "restore_to_first_commit_ms": (
            None if first_commit_ms is None else round(first_commit_ms, 2)),
    }
    results.update(best)
    for arm in arms:
        r = best[arm]
        print(f"{arm}: {r['ops_per_sec']:.0f} ops/s steady "
              f"({r['measured_rows']} rows / {r['measured_s']:.2f} s) | "
              f"{r['commits']} commits | "
              f"{r['checkpoints_written']} snapshots")
    print(f"overhead ratio {ratio} (floor {CHECKPOINT_OVERHEAD_FLOOR}) | "
          f"restore {results['restore_ms']} ms, first commit "
          f"{results['restore_to_first_commit_ms']} ms | "
          f"{len(steps)} kill points replayed, "
          f"{sum(replay_ok.values())} bit-identical")
    if check:
        _gate("checkpoint/overhead",
              ratio >= CHECKPOINT_OVERHEAD_FLOOR,
              f"checkpointed arm at {ratio}x of plain < floor "
              f"{CHECKPOINT_OVERHEAD_FLOOR}")
        _gate("checkpoint/arms_identical", arms_identical,
              "checkpointed arm's final graph diverged from the plain arm")
        _gate("restore/kill_points", len(steps) >= cfg["keep"],
              f"only {len(steps)} retained checkpoints; expected "
              f">= {cfg['keep']} kill points to sample")
        for s, ok in replay_ok.items():
            _gate(f"restore/step_{s}", ok,
                  f"restore+replay from commit {s} diverged from the "
                  "uninterrupted run")
    shutil.rmtree(os.path.dirname(ckpt_dir), ignore_errors=True)
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 1200-row seed stream")
    ap.add_argument("--check", action="store_true",
                    help="assert the overhead floor + restore-and-replay "
                         "bit-identity from every retained checkpoint")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(out=args.out, tiny=args.tiny, check=args.check)

"""Ingestion benchmark: host-staged vs device-resident vs mesh-sharded
kNN candidate search feeding the same streaming LP engine.

Three arms replay ONE pre-generated embedding stream (so their graphs
are comparable bit-for-bit) through ``StreamEngine``:

  * ``host``    — ``ingest="host"``: the staging path this PR's device
                  pipeline replaces.  Candidate search runs
                  ``graph.knn.build_knn_graph`` on the host per batch.
  * ``device``  — ``ingest="device"``: embeddings land in the
                  device-resident ``EmbeddingStore`` and one fused
                  ``kernels.argkmin`` pass per batch returns the new
                  rows' candidate supersets plus the displaced-row set.
  * ``sharded`` — ``ingest="device"`` with the STORE sharded over a
                  forced 8-virtual-device mesh (own subprocess,
                  ``--xla_force_host_platform_device_count=8``): the
                  ``ShardedEmbeddingStore`` row-shards the ladder and
                  the argkmin orientation flips to move-the-batch
                  (``core.distributed.StoreShardPlan``); the LP solve
                  stays single-device so the arm isolates the store
                  flip rather than re-timing the mesh solve
                  (``stream_throughput.py``'s job).  Virtual devices
                  share the same cores, so the gate is a no-regression
                  bound, not a speedup claim — the headline here is
                  per-device memory: each device holds exactly 1/D of
                  the store.

Each arm seeds a mixed insert/delete/mostly-labeled stream (growing the
graph through several bucket rungs, so rung-crossing compiles are paid
up front), then times a steady-state all-labeled insert phase —
"embeddings in → labels committed" throughput, the number the ROADMAP
ingestion item is about.  Arms run interleaved best-of-``ROUNDS``
(the stream_throughput precedent: scheduler drift hits both alike).

``--check`` gates the recorded floors:

  * device throughput ≥ ``DEVICE_OVER_REFERENCE_FLOOR`` x the recorded
    ``HOST_STAGING_OPS_PER_SEC`` reference (the acceptance headline);
  * the live host arm still clears the recorded reference (provenance
    stays conservative);
  * kernel-vs-oracle agreement == 1.0 — the device arm's final graph
    (labels, adjacency, edges) is BIT-IDENTICAL to the host oracle's,
    the ``graph.knn`` module-docstring contract measured end to end;
  * sharded-arm floors: its graph byte-identical to both single-device
    arms, per-device store bytes ≤ 1/D of the unsharded store (+ one
    ladder rung of slack), steady ops/s ≥
    ``SHARDED_OVER_DEVICE_FLOOR`` x the device arm, and the sharded
    ingest jit cache ≤ ``ingest_ladder_bound(..., sharded=True)``;
  * compile-once: engine recompiles ≤ the snapshot ladder bound, and
    the ingest path's jit entries ≤ ``ingest_ladder_bound`` — stream
    length never shows up in either cache.

The ``locality`` side-arm (not an identity arm: reordering arrivals
changes id assignment by design) replays the device arm once with
``ingest_order="locality"`` — ``data.synth.cosine_locality_order`` over
each admitted batch — and records the top-rung halo export fraction
next to the arrival-order arm's, the delta being the recorded measure
of how much locality-ordered admission shrinks cross-shard halos.

The single-device arms stay mesh-less by design (the sharded arm forces
its own 8-virtual-device subprocess): on a CPU-only host all arms share
the same silicon, so the live host arm (sped up by the same graph-merge
work) is the agreement oracle while the *recorded* 200 ops/s reference
carries the cross-PR throughput claim.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

try:
    from benchmarks.common import check_gate as _gate, finish_checks
except ImportError:  # run as a script: sys.path[0] is benchmarks/ itself
    from common import check_gate as _gate, finish_checks

from repro.core.snapshot import ladder_size
from repro.core.stream import StreamEngine
from repro.graph.dynamic import UNLABELED, BatchUpdate, DynamicGraph
from repro.graph.partition import build_halo_plan
from repro.ingest.incremental_knn import (DeviceIngestor, ingest_cache_size,
                                          ingest_ladder_bound)

OUT = "BENCH_ingest.json"
DELTA = 1e-3  # match stream_throughput: measure machinery, not solve depth
K = 5

# seed phase: mixed stream (mostly-labeled inserts + deletes) growing the
# store through several capacity rungs; measured phase: all-labeled
# insert batches (steady state — no supernode re-init churn, every batch
# still solves the affected frontier)
FULL = dict(dim=256, seed_rows=8000, seed_batch=200,
            meas_batches=30, meas_batch=128)
TINY = dict(dim=128, seed_rows=2000, seed_batch=200,
            meas_batches=10, meas_batch=128)
SEED_LABELED_FRAC = 0.9
SEED_DELETE_FRAC = 0.05  # of each seed batch, from prior alive rows
WARM_STEPS = 2  # measured-shape batches stepped before the clock starts
ROUNDS = 2

# Recorded floors for --check.  The reference is the ROADMAP ingestion
# item's number for the path the device pipeline replaces: "host kNN
# staging caps mutation throughput at ~200 ops/s" (ROADMAP.md §Open
# items, measured on the pre-incremental host selector).  The device
# floor is the PR's acceptance headline — 5x that reference, end to end
# through commit.  The live host arm is gated against the reference
# too: it shares this PR's graph-merge speedups, so it clearing 200
# ops/s keeps the recorded provenance conservative rather than stale.
HOST_STAGING_OPS_PER_SEC = 200.0
DEVICE_OVER_REFERENCE_FLOOR = 5.0

# Sharded-arm floors.  Virtual devices time-share the host cores and the
# sweep adds two all-gathers per batch, so the throughput gate is a
# no-regression bound (real speedup is a TPU claim).  The bytes slack
# covers one capacity rung of ladder skew between arms.
SHARDED_OVER_DEVICE_FLOOR = 0.8
SHARD_BYTES_SLACK = 2.0
SHARD_DEVICES = 8  # forced-virtual-CPU mesh size (and halo shard count
                   # for the export-fraction measurement)


def _make_stream(cfg: dict, seed: int = 0):
    """One deterministic stream, replayed verbatim by both arms.

    Returns (seed_batches, warm_batches, measured_batches); deletes pick
    from rows alive at generation time, so the same ids are valid in
    every replay.
    """
    rng = np.random.default_rng(seed)
    dim = cfg["dim"]

    def insert_batch(m: int, labeled_frac: float) -> BatchUpdate:
        emb = rng.normal(0, 1, (m, dim)).astype(np.float32)
        lab = np.where(rng.random(m) < labeled_frac,
                       rng.integers(0, 2, m), UNLABELED).astype(np.int8)
        return BatchUpdate(emb, lab, np.zeros(0, np.int64))

    next_id = 0
    alive: list[int] = []
    seed_batches = []
    n_del = int(cfg["seed_batch"] * SEED_DELETE_FRAC)
    for _ in range(cfg["seed_rows"] // cfg["seed_batch"]):
        b = insert_batch(cfg["seed_batch"], SEED_LABELED_FRAC)
        dels = np.zeros(0, np.int64)
        if len(alive) > 4 * n_del > 0:
            dels = rng.choice(np.asarray(alive, np.int64), n_del,
                              replace=False)
            gone = set(dels.tolist())
            alive = [i for i in alive if i not in gone]
        seed_batches.append(BatchUpdate(b.ins_emb, b.ins_labels,
                                        np.sort(dels)))
        alive += range(next_id, next_id + cfg["seed_batch"])
        next_id += cfg["seed_batch"]
    warm = [insert_batch(cfg["meas_batch"], 1.0) for _ in range(WARM_STEPS)]
    meas = [insert_batch(cfg["meas_batch"], 1.0)
            for _ in range(cfg["meas_batches"])]
    return seed_batches, warm, meas


def _fingerprint(g: DynamicGraph) -> dict[str, str]:
    """sha256 images of everything the selector contract promises to keep
    identical: committed labels, per-row adjacency, and the edge list —
    hex digests so the sharded subprocess can ship its own over JSON."""
    return {name: hashlib.sha256(np.ascontiguousarray(arr).tobytes())
            .hexdigest()
            for name, arr in (("f", g.f), ("labels", g.labels),
                              ("knn_idx", g.knn_idx), ("knn_wgt", g.knn_wgt),
                              ("src", g.src), ("dst", g.dst),
                              ("wgt", g.wgt))}


def _export_fraction(g: DynamicGraph) -> float:
    """Fraction of alive rows a SHARD_DEVICES-way halo layout of the
    final (top-rung) adjacency would export — the transport-facing
    number locality-ordered admission is supposed to shrink."""
    plan = build_halo_plan(np.asarray(g.knn_idx, np.int32), SHARD_DEVICES)
    return round(float(plan.export_counts.sum())
                 / max(1, int(g.alive.sum())), 4)


def _run_arm(ingest: str, cfg: dict, stream, store_mesh=None,
             ingest_order: str = "arrival") -> dict:
    seed_batches, warm, meas = stream
    g = DynamicGraph(emb_dim=cfg["dim"], k=K)
    eng = StreamEngine(g, delta=DELTA, ingest=ingest,
                       ingest_order=ingest_order)
    if store_mesh is not None:
        # shard ONLY the store: the solve stays single-device so the arm
        # isolates the tentpole (move-the-batch sweep vs resident-batch
        # argkmin) instead of also timing the mesh solve's collectives —
        # 8 virtual devices time-share the same cores, and the solve-on-
        # mesh cost is stream_throughput.py's measurement, not this one
        eng.ingestor = DeviceIngestor(cfg["dim"], mesh=store_mesh)
    for b in seed_batches:
        eng.step(b)
    for b in warm:
        eng.step(b)
    rows = sum(len(b.ins_emb) for b in meas)
    t0 = time.perf_counter()
    for b in meas:
        eng.step(b)
    dt = time.perf_counter() - t0
    max_k = max(k for _, k in eng.bucket_keys)
    out = {
        "ops_per_sec": round(rows / dt, 1),
        "measured_rows": rows,
        "measured_s": round(dt, 4),
        "total_rows": g.num_nodes,
        "alive_rows": int(g.alive.sum()),
        "recompiles": eng.recompile_count,
        "ladder_bound": ladder_size(g.num_nodes + 256, max_k),
        "fingerprint": _fingerprint(g),
        "export_fraction": _export_fraction(g),
    }
    if ingest == "device":
        # per-device residency: max over devices (== total bytes on a
        # single device, total/D on the sharded mesh)
        out["store_device_bytes"] = eng.ingestor.store.device_bytes()
        out["store_shards"] = eng.ingestor.store.n_shards
    return out


# The sharded arm needs its own process: the virtual-device count is a
# one-shot XLA flag read before jax initializes (same pattern as the
# tests/test_ingest.py 8-dev arm).  Pure JSON on stdout.
_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count={ndev}"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    sys.path.insert(0, {src!r})
    sys.path.insert(0, {bench!r})
    import ingest_lp
    from repro.ingest.incremental_knn import (ingest_cache_size,
                                              ingest_ladder_bound)
    from repro.launch.mesh import make_stream_mesh

    mesh = make_stream_mesh()
    assert mesh.devices.size == {ndev}, mesh
    cfg = ingest_lp.TINY if {tiny} else ingest_lp.FULL
    stream = ingest_lp._make_stream(cfg)
    best = None
    for _ in range(ingest_lp.ROUNDS):
        r = ingest_lp._run_arm("device", cfg, stream, store_mesh=mesh)
        if best is None or r["ops_per_sec"] > best["ops_per_sec"]:
            best = r
    best["n_devices"] = {ndev}
    best["ingest_cache_entries"] = ingest_cache_size()
    best["ingest_cache_bound"] = ingest_ladder_bound(
        best["total_rows"], max(cfg["seed_batch"], cfg["meas_batch"]),
        sharded=True)
    json.dump(best, sys.stdout)
""")


def _run_sharded_arm(tiny: bool) -> dict:
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_STREAM_TRANSPORT", None)  # rung transports stay auto
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT.format(
            ndev=SHARD_DEVICES, tiny=tiny,
            src=os.path.abspath(os.path.join(os.path.dirname(__file__),
                                             os.pardir, "src")),
            bench=os.path.abspath(os.path.dirname(__file__)))],
        capture_output=True, text=True, env=env, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded arm subprocess failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout)


def main(out: str = OUT, tiny: bool = False, check: bool = False) -> dict:
    cfg = TINY if tiny else FULL
    stream = _make_stream(cfg)
    max_batch = max(cfg["seed_batch"], cfg["meas_batch"])
    arms = ("host", "device")
    best: dict[str, dict] = {}
    history: dict[str, list] = {a: [] for a in arms}
    for _ in range(ROUNDS):  # interleaved best-of: drift hits both arms
        for arm in arms:
            r = _run_arm(arm, cfg, stream)
            history[arm].append(r["ops_per_sec"])
            if arm not in best or r["ops_per_sec"] > best[arm]["ops_per_sec"]:
                best[arm] = r
    # the sharded arm replays the same stream on its forced 8-virtual-
    # device mesh; the locality side-arm replays the device arm with
    # reordered admission to record the halo export-fraction delta
    best["sharded"] = _run_sharded_arm(tiny)
    locality = _run_arm("device", cfg, stream, ingest_order="locality")
    locality.pop("fingerprint")  # reordered ids: not an identity arm
    arms = arms + ("sharded",)

    # kernel-vs-oracle agreement, end to end: the device AND sharded
    # arms' committed graphs must be byte-identical to the host
    # oracle's.  Deterministic per arm, so comparing the best rounds
    # compares every round.
    fp_h = best["host"].pop("fingerprint")
    fp_d = best["device"].pop("fingerprint")
    fp_s = best["sharded"].pop("fingerprint")
    mismatch = [k for k in fp_h if fp_h[k] != fp_d[k]]
    mismatch_sharded = [k for k in fp_h if fp_h[k] != fp_s[k]]
    agreement = 0.0 if mismatch else 1.0
    agreement_sharded = 0.0 if mismatch_sharded else 1.0

    cache = ingest_cache_size()
    cache_bound = ingest_ladder_bound(best["device"]["total_rows"], max_batch)
    best["device"]["ingest_cache_entries"] = cache
    best["device"]["ingest_cache_bound"] = cache_bound
    per_dev = best["sharded"]["store_device_bytes"]
    n_dev = best["sharded"]["n_devices"]
    single_bytes = best["device"]["store_device_bytes"]
    bytes_bound = int(single_bytes / n_dev * SHARD_BYTES_SLACK)

    results = {
        "config": {k: v for k, v in cfg.items()},
        "rounds": ROUNDS,
        "ops_per_sec_per_round": history,
        "floors": {
            "host_staging_ops_per_sec": HOST_STAGING_OPS_PER_SEC,
            "device_over_reference": DEVICE_OVER_REFERENCE_FLOOR,
            "sharded_over_device": SHARDED_OVER_DEVICE_FLOOR,
            "shard_bytes_slack": SHARD_BYTES_SLACK,
        },
        "device_over_reference": round(
            best["device"]["ops_per_sec"] / HOST_STAGING_OPS_PER_SEC, 2),
        "device_over_host_live": round(
            best["device"]["ops_per_sec"]
            / max(best["host"]["ops_per_sec"], 1e-9), 3),
        "sharded_over_device": round(
            best["sharded"]["ops_per_sec"]
            / max(best["device"]["ops_per_sec"], 1e-9), 3),
        "sharded_bytes_per_device_bound": bytes_bound,
        "agreement": agreement,
        "agreement_sharded": agreement_sharded,
        "locality": {
            "ops_per_sec": locality["ops_per_sec"],
            "export_fraction": locality["export_fraction"],
            "export_fraction_arrival": best["device"]["export_fraction"],
            "export_fraction_delta": round(
                best["device"]["export_fraction"]
                - locality["export_fraction"], 4),
        },
    }
    results.update(best)
    for arm in arms:
        r = best[arm]
        print(f"{arm}: {r['ops_per_sec']:.0f} ops/s steady "
              f"({r['measured_rows']} rows / {r['measured_s']:.2f} s) | "
              f"{r['total_rows']} rows total | {r['recompiles']} recompiles "
              f"≤ ladder {r['ladder_bound']}")
    print(f"device/reference {results['device_over_reference']}x "
          f"(recorded host staging {HOST_STAGING_OPS_PER_SEC} ops/s) | "
          f"device/host-live {results['device_over_host_live']}x | "
          f"agreement {agreement} | ingest cache {cache} ≤ {cache_bound}")
    print(f"sharded/device {results['sharded_over_device']}x | "
          f"agreement {agreement_sharded} | per-device bytes {per_dev} "
          f"≤ {bytes_bound} ({n_dev} devices, single {single_bytes}) | "
          f"sharded cache {best['sharded']['ingest_cache_entries']} ≤ "
          f"{best['sharded']['ingest_cache_bound']}")
    print(f"locality admission: export fraction "
          f"{results['locality']['export_fraction']} vs arrival "
          f"{results['locality']['export_fraction_arrival']} "
          f"(delta {results['locality']['export_fraction_delta']})")
    if check:
        floor = DEVICE_OVER_REFERENCE_FLOOR * HOST_STAGING_OPS_PER_SEC
        _gate("device/throughput",
              best["device"]["ops_per_sec"] >= floor,
              f"{best['device']['ops_per_sec']} ops/s < "
              f"{DEVICE_OVER_REFERENCE_FLOOR}x recorded host staging "
              f"({floor} ops/s)")
        _gate("host/reference",
              best["host"]["ops_per_sec"] >= HOST_STAGING_OPS_PER_SEC,
              f"live host arm {best['host']['ops_per_sec']} ops/s < the "
              f"recorded {HOST_STAGING_OPS_PER_SEC} ops/s reference it "
              "is supposed to dominate")
        _gate("agreement", agreement == 1.0,
              f"device arm diverged from the host oracle in: {mismatch}")
        _gate("sharded/agreement", agreement_sharded == 1.0,
              f"sharded arm diverged from the host oracle in: "
              f"{mismatch_sharded}")
        _gate("sharded/throughput",
              best["sharded"]["ops_per_sec"]
              >= SHARDED_OVER_DEVICE_FLOOR * best["device"]["ops_per_sec"],
              f"{best['sharded']['ops_per_sec']} ops/s < "
              f"{SHARDED_OVER_DEVICE_FLOOR}x the device arm "
              f"({best['device']['ops_per_sec']} ops/s)")
        _gate("sharded/device_bytes", per_dev <= bytes_bound,
              f"per-device store bytes {per_dev} > 1/{n_dev} of the "
              f"unsharded store ({single_bytes}) x {SHARD_BYTES_SLACK} "
              "ladder slack")
        _gate("sharded/ingest_cache",
              best["sharded"]["ingest_cache_entries"]
              <= best["sharded"]["ingest_cache_bound"],
              f"{best['sharded']['ingest_cache_entries']} sharded ingest "
              f"jit entries > ladder bound "
              f"{best['sharded']['ingest_cache_bound']}")
        for arm in arms:
            _gate(f"{arm}/recompiles",
                  best[arm]["recompiles"] <= best[arm]["ladder_bound"],
                  f"{best[arm]['recompiles']} recompiles > ladder bound "
                  f"{best[arm]['ladder_bound']}")
        _gate("device/ingest_cache", cache <= cache_bound,
              f"{cache} ingest jit entries > ladder bound {cache_bound}")
    with open(out, "w") as fh:
        json.dump(results, fh, indent=2)
    print(f"wrote {os.path.abspath(out)}")
    if check:
        finish_checks()
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: 2000-row seed stream")
    ap.add_argument("--check", action="store_true",
                    help="assert recorded floors + bit-identical arms "
                         "+ compile-once bounds")
    ap.add_argument("--out", default=OUT, help="output JSON path")
    args = ap.parse_args()
    main(out=args.out, tiny=args.tiny, check=args.check)
